"""Quickstart: Tessera's full pipeline on a real model in ~60 lines.

  PYTHONPATH=src python examples/quickstart.py

1. trace a model's decode step into a kernel graph (exact RAW deps),
2. inspect kernel heterogeneity across a heterogeneous device pair,
3. plan placement (throughput + latency policies),
4. execute disaggregated and verify against single-device execution.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.core import analyzer, planner
from repro.core.costmodel import GPU_A100, GPU_L40S
from repro.core.executor import build_executable
from repro.models import model as M

cfg = dataclasses.replace(configs.get_smoke("llama3_8b"), dtype="float32")
params = M.init_params(cfg)
cache = M.init_cache(cfg, batch=2, max_len=32)
toks = jnp.array([[5], [9]], jnp.int32)
pos = jnp.array([3, 7], jnp.int32)

def step(p, c, t, q):
    return M.decode_step(p, cfg, t, c, q, scan_layers=False)

# 1. analyze ---------------------------------------------------------- #
traced = analyzer.analyze(step, params, cache, toks, pos,
                          state_argnums=(1,))
print("kernel graph:", traced.graph.stats())

# 2. heterogeneity ---------------------------------------------------- #
devs = [GPU_A100, GPU_L40S]
faster_on_b = sum(devs[1].kernel_time(n) < devs[0].kernel_time(n)
                  for n in traced.graph.nodes)
print(f"{faster_on_b}/{len(traced.graph)} kernels faster on "
      f"{devs[1].name} (paper Fig. 2)")

# 3. plan (pin KV-touching kernels to the cache's home device) -------- #
g = analyzer.pin_nodes(traced.graph,
                       traced.state_readers | traced.state_writers, 0)
for policy in ("throughput", "latency"):
    plan = planner.plan(g, devs, policy=policy, cache=False)
    print(plan.summary())

# 4. execute disaggregated and verify --------------------------------- #
plan = planner.plan(g, devs, policy="throughput", cache=False)
exe = build_executable(traced.with_graph(g), plan)
logits, new_cache = exe(params, cache, toks, pos)
ref_logits, _ = jax.jit(step)(params, cache, toks, pos)
np.testing.assert_allclose(np.asarray(logits), np.asarray(ref_logits),
                           rtol=1e-5, atol=1e-5)
print("disaggregated == single-device: OK")
