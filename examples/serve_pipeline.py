"""End-to-end serving driver (the paper's kind of workload).

  PYTHONPATH=src python examples/serve_pipeline.py

Continuous-batching engine serving a small model with batched requests;
the decode step runs DISAGGREGATED across a heterogeneous pair via
Tessera, and the online monitor switches between latency- and
throughput-oriented plans as queueing pressure changes.
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.core import analyzer, planner
from repro.core.costmodel import TPU_V5E, TPU_V5P
from repro.core.executor import build_executable
from repro.core.monitor import MonitorConfig, OnlineMonitor
from repro.models import model as M
from repro.serving.engine import Request, ServingEngine

SLOTS, MAX_LEN = 4, 48
cfg = dataclasses.replace(configs.get_smoke("gpt_oss_20b"),
                          dtype="float32")
params = M.init_params(cfg)

# --- Tessera: plan the decode step for both policies ----------------- #
cache0 = M.init_cache(cfg, SLOTS, MAX_LEN)
toks0 = jnp.zeros((SLOTS, 1), jnp.int32)
pos0 = jnp.zeros((SLOTS,), jnp.int32)

def step(p, c, t, q):
    return M.decode_step(p, cfg, t, c, q, scan_layers=False)

traced = analyzer.analyze(step, params, cache0, toks0, pos0,
                          state_argnums=(1,))
g = analyzer.pin_nodes(traced.graph,
                       traced.state_readers | traced.state_writers, 0)
traced = traced.with_graph(g)
devs = [TPU_V5P, TPU_V5E]
plans = {pol: planner.plan(g, devs, policy=pol) for pol in
         ("latency", "throughput")}
for pol, p in plans.items():
    print(f"{pol:>10}: {p.summary()}")
executables = {pol: build_executable(traced, p)
               for pol, p in plans.items()}

monitor = OnlineMonitor(MonitorConfig(window=0.5, beta=1.5))

def decode_fn(p, c, t, q):
    return executables[monitor.policy](p, c, t, q)

# --- workload: a burst of requests ------------------------------------ #
rng = np.random.default_rng(0)
reqs = [Request(rid=i,
                prompt=rng.integers(0, cfg.vocab_size, size=6)
                .astype(np.int32),
                max_new_tokens=5,
                arrival=0.02 * i + (0.5 if i > 8 else 0.0))
        for i in range(12)]
engine = ServingEngine(cfg, params, slots=SLOTS, max_len=MAX_LEN,
                       decode_fn=decode_fn)
t0 = time.perf_counter()
stats = engine.run(reqs)
for r in reqs:
    lat = r.finished - r.arrival
    monitor.record_request(r.finished, lat, lat * 0.5)
monitor.tick(time.perf_counter() - t0 + 1.0)
print("engine:", stats.summary())
print(f"monitor: policy={monitor.policy} switches={monitor.switches}")
print("sample output tokens:", reqs[0].output)
