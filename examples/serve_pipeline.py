"""End-to-end serving driver (the paper's kind of workload).

  PYTHONPATH=src python examples/serve_pipeline.py

A Poisson open-loop trace (serving/workload.py) drives the REAL
continuous-batching engine; the decode step runs DISAGGREGATED across a
heterogeneous pair via Tessera, and the online monitor switches between
latency- and throughput-oriented plans as queueing pressure changes.

The cost model's predictions for the same plan are printed next to the
engine's wall-clock SLO stats: modeled TPOT is the decode plan's
pipelined bottleneck, modeled TTFT the serial prefill time on the
fastest device.  (Modeled numbers are for the TPU pair the plan was
solved for; wall clock is whatever host runs this script — the point is
the side-by-side harness, which later PRs tighten.)
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.core import analyzer, planner
from repro.core.costmodel import TPU_V5E, TPU_V5P, graph_time_on
from repro.core.executor import build_executable
from repro.core.monitor import MonitorConfig, OnlineMonitor
from repro.models import model as M
from repro.serving.engine import ServingEngine, requests_from_trace
from repro.serving.workload import poisson_trace, trace_stats

SLOTS, MAX_LEN = 4, 48
cfg = dataclasses.replace(configs.get_smoke("gpt_oss_20b"),
                          dtype="float32")
params = M.init_params(cfg)

# --- Tessera: plan the decode step for both policies ----------------- #
cache0 = M.init_cache(cfg, SLOTS, MAX_LEN)
toks0 = jnp.zeros((SLOTS, 1), jnp.int32)
pos0 = jnp.zeros((SLOTS,), jnp.int32)

def step(p, c, t, q):
    return M.decode_step(p, cfg, t, c, q, scan_layers=False)

traced = analyzer.analyze(step, params, cache0, toks0, pos0,
                          state_argnums=(1,))
g = analyzer.pin_nodes(traced.graph,
                       traced.state_readers | traced.state_writers, 0)
traced = traced.with_graph(g)
devs = [TPU_V5P, TPU_V5E]
plans = {pol: planner.plan(g, devs, policy=pol) for pol in
         ("latency", "throughput")}
for pol, p in plans.items():
    print(f"{pol:>10}: {p.summary()}")
executables = {pol: build_executable(traced, p)
               for pol, p in plans.items()}

monitor = OnlineMonitor(MonitorConfig(window=0.5, beta=1.5))

def decode_fn(p, c, t, q):
    return executables[monitor.policy](p, c, t, q)

# --- workload: an open-loop Poisson trace ----------------------------- #
PROMPT_CAP, NEW_CAP = 8, 6
trace = poisson_trace(rate=40.0, num_requests=12, seed=0)
print("trace:", {k: round(v, 3) for k, v in trace_stats(trace).items()})
reqs = requests_from_trace(trace, cfg.vocab_size, max_prompt=PROMPT_CAP,
                           max_new=NEW_CAP, time_scale=0.5)
engine = ServingEngine(cfg, params, slots=SLOTS, max_len=MAX_LEN,
                       decode_fn=decode_fn, sync_every=4)
t0 = time.perf_counter()
stats = engine.run(reqs)
for r in reqs:
    lat = r.finished - r.arrival
    monitor.record_request(r.finished, lat, lat * 0.5)
monitor.tick(time.perf_counter() - t0 + 1.0)

# --- modeled vs wall-clock SLOs --------------------------------------- #
# modeled TTFT: serial prefill on the faster device (no queueing term);
# modeled TPOT: pipelined steady-state bottleneck of the decode plan.
prefill_toks = jax.ShapeDtypeStruct((1, PROMPT_CAP), jnp.int32)
cache1 = jax.eval_shape(lambda: M.init_cache(cfg, 1, MAX_LEN))
tg_pre = analyzer.analyze(
    lambda p, c, t: M.prefill(p, cfg, t, c, scan_layers=False),
    jax.eval_shape(lambda: params), cache1, prefill_toks,
    state_argnums=(1,))
modeled_ttft = min(graph_time_on(tg_pre.graph, d) for d in devs)
s = stats.summary()
print("engine:", s)
print(f"{'':14}{'modeled':>12}{'wall-clock':>12}")
for name, model_v, wall_v in (
        ("TTFT", modeled_ttft, s["mean_ttft"]),
        ("TPOT", plans[monitor.policy].bottleneck, s["mean_tpot"])):
    ratio = wall_v / max(model_v, 1e-12)
    print(f"  {name:<12}{model_v * 1e3:>10.3f}ms{wall_v * 1e3:>10.3f}ms"
          f"   (wall/model {ratio:,.0f}x)")
# machine-readable modeled-vs-wall gap: the tracked baseline for the
# cost-model calibration follow-on (one JSON object per line, greppable
# by CALIBRATION)
import json                                            # noqa: E402
modeled_tpot = plans[monitor.policy].bottleneck
print("CALIBRATION " + json.dumps({
    "modeled_ttft_s": modeled_ttft, "wall_ttft_s": s["mean_ttft"],
    "ttft_wall_over_model": s["mean_ttft"] / max(modeled_ttft, 1e-12),
    "modeled_tpot_s": modeled_tpot, "wall_tpot_s": s["mean_tpot"],
    "tpot_wall_over_model": s["mean_tpot"] / max(modeled_tpot, 1e-12),
}))
print(f"monitor: policy={monitor.policy} switches={monitor.switches}")
print("sample output tokens:", reqs[0].output)

# --- phase-split: two-engine prefill→transfer→decode handoff ---------- #
# The real-engine analogue of the cluster simulator's KV-transfer edge,
# launched from the DECLARATIVE deployment spec: engine P runs ONLY
# prefills (the compute-rich pool's job), exports each request's
# KV/recurrent state, and engine D starts decode_only sessions from the
# imported state.  Greedy decode must be bit-identical to a single
# engine that never split the request.  The same spec object could be
# .simulate()d on the cluster DES instead — one description, two
# backends.
print("\n--- phase-split handoff (prefill engine -> decode engine) ---")
from repro.core.simulator import Interconnect          # noqa: E402
from repro.serving.spec import DeploymentSpec          # noqa: E402

ic = Interconnect(default_bw=100e9)
pd_trace = poisson_trace(rate=40.0, num_requests=6, seed=3)
single = requests_from_trace(pd_trace, cfg.vocab_size,
                             max_prompt=PROMPT_CAP, max_new=NEW_CAP,
                             time_scale=0.0)
split = requests_from_trace(pd_trace, cfg.vocab_size,
                            max_prompt=PROMPT_CAP, max_new=NEW_CAP,
                            time_scale=0.0)
ref_engine = ServingEngine(cfg, params, slots=SLOTS, max_len=MAX_LEN)
ref_engine.run(single)

ENGINE_KW = {"slots": SLOTS, "max_len": MAX_LEN, "sync_every": 4}
pd_spec = DeploymentSpec(groups=[["tpu-v5p"], ["tpu-v5e"]], pd=True,
                         arch="gpt_oss_20b", engine=ENGINE_KW)
t0 = time.perf_counter()
out = pd_spec.compile().launch(cfg, params).run(split)
wall = time.perf_counter() - t0

match = all(a.output == b.output for a, b in zip(single, split))
wire_bytes = out["wire_bytes"]
print(f"requests={len(split)}  KV wire bytes={wire_bytes}  "
      f"modeled transfer={ic.transfer_time(wire_bytes, 0, 1) * 1e6:.1f}us"
      f"  wall={wall * 1e3:.1f}ms")
print(f"decode-only engine: {out['engine']}")
print("bit-identical to single engine:", match)
assert match, "phase-split decode diverged from the single-engine run"

# --- overlapped handoff: (layer, chunk) shards stream during prefill -- #
# kv_chunks > 1 in the spec launches the STREAMED pairing:
# prefill_handoff_stream yields each layer's KV for a chunk the moment
# the chunk's prefill completes; admit_handoff_stream installs shards
# eagerly and starts decoding when the last one lands.  On real
# hardware the shard transfers ride the fabric concurrently with the
# remaining prefill compute, so only the transfer tail lands in TTFT
# (the engine analogue of simulate(kv_chunks=n) on the DES backend).
print("\n--- overlapped handoff (streamed (layer, chunk) shards) ---")
streamed = requests_from_trace(pd_trace, cfg.vocab_size,
                               max_prompt=PROMPT_CAP, max_new=NEW_CAP,
                               time_scale=0.0)
ov_spec = DeploymentSpec(groups=[["tpu-v5p"], ["tpu-v5e"]], pd=True,
                         kv_chunks=MAX_LEN // 4,   # 4-token chunks
                         arch="gpt_oss_20b", engine=ENGINE_KW)
out_s = ov_spec.compile().launch(cfg, params).run(streamed)
match_s = all(a.output == b.output for a, b in zip(single, streamed))
n_shards, shard_bytes = out_s["shards"], out_s["wire_bytes"]
per_chunk = ic.transfer_time(shard_bytes / max(n_shards, 1), 0, 1)
print(f"requests={len(streamed)}  shards={n_shards}  "
      f"bytes={shard_bytes}  modeled tail/shard={per_chunk * 1e6:.1f}us")
print("streamed decode bit-identical to single engine:", match_s)
assert match_s, "streamed handoff diverged from the single-engine run"
