"""Cluster-scale heterogeneous serving demo.

  PYTHONPATH=src python examples/serve_cluster.py

Plans one model's decode graph onto four heterogeneous TPU replica
groups, then replays a bursty open-loop trace through the cluster
simulator under round-robin vs workload-aware (JSED) routing.  Each
replica runs its own online monitor and flips between latency- and
throughput-oriented plans as its queueing ratio crosses beta.
"""
import dataclasses

import jax.numpy as jnp

import repro.configs as configs
from repro.core import analyzer
from repro.core.monitor import MonitorConfig
from repro.models import model as M
from repro.serving.cluster import TesseraCluster
from repro.serving.router import (JSEDRouter, LeastLoadedRouter,
                                  RoundRobinRouter)
from repro.serving.workload import bursty_trace, trace_stats

# --- trace the decode step of a small model -------------------------- #
cfg = dataclasses.replace(configs.get_smoke("llama3_8b"), dtype="float32")
params = M.init_params(cfg)
SLOTS, MAX_LEN = 4, 64
cache0 = M.init_cache(cfg, SLOTS, MAX_LEN)
toks0 = jnp.zeros((SLOTS, 1), jnp.int32)
pos0 = jnp.zeros((SLOTS,), jnp.int32)
traced = analyzer.analyze(
    lambda p, c, t, q: M.decode_step(p, cfg, t, c, q, scan_layers=False),
    params, cache0, toks0, pos0, state_argnums=(1,))
graph = traced.graph

# --- a 4-replica, 8-device heterogeneous cluster --------------------- #
GROUPS = [["tpu-v5p", "tpu-v5e"], ["tpu-v6e", "tpu-v5e"],
          ["tpu-v4", "tpu-v5e"], ["tpu-v5p", "tpu-v5e"]]
cluster = TesseraCluster(graph, GROUPS, base_prompt=256, base_output=128,
                         monitor_cfg=MonitorConfig(window=0.010),
                         anneal_iters=500)
print(cluster.describe())

trace = bursty_trace(rate=1.1 * cluster.capacity, num_requests=300,
                     seed=7)
print("trace:", {k: round(v, 2) for k, v in trace_stats(trace).items()})

for router in (RoundRobinRouter(), LeastLoadedRouter(), JSEDRouter()):
    r = cluster.simulate(trace, router)
    print(f"{router.name:>12}: thr={r.throughput:7.1f} req/s  "
          f"mean_lat={r.mean_latency * 1e3:8.2f} ms  "
          f"p95={r.p(0.95) * 1e3:8.2f} ms  "
          f"cost_eff={r.cost_efficiency:8.1f} req/$  "
          f"switches={r.switches}  per_replica={r.per_replica_completed}")
