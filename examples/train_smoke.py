"""Training driver: data pipeline -> AdamW -> checkpoints -> crash
recovery, with EF-int8 gradient compression on.

  PYTHONPATH=src python examples/train_smoke.py

Uses a reduced llama3-family config (the 8B trains with the same step
function on the production mesh via launch/dryrun.py).
"""
import dataclasses
import tempfile

import repro.configs as configs
from repro.data.pipeline import TokenBatches
from repro.train.compress import CompressionConfig
from repro.train.loop import SimulatedFailure, TrainConfig, Trainer
from repro.train import optim

cfg = dataclasses.replace(configs.get_smoke("llama3_8b"),
                          dtype="float32", d_model=128, d_ff=256,
                          num_layers=4)
with tempfile.TemporaryDirectory() as ckpt_dir:
    tcfg = TrainConfig(steps=60, ckpt_every=20, ckpt_dir=ckpt_dir,
                       log_every=10,
                       compression=CompressionConfig("int8"))
    ocfg = optim.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60)
    trainer = Trainer(cfg, tcfg, ocfg)
    batches = TokenBatches(cfg.vocab_size, batch=4, seq_len=32)

    # train, crash at step 40, restart from the checkpoint
    try:
        trainer.run(batches, fail_at=40)
    except SimulatedFailure as e:
        print(f"!! {e} — restarting from latest checkpoint")
    trainer2 = Trainer(cfg, tcfg, ocfg)
    trainer2.resume(batches)
    for m in trainer.metrics + trainer2.metrics:
        print(f"step {m['step']:4d}  loss {m['loss']:.4f}")
    first = trainer.metrics[0]["loss"]
    last = trainer2.metrics[-1]["loss"]
    print(f"loss {first:.3f} -> {last:.3f} across a simulated crash")
    assert last < first
