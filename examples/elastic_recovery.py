"""Kernel-granularity elasticity: lose a device, re-plan, keep serving.

  PYTHONPATH=src python examples/elastic_recovery.py

PD/AF disaggregation must re-provision a whole phase/block pool on node
loss; Tessera just re-solves kernel placement over the survivors.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.core import analyzer
from repro.core.costmodel import GPU_A100, GPU_H100, GPU_L40S
from repro.models import model as M
from repro.runtime.fault import ElasticExecutor

cfg = dataclasses.replace(configs.get_smoke("qwen3_1_7b"),
                          dtype="float32")
params = M.init_params(cfg)
toks = jnp.zeros((2, 8), jnp.int32)

def fwd(p, t):
    return M.forward_logits(p, cfg, t, scan_layers=False)

traced = analyzer.analyze(fwd, params, toks)
exe = ElasticExecutor(traced, [GPU_A100, GPU_L40S, GPU_H100],
                      jax.devices())
want = np.asarray(jax.jit(fwd)(params, toks))
print("3 devices:", exe.plan.summary())
np.testing.assert_allclose(np.asarray(exe(params, toks)), want,
                           rtol=1e-5)
exe.mark_failed(2)          # lose the H100
print("2 devices:", exe.plan.summary())
np.testing.assert_allclose(np.asarray(exe(params, toks)), want,
                           rtol=1e-5)
exe.mark_failed(1)          # lose the L40s too
print("1 device :", exe.plan.summary())
np.testing.assert_allclose(np.asarray(exe(params, toks)), want,
                           rtol=1e-5)
print(f"elastic re-plans: {exe.replans}; output identical throughout")
