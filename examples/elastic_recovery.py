"""Elasticity at both granularities: device loss and replica-group loss.

  PYTHONPATH=src python examples/elastic_recovery.py

Part 1 (kernel granularity): PD/AF disaggregation must re-provision a
whole phase/block pool on node loss; Tessera just re-solves kernel
placement over the survivors (ElasticExecutor, real arrays).

Part 2 (cluster granularity): a whole replica GROUP dies mid-trace on
the deployment DES — the router masks it and its in-flight requests
re-route across the survivors — then autoscaling drains a group
gracefully while a warm replacement spins up, with zero accepted
requests dropped.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.core import analyzer
from repro.core.costmodel import GPU_A100, GPU_H100, GPU_L40S
from repro.models import model as M
from repro.runtime.fault import ElasticExecutor

cfg = dataclasses.replace(configs.get_smoke("qwen3_1_7b"),
                          dtype="float32")
params = M.init_params(cfg)
toks = jnp.zeros((2, 8), jnp.int32)

def fwd(p, t):
    return M.forward_logits(p, cfg, t, scan_layers=False)

traced = analyzer.analyze(fwd, params, toks)
exe = ElasticExecutor(traced, [GPU_A100, GPU_L40S, GPU_H100],
                      jax.devices())
want = np.asarray(jax.jit(fwd)(params, toks))
print("3 devices:", exe.plan.summary())
np.testing.assert_allclose(np.asarray(exe(params, toks)), want,
                           rtol=1e-5)
exe.mark_failed(2)          # lose the H100
print("2 devices:", exe.plan.summary())
np.testing.assert_allclose(np.asarray(exe(params, toks)), want,
                           rtol=1e-5)
exe.mark_failed(1)          # lose the L40s too
print("1 device :", exe.plan.summary())
np.testing.assert_allclose(np.asarray(exe(params, toks)), want,
                           rtol=1e-5)
print(f"elastic re-plans: {exe.replans}; output identical throughout")

# --- cluster granularity: replica-group failure + autoscaling -------- #
from repro.serving.spec import DeploymentSpec
from repro.serving.workload import poisson_trace

print("\n--- replica-group failure on the deployment DES ---")
spec = DeploymentSpec(groups=[["h100", "rtxpro6000"], ["a100", "l40s"],
                              ["a100", "l40s"]],
                      router="jsed", anneal_iters=300)
dep = spec.compile(traced.graph)
trace = poisson_trace(rate=1.5 * dep.cluster().capacity,
                      num_requests=200, seed=5)
t_fail = trace[len(trace) // 2].arrival
base = dep.simulate(trace)
hit = dep.simulate(trace, failures=[(t_fail, 0)])
after = [a for r, a in zip(sorted(trace, key=lambda r: r.arrival),
                           hit.assignments) if r.arrival > t_fail]
print(f"baseline: completed={base.completed} "
      f"per-replica={base.per_replica_completed}")
print(f"group 0 dies at t={t_fail:.3f}s: completed={hit.completed} "
      f"rerouted={hit.rerouted} dropped={hit.dropped} "
      f"per-replica={hit.per_replica_completed}")
assert hit.completed == len(trace) and hit.dropped == 0
assert 0 not in after, "router kept sending work to a dead group"
print(f"recovery cost: mean latency {base.mean_latency * 1e3:.1f}ms -> "
      f"{hit.mean_latency * 1e3:.1f}ms on 2/3 capacity")

print("\n--- autoscaling: drain one group, warm up a replacement ---")
dep2 = spec.compile(traced.graph)
t_mid = trace[len(trace) // 2].arrival
# warm-up sized to the trace (this smoke graph serves in milliseconds)
warm = 0.25 * (trace[-1].arrival - t_mid)
dep2.scale(add=[["h100", "rtxpro6000"]], at=t_mid, warmup=warm)
dep2.scale(remove=[1], at=t_mid)
scaled = dep2.simulate(trace)
ordered = sorted(trace, key=lambda r: r.arrival)
drained = [a for r, a in zip(ordered, scaled.assignments)
           if r.arrival > t_mid]
early = [a for r, a in zip(ordered, scaled.assignments)
         if r.arrival < t_mid]
print(f"scaled: completed={scaled.completed} dropped={scaled.dropped} "
      f"per-replica={scaled.per_replica_completed}")
assert scaled.dropped == 0 and scaled.completed == len(trace), \
    "graceful drain must be loss-free"
assert 1 not in drained, "drained group must take no new requests"
assert 3 not in early, "warming group must not serve before warm-up"
assert 3 in scaled.assignments, "replacement group never became eligible"
print("drain loss-free; replacement eligible after warm-up; "
      f"post-scale price ${dep2.price_rate:.1f}/hr")
