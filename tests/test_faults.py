"""Chaos-hardened serving, DES side + fault-plan units.

Covers:
  * FaultPlan: typed validation, JSON round trip (unknown fields
    rejected), seeded determinism of the bound per-link RNGs,
  * ControlEvent timeline validation — the regression tests for
    contradictory timelines (duplicate fails, "up" for an eligible
    group, fail-after-down) that the pre-validation code replayed
    silently,
  * crash + scheduled recovery through the existing "up" path,
  * straggle windows: service-time inflation both DES walks apply
    identically (same-seed-same-event-log, reference vs fast),
  * flaky KV links: seeded per-chunk failures with retry/backoff
    accounting, p=0 bit-identity, deadline-blown re-prefill fallback,
  * checkpoint-based recovery: a full-outage blip drops accepted
    in-flight sessions under naive drop-and-reroute and ZERO under
    recovery, at higher goodput,
  * GroupHealth breaker transitions + health-aware JSED/PD routing
    (open groups skipped, brown-out priority shedding),
  * the runtime/fault.py DeviceHealth shim over the same primitives.
"""
import dataclasses
import json

import pytest

from conftest import random_dag
from repro.core.simulator import ControlEvent, validate_timeline
from repro.serving.faults import (BreakerConfig, Crash, DeviceHealth,
                                  FaultPlan, FlakyLink, GroupHealth,
                                  RecoveryConfig, Straggle)
from repro.serving.router import JSEDRouter, PDRouter
from repro.serving.spec import DeploymentSpec
from repro.serving.workload import WorkloadRequest, poisson_trace

GROUPS = [["h100", "rtxpro6000"], ["a100", "l40s"], ["a100", "l40s"]]
ANNEAL = 200


def pd_graph(n: int = 24, seed: int = 2):
    g = random_dag(n, seed=seed)
    nodes = [dataclasses.replace(
        node, phase="prefill" if node.idx < n // 2 else "decode")
        for node in g.nodes]
    g2 = type(g)(nodes, dict(g.edges), name=g.name + ".dep")
    g2.validate()
    return g2


@pytest.fixture(scope="module")
def graph():
    return pd_graph()


@pytest.fixture(scope="module")
def deployment(graph):
    return DeploymentSpec(groups=GROUPS,
                          anneal_iters=ANNEAL).compile(graph)


@pytest.fixture(scope="module")
def trace(deployment):
    return poisson_trace(rate=1.5 * deployment.cluster().capacity,
                         num_requests=150, seed=5)


def _result_key(res):
    """Everything that must be identical between two replays."""
    return (res.completed, res.dropped, res.shed, res.rerouted,
            res.recovered, res.kv_retries, res.kv_refills,
            res.makespan, tuple(res.latencies), tuple(res.assignments))


# ===================================================================== #
# FaultPlan: validation + JSON round trip
# ===================================================================== #
def test_plan_round_trip():
    plan = (FaultPlan(seed=7)
            .crash(3.0, group=1, recover_at=5.0)
            .crash(8.0, group=0)
            .straggle(1.0, 2.0, group=0, factor=3.0)
            .flaky_link(0, 1, p=0.05, seed=2, max_retries=4,
                        backoff=2e-3, deadline=0.5))
    assert FaultPlan.from_json(plan.to_json()) == plan
    assert json.loads(plan.to_json())["seed"] == 7


def test_plan_round_trip_file(tmp_path):
    plan = FaultPlan(seed=3).crash(1.0, group=0, recover_at=2.0)
    path = tmp_path / "plan.json"
    plan.save(path)
    assert FaultPlan.load(path) == plan


def test_plan_rejects_unknown_json_fields():
    with pytest.raises(ValueError, match="unknown FaultPlan fields"):
        FaultPlan.from_json('{"seed": 0, "mystery": []}')


@pytest.mark.parametrize("build", [
    lambda p: p.crash(3.0, group=1, recover_at=3.0),   # not strictly later
    lambda p: p.crash(1.0, group=-1),
    lambda p: p.straggle(2.0, 2.0, group=0, factor=2.0),   # empty window
    lambda p: p.straggle(1.0, 2.0, group=0, factor=0.0),
    lambda p: p.flaky_link(0, 0, p=0.5),               # src == dst
    lambda p: p.flaky_link(0, 1, p=1.5),               # p out of range
    lambda p: p.flaky_link(0, 1, p=0.5, deadline=0.0),
], ids=["recover-at-t", "neg-group", "empty-straggle", "zero-factor",
        "self-link", "bad-p", "bad-deadline"])
def test_plan_rejects_bad_specs(build):
    with pytest.raises(ValueError):
        build(FaultPlan())


def test_plan_rejects_overlapping_straggles():
    plan = FaultPlan().straggle(1.0, 2.0, group=0, factor=2.0)
    with pytest.raises(ValueError, match="overlap"):
        plan.straggle(1.5, 2.5, group=0, factor=3.0)
    # disjoint window and other group are both fine
    plan.straggle(2.0, 3.0, group=0, factor=3.0)
    plan.straggle(1.5, 2.5, group=1, factor=3.0)


def test_bind_checks_group_range():
    with pytest.raises(ValueError, match="deployment has 2"):
        FaultPlan().crash(1.0, group=2).bind(2)
    with pytest.raises(ValueError, match="exceeds 2 groups"):
        FaultPlan().flaky_link(0, 2, p=0.1).bind(2)


def test_bound_link_rngs_are_seeded_and_fresh():
    plan = FaultPlan(seed=9).flaky_link(0, 1, p=0.5)
    a = [plan.bind(2).link(0, 1).rng.random() for _ in range(8)]
    b = [plan.bind(2).link(0, 1).rng.random() for _ in range(8)]
    assert a == b                       # same seed -> same draws
    c = [FaultPlan(seed=10).flaky_link(0, 1, p=0.5)
         .bind(2).link(0, 1).rng.random() for _ in range(8)]
    assert a != c                       # seed actually matters


def test_control_events_cover_crash_and_straggle():
    plan = (FaultPlan().crash(3.0, group=1, recover_at=5.0)
            .straggle(1.0, 2.0, group=0, factor=4.0))
    evs = {(e.time, e.kind, e.group, e.factor)
           for e in plan.control_events()}
    assert evs == {(3.0, "fail", 1, 1.0), (5.0, "up", 1, 1.0),
                   (1.0, "slow", 0, 4.0), (2.0, "slow", 0, 1.0)}


# ===================================================================== #
# Satellite: contradictory-timeline validation (regression — the
# pre-validation code replayed these silently)
# ===================================================================== #
def test_timeline_rejects_duplicate_fail():
    with pytest.raises(ValueError, match="already down"):
        validate_timeline([ControlEvent(1.0, "fail", 0),
                           ControlEvent(2.0, "fail", 0)], 2)


def test_timeline_rejects_fail_after_down():
    with pytest.raises(ValueError, match="already down"):
        validate_timeline([ControlEvent(1.0, "down", 0),
                           ControlEvent(2.0, "fail", 0)], 2)


def test_timeline_rejects_up_for_eligible_group():
    # the first "up" after a fail is a recovery; a SECOND one is a
    # contradiction (the group is already back)
    with pytest.raises(ValueError, match="already eligible"):
        validate_timeline([ControlEvent(1.0, "fail", 0),
                           ControlEvent(2.0, "up", 0),
                           ControlEvent(3.0, "up", 0)], 2)


def test_timeline_rejects_out_of_range_group():
    with pytest.raises(ValueError, match="names group 5"):
        validate_timeline([ControlEvent(1.0, "fail", 5)], 2)


def test_timeline_first_up_is_warmup_recovery_up_is_not():
    # sole "up" = warm-up: the group starts masked
    assert validate_timeline([ControlEvent(1.0, "up", 1)], 2) == {1}
    # fail-then-up = crash recovery: the group must NOT start masked
    assert validate_timeline([ControlEvent(1.0, "fail", 1),
                              ControlEvent(2.0, "up", 1)], 2) == set()


def test_timeline_reserve_groups_may_come_up():
    # a parked reserve group is down at start; its activation "up" is
    # legal and does not mark it as warming up twice
    assert validate_timeline([ControlEvent(1.0, "up", 1)], 2,
                             start_ineligible=[1]) == set()


def test_simulate_rejects_contradictory_failures(deployment, trace):
    with pytest.raises(ValueError, match="already down"):
        deployment.simulate(trace, failures=[(1.0, 1), (2.0, 1)])


def test_slow_events_validate_group_only():
    assert validate_timeline([ControlEvent(1.0, "slow", 0, factor=2.0),
                              ControlEvent(2.0, "slow", 0, factor=2.0)],
                             1) == set()


def test_control_event_validates_kind_and_factor():
    with pytest.raises(ValueError):
        ControlEvent(1.0, "explode", 0)
    with pytest.raises(ValueError):
        ControlEvent(1.0, "slow", 0, factor=0.0)


# ===================================================================== #
# DES: crash + recovery via the "up" path
# ===================================================================== #
def test_crash_with_recovery_serves_after_recover_at(deployment, trace):
    mid = trace[len(trace) // 2].arrival
    base = deployment.simulate(trace)
    perm = deployment.simulate(trace, faults=FaultPlan().crash(
        mid, group=1))
    back = deployment.simulate(trace, faults=FaultPlan().crash(
        mid, group=1, recover_at=mid + 1e-3))
    # the returned group takes arrivals again: strictly more work lands
    # on group 1 than under the permanent kill
    per_g1 = [r.per_replica_completed[1] for r in (perm, back)]
    assert per_g1[1] > per_g1[0]
    assert base.completed >= back.completed >= perm.completed


def test_faults_none_noop_and_equivalent_to_failures(deployment, trace):
    """faults=permanent-crash == legacy failures=[(t, g)] exactly."""
    mid = trace[len(trace) // 2].arrival
    legacy = deployment.simulate(trace, failures=[(mid, 1)])
    plan = deployment.simulate(trace,
                               faults=FaultPlan().crash(mid, group=1))
    assert _result_key(legacy) == _result_key(plan)
    assert legacy.events == plan.events


# ===================================================================== #
# DES: straggle windows
# ===================================================================== #
def test_straggle_inflates_service_and_recovers(deployment, trace):
    base = deployment.simulate(trace)
    t1 = trace[-1].arrival
    slow = deployment.simulate(trace, faults=FaultPlan().straggle(
        0.0, t1 * 0.5, group=0, factor=8.0))
    assert slow.completed + slow.shed + slow.dropped == len(trace)
    # an 8x straggler on one group strictly hurts the latency profile
    assert sum(slow.latencies) > sum(base.latencies)
    # ... but the window closes: a run with the window over the whole
    # trace is strictly worse than the half-trace window
    slower = deployment.simulate(trace, faults=FaultPlan().straggle(
        0.0, t1 * 10.0, group=0, factor=8.0))
    assert sum(slower.latencies) > sum(slow.latencies)


def test_straggle_same_seed_same_event_log_both_walks(deployment, trace):
    """Satellite: the full chaos plan replays bit-identically on the
    reference per-unit walk and the fast vectorized walk."""
    mid = trace[len(trace) // 2].arrival
    plan = (FaultPlan(seed=11)
            .crash(mid, group=1, recover_at=mid + 1.0)
            .straggle(mid * 0.2, mid * 0.9, group=0, factor=3.0))
    kw = dict(faults=plan, recovery=RecoveryConfig(interval=1e-3))
    fast = deployment.simulate(trace, **kw)
    fast2 = deployment.simulate(trace, **kw)
    ref = deployment.simulate(trace, reference=True, **kw)
    assert _result_key(fast) == _result_key(fast2)      # deterministic
    assert fast.events == fast2.events
    assert _result_key(fast) == _result_key(ref)        # walk parity
    assert fast.events == ref.events


# ===================================================================== #
# DES: flaky KV links (pd deployments)
# ===================================================================== #
@pytest.fixture(scope="module")
def pd_deployment(graph):
    return DeploymentSpec(groups=GROUPS, router="pd_split",
                          pd=True, kv_chunks=4,
                          anneal_iters=ANNEAL).compile(graph)


def test_flaky_link_p0_bit_identical(pd_deployment, trace):
    base = pd_deployment.simulate(trace)
    p0 = pd_deployment.simulate(trace, faults=FaultPlan().flaky_link(
        0, 1, p=0.0))
    assert _result_key(base) == _result_key(p0)
    assert base.events == p0.events
    assert p0.kv_retries == 0 and p0.kv_refills == 0


def _all_links_plan(seed, **kw):
    """Flaky links on every directed pair — the PD router may pick any
    (prefill, decode) edge among the groups."""
    plan = FaultPlan(seed=seed)
    for s in range(len(GROUPS)):
        for d in range(len(GROUPS)):
            if s != d:
                plan.flaky_link(s, d, **kw)
    return plan


def test_flaky_link_charges_retries(pd_deployment, trace):
    base = pd_deployment.simulate(trace)
    flaky = pd_deployment.simulate(
        trace, faults=_all_links_plan(5, p=0.2, max_retries=8,
                                      deadline=10.0))
    assert flaky.kv_retries > 0
    # retries charge fabric time: transfer seconds strictly grow
    assert flaky.transfer_seconds > base.transfer_seconds
    # generous retry budget + deadline: nothing aborts, nothing lost
    assert flaky.kv_refills == 0
    assert flaky.completed + flaky.shed + flaky.dropped == len(trace)


def test_flaky_link_deadline_refills_on_decode(pd_deployment, trace):
    """Exhausted retries / blown deadline abort the handoff: the
    request re-prefills on the decode group (kv_refills) instead of
    being dropped — never-later is preserved as not-lost."""
    hostile = pd_deployment.simulate(
        trace, faults=_all_links_plan(5, p=0.9, max_retries=1,
                                      deadline=1e-6))
    assert hostile.kv_refills > 0
    assert hostile.dropped == 0
    assert hostile.completed + hostile.shed == len(trace)


def test_flaky_link_seeded_determinism(pd_deployment, trace):
    plan = _all_links_plan(6, p=0.3, max_retries=4)
    a = pd_deployment.simulate(trace, faults=plan)
    b = pd_deployment.simulate(trace, faults=plan)
    assert _result_key(a) == _result_key(b)
    assert a.events == b.events
    other = pd_deployment.simulate(
        trace, faults=_all_links_plan(60, p=0.3, max_retries=4))
    assert other.kv_retries != a.kv_retries or \
        other.events != a.events


# ===================================================================== #
# DES: checkpoint recovery beats naive drop-and-reroute
# ===================================================================== #
def test_full_outage_blip_recovery_drops_nothing(deployment, trace):
    """Every group crashes and comes back: naive loses the in-flight
    accepted sessions, recovery parks + replays them from checkpoints
    at higher goodput."""
    mid = trace[len(trace) // 2].arrival
    plan = FaultPlan(seed=1)
    for g in range(len(GROUPS)):
        plan.crash(mid, group=g, recover_at=mid + 0.01)
    naive = deployment.simulate(trace, faults=plan)
    # checkpoint interval well under the sub-millisecond decode times
    # of this toy-scale DES, so victims have checkpoint progress
    rec = deployment.simulate(trace, faults=plan,
                              recovery=RecoveryConfig(interval=1e-5),
                              health=GroupHealth())
    assert naive.dropped > 0
    assert rec.dropped == 0
    assert rec.recovered > 0
    assert rec.completed > naive.completed          # goodput win
    assert rec.completed + rec.shed == len(trace)


def test_recovery_replays_only_the_lost_suffix(deployment, trace):
    """Checkpointed victims replay less decode than from-scratch
    victims: recovery's makespan tail is no worse and its completions
    are at least as many."""
    mid = trace[len(trace) // 2].arrival
    plan = FaultPlan().crash(mid, group=1, recover_at=mid + 0.01)
    naive = deployment.simulate(trace, faults=plan)
    rec = deployment.simulate(trace, faults=plan,
                              recovery=RecoveryConfig(interval=1e-3))
    assert rec.completed >= naive.completed
    assert rec.dropped == 0


def test_recovery_requires_faults(deployment, trace):
    with pytest.raises(ValueError, match="faults"):
        deployment.simulate(trace, recovery=RecoveryConfig())


# ===================================================================== #
# GroupHealth: breaker transitions + health-aware routing
# ===================================================================== #
def test_breaker_lifecycle():
    h = GroupHealth(2, BreakerConfig(alpha=0.5, open_threshold=0.6,
                                     cooldown=1.0))
    assert h.state(0, 0.0) == "closed" and h.allow(0, 0.0)
    h.record_error(0, 0.0)              # rate 0.5 < 0.6: still closed
    assert h.state(0, 0.0) == "closed"
    h.record_error(0, 0.1)              # rate 0.75: opens
    assert h.state(0, 0.1) == "open" and not h.allow(0, 0.1)
    assert h.state(0, 0.5) == "open"    # cooldown not elapsed
    assert h.state(0, 1.2) == "half_open"   # probes allowed
    h.record_error(0, 1.3)              # failed probe: re-opens
    assert h.state(0, 1.3) == "open"
    assert h.state(0, 2.4) == "half_open"
    h.record_ok(0, 2.5)                 # successful probe: closes
    assert h.state(0, 2.5) == "closed"
    assert not h.degraded(2.5)


def test_breaker_trip_latches_until_reset():
    h = GroupHealth(2, BreakerConfig(cooldown=0.1))
    h.trip(0, 0.0)
    assert h.state(0, 99.0) == "open"   # cooldown does NOT half-open
    assert h.degraded(99.0)
    h.reset(0, 99.0)
    assert h.state(0, 99.0) == "half_open"
    h.record_ok(0, 99.1)
    assert h.state(0, 99.1) == "closed"


def test_breaker_penalty_tracks_error_rate():
    h = GroupHealth(2, BreakerConfig(alpha=0.5, open_threshold=2.0,
                                     penalty=10.0))
    assert h.penalty(0, 0.0) == 0.0
    h.record_error(0, 0.0)
    assert h.penalty(0, 0.0) == pytest.approx(5.0)
    assert h.penalty(1, 0.0) == 0.0     # per-group isolation


class _StubReplica:
    def __init__(self, backlog=0.0, eligible=True):
        self._b = backlog
        self.eligible = eligible

    def backlog(self, now):
        return self._b

    def predicted_service(self, req):
        return 1.0

    def predicted_phase_service(self, req, phase):
        return 0.5


def _wreq(rid=0, priority=0):
    from repro.core.simulator import ClusterRequest
    return ClusterRequest(rid=rid, arrival=0.0, priority=priority)


def test_jsed_skips_open_breaker_and_fails_open():
    h = GroupHealth(2)
    router = JSEDRouter(health=h)
    reps = [_StubReplica(backlog=0.0), _StubReplica(backlog=5.0)]
    assert router.route(_wreq(), reps, 0.0) == 0
    h.trip(0, 0.0)                      # best group's breaker opens
    assert router.route(_wreq(1), reps, 0.0) == 1
    h.trip(1, 0.0)                      # ALL open: fail open, not -1
    assert router.route(_wreq(2), reps, 0.0) in (0, 1)


def test_jsed_brownout_sheds_low_priority_first():
    h = GroupHealth(2)
    router = JSEDRouter(health=h, brownout_priority=1)
    reps = [_StubReplica(), _StubReplica()]
    assert router.route(_wreq(0, priority=0), reps, 0.0) >= 0
    h.trip(0, 0.0)                      # brown-out begins
    assert router.route(_wreq(1, priority=0), reps, 0.0) == -1
    assert router.route(_wreq(2, priority=1), reps, 0.0) == 1
    h.reset(0, 0.0)
    h.record_ok(0, 0.1)                 # probe closes the breaker
    assert router.route(_wreq(3, priority=0), reps, 0.2) >= 0


def test_jsed_health_none_bit_identical():
    reps = [_StubReplica(backlog=2.0), _StubReplica(backlog=1.0)]
    plain = JSEDRouter()
    health = JSEDRouter(health=None)
    for rid in range(5):
        assert plain.route(_wreq(rid), reps, 0.0) \
            == health.route(_wreq(rid), reps, 0.0)


def test_pd_router_brownout_and_penalty():
    h = GroupHealth(2)
    router = PDRouter(prefill_pool=[0], decode_pool=[1], health=h,
                      brownout_priority=5)
    reps = [_StubReplica(), _StubReplica()]
    out = router.route(_wreq(0, priority=5), reps, 0.0)
    assert out != -1
    h.trip(1, 0.0)
    assert router.route(_wreq(1, priority=0), reps, 0.0) == -1  # brown-out
    out = router.route(_wreq(2, priority=9), reps, 0.0)         # survives
    assert out != -1


def test_des_health_integration_records_crash(deployment, trace):
    """The GroupHealth handed to simulate() observes the DES crash and
    recovery, and the router sees its penalties."""
    mid = trace[len(trace) // 2].arrival
    h = GroupHealth()
    deployment.simulate(trace, faults=FaultPlan().crash(
        mid, group=1, recover_at=mid + 1.0), health=h)
    # post-run: group 1 was tripped then reset to half-open; with no
    # probe traffic after the trace it cannot have silently closed
    assert h.error_rate(1) > 0.0
    assert h.state(1, mid + 2.0) in ("half_open", "closed")


# ===================================================================== #
# runtime/fault.py shim
# ===================================================================== #
def test_device_health_shim_is_the_faults_primitive():
    import repro.runtime.fault as rf
    assert rf.DeviceHealth is DeviceHealth


def test_device_health_latches_breakers():
    dh = DeviceHealth([True] * 3)
    assert dh.lost() == set()
    dh.fail(1)
    assert dh.lost() == {1}
    assert dh.alive == [True, False, True]
    assert dh.breakers.state(1, 1e9) == "open"      # latched
    assert dh.breakers.allow(0, 0.0)
