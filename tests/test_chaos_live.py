"""Live-engine chaos: flaky KV transfers and crash recovery must be
invisible in the tokens.

For every kernel family (dense / MoE / recurrent / hybrid):
  * a transiently flaky streamed PD handoff (per-shard failures fully
    absorbed by the link's retransmit budget) serves greedy tokens
    bit-identical to the fault-free run,
  * a persistently corrupting link (retry budget exhausted -> shard
    delivered corrupted) trips the receiver's checksum, and the
    re-prefill fallback on the decode engine is bit-identical,
  * an engine crash mid-decode with checkpoint-based recovery restores
    every accepted session on the survivor — zero lost, tokens
    bit-identical to the crash-free run.
"""
import dataclasses

import numpy as np
import pytest

import repro.configs as configs
from repro.models import model as M
from repro.serving.engine import Request
from repro.serving.faults import FaultPlan, RecoveryConfig
from repro.serving.kvpool import ShardChecksumError
from repro.serving.spec import DeploymentSpec

ARCHS = ("llama3_8b", "gpt_oss_20b", "rwkv6_3b", "zamba2_7b")


@pytest.fixture(scope="module", params=ARCHS)
def arch_setup(request):
    cfg = dataclasses.replace(configs.get_smoke(request.param),
                              dtype="float32")
    return request.param, cfg, M.init_params(cfg)


def _prompts(cfg, sizes, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
            for n in sizes]


def _reqs(cfg, max_new=6):
    return [Request(rid=i, prompt=p.copy(), max_new_tokens=max_new,
                    arrival=0.0)
            for i, p in enumerate(_prompts(cfg, (12, 9, 17)))]


def _pd_spec(arch):
    return DeploymentSpec(groups=[["h100"], ["a100"]], arch=arch,
                          pd=True, kv_chunks=4,
                          engine={"slots": 2, "max_len": 64})


def _pool_spec(arch):
    return DeploymentSpec(groups=[["h100"], ["a100"]], arch=arch,
                          engine={"slots": 4, "max_len": 64})


# ===================================================================== #
# Flaky streamed handoff
# ===================================================================== #
def test_transient_flaky_stream_bit_identical(arch_setup):
    arch, cfg, params = arch_setup
    spec = _pd_spec(arch)

    ref = _reqs(cfg)
    spec.compile().launch(cfg, params).run(ref)
    ref_out = [list(r.output) for r in ref]
    assert all(len(o) == 6 for o in ref_out)

    flaky = _reqs(cfg)
    dep = spec.compile().launch(cfg, params)
    dep.inject(FaultPlan(seed=1).flaky_link(0, 1, p=0.3,
                                            max_retries=50))
    stats = dep.run(flaky)
    assert [list(r.output) for r in flaky] == ref_out
    assert stats["kv_retries"] > 0          # the link really failed
    assert stats["kv_corrupted"] == 0       # ... and retries absorbed it
    assert stats["reprefills"] == 0


def test_corrupted_stream_reprefills_bit_identical(arch_setup):
    arch, cfg, params = arch_setup
    spec = _pd_spec(arch)

    ref = _reqs(cfg)
    spec.compile().launch(cfg, params).run(ref)
    ref_out = [list(r.output) for r in ref]

    bad = _reqs(cfg)
    dep = spec.compile().launch(cfg, params)
    # p=1, zero retries: every handoff delivers a corrupted first shard
    dep.inject(FaultPlan(seed=2).flaky_link(0, 1, p=1.0, max_retries=0))
    stats = dep.run(bad)
    assert [list(r.output) for r in bad] == ref_out
    assert stats["kv_corrupted"] == len(bad)
    assert stats["reprefills"] == len(bad)  # all fell back to decode


# ===================================================================== #
# Crash + checkpoint recovery on the colocated pool
# ===================================================================== #
def test_crash_recovery_zero_lost_bit_identical(arch_setup):
    arch, cfg, params = arch_setup
    spec = _pool_spec(arch)

    ref = _reqs(cfg, max_new=12)
    spec.compile().launch(cfg, params).run(ref)
    ref_out = [list(r.output) for r in ref]
    assert all(len(o) == 12 for o in ref_out)

    chaos = _reqs(cfg, max_new=12)
    dep = spec.compile().launch(cfg, params)
    dep.inject(FaultPlan(seed=4).crash(0.25, group=0, recover_at=0.6),
               recovery=RecoveryConfig(interval=0.02,
                                       min_dirty_tokens=1))
    stats = dep.run(chaos)
    # recovered == 0 means the crash landed before any admission (or
    # after the drain) — the run proves nothing about recovery then
    assert stats["lost_sessions"] > 0
    assert stats["recovered_sessions"] == stats["lost_sessions"]
    assert stats["checkpoints"] > 0
    assert [list(r.output) for r in chaos] == ref_out   # dropped == 0,
    #                                         replay is bit-identical


# ===================================================================== #
# Cheap mechanism units (one family is enough)
# ===================================================================== #
def test_checksum_detects_corruption():
    cfg = dataclasses.replace(configs.get_smoke("llama3_8b"),
                              dtype="float32")
    params = M.init_params(cfg)
    from repro.serving.engine import ServingEngine
    from repro.serving.faults import corrupt_slice
    from repro.serving.kvpool import KvSlice

    pre = ServingEngine(cfg, params, slots=2, max_len=32,
                        prefill_chunk=4)
    req = Request(rid=0, prompt=_prompts(cfg, (9,))[0],
                  max_new_tokens=4, arrival=0.0)
    items = list(pre.sessions.stream(req, 0.0, checksum=True))
    shard = items[0]
    assert isinstance(shard, KvSlice) and shard.checksum is not None
    assert shard.verify()
    bad = corrupt_slice(shard)
    assert bad.checksum == shard.checksum   # checksum kept, data bad
    assert not bad.verify()

    dec = ServingEngine(cfg, params, slots=2, max_len=32, sync_every=2)
    req2 = Request(rid=1, prompt=req.prompt.copy(), max_new_tokens=4,
                   arrival=0.0)
    with pytest.raises(ShardChecksumError):
        dec.sessions.receive(req2, iter([bad] + items[1:]), 0.0)
    # rollback freed the reserved slot; a clean retry succeeds
    assert dec.active.count(None) == dec.slots
    assert req2.output == []
    items2 = list(pre.sessions.stream(req2, 0.0, checksum=True))
    assert dec.sessions.receive(req2, iter(items2), 0.0)


def test_inject_rejects_crash_on_pd_pair():
    spec = _pd_spec("llama3_8b")
    cfg = dataclasses.replace(configs.get_smoke("llama3_8b"),
                              dtype="float32")
    dep = spec.compile().launch(cfg, M.init_params(cfg))
    with pytest.raises(ValueError, match="fixed topology"):
        dep.inject(FaultPlan().crash(1.0, group=0))
