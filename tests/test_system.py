"""End-to-end behaviour tests of the paper's system.

The full Tessera pipeline — analyze a real model's decode step, pin the
KV state, plan across a heterogeneous device pair, execute disaggregated,
adapt policy online — exercised exactly as the serving launcher wires it.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.core import analyzer, planner
from repro.core.costmodel import TPU_V5E, TPU_V5P, GPU_A100, GPU_L40S
from repro.core.executor import build_executable
from repro.core.monitor import MonitorConfig, OnlineMonitor
from repro.core.simulator import simulate_offline
from repro.models import model as M


def _traced_decode(arch="llama3_8b"):
    cfg = dataclasses.replace(C.get_smoke(arch), dtype="float32")
    params = M.init_params(cfg)
    B, maxlen = 2, 32
    cache = M.init_cache(cfg, B, maxlen)
    toks = jnp.zeros((B, 1), jnp.int32)
    pos = jnp.zeros((B,), jnp.int32)

    def step(p, c, t, q):
        return M.decode_step(p, cfg, t, c, q, scan_layers=False)

    traced = analyzer.analyze(step, params, cache, toks, pos,
                              state_argnums=(1,))
    return cfg, params, cache, traced, step


def test_full_tessera_flow_decode_correctness():
    """analyze -> pin KV -> plan -> disaggregated execution must produce
    exactly the jitted single-device logits, for both policies."""
    cfg, params, cache, traced, step = _traced_decode()
    g = analyzer.pin_nodes(
        traced.graph, traced.state_readers | traced.state_writers, 0)
    traced = traced.with_graph(g)
    toks = jnp.array([[3], [5]], jnp.int32)
    pos = jnp.array([4, 7], jnp.int32)
    want_logits, want_cache = jax.jit(step)(params, cache, toks, pos)
    for policy in ("throughput", "latency"):
        plan = planner.plan(g, [TPU_V5P, TPU_V5E], policy=policy,
                            cache=False)
        exe = build_executable(traced, plan)
        logits, new_cache = exe(params, cache, toks, pos)
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(want_logits),
                                   rtol=1e-5, atol=1e-5)
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5),
            new_cache, want_cache)


def test_kv_pinning_constrains_placement():
    cfg, params, cache, traced, _ = _traced_decode()
    pinned = traced.state_readers | traced.state_writers
    assert pinned, "decode step must touch KV state"
    g = analyzer.pin_nodes(traced.graph, pinned, 1)
    plan = planner.plan(g, [TPU_V5P, TPU_V5E], cache=False)
    for nid in pinned:
        assert plan.labels[nid] == 1


def test_kernel_heterogeneity_exists_in_real_model():
    """Paper §II-B: a real model's kernels must show mixed device
    preference on a heterogeneous pair (some faster on each).  Traced at
    full width via ShapeDtypeStructs (no allocation) so GEMMs are
    genuinely compute-bound and elementwise/norms memory-bound."""
    cfg = dataclasses.replace(C.get("llama3_8b"), num_layers=2)
    params = jax.eval_shape(lambda: M.init_params(cfg))
    toks = jax.ShapeDtypeStruct((1, 512), jnp.int32)

    def fwd(p, t):
        return M.forward_logits(p, cfg, t, scan_layers=False)

    traced = analyzer.analyze(fwd, params, toks)
    a, b = GPU_A100, GPU_L40S
    prefer_a = prefer_b = 0
    t_a = t_b = 0.0
    for n in traced.graph.nodes:
        ta, tb = a.kernel_time(n), b.kernel_time(n)
        t_a += ta
        t_b += tb
        if ta < tb:
            prefer_a += 1
        else:
            prefer_b += 1
    # mixed preference (paper Fig. 2: ~45-70% of kernels favor the
    # cheaper GPU depending on workload)
    assert prefer_a > 0 and prefer_b > 0, (prefer_a, prefer_b)


def test_disaggregation_beats_single_device_in_model():
    """Paper headline: the heterogeneous pair outperforms either device
    alone under the planner's cost model (steady-state pipelined)."""
    _, _, _, traced, _ = _traced_decode("gpt_oss_20b")
    from repro.core.costmodel import graph_time_on
    devs = [GPU_A100, GPU_L40S]
    plan = planner.plan(traced.graph, devs, policy="throughput",
                        cache=False)
    best_single = min(graph_time_on(traced.graph, d) for d in devs)
    assert plan.bottleneck < best_single
    # and the DES agrees within the plan's steady-state ceiling
    sim = simulate_offline(traced.graph, plan, devs, num_requests=64)
    assert sim.throughput > 1.0 / best_single


def test_online_policy_switch_roundtrip():
    """Monitor must move latency->throughput under load and back."""
    mon = OnlineMonitor(MonitorConfig(window=1.0, beta=1.5))
    for i in range(4):
        mon.record_request(i * 0.1, request_latency=0.5,
                           exec_latency=0.05)
    mon.tick(1.0)
    assert mon.policy == "throughput"
    for i in range(4):
        mon.record_request(1.1 + i * 0.1, request_latency=0.055,
                           exec_latency=0.05)
    mon.tick(2.5)
    assert mon.policy == "latency"
    assert mon.switches == 2


def test_plan_solver_speed_matches_paper_scale():
    """Paper §III-B: |K| ~ 500 solves in ~20ms (Gurobi).  Our exact
    min-cut must solve a 500-node DDG well under 1s."""
    import time
    import sys
    sys.path.insert(0, "tests")
    from conftest import random_dag
    g = random_dag(500, seed=1, p=0.02)
    t0 = time.perf_counter()
    p = planner.plan(g, [GPU_A100, GPU_L40S], policy="latency",
                     cache=False)
    dt = time.perf_counter() - t0
    assert dt < 1.0, f"latency solve took {dt:.3f}s"
    assert len(p.labels) == 500
