"""Paged KV block pool + priority preemption + the sessions facade.

Covers the paged-KV acceptance criteria:
  * BlockPool invariants (no double allocation, ``free + allocated ==
    pool``) hold across arbitrary admit/preempt/drain interleavings —
    property-tested (hypothesis, deterministic stub fallback),
  * a paged engine admits more concurrent sessions than it has slots,
    with preempt-and-resume greedy tokens bit-identical to a roomy
    fixed-slot engine, for all four kernel families,
  * spill-to-host / prefetch round-trips equal the in-HBM decode,
  * priority preemption: a high-priority arrival displaces the
    lowest-priority resident; ``preempt_priority=False`` disables it,
  * the ``engine.sessions`` facade (checkpoint / restore / migrate /
    stream) matches the ten legacy movers bit-for-bit through shims,
  * DES mirror: ``KvPoolModel`` occupancy (delayed admission, LRU
    eviction, prefix/session cache hits), session-affinity ON strictly
    beating OFF on a multi-turn chat trace, kv_util reaching the
    autoscaler, and spec knob validation + JSON round-trip.
"""
import dataclasses

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_stub import given, settings, strategies as st

from conftest import random_dag
import repro.configs as configs
from repro.core.simulator import ClusterRequest, KvPoolModel
from repro.models import model as M
from repro.serving.controller import AutoscaleConfig, AutoscalePolicy
from repro.serving.engine import Request, ServingEngine
from repro.serving.kvpool import BlockPool, KvSlice, SessionState
from repro.serving.router import JSEDRouter
from repro.serving.spec import DeploymentSpec
from repro.serving.workload import make_trace

ARCHS = ("llama3_8b", "gpt_oss_20b", "rwkv6_3b", "zamba2_7b")


def _smoke(arch):
    return dataclasses.replace(configs.get_smoke(arch), dtype="float32")


def _prompts(cfg, sizes, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
            for n in sizes]


def _reqs(prompts, max_new=6, priority=None):
    return [Request(rid=i, prompt=p.copy(), max_new_tokens=max_new,
                    arrival=0.0,
                    priority=0 if priority is None else priority[i])
            for i, p in enumerate(prompts)]


def _drain(eng, t=0.0):
    while eng._any_active():
        eng.step(t)
        eng.sync(t)


# ===================================================================== #
# BlockPool property tests
# ===================================================================== #
@settings(max_examples=60, deadline=None)
@given(n_blocks=st.integers(min_value=1, max_value=24),
       seed=st.integers(min_value=0, max_value=10_000))
def test_blockpool_invariants_any_interleaving(n_blocks, seed):
    """free + allocated == pool and no double allocation after ANY
    interleaving of alloc (admit), partial release (preempt) and full
    release (drain)."""
    import random
    rng = random.Random(seed)
    pool = BlockPool(n_blocks)
    held = {}                            # rid -> block ids
    rid = 0
    for _ in range(80):
        op = rng.random()
        if op < 0.5:                     # admit
            want = rng.randint(1, max(1, n_blocks // 2))
            if want <= pool.free:
                ids = pool.alloc(rid, want)
                assert len(ids) == len(set(ids)) == want
                for other in held.values():
                    assert not set(ids) & set(other), "double allocation"
                held[rid] = ids
                rid += 1
            else:
                with pytest.raises(MemoryError):
                    pool.alloc(rid, want)
        elif op < 0.8 and held:          # preempt: release one session
            victim = rng.choice(sorted(held))
            pool.release(held.pop(victim))
        elif held:                       # drain: release everything
            for ids in held.values():
                pool.release(ids)
            held.clear()
        assert pool.check()
        assert pool.free + pool.allocated == n_blocks
        assert pool.allocated == sum(len(v) for v in held.values())
    for ids in held.values():
        pool.release(ids)
    assert pool.free == n_blocks and pool.check()


def test_blockpool_rejects_foreign_release():
    pool = BlockPool(4)
    pool.alloc(0, 2)
    with pytest.raises(AssertionError):
        pool.release([3])                # block 3 was never allocated


# ===================================================================== #
# Preempt-and-resume bit-parity (all four families)
# ===================================================================== #
@pytest.mark.parametrize("arch", ARCHS)
def test_paged_preempt_resume_bit_identical(arch):
    """Six sessions on a two-slot paged engine (forced park/activate
    cycling) produce exactly the greedy tokens of a six-slot engine."""
    cfg = _smoke(arch)
    params = M.init_params(cfg)
    prompts = _prompts(cfg, (6, 3, 5, 4, 7, 2), seed=1)

    singles = _reqs(prompts)
    ref = ServingEngine(cfg, params, slots=6, max_len=32, sync_every=2)
    ref.run(singles)

    paged = _reqs(prompts)
    eng = ServingEngine(cfg, params, slots=2, max_len=32, sync_every=2,
                        kv_block_tokens=8, kv_pool_blocks=64)
    eng.run(paged)
    assert eng._paged.preemptions + len(eng._paged.parked()) >= 0
    for a, b in zip(singles, paged):
        assert a.output == b.output, f"{arch}: rid {a.rid} diverged"
    # the pool drains clean: every block back, bookkeeping intact
    assert eng._paged.pool.free == eng._paged.pool.n_blocks
    assert eng._paged.pool.check()


def test_paged_admits_beyond_slots():
    """Admission is gated by BLOCKS, not slots: 8 sessions enter a
    2-slot engine at once and all complete."""
    cfg = _smoke("llama3_8b")
    params = M.init_params(cfg)
    prompts = _prompts(cfg, (4, 5, 3, 6, 4, 5, 3, 4), seed=2)
    reqs = _reqs(prompts, max_new=4)
    eng = ServingEngine(cfg, params, slots=2, max_len=32, sync_every=2,
                        kv_block_tokens=8, kv_pool_blocks=64)
    n = eng.admit_batch(reqs, 0.0)
    assert n == 8                        # all resident (2 active, 6 parked)
    assert len(eng._paged.parked()) >= 6
    _drain(eng)
    assert eng.stats.completed == 8
    assert all(len(r.output) == 4 for r in reqs)


# ===================================================================== #
# Spill / prefetch
# ===================================================================== #
@pytest.mark.parametrize("arch", ("llama3_8b", "zamba2_7b"))
def test_spill_prefetch_roundtrip_bit_identical(arch):
    """Host-spilling a parked session and letting the scheduler
    prefetch it back must not change a single sampled token."""
    cfg = _smoke(arch)
    params = M.init_params(cfg)
    prompts = _prompts(cfg, (5, 3, 4, 6), seed=3)

    singles = _reqs(prompts)
    ref = ServingEngine(cfg, params, slots=4, max_len=32, sync_every=2)
    ref.run(singles)

    spilled = _reqs(prompts)
    eng = ServingEngine(cfg, params, slots=2, max_len=32, sync_every=2,
                        kv_block_tokens=8, kv_pool_blocks=64)
    assert eng.admit_batch(spilled, 0.0) == 4
    parked = eng._paged.parked()
    assert parked
    eng._paged.spill(parked[0])          # HBM -> host
    assert eng._paged.spills == 1
    _drain(eng)
    assert eng._paged.prefetches == 1    # came back on activation
    for a, b in zip(singles, spilled):
        assert a.output == b.output
    assert eng._paged.pool.free == eng._paged.pool.n_blocks


# ===================================================================== #
# Priority preemption
# ===================================================================== #
def test_priority_preempts_lowest_resident():
    """Under block pressure a high-priority arrival parks + spills the
    lowest-priority resident instead of waiting; with
    ``preempt_priority=False`` nothing is displaced for priority."""
    cfg = _smoke("llama3_8b")
    params = M.init_params(cfg)
    prompts = _prompts(cfg, (8, 8, 8), seed=4)
    # pool fits ~2 sessions (each needs ceil(min(8+6,16)/8)=2 blocks
    # of 8 tokens + fixed state); priorities 0, 0, then 5
    reqs = _reqs(prompts, priority=[0, 0, 5])
    eng = ServingEngine(cfg, params, slots=2, max_len=16, sync_every=2,
                        kv_block_tokens=8, kv_pool_blocks=4)
    n0 = eng.admit_batch(reqs[:2], 0.0)
    assert n0 == 2
    n1 = eng.admit_batch(reqs[2:], 0.0)
    assert n1 == 1
    assert eng._paged.preemptions >= 1
    _drain(eng)
    assert eng.stats.completed == 3      # preempted sessions resume
    assert eng._paged.pool.check()

    # same shape, preemption off: the high-priority request cannot
    # displace anyone, so the full batch refuses (engine.run would
    # retry it at the next wave instead)
    eng2 = ServingEngine(cfg, params, slots=2, max_len=16, sync_every=2,
                         kv_block_tokens=8, kv_pool_blocks=4,
                         preempt_priority=False, spill=False)
    reqs2 = _reqs(prompts, priority=[0, 0, 5])
    assert eng2.admit_batch(reqs2[:2], 0.0) == 2
    assert eng2.admit_batch(reqs2[2:], 0.0) == 0
    assert eng2._paged.preemptions == 0
    _drain(eng2)


# ===================================================================== #
# The sessions facade vs the ten legacy movers
# ===================================================================== #
def test_facade_matches_legacy_handoff_bits():
    """sessions.prefill/restore and prefill_handoff/admit_handoff are
    the same machine: identical wire dicts, identical decode."""
    cfg = _smoke("llama3_8b")
    params = M.init_params(cfg)
    prompts = _prompts(cfg, (6, 4), seed=5)

    legacy = _reqs(prompts)
    pre_l = ServingEngine(cfg, params, slots=2, max_len=32)
    dec_l = ServingEngine(cfg, params, slots=2, max_len=32, sync_every=2)
    for r in legacy:
        h = pre_l.prefill_handoff(r, 0.0)
        assert set(h) == {"rid", "state", "last_tok", "pos", "budget",
                          "kv_bytes", "done"}
        assert dec_l.admit_handoff(r, h, 0.0)
    dec_l.run([])

    facade = _reqs(prompts)
    pre_f = ServingEngine(cfg, params, slots=2, max_len=32)
    dec_f = ServingEngine(cfg, params, slots=2, max_len=32, sync_every=2)
    for r in facade:
        st_ = pre_f.sessions.prefill(r, 0.0)
        assert isinstance(st_, SessionState)
        assert set(st_.to_legacy()) == {"rid", "state", "last_tok",
                                        "pos", "budget", "kv_bytes",
                                        "done"}
        assert dec_f.sessions.restore(r, st_, 0.0)
    dec_f.run([])

    for a, b in zip(legacy, facade):
        assert a.output == b.output


def test_facade_checkpoint_restore_matches_export_import():
    """sessions.checkpoint()/restore() == export_sessions()/
    import_session() (same wire payloads through the shims)."""
    cfg = _smoke("llama3_8b")
    params = M.init_params(cfg)
    prompts = _prompts(cfg, (5, 3, 4), seed=6)

    ref = _reqs(prompts)
    e_ref = ServingEngine(cfg, params, slots=3, max_len=32, sync_every=2)
    e_ref.run(ref)

    moved = _reqs(prompts)
    src = ServingEngine(cfg, params, slots=3, max_len=32, sync_every=2)
    assert src.admit_batch(moved, 0.0) == 3
    src.step(0.0)
    src.step(0.0)
    exported = src.export_sessions(0.0)          # legacy shim
    assert len(exported) == 3
    assert not src._any_active()
    dst = ServingEngine(cfg, params, slots=3, max_len=32, sync_every=2)
    for r, h in ((next(r for r in moved if r.rid == h["rid"]), h)
                 for _, h in exported):
        assert dst.import_session(r, h, 0.0)     # legacy shim
    _drain(dst)
    for a, b in zip(ref, moved):
        assert a.output == b.output


def test_sessions_migrate_between_engines():
    """sessions.migrate() moves every resident session to a peer and
    decode finishes there bit-identically."""
    cfg = _smoke("llama3_8b")
    params = M.init_params(cfg)
    prompts = _prompts(cfg, (4, 6), seed=7)

    ref = _reqs(prompts)
    e_ref = ServingEngine(cfg, params, slots=2, max_len=32, sync_every=2)
    e_ref.run(ref)

    mig = _reqs(prompts)
    a = ServingEngine(cfg, params, slots=2, max_len=32, sync_every=2)
    b = ServingEngine(cfg, params, slots=2, max_len=32, sync_every=2)
    assert a.admit_batch(mig, 0.0) == 2
    a.step(0.0)
    a.step(0.0)
    assert a.sessions.migrate(b, 0.0) == 2
    assert not a._any_active()
    _drain(b)
    for x, y in zip(ref, mig):
        assert x.output == y.output


def test_stream_receive_kvslice_and_legacy_dicts():
    """sessions.stream() yields KvSlice/SessionState; sessions.receive()
    accepts both the typed objects and their legacy dict encodings."""
    cfg = _smoke("llama3_8b")
    params = M.init_params(cfg)
    prompts = _prompts(cfg, (7, 5), seed=8)

    ref = _reqs(prompts)
    e_ref = ServingEngine(cfg, params, slots=2, max_len=32, sync_every=2)
    e_ref.run(ref)

    typed = _reqs(prompts)
    pre = ServingEngine(cfg, params, slots=2, max_len=32,
                        prefill_chunk=4)
    dec = ServingEngine(cfg, params, slots=2, max_len=32, sync_every=2)
    for r in typed:
        items = list(pre.sessions.stream(r, 0.0))
        assert isinstance(items[-1], SessionState)
        assert all(isinstance(i, KvSlice) for i in items[:-1])
        # round-trip every item through the legacy dict encoding
        wire = [i.to_legacy(header=True) if isinstance(i, SessionState)
                else i.to_legacy() for i in items]
        assert dec.sessions.receive(r, wire, 0.0)
    dec.run([])
    for a, b in zip(ref, typed):
        assert a.output == b.output


def test_peer_prefetch_pulls_session():
    """sessions.prefetch(rid, peer) pulls one resident session from a
    peer engine (the peer-tier of the HBM -> host -> peer hierarchy)."""
    cfg = _smoke("llama3_8b")
    params = M.init_params(cfg)
    prompts = _prompts(cfg, (5, 4), seed=9)

    ref = _reqs(prompts)
    e_ref = ServingEngine(cfg, params, slots=2, max_len=32, sync_every=2)
    e_ref.run(ref)

    far = _reqs(prompts)
    peer = ServingEngine(cfg, params, slots=2, max_len=32, sync_every=2)
    local = ServingEngine(cfg, params, slots=2, max_len=32, sync_every=2)
    assert peer.admit_batch(far, 0.0) == 2
    peer.step(0.0)
    peer.step(0.0)
    assert local.sessions.prefetch(far[0].rid, peer, 0.0)
    _drain(local)
    _drain(peer)
    for a, b in zip(ref, far):
        assert a.output == b.output


# ===================================================================== #
# DES mirror: KvPoolModel
# ===================================================================== #
def test_kvpool_model_occupancy_and_hits():
    kvm = KvPoolModel(block_tokens=64, pool_blocks=8,
                      base_prompt=1024, base_output=256).bind(1)
    r0 = ClusterRequest(rid=0, arrival=0.0, scale_prompt=0.25,
                        scale_output=0.25, session=7)
    assert kvm.admit(0, r0, 0.0) == 0.0          # 5 of 8 blocks
    kvm.release(0, r0, 10.0)
    assert kvm.cached(0, 7, 11.0) == 320         # resident after finish
    # same session re-admits: accumulated context rolls the blocks over
    r1 = ClusterRequest(rid=1, arrival=11.0, scale_prompt=352 / 1024,
                        scale_output=0.25, session=7)
    assert kvm.admit(0, r1, 11.0) == 11.0
    kvm.release(0, r1, 20.0)
    # pressure: a stranger needing 6 blocks waits for the active finish
    r2 = ClusterRequest(rid=2, arrival=12.0, scale_prompt=0.25,
                        scale_output=0.4, session=None)
    assert kvm.admit(0, r2, 12.0) == 20.0
    assert kvm.delayed == 1
    assert kvm.peaks()[0] >= 7
    assert 0.0 < kvm.util_at(0, 21.0) <= 1.0


def test_kvpool_model_lru_eviction():
    kvm = KvPoolModel(block_tokens=64, pool_blocks=6,
                      base_prompt=1024, base_output=256).bind(1)
    for sid in (1, 2):
        r = ClusterRequest(rid=sid, arrival=0.0, scale_prompt=0.125,
                           scale_output=0.125, session=sid)   # 3 blocks
        kvm.admit(0, r, float(sid))
        kvm.release(0, r, float(sid) + 0.5)
    # both resident; session 1 is LRU.  A 3-block stranger evicts it
    # (and only it: the freed 3 blocks cover the need).
    r = ClusterRequest(rid=9, arrival=5.0, scale_prompt=0.125,
                       scale_output=0.125, session=None)
    assert kvm.admit(0, r, 5.0) == 5.0           # no wait: eviction
    assert kvm.evictions == 1
    assert kvm.cached(0, 1, 5.0) == 0            # evicted
    assert kvm.cached(0, 2, 5.0) > 0             # survivor


def _chat_deployment(slo_ttft=0.005):
    g0 = random_dag(24, seed=2)
    nodes = [dataclasses.replace(
        n, phase="prefill" if n.idx < 12 else "decode")
        for n in g0.nodes]
    g = type(g0)(nodes, dict(g0.edges), name=g0.name + ".kv")
    g.validate()
    spec = DeploymentSpec(
        groups=[["a100", "l40s"]] * 4, anneal_iters=200,
        slos={"base": 0.05, "per_output_token": 0.0005,
              "ttft": slo_ttft},
        engine={"kv_block_tokens": 16, "max_len": 64, "slots": 4,
                "kv_pool_blocks": 8192})
    return spec, spec.compile(g)


def test_affinity_on_strictly_beats_off_on_chat_trace():
    """The tentpole's measured claim: with per-group KV occupancy and
    prefix-cache hits modeled, decode-session affinity ON yields
    strictly higher goodput than OFF on a multi-turn chat trace."""
    spec, dep = _chat_deployment()
    cap = dep.cluster().capacity
    tr = make_trace("chat", 8.0 * cap, 800, seed=7, think_mean=0.05,
                    first_prompt_mean=1024, new_tokens_mean=512,
                    output_mean=16)
    on = dep.simulate(tr, router=JSEDRouter(session_affinity=True),
                      events=None)
    off = dep.simulate(tr, router=JSEDRouter(session_affinity=False),
                       events=None)
    assert on.kv_hits > off.kv_hits
    assert on.slo_ok > off.slo_ok, (on.slo_ok, off.slo_ok)
    assert on.kv_hit_tokens > 0 and on.peak_kv_blocks


def test_kv_util_reaches_autoscaler():
    """ControlSignals.kv_util is populated and a kv_hi breach scales
    up from the reserve pool."""
    spec, dep = _chat_deployment()
    cap = dep.cluster().capacity
    tr = make_trace("chat", 8.0 * cap, 300, seed=7, think_mean=0.05,
                    first_prompt_mean=1024, new_tokens_mean=512,
                    output_mean=16)
    seen = []

    class Probe(AutoscalePolicy):
        def decide(self, sig):
            seen.append(sig.kv_util)
            return super().decide(sig)

    ctl = Probe(AutoscaleConfig(interval=0.005, window=0.02,
                                cooldown=0.0, warmup=0.0,
                                kv_hi=0.0001, queue_hi=1e9),
                inventory=[["a100"]])
    dep.simulate(tr, controller=ctl, events=None)
    assert seen and any(ku for ku in seen)       # kv_util populated
    assert any(d.action == "up" and "kv_util" in d.reason
               for d in ctl.decisions)


def test_spec_kv_knobs_validation_and_roundtrip():
    s = DeploymentSpec(groups=[["a100"]],
                       engine={"slots": 4, "max_len": 64,
                               "kv_block_tokens": 16})
    # slots= shim: pool defaults to the fixed-slot footprint
    assert s.kv_config() == {"kv_block_tokens": 16,
                             "kv_pool_blocks": 16,
                             "spill": True, "preempt_priority": True}
    assert DeploymentSpec.from_json(s.to_json()) == s
    assert s.kv_model() is not None
    assert DeploymentSpec(groups=[["a100"]]).kv_model() is None
    with pytest.raises(ValueError, match="requires kv_block_tokens"):
        DeploymentSpec(groups=[["a100"]], engine={"kv_pool_blocks": 8})
    with pytest.raises(ValueError, match="must divide"):
        DeploymentSpec(groups=[["a100"]],
                       engine={"kv_block_tokens": 48, "max_len": 64})
    with pytest.raises(ValueError, match="kv_block_tokens must be"):
        DeploymentSpec(groups=[["a100"]], engine={"kv_block_tokens": 0})


def test_chat_trace_accumulates_context():
    tr = make_trace("chat", 8.0, 300, seed=3)
    assert all(r.session is not None for r in tr)
    last = {}
    follow = 0
    for r in tr:
        if r.session in last:
            follow += 1
            assert r.prompt_tokens > last[r.session]
        last[r.session] = r.prompt_tokens
    assert follow > 30                   # genuinely multi-turn
    # deterministic
    assert tr == make_trace("chat", 8.0, 300, seed=3)
