"""Substrates: data pipeline, checkpointing, train loop + fault
tolerance, gradient compression, serving engine, elastic executor."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # collect without hypothesis (tier-1 guard)
    from _hypothesis_stub import given, settings, strategies as st

import repro.configs as configs
from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import Prefetcher, TokenBatches
from repro.models import model as M
from repro.serving.engine import Request, ServingEngine
from repro.train.compress import (CompressionConfig, compress_decompress,
                                  init_residual, quantize_int8,
                                  dequantize_int8, topk_densify,
                                  topk_sparsify)
from repro.train.loop import SimulatedFailure, TrainConfig, Trainer


# ===================================================================== #
# Data pipeline
# ===================================================================== #
def test_batches_deterministic_random_access():
    tb = TokenBatches(vocab_size=128, batch=4, seq_len=16, seed=7)
    b1 = tb.batch_at(5)
    b2 = tb.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = tb.batch_at(6)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # targets are tokens shifted by one
    np.testing.assert_array_equal(b1["tokens"][:, 1:],
                                  b1["targets"][:, :-1])


def test_prefetcher_preserves_order():
    tb = TokenBatches(vocab_size=64, batch=2, seq_len=8)
    it = iter([tb.batch_at(i) for i in range(5)])
    got = list(Prefetcher(it, depth=2))
    assert len(got) == 5
    for i, b in enumerate(got):
        np.testing.assert_array_equal(b["tokens"],
                                      tb.batch_at(i)["tokens"])


# ===================================================================== #
# Checkpointing
# ===================================================================== #
def test_checkpoint_roundtrip_bf16(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    state = {"w": jnp.arange(8, dtype=jnp.bfloat16) * 0.5,
             "m": {"v": jnp.ones((3, 3), jnp.float32) * 3},
             "step": jnp.int32(7)}
    mgr.save(10, state)
    step, restored = mgr.restore(state)
    assert step == 10
    np.testing.assert_array_equal(np.asarray(restored["w"], np.float32),
                                  np.asarray(state["w"], np.float32))
    np.testing.assert_array_equal(restored["m"]["v"], state["m"]["v"])


def test_checkpoint_gc_keeps_last_k(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    x = {"a": jnp.zeros(2)}
    for s in (1, 2, 3, 4):
        mgr.save(s, x)
    assert mgr.steps() == [3, 4]


def test_checkpoint_ignores_partial_writes(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3)
    mgr.save(1, {"a": jnp.zeros(2)})
    # simulate a crash mid-write: tmp dir left behind
    (tmp_path / "step_00000002.tmp").mkdir()
    assert mgr.latest_step() == 1


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save_async(5, {"a": jnp.ones(4)})
    mgr.wait()
    assert mgr.latest_step() == 5


# ===================================================================== #
# Train loop + fault tolerance
# ===================================================================== #
def _tiny_trainer(tmp_path, steps=12, **kw):
    from repro.train import optim
    cfg = dataclasses.replace(configs.get_smoke("llama3_8b"),
                              dtype="float32")
    tcfg = TrainConfig(steps=steps, ckpt_every=4, ckpt_dir=str(tmp_path),
                       log_every=1, **kw)
    ocfg = optim.AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=steps)
    return Trainer(cfg, tcfg, ocfg), TokenBatches(cfg.vocab_size, 2, 16)


def test_crash_restart_resumes_identically(tmp_path):
    """Train 12 steps straight vs crash-at-8 + resume: identical params."""
    trainer, batches = _tiny_trainer(tmp_path / "a")
    final = trainer.run(batches)

    trainer2, batches2 = _tiny_trainer(tmp_path / "b")
    with pytest.raises(SimulatedFailure):
        trainer2.run(batches2, fail_at=8)
    # fresh trainer = process restart
    trainer3, _ = _tiny_trainer(tmp_path / "b")
    resumed = trainer3.resume(batches2)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=1e-6, atol=1e-6),
        final["params"], resumed["params"])


def test_loss_decreases_on_learnable_data(tmp_path):
    trainer, batches = _tiny_trainer(tmp_path, steps=40)
    trainer.run(batches)
    losses = [m["loss"] for m in trainer.metrics]
    assert losses[-1] < losses[0] - 0.05, losses


def test_grad_accumulation_matches_full_batch(tmp_path):
    cfg = dataclasses.replace(configs.get_smoke("llama3_8b"),
                              dtype="float32")
    batches = TokenBatches(cfg.vocab_size, 4, 16)
    t1 = Trainer(cfg, TrainConfig(steps=3, log_every=1))
    t2 = Trainer(cfg, TrainConfig(steps=3, log_every=1, accum=2))
    s1 = t1.run(batches)
    s2 = t2.run(batches)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=5e-4, atol=5e-5),
        s1["params"], s2["params"])


# ===================================================================== #
# Gradient compression
# ===================================================================== #
def test_int8_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(0), (256,))
    q, s = quantize_int8(x)
    y = dequantize_int8(q, s)
    assert float(jnp.abs(x - y).max()) <= float(s) * 0.5 + 1e-6


def test_topk_keeps_largest():
    x = jnp.array([0.1, -5.0, 0.2, 3.0, -0.05])
    vals, idx = topk_sparsify(x, ratio=0.4)
    dense = topk_densify(vals, idx, x.shape)
    np.testing.assert_allclose(
        np.asarray(dense), [0.0, -5.0, 0.0, 3.0, 0.0])


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000),
       scheme=st.sampled_from(["int8", "topk"]))
def test_property_error_feedback_conserves_mass(seed, scheme):
    """EF invariant: decompressed + new_residual == grads + old_residual."""
    g = {"a": jax.random.normal(jax.random.PRNGKey(seed), (64,)),
         "b": jax.random.normal(jax.random.PRNGKey(seed + 1), (4, 8))}
    r = init_residual(g)
    cfg = CompressionConfig(scheme=scheme, topk_ratio=0.25)
    y, r2 = compress_decompress(cfg, g, r)
    jax.tree_util.tree_map(
        lambda gi, ri, yi, r2i: np.testing.assert_allclose(
            np.asarray(yi + r2i), np.asarray(gi + ri), rtol=1e-5,
            atol=1e-5),
        g, r, y, r2)


def test_compressed_training_still_learns(tmp_path):
    trainer, batches = _tiny_trainer(
        tmp_path, steps=40,
        compression=CompressionConfig("int8"))
    trainer.run(batches)
    losses = [m["loss"] for m in trainer.metrics]
    assert losses[-1] < losses[0] - 0.05, losses


# ===================================================================== #
# Serving engine
# ===================================================================== #
def test_engine_matches_sequential_generation():
    """Continuous batching must emit the same greedy tokens as a naive
    one-request-at-a-time loop."""
    cfg = dataclasses.replace(configs.get_smoke("llama3_8b"),
                              dtype="float32")
    params = M.init_params(cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=5).astype(np.int32)
               for _ in range(3)]

    def naive(prompt, n):
        cache = M.init_cache(cfg, 1, 64)
        logits, cache = M.prefill(params, cfg, jnp.asarray(prompt)[None],
                                  cache)
        toks = [int(jnp.argmax(logits, -1)[0])]
        pos = len(prompt)
        for _ in range(n - 1):
            lg, cache = M.decode_step(
                params, cfg, jnp.asarray([[toks[-1]]], jnp.int32),
                cache, jnp.asarray([pos], jnp.int32))
            toks.append(int(jnp.argmax(lg, -1)[0]))
            pos += 1
        return toks

    want = [naive(p, 4) for p in prompts]
    engine = ServingEngine(cfg, params, slots=3, max_len=64)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=4, arrival=0.0)
            for i, p in enumerate(prompts)]
    stats = engine.run(reqs)
    assert stats.completed == 3
    got = [r.output for r in reqs]
    assert got == want


def test_engine_more_requests_than_slots():
    cfg = dataclasses.replace(configs.get_smoke("qwen3_1_7b"),
                              dtype="float32")
    params = M.init_params(cfg)
    rng = np.random.default_rng(1)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, size=4)
                    .astype(np.int32),
                    max_new_tokens=3, arrival=0.0) for i in range(5)]
    engine = ServingEngine(cfg, params, slots=2, max_len=32)
    stats = engine.run(reqs)
    assert stats.completed == 5
    assert all(len(r.output) == 3 for r in reqs)


# ===================================================================== #
# Elastic executor (Tessera-native fault tolerance)
# ===================================================================== #
def test_elastic_executor_survives_device_loss():
    from repro.core import analyzer
    from repro.core.costmodel import GPU_A100, GPU_H100, GPU_L40S
    from repro.runtime.fault import ElasticExecutor

    def fn(x, w):
        for _ in range(4):
            x = jnp.tanh(x @ w)
        return x

    x = jnp.ones((4, 16))
    w = jnp.eye(16) * 0.5
    traced = analyzer.analyze(fn, x, w)
    exe = ElasticExecutor(traced, [GPU_A100, GPU_L40S, GPU_H100],
                          jax.devices())
    want = np.asarray(jax.jit(fn)(x, w))
    np.testing.assert_allclose(np.asarray(exe(x, w)), want, rtol=1e-6)
    exe.mark_failed(1)
    assert set(exe.plan.labels) <= {0, 1}       # survivors only
    np.testing.assert_allclose(np.asarray(exe(x, w)), want, rtol=1e-6)
    exe.mark_failed(0)
    np.testing.assert_allclose(np.asarray(exe(x, w)), want, rtol=1e-6)
    assert exe.replans == 2
