"""Deterministic fallback for ``hypothesis`` (tier-1 must collect without it).

Test modules guard their import like::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_stub import given, settings, strategies as st

With hypothesis installed (CI installs requirements-dev.txt) the real
library runs; without it, ``given`` degrades to a fixed sweep of
deterministic examples drawn from the declared strategies — far weaker
than real property testing, but the invariants still get exercised and
the suite collects and passes either way.  Shrinking, example databases
and assume() are intentionally out of scope.
"""
from __future__ import annotations

import functools
import inspect
import random
from typing import Any, Callable, List

# How many deterministic examples each @given test runs without
# hypothesis.  Kept small: the real sweep happens in CI.
FALLBACK_EXAMPLES = 4


class _Strategy:
    """A sampleable value source; ``draw`` must be deterministic in rng."""

    def __init__(self, draw: Callable[[random.Random], Any],
                 edge_cases: List[Any]):
        self._draw = draw
        self.edge_cases = edge_cases

    def draw(self, rng: random.Random) -> Any:
        return self._draw(rng)


class strategies:
    """Subset of ``hypothesis.strategies`` used by this repo's tests."""

    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(min_value, max_value),
                         [min_value, max_value])

    @staticmethod
    def sampled_from(values) -> _Strategy:
        vals = list(values)
        return _Strategy(lambda rng: rng.choice(vals), [vals[0], vals[-1]])

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy(lambda rng: rng.random() < 0.5, [False, True])

    @staticmethod
    def floats(min_value: float, max_value: float) -> _Strategy:
        return _Strategy(lambda rng: rng.uniform(min_value, max_value),
                         [min_value, max_value])

    @staticmethod
    def tuples(*elements: _Strategy) -> _Strategy:
        return _Strategy(
            lambda rng: tuple(s.draw(rng) for s in elements),
            [tuple(s.edge_cases[0] for s in elements),
             tuple(s.edge_cases[-1] for s in elements)])

    @staticmethod
    def lists(elements: _Strategy, min_size: int = 0,
              max_size: int = 10) -> _Strategy:
        def draw(rng: random.Random):
            n = rng.randint(min_size, max_size)
            return [elements.draw(rng) for _ in range(n)]
        lo = max(min_size, 1)
        return _Strategy(draw, [[elements.edge_cases[0]] * lo,
                                [elements.edge_cases[-1]] * lo])


def given(**strats: _Strategy):
    """Run the test on edge cases + seeded-random draws (no shrinking)."""

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            # Example 0 pins every strategy to its first edge case,
            # example 1 to its last; the rest are seeded-random draws.
            names = sorted(strats)
            for ex in range(FALLBACK_EXAMPLES):
                rng = random.Random(f"{fn.__name__}:{ex}")
                if ex == 0:
                    drawn = {k: strats[k].edge_cases[0] for k in names}
                elif ex == 1:
                    drawn = {k: strats[k].edge_cases[-1] for k in names}
                else:
                    drawn = {k: strats[k].draw(rng) for k in names}
                fn(*args, **kwargs, **drawn)
        # Hide the drawn parameters from pytest so remaining arguments
        # (fixtures) are still collected normally — mirrors hypothesis.
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items() if name not in strats])
        wrapper.hypothesis_fallback = True
        return wrapper
    return deco


def settings(*_a, **_kw):
    """Accepts and ignores all hypothesis settings."""
    def deco(fn):
        return fn
    return deco
