"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + hypothesis.

All kernels run in interpret mode on CPU (the TPU-target BlockSpecs are
exercised; Mosaic compilation happens on real TPUs)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# The suite must collect (and the non-property tests must run) without
# hypothesis installed.  A bare ``pytest.importorskip("hypothesis")``
# would skip this whole module — including the parametrized shape sweeps
# — so absent hypothesis we fall back to a deterministic example sweep
# instead (see tests/_hypothesis_stub.py; CI installs the real thing via
# requirements-dev.txt).
try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_stub import given, settings, strategies as st

from repro.kernels.flash_attention.kernel import (
    flash_attention_decode, flash_attention_prefill)
from repro.kernels.flash_attention.ref import attention_ref, decode_ref
from repro.kernels.mamba2_ssd.kernel import ssd_chunk_scan
from repro.kernels.mamba2_ssd.ref import ssd_ref
from repro.kernels.moe_gmm.kernel import gmm, pad_groups
from repro.kernels.moe_gmm.ref import gmm_ref
from repro.kernels.rwkv6.kernel import wkv
from repro.kernels.rwkv6.ref import wkv_ref

KEY = jax.random.PRNGKey(0)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


# ===================================================================== #
# Flash attention
# ===================================================================== #
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,Hkv,S,D,bq,bk", [
    (1, 4, 4, 128, 64, 64, 64),      # MHA
    (2, 8, 2, 256, 64, 128, 64),     # GQA
    (1, 4, 1, 128, 128, 32, 32),     # MQA, head_dim 128
    (2, 2, 2, 64, 32, 64, 64),       # single q block
])
def test_flash_prefill_shapes(dtype, B, H, Hkv, S, D, bq, bk):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, S, D), dtype)
    k = jax.random.normal(ks[1], (B, Hkv, S, D), dtype)
    v = jax.random.normal(ks[2], (B, Hkv, S, D), dtype)
    out = flash_attention_prefill(q, k, v, causal=True, block_q=bq,
                                  block_k=bk, interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               **_tol(dtype))


@pytest.mark.parametrize("window", [32, 96, 200])
def test_flash_prefill_sliding_window(window):
    ks = jax.random.split(KEY, 3)
    B, H, S, D = 1, 2, 256, 64
    q = jax.random.normal(ks[0], (B, H, S, D))
    k = jax.random.normal(ks[1], (B, H, S, D))
    v = jax.random.normal(ks[2], (B, H, S, D))
    out = flash_attention_prefill(q, k, v, causal=True, window=window,
                                  block_q=64, block_k=64, interpret=True)
    ref = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_flash_prefill_noncausal():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 32))
    k = jax.random.normal(ks[1], (1, 2, 128, 32))
    v = jax.random.normal(ks[2], (1, 2, 128, 32))
    out = flash_attention_prefill(q, k, v, causal=False, block_q=64,
                                  block_k=64, interpret=True)
    ref = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_varlen(dtype):
    ks = jax.random.split(KEY, 3)
    B, H, Hkv, T, D = 4, 8, 2, 256, 64
    q = jax.random.normal(ks[0], (B, H, D), dtype)
    k = jax.random.normal(ks[1], (B, Hkv, T, D), dtype)
    v = jax.random.normal(ks[2], (B, Hkv, T, D), dtype)
    lengths = jnp.array([1, 77, 128, 256], jnp.int32)
    out = flash_attention_decode(q, k, v, lengths, block_k=64,
                                 interpret=True)
    ref = decode_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@settings(max_examples=10, deadline=None)
@given(
    s_blocks=st.integers(1, 4), d=st.sampled_from([32, 64]),
    h=st.sampled_from([1, 2, 4]), seed=st.integers(0, 2**16),
    causal=st.booleans())
def test_property_flash_matches_ref(s_blocks, d, h, seed, causal):
    S = 64 * s_blocks
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (1, h, S, d))
    k = jax.random.normal(ks[1], (1, h, S, d))
    v = jax.random.normal(ks[2], (1, h, S, d))
    out = flash_attention_prefill(q, k, v, causal=causal, block_q=64,
                                  block_k=64, interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=3e-5, rtol=3e-5)


# ===================================================================== #
# Mamba2 SSD
# ===================================================================== #
@pytest.mark.parametrize("S,chunk", [(32, 8), (64, 16), (128, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_chunked_vs_sequential(S, chunk, dtype):
    ks = jax.random.split(KEY, 4)
    B, H, P, N = 2, 3, 16, 8
    xh = (jax.random.normal(ks[0], (B, S, H, P)) * 0.5).astype(dtype)
    B_ = (jax.random.normal(ks[1], (B, S, N)) * 0.5).astype(dtype)
    C_ = (jax.random.normal(ks[2], (B, S, N)) * 0.5).astype(dtype)
    a_log = -jax.nn.softplus(jax.random.normal(ks[3], (B, S, H)))
    y, hT = ssd_chunk_scan(xh, B_, C_, a_log, chunk=chunk,
                           interpret=True)
    yr, hr = ssd_ref(xh, B_, C_, a_log)
    tol = dict(atol=3e-2, rtol=3e-2) if dtype == jnp.bfloat16 \
        else dict(atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), **tol)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(hr), **tol)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**16), chunks=st.integers(1, 4),
       h=st.integers(1, 4))
def test_property_ssd_state_carry(seed, chunks, h):
    """Chunked state must equal the sequential recurrence exactly."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    B, P, N = 1, 8, 4
    S = 16 * chunks
    xh = jax.random.normal(ks[0], (B, S, h, P)) * 0.3
    B_ = jax.random.normal(ks[1], (B, S, N)) * 0.3
    C_ = jax.random.normal(ks[2], (B, S, N)) * 0.3
    a_log = -jax.nn.softplus(jax.random.normal(ks[3], (B, S, h)))
    y, hT = ssd_chunk_scan(xh, B_, C_, a_log, chunk=16, interpret=True)
    yr, hr = ssd_ref(xh, B_, C_, a_log)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(hr),
                               atol=1e-4, rtol=1e-4)


# ===================================================================== #
# RWKV6 WKV
# ===================================================================== #
@pytest.mark.parametrize("S,chunk", [(16, 4), (64, 16), (32, 32)])
def test_wkv_chunked_vs_sequential(S, chunk):
    ks = jax.random.split(KEY, 6)
    B, H, P = 2, 2, 8
    r = jax.random.normal(ks[0], (B, S, H, P)) * 0.5
    k = jax.random.normal(ks[1], (B, S, H, P)) * 0.5
    v = jax.random.normal(ks[2], (B, S, H, P)) * 0.5
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H, P)))
    u = jax.random.normal(ks[4], (H, P)) * 0.1
    s0 = jax.random.normal(ks[5], (B, H, P, P)) * 0.1
    y, sT = wkv(r, k, v, w, u, s0, chunk=chunk, interpret=True)
    yr, sr = wkv_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(sr),
                               atol=1e-5, rtol=1e-5)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_property_wkv_decay_bounds(seed):
    """With decay w == 1 and u == 0, the state accumulates sum(k v^T)."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    B, S, H, P = 1, 8, 1, 4
    r = jax.random.normal(ks[0], (B, S, H, P))
    k = jax.random.normal(ks[1], (B, S, H, P))
    v = jax.random.normal(ks[2], (B, S, H, P))
    w = jnp.ones((B, S, H, P))
    u = jnp.zeros((H, P))
    s0 = jnp.zeros((B, H, P, P))
    _, sT = wkv(r, k, v, w, u, s0, chunk=8, interpret=True)
    expect = jnp.einsum("bshp,bshq->bhpq", k, v)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(expect),
                               atol=1e-5, rtol=1e-5)


# ===================================================================== #
# MoE grouped matmul
# ===================================================================== #
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("sizes", [
    [13, 0, 25, 12], [8, 8, 8, 8], [0, 0, 50, 0], [1, 2, 3, 4]])
def test_gmm_group_sweep(sizes, dtype):
    sizes = np.array(sizes)
    T, d, E, f, bm = int(sizes.sum()), 32, 4, 64, 8
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], (T, d), dtype)
    w = (jax.random.normal(ks[1], (E, d, f)) * 0.1).astype(dtype)
    xp, tile_gid, scatter = pad_groups(x, sizes, bm)
    out = gmm(xp, w, tile_gid, block_m=bm, block_n=32,
              interpret=True)[scatter]
    ref = gmm_ref(x, w, jnp.asarray(sizes))
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_gmm_matches_ragged_dot():
    """The kernel must agree with jax.lax.ragged_dot (the model path)."""
    sizes = np.array([10, 22, 0, 16])
    T, d, E, f = int(sizes.sum()), 16, 4, 32
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], (T, d))
    w = jax.random.normal(ks[1], (E, d, f)) * 0.1
    ragged = jax.lax.ragged_dot(x, w, jnp.asarray(sizes, jnp.int32))
    xp, tile_gid, scatter = pad_groups(x, sizes, 8)
    out = gmm(xp, w, tile_gid, block_m=8, block_n=16,
              interpret=True)[scatter]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ragged),
                               atol=1e-5, rtol=1e-5)
