"""Rollback paths of SessionManager.migrate / prefetch under receiver
exhaustion.

The two bare asserts at the end of ``SessionManager.migrate`` ("failed
to re-import unmigrated session") and ``SessionManager.prefetch``
("failed to return prefetched session to peer") are the safety net for
a receiver that cannot take a session — slots full, or (paged,
``spill=False``) block pool exhausted.  These tests drive both
rollback paths on real engines and assert the rolled-back sessions
finish decoding bit-identically to never having attempted the move.
"""
import dataclasses

import numpy as np
import pytest

import repro.configs as configs
from repro.models import model as M
from repro.serving.engine import Request, ServingEngine


def _smoke(arch):
    return dataclasses.replace(configs.get_smoke(arch), dtype="float32")


def _prompts(cfg, sizes, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
            for n in sizes]


def _reqs(prompts, max_new=6, rid0=0):
    return [Request(rid=rid0 + i, prompt=p.copy(),
                    max_new_tokens=max_new, arrival=0.0)
            for i, p in enumerate(prompts)]


def _drain(eng, t=0.0):
    while eng._any_active():
        eng.step(t)
        eng.sync(t)


@pytest.fixture(scope="module")
def setup():
    cfg = _smoke("llama3_8b")
    return cfg, M.init_params(cfg)


# ===================================================================== #
# migrate: peer cannot take — sessions re-import locally
# ===================================================================== #
def test_migrate_rolls_back_when_peer_slots_full(setup):
    cfg, params = setup
    prompts = _prompts(cfg, (4, 6), seed=7)

    ref = _reqs(prompts)
    e_ref = ServingEngine(cfg, params, slots=2, max_len=32, sync_every=2)
    e_ref.run(ref)

    mig = _reqs(prompts)
    src = ServingEngine(cfg, params, slots=2, max_len=32, sync_every=2)
    peer = ServingEngine(cfg, params, slots=1, max_len=32, sync_every=2)
    blocker = _reqs(_prompts(cfg, (5,), seed=8), max_new=12, rid0=100)
    assert peer.admit_batch(blocker, 0.0) == 1      # peer's only slot
    assert src.admit_batch(mig, 0.0) == 2
    src.step(0.0)
    src.step(0.0)
    # peer has no free slot: nothing moves, everything re-imports
    assert src.sessions.migrate(peer, 0.0) == 0
    assert sorted(r.rid for r in src.active if r is not None) == [0, 1]
    _drain(src)
    _drain(peer)
    for a, b in zip(ref, mig):
        assert a.output == b.output     # rollback was loss-free


def test_migrate_rolls_back_when_peer_pool_exhausted(setup):
    """Paged peer with a free SLOT but an exhausted block pool
    (spill=False): restore fails at reserve, the session re-imports
    locally through the migrate rollback assert."""
    cfg, params = setup
    prompts = _prompts(cfg, (4, 6), seed=7)

    ref = _reqs(prompts)
    e_ref = ServingEngine(cfg, params, slots=2, max_len=32, sync_every=2)
    e_ref.run(ref)

    mig = _reqs(prompts)
    src = ServingEngine(cfg, params, slots=2, max_len=32, sync_every=2)
    # 2 slots but a pool of exactly one 32-token session: the blocker
    # takes every block, and spill=False forbids making room
    peer = ServingEngine(cfg, params, slots=2, max_len=32, sync_every=2,
                         kv_block_tokens=8, kv_pool_blocks=4,
                         spill=False)
    blocker = _reqs(_prompts(cfg, (20,), seed=8), max_new=11, rid0=100)
    assert peer.admit_batch(blocker, 0.0) == 1
    assert peer.active.count(None) >= 1             # slot IS free
    assert src.admit_batch(mig, 0.0) == 2
    src.step(0.0)
    src.step(0.0)
    assert src.sessions.migrate(peer, 0.0) == 0     # pool said no
    assert sorted(r.rid for r in src.active if r is not None) == [0, 1]
    _drain(src)
    _drain(peer)
    for a, b in zip(ref, mig):
        assert a.output == b.output


def test_migrate_partial_move_rolls_back_the_rest(setup):
    """Peer takes exactly one of two sessions; the other re-imports
    locally.  Both finish bit-identically wherever they ended up."""
    cfg, params = setup
    prompts = _prompts(cfg, (4, 6), seed=7)

    ref = _reqs(prompts)
    e_ref = ServingEngine(cfg, params, slots=2, max_len=32, sync_every=2)
    e_ref.run(ref)

    mig = _reqs(prompts)
    src = ServingEngine(cfg, params, slots=2, max_len=32, sync_every=2)
    peer = ServingEngine(cfg, params, slots=2, max_len=32, sync_every=2)
    blocker = _reqs(_prompts(cfg, (5,), seed=8), max_new=12, rid0=100)
    assert peer.admit_batch(blocker, 0.0) == 1      # one slot left
    assert src.admit_batch(mig, 0.0) == 2
    src.step(0.0)
    src.step(0.0)
    assert src.sessions.migrate(peer, 0.0) == 1
    assert sum(1 for r in src.active if r is not None) == 1
    _drain(src)
    _drain(peer)
    for a, b in zip(ref, mig):
        assert a.output == b.output


# ===================================================================== #
# prefetch: local engine cannot take — session returns to the peer
# ===================================================================== #
def test_prefetch_returns_session_when_local_full(setup):
    cfg, params = setup
    prompts = _prompts(cfg, (5, 4), seed=9)

    ref = _reqs(prompts)
    e_ref = ServingEngine(cfg, params, slots=2, max_len=32, sync_every=2)
    e_ref.run(ref)

    far = _reqs(prompts)
    peer = ServingEngine(cfg, params, slots=2, max_len=32, sync_every=2)
    local = ServingEngine(cfg, params, slots=1, max_len=32, sync_every=2)
    blocker = _reqs(_prompts(cfg, (5,), seed=10), max_new=12, rid0=100)
    assert local.admit_batch(blocker, 0.0) == 1     # local's only slot
    assert peer.admit_batch(far, 0.0) == 2
    peer.step(0.0)
    peer.step(0.0)
    # local cannot take it: the pull fails and the session must be
    # back on the peer (the prefetch rollback assert)
    assert not local.sessions.prefetch(far[0].rid, peer, 0.0)
    assert any(r is not None and r.rid == far[0].rid
               for r in peer.active)
    _drain(peer)
    _drain(local)
    for a, b in zip(ref, far):
        assert a.output == b.output


def test_prefetch_returns_session_when_local_pool_exhausted(setup):
    cfg, params = setup
    prompts = _prompts(cfg, (5, 4), seed=9)

    ref = _reqs(prompts)
    e_ref = ServingEngine(cfg, params, slots=2, max_len=32, sync_every=2)
    e_ref.run(ref)

    far = _reqs(prompts)
    peer = ServingEngine(cfg, params, slots=2, max_len=32, sync_every=2)
    local = ServingEngine(cfg, params, slots=2, max_len=32, sync_every=2,
                          kv_block_tokens=8, kv_pool_blocks=4,
                          spill=False)
    blocker = _reqs(_prompts(cfg, (20,), seed=10), max_new=11, rid0=100)
    assert local.admit_batch(blocker, 0.0) == 1     # takes every block
    assert peer.admit_batch(far, 0.0) == 2
    peer.step(0.0)
    peer.step(0.0)
    assert not local.sessions.prefetch(far[0].rid, peer, 0.0)
    assert any(r is not None and r.rid == far[0].rid
               for r in peer.active)
    _drain(peer)
    _drain(local)
    for a, b in zip(ref, far):
        assert a.output == b.output
