"""Online monitor switching logic + discrete-event simulator invariants."""
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # collect without hypothesis (tier-1 guard)
    from _hypothesis_stub import given, settings, strategies as st

from repro.core import planner
from repro.core.costmodel import GPU_A100, GPU_L40S
from repro.core.monitor import MonitorConfig, OnlineMonitor
from repro.core.simulator import simulate_offline, simulate_online, \
    stage_tasks

from conftest import random_dag

DEVS = [GPU_A100, GPU_L40S]


# --------------------------------------------------------------------- #
def test_monitor_switches_to_throughput_under_queueing():
    mon = OnlineMonitor(MonitorConfig(window=1.0, beta=1.5))
    assert mon.policy == "latency"
    # heavy queueing: request latency 10x exec latency
    for i in range(5):
        mon.record_request(now=0.2 * i, request_latency=1.0,
                           exec_latency=0.1)
    mon.tick(1.1)
    assert mon.policy == "throughput"
    assert mon.switches == 1
    assert mon.stall_time == pytest.approx(0.030)


def test_monitor_switches_back_under_light_load():
    mon = OnlineMonitor(MonitorConfig(window=1.0, beta=1.5),
                        initial_policy="throughput")
    for i in range(5):
        mon.record_request(now=0.2 * i, request_latency=0.105,
                           exec_latency=0.1)
    mon.tick(1.1)
    assert mon.policy == "latency"


def test_monitor_no_switch_without_samples():
    mon = OnlineMonitor(MonitorConfig(window=0.1, beta=1.5))
    for t in range(20):
        mon.tick(t * 0.1)
    assert mon.switches == 0


def test_monitor_switches_only_at_window_boundary():
    """Regression: even an extreme queueing ratio mid-window must not
    switch the policy before the window closes."""
    mon = OnlineMonitor(MonitorConfig(window=1.0, beta=1.5))
    for i in range(8):
        mon.record_request(now=0.1 * (i + 1), request_latency=50.0,
                           exec_latency=0.1)      # ratio 500 >> beta
        assert mon.policy == "latency", "switched before window end"
    mon.tick(1.2)                                 # crosses 0.1 + 1.0
    assert mon.policy == "throughput"
    assert mon.switches == 1


def test_monitor_stall_time_accounting():
    """stall_time must equal switches * switch_stall exactly, across
    repeated flips."""
    cfg = MonitorConfig(window=1.0, beta=1.5, switch_stall=0.025)
    mon = OnlineMonitor(cfg)
    for k in range(6):
        ratio = 10.0 if k % 2 == 0 else 1.0       # flip every window
        t0 = float(k)
        mon.record_request(now=t0 + 0.1, request_latency=ratio * 0.1,
                           exec_latency=0.1)
        mon.tick(t0 + 1.2)
    assert mon.switches == 6
    assert mon.stall_time == pytest.approx(6 * 0.025)


def test_monitor_no_flapping_when_ratio_hovers_at_beta():
    """Regression: a ratio dithering right at beta sits inside the
    hysteresis band and must never flap."""
    import random
    rng = random.Random(0)
    mon = OnlineMonitor(MonitorConfig(window=1.0, beta=1.5,
                                      hysteresis=0.05))
    for k in range(50):
        ratio = 1.5 * (1.0 + rng.uniform(-0.04, 0.04))   # inside band
        mon.record_request(now=k + 0.5, request_latency=ratio,
                           exec_latency=1.0)
        mon.tick(k + 1.0)
    assert mon.switches == 0
    assert mon.policy == "latency"


def test_monitor_hysteresis_still_switches_outside_band():
    mon = OnlineMonitor(MonitorConfig(window=1.0, beta=1.5,
                                      hysteresis=0.05))
    mon.record_request(now=0.5, request_latency=1.6, exec_latency=1.0)
    mon.tick(1.6)                                  # 1.6 > 1.5*1.05
    assert mon.policy == "throughput"
    mon.record_request(now=2.0, request_latency=1.40, exec_latency=1.0)
    mon.tick(3.1)                                  # 1.40 < 1.5*0.95
    assert mon.policy == "latency"
    assert mon.switches == 2


def test_monitor_idle_gap_no_switch_storm():
    """A long idle gap advances the window in whole multiples without
    emitting a burst of decisions."""
    mon = OnlineMonitor(MonitorConfig(window=0.5, beta=1.5))
    mon.record_request(now=0.1, request_latency=10.0, exec_latency=0.1)
    mon.tick(100.0)                                # one switch, not 200
    assert mon.switches == 1
    assert len(mon.history) == 1


def test_monitor_tick_opens_first_window_when_idle_at_start():
    """Regression: a group that is idle from t=0 only ever sees
    tick()s.  tick() must open the first window; before the fix it
    no-opped until the first record_request, so the boundary anchored
    at the first SAMPLE and the monitor re-evaluated one full window
    late (here: no switch by t=1.05 despite a 10x queueing ratio)."""
    mon = OnlineMonitor(MonitorConfig(window=1.0, beta=1.5))
    mon.tick(0.0)                      # idle start: opens [0, 1)
    for i in range(5):
        mon.record_request(now=0.2 + 0.1 * i, request_latency=1.0,
                           exec_latency=0.1)      # ratio 10 >> beta
    mon.tick(1.05)                     # crosses the tick-opened boundary
    assert mon.policy == "throughput"
    assert mon.switches == 1


def test_monitor_history_reports_mean_group_latency():
    """Regression: record_kernel_group() samples were collected and
    silently discarded at every window close.  Each history row must
    expose their per-window mean (the paper's monitoring unit)."""
    mon = OnlineMonitor(MonitorConfig(window=1.0, beta=1.5))
    mon.record_request(now=0.1, request_latency=1.0, exec_latency=0.1)
    mon.record_kernel_group(0.004)
    mon.record_kernel_group(0.008)
    mon.tick(1.2)
    assert len(mon.history) == 1
    _, _, _, grp = mon.history[-1]
    assert grp == pytest.approx(0.006)
    # a window with no group samples reports 0.0, and the buffer from
    # the first window must not leak into it
    mon.record_request(now=1.5, request_latency=1.0, exec_latency=0.1)
    mon.tick(2.5)
    assert mon.history[-1][3] == 0.0


def test_monitor_aggressive_beta_switches_more():
    def run(beta):
        mon = OnlineMonitor(MonitorConfig(window=0.5, beta=beta))
        import random
        rng = random.Random(0)
        for i in range(200):
            t = i * 0.05
            q = 2.5 if (i // 40) % 2 == 0 else 1.01   # alternating load
            q *= rng.uniform(0.9, 1.1)
            mon.record_request(t, request_latency=q * 0.1,
                               exec_latency=0.1)
        return mon.switches
    assert run(1.1) >= run(3.0)


# --------------------------------------------------------------------- #
def _toy_plan(seed=0, n=20):
    g = random_dag(n, seed=seed)
    p = planner.plan(g, DEVS, policy="throughput", cache=False,
                     anneal_iters=500)
    return g, p


def test_sim_pipeline_beats_no_pipeline():
    g, p = _toy_plan()
    r_none = simulate_offline(g, p, DEVS, num_requests=32, pipelined=False)
    r_prio = simulate_offline(g, p, DEVS, num_requests=32)
    assert r_prio.throughput > r_none.throughput


def test_sim_priority_beats_naive():
    """Priority staggering helps on comm-heavy structured pipelines
    (paper Fig 9; benchmarks/fig9 shows it on real model graphs); on
    small random DAGs the two schedulers are within noise, so this only
    asserts priority is not materially worse."""
    g, p = _toy_plan(seed=3)
    r_naive = simulate_offline(g, p, DEVS, num_requests=48,
                               scheduling="fifo")
    r_prio = simulate_offline(g, p, DEVS, num_requests=48,
                              scheduling="priority")
    assert r_prio.throughput >= r_naive.throughput * 0.95


def test_sim_throughput_bounded_by_plan_optimum():
    """1 / max_g W_g is the steady-state ceiling; the simulator must not
    exceed it (conservation) and priority pipelining should approach it."""
    g, p = _toy_plan(seed=5, n=40)
    r = simulate_offline(g, p, DEVS, num_requests=128)
    opt = p.steady_state_throughput
    assert r.throughput <= opt * 1.001
    assert r.throughput >= opt * 0.5


def test_sim_busy_time_conservation():
    g, p = _toy_plan(seed=7)
    n_req = 16
    r = simulate_offline(g, p, DEVS, num_requests=n_req)
    tasks = stage_tasks(g, p, DEVS)
    for dev in range(2):
        expect = sum(t.compute for t in tasks if t.device == dev) * n_req
        assert r.device_busy[dev] == pytest.approx(expect, rel=1e-9)


def test_sim_latency_grows_with_rate():
    g, p = _toy_plan(seed=9)
    p_lat = planner.plan(g, DEVS, policy="latency", cache=False)
    lat_lo = simulate_online(g, {"latency": p_lat}, DEVS, rate=10.0,
                             num_requests=50).mean_latency
    lat_hi = simulate_online(g, {"latency": p_lat}, DEVS, rate=1e6,
                             num_requests=50).mean_latency
    assert lat_hi >= lat_lo


def test_sim_monitor_reduces_latency_under_bursts():
    g, p_thr = _toy_plan(seed=11, n=30)
    p_lat = planner.plan(g, DEVS, policy="latency", cache=False)
    plans = {"latency": p_lat, "throughput": p_thr}
    exec_lat = p_lat.unpipelined_latency
    rate = 2.0 / exec_lat       # heavy load relative to service time
    mon = OnlineMonitor(MonitorConfig(window=exec_lat * 20, beta=1.5))
    adaptive = simulate_online(g, plans, DEVS, rate=rate,
                               num_requests=120, monitor=mon)
    static = simulate_online(g, {"latency": p_lat}, DEVS, rate=rate,
                             num_requests=120)
    # adaptive switching must not be (much) worse than static-latency,
    # and should switch at least once under this load
    assert adaptive.switches >= 1
    assert adaptive.mean_latency <= static.mean_latency * 1.5


def test_sim_event_log_bit_identical_across_runs():
    """Determinism: identical seed + trace + plan -> bit-identical event
    log and makespan (no wall clocks, no unseeded randomness)."""
    g, p = _toy_plan(seed=13)
    r1 = simulate_offline(g, p, DEVS, num_requests=24)
    r2 = simulate_offline(g, p, DEVS, num_requests=24)
    assert r1.events, "event log must be populated"
    assert r1.events == r2.events          # tuple == is exact float ==
    assert r1.makespan == r2.makespan
    assert r1.latencies == r2.latencies

    o1 = simulate_online(g, {"latency": p}, DEVS, rate=200.0,
                         num_requests=30, seed=3)
    o2 = simulate_online(g, {"latency": p}, DEVS, rate=200.0,
                         num_requests=30, seed=3)
    assert o1.events == o2.events
    assert o1.makespan == o2.makespan
    o3 = simulate_online(g, {"latency": p}, DEVS, rate=200.0,
                         num_requests=30, seed=4)
    assert o3.makespan != o1.makespan      # the seed actually matters


def test_sim_event_log_consistent_with_busy_time():
    g, p = _toy_plan(seed=7)
    r = simulate_offline(g, p, DEVS, num_requests=8)
    for dev in range(2):
        from_log = sum(e - s for kind, d, _, s, e in r.events
                       if kind == 1 and d == dev)
        assert from_log == pytest.approx(r.device_busy[dev], rel=1e-9)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000), n_req=st.integers(1, 40))
def test_property_sim_completes_all(seed, n_req):
    g = random_dag(10, seed=seed)
    p = planner.plan(g, DEVS, policy="throughput", cache=False,
                     anneal_iters=200)
    r = simulate_offline(g, p, DEVS, num_requests=n_req)
    assert r.completed == n_req
    assert all(l >= 0 for l in r.latencies)
    assert r.makespan >= max(r.latencies) * 0.999
