"""Per-architecture smoke tests (assignment requirement): a REDUCED
config of each family runs one forward + one train step on CPU, asserting
output shapes and the absence of NaNs; serving paths (prefill + decode)
must agree with teacher forcing."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.models import model as M
from repro.train import optim

KEY = jax.random.PRNGKey(0)


def _extras(cfg, B, S):
    kw = {}
    if cfg.family == "vlm":
        kw["patch_embeds"] = jax.random.normal(
            KEY, (B, min(4, S), cfg.d_model)) * 0.02
        kw["positions3"] = jnp.broadcast_to(
            jnp.arange(S)[None, None], (3, B, S)).astype(jnp.int32)
    if cfg.family == "encdec":
        kw["enc_embeds"] = jax.random.normal(KEY, (B, 5, cfg.d_model)) \
            * 0.02
    return kw


@pytest.mark.parametrize("arch", C.ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = C.get_smoke(arch)
    params = M.init_params(cfg, KEY)
    B, S = 2, 8
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    logits = M.forward_logits(params, cfg, toks, **_extras(cfg, B, S))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", C.ARCHS)
def test_one_train_step_no_nans(arch):
    cfg = C.get_smoke(arch)
    params = M.init_params(cfg, KEY)
    ocfg = optim.AdamWConfig(warmup_steps=1, total_steps=10)
    opt = optim.init(ocfg, params)
    B, S = 2, 8
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    kw = _extras(cfg, B, S)

    def lf(p):
        return M.loss_fn(p, cfg, toks, toks, **kw)

    loss, grads = jax.value_and_grad(lf)(params)
    assert bool(jnp.isfinite(loss))
    new_params, _ = optim.apply(ocfg, grads, opt, params)
    for leaf in jax.tree_util.tree_leaves(new_params):
        assert bool(jnp.isfinite(leaf.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", C.ASSIGNED)
def test_prefill_decode_consistency(arch):
    """Serving path == teacher forcing at every decoded position."""
    cfg = dataclasses.replace(C.get_smoke(arch), dtype="float32")
    params = M.init_params(cfg, KEY)
    B, S, T = 2, 6, 9
    toks = jax.random.randint(KEY, (B, T), 0, cfg.vocab_size)
    kw = _extras(cfg, B, T)
    full = M.forward_logits(params, cfg, toks, **kw)

    enc_len = 5 if cfg.family == "encdec" else None
    cache = M.init_cache(cfg, B, max_len=T, enc_len=enc_len)
    pre_kw = dict(kw)
    if cfg.family == "vlm":
        pre_kw["positions3"] = kw["positions3"][:, :, :S]
    lg, cache = M.prefill(params, cfg, toks[:, :S], cache, **pre_kw)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, S - 1]),
                               atol=2e-4, rtol=2e-4)
    for t in range(S, T):
        pos = jnp.full((B,), t, dtype=jnp.int32)
        dkw = {}
        if cfg.family == "vlm":
            dkw["positions3"] = kw["positions3"][:, :, t:t + 1]
        lg, cache = M.decode_step(params, cfg, toks[:, t:t + 1], cache,
                                  pos, **dkw)
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(full[:, t]),
                                   atol=2e-4, rtol=2e-4)


def test_param_counts_match_published_scale():
    """Full configs should land near their nameplate sizes."""
    expect = {
        "mixtral_8x7b": (45e9, 49e9),     # 46.7B total
        "granite_8b": (7e9, 9e9),
        "qwen2_5_14b": (13e9, 16e9),
        "gemma_2b": (2e9, 3.2e9),
        "qwen3_1_7b": (1.4e9, 2.4e9),
        "rwkv6_3b": (2.5e9, 3.8e9),
        "llama3_8b": (7e9, 9e9),
        "dbrx_132b": (125e9, 140e9),
    }
    for arch, (lo, hi) in expect.items():
        n = C.get(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n / 1e9:.2f}B not in " \
                              f"[{lo / 1e9}, {hi / 1e9}]"


def test_moe_active_params_smaller():
    cfg = C.get("mixtral_8x7b")
    assert cfg.active_param_count() < cfg.param_count() * 0.4


@pytest.mark.parametrize("arch", ["mixtral_8x7b", "gpt_oss_20b"])
def test_moe_impls_agree(arch):
    """ragged_dot path == dense-einsum fallback."""
    cfg = dataclasses.replace(C.get_smoke(arch), dtype="float32")
    from repro.models import layers as L
    p = L.init_moe(cfg, KEY)
    x = jax.random.normal(KEY, (2, 4, cfg.d_model)) * 0.5
    y_ragged = L.moe(p, x, cfg)
    y_dense = L.moe(p, x, dataclasses.replace(cfg,
                                              moe_impl="dense_einsum"))
    np.testing.assert_allclose(np.asarray(y_ragged), np.asarray(y_dense),
                               atol=1e-4, rtol=1e-4)
