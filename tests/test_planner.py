"""Planner: exactness of min-cut, quality of makespan, folding, elasticity."""
import dataclasses

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # collect without hypothesis (tier-1 guard)
    from _hypothesis_stub import given, settings, strategies as st

from repro.core import bnb, planner
from repro.core.costmodel import (GPU_A100, GPU_H100, GPU_L40S, TPU_V5E,
                                  TPU_V5P)
from repro.core.graph import KernelGraph, KernelNode
from repro.core.makespan import MakespanProblem, fold_and_solve, \
    solve_throughput

from conftest import random_dag

DEVS2 = [GPU_A100, GPU_L40S]
DEVS3 = [GPU_A100, GPU_L40S, GPU_H100]


# --------------------------------------------------------------------- #
@pytest.mark.parametrize("seed", range(8))
def test_mincut_matches_exact_latency(seed):
    g = random_dag(10, seed=seed, pin_frac=0.2)
    p = planner.plan(g, DEVS2, policy="latency", cache=False)
    _, w_exact = bnb.solve_exact(g, DEVS2, objective="latency")
    assert p.objective == pytest.approx(w_exact, rel=1e-9)


@pytest.mark.parametrize("seed", range(6))
def test_alpha_expansion_matches_exact_3dev(seed):
    g = random_dag(9, seed=seed, pin_frac=0.0)
    p = planner.plan(g, DEVS3, policy="latency", cache=False)
    _, w_exact = bnb.solve_exact(g, DEVS3, objective="latency")
    assert p.objective <= w_exact * 1.001


@pytest.mark.parametrize("seed", range(8))
def test_throughput_heuristic_near_optimal(seed):
    g = random_dag(10, seed=seed, pin_frac=0.2)
    p = planner.plan(g, DEVS2, policy="throughput", cache=False,
                     anneal_iters=2000)
    _, w_exact = bnb.solve_exact(g, DEVS2, objective="throughput")
    assert p.objective <= w_exact * 1.05
    assert p.objective >= w_exact * (1 - 1e-9)      # never below optimum


def test_pins_are_respected():
    g = random_dag(12, seed=3, pin_frac=0.4)
    for policy in ("latency", "throughput"):
        p = planner.plan(g, DEVS2, policy=policy, cache=False)
        for n in g.nodes:
            if n.pinned is not None:
                assert p.labels[n.idx] == n.pinned


def test_every_kernel_assigned_exactly_once():
    g = random_dag(30, seed=5)
    p = planner.plan(g, DEVS2, policy="throughput", cache=False)
    assert len(p.labels) == len(g)
    assert set(p.labels) <= {0, 1}
    covered = sorted(k for s in p.stages for k in s.node_ids)
    assert covered == list(range(len(g)))


def test_throughput_objective_definition():
    """W = max_g max(T_g, M_g), the paper's pipelined stage time."""
    g = random_dag(15, seed=7)
    prob = MakespanProblem(g, DEVS2)
    x = [k % 2 for k in range(len(g))]
    T, M = prob.loads(x)
    assert prob.objective(x) == pytest.approx(
        max(max(T[0], M[0]), max(T[1], M[1])))


def test_homogeneous_fallback_no_cut():
    """With a near-zero interconnect the latency policy must degenerate
    to single-device execution (paper §V-D robustness)."""
    g = random_dag(14, seed=2)
    p = planner.plan(g, DEVS2, policy="latency", cache=False,
                     bw_override=1e3)        # 1 KB/s: transfers hopeless
    assert p.cut_edges == 0
    assert len(set(p.labels)) == 1


def test_bandwidth_sensitivity_monotone_cut():
    """Higher interconnect bandwidth must never reduce planned cut size
    to the point of worse objective (robustness, paper Fig 11a)."""
    g = random_dag(25, seed=11)
    objs = []
    for bw in (1e6, 1e9, 25e9, 200e9):
        p = planner.plan(g, DEVS2, policy="latency", cache=False,
                         bw_override=bw)
        objs.append(p.objective)
    assert objs == sorted(objs, reverse=True), \
        "latency objective must improve (or hold) with more bandwidth"


def test_layer_folding_quality():
    """Folded solve must be close to the direct solve on repeated layers."""
    base = random_dag(6, seed=4)
    nodes, edges = [], {}
    L = 6
    for l in range(L):
        off = l * len(base)
        for n in base.nodes:
            nodes.append(dataclasses.replace(n, idx=off + n.idx, layer=l))
        for (i, j), b in base.edges.items():
            edges[(off + i, off + j)] = b
        if l > 0:
            edges[(off - 1, off)] = 1e5
    g = KernelGraph(nodes, edges, name="stack")
    g.validate()

    direct, w_direct = solve_throughput(g, DEVS2, anneal_iters=3000)
    folded, w_folded = fold_and_solve(g, DEVS2, solve_throughput,
                                      anneal_iters=3000)
    # Folding trades solution quality for solve time (paper §V-D uses it
    # purely to shrink the MILP); allow a 2x gap on adversarial toys.
    assert w_folded <= w_direct * 2.0
    prob = MakespanProblem(g, DEVS2)
    assert prob.objective(folded) == pytest.approx(w_folded)


def test_folding_reduces_solver_time():
    base = random_dag(8, seed=9)
    nodes, edges = [], {}
    for l in range(24):
        off = l * len(base)
        for n in base.nodes:
            nodes.append(dataclasses.replace(n, idx=off + n.idx, layer=l))
        for (i, j), b in base.edges.items():
            edges[(off + i, off + j)] = b
        if l > 0:
            edges[(off - 1, off)] = 1e5
    g = KernelGraph(nodes, edges)
    p_fold = planner.plan(g, DEVS2, policy="throughput", cache=False,
                          use_folding=True)
    p_full = planner.plan(g, DEVS2, policy="throughput", cache=False,
                          use_folding=False, anneal_iters=1000)
    assert p_fold.solve_seconds < p_full.solve_seconds


def test_elastic_replan_on_device_loss():
    g = random_dag(20, seed=6, pin_frac=0.2, num_devices=3)
    p3 = planner.plan(g, DEVS3, policy="throughput", cache=False)
    p2 = planner.replan_on_failure(g, DEVS3, lost={2}, old=p3, cache=False)
    assert set(p2.labels) <= {0, 1}
    assert len(p2.labels) == len(g)


def test_plan_cache_hit():
    g = random_dag(15, seed=8)
    p1 = planner.plan(g, DEVS2, policy="throughput")
    p2 = planner.plan(g, DEVS2, policy="throughput")
    assert p1 is p2


def test_tpu_pair_heterogeneity_is_exploited():
    """On a v5p+v5e pair, compute-heavy kernels should prefer v5p and the
    plan should beat all-on-one-device for a mixed workload."""
    nodes = []
    for i in range(16):
        if i % 2 == 0:        # compute-bound GEMM
            nodes.append(KernelNode(i, "dot_general", flops=2e11,
                                    bytes_accessed=1e8, out_bytes=1e6,
                                    eqn_ids=(i,)))
        else:                 # memory-bound elementwise
            nodes.append(KernelNode(i, "add", flops=1e8,
                                    bytes_accessed=4e9, out_bytes=1e6,
                                    eqn_ids=(i,)))
    edges = {(i, i + 1): 1e5 for i in range(15)}
    g = KernelGraph(nodes, edges)
    devs = [TPU_V5P, TPU_V5E]
    p = planner.plan(g, devs, policy="throughput", cache=False)
    from repro.core.costmodel import graph_time_on
    t_single = min(graph_time_on(g, d) for d in devs)
    assert p.objective < t_single, "disaggregation must beat single device"


# --------------------------------------------------------------------- #
@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(4, 24),
       policy=st.sampled_from(["latency", "throughput"]),
       pin_frac=st.sampled_from([0.0, 0.25]))
def test_property_plan_invariants(seed, n, policy, pin_frac):
    """Random DAG -> core plan invariants, any policy:

      1. every kernel is placed exactly once (labels AND stages),
      2. stages are MAXIMAL topological same-device runs — consecutive
         stages differ in device and stage node ranges are contiguous,
      3. send/recv bytes balance: every byte sent across a cut edge is
         received exactly once, and both equal the plan's cut_bytes.
    """
    g = random_dag(n, seed=seed, pin_frac=pin_frac)
    p = planner.plan(g, DEVS2, policy=policy, cache=False,
                     anneal_iters=300)
    # (1) exactly-once placement
    assert len(p.labels) == n
    assert set(p.labels) <= {0, 1}
    covered = sorted(k for s in p.stages for k in s.node_ids)
    assert covered == list(range(n))
    # (2) maximal topological same-device runs
    for s in p.stages:
        assert all(p.labels[k] == s.device for k in s.node_ids)
        assert list(s.node_ids) == list(range(min(s.node_ids),
                                               max(s.node_ids) + 1))
    for a, b in zip(p.stages, p.stages[1:]):
        assert a.device != b.device, "adjacent same-device stages " \
            "violate maximality"
        assert max(a.node_ids) < min(b.node_ids)
    # (3) cut-edge byte conservation
    total_send = sum(s.send_bytes for s in p.stages)
    total_recv = sum(s.recv_bytes for s in p.stages)
    cut = sum(b for (i, j), b in g.edges.items()
              if p.labels[i] != p.labels[j])
    assert total_send == pytest.approx(total_recv)
    assert total_send == pytest.approx(cut)
    assert p.cut_bytes == pytest.approx(cut)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(4, 16))
def test_property_mincut_no_worse_than_single_device(seed, n):
    """The latency solver may always place everything on one device, so
    its objective can never exceed the best single-device time."""
    from repro.core.costmodel import graph_time_on
    g = random_dag(n, seed=seed)
    p = planner.plan(g, DEVS2, policy="latency", cache=False)
    best_single = min(graph_time_on(g, d) for d in DEVS2)
    assert p.objective <= best_single * (1 + 1e-9)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(4, 16))
def test_property_placement_valid_and_bounded(seed, n):
    """Any plan: valid labels, pins honored, objective >= trivial LBs."""
    g = random_dag(n, seed=seed, pin_frac=0.25)
    p = planner.plan(g, DEVS2, policy="throughput", cache=False,
                     anneal_iters=300)
    assert len(p.labels) == n
    for nd in g.nodes:
        if nd.pinned is not None:
            assert p.labels[nd.idx] == nd.pinned
    prob = MakespanProblem(g, DEVS2)
    lb = sum(min(prob.t[k]) for k in range(n)) / 2
    assert p.objective >= lb * (1 - 1e-9)
