"""Disaggregated executor: bit-faithful reproduction of the traced fn."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import marker, planner
from repro.core.analyzer import analyze, pin_nodes
from repro.core.costmodel import GPU_A100, GPU_L40S
from repro.core.executor import build_executable
from repro.core.pipeline import PipelinedRunner

DEVS = [GPU_A100, GPU_L40S]


def _check(fn, *args, policy="throughput", rtol=1e-6, state_argnums=()):
    tg = analyze(fn, *args, state_argnums=state_argnums)
    p = planner.plan(tg.graph, DEVS, policy=policy, cache=False)
    exe = build_executable(tg, p)
    got = exe(*args)
    want = jax.jit(fn)(*args)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=rtol, atol=1e-6),
        got, want)
    return exe, p


def test_mlp_both_policies(small_mlp):
    fn, args = small_mlp
    for policy in ("throughput", "latency"):
        _check(fn, *args, policy=policy)


def test_multi_output_function():
    def f(x, w):
        h = x @ w
        return jnp.tanh(h), h.sum(), {"logits": h * 2}

    _check(f, jnp.arange(12.0).reshape(3, 4), jnp.ones((4, 4)))


def test_literal_and_const_handling():
    c = jnp.linspace(0, 1, 8)

    def f(x):
        return x * 2.0 + c          # c closes over -> constvar

    _check(f, jnp.ones((8,)))


def test_kwargs_and_pytrees():
    def f(x, params):
        return jax.nn.relu(x @ params["w"]) + params["b"]

    x = jnp.ones((4, 8))
    params = {"w": jnp.full((8, 8), 0.1), "b": jnp.ones((8,))}
    _check(f, x, params)


def test_stateful_step_with_pinning():
    """KV-cache-like carried state: pinned kernels keep the cache home."""
    def step(kv, x):
        score = (kv * x).sum()
        new_kv = jnp.roll(kv, 1).at[0].set(score)
        return new_kv, jnp.tanh(score)

    kv = jnp.arange(16.0)
    x = jnp.ones((16,))
    tg = analyze(step, kv, x, state_argnums=(0,))
    g = pin_nodes(tg.graph, tg.state_readers | tg.state_writers, 0)
    tg = tg.with_graph(g)
    p = planner.plan(g, DEVS, policy="throughput", cache=False)
    for nid in tg.state_readers | tg.state_writers:
        assert p.labels[nid] == 0
    exe = build_executable(tg, p)
    new_kv, out = exe(kv, x)
    ref_kv, ref_out = jax.jit(step)(kv, x)
    np.testing.assert_allclose(np.asarray(new_kv), np.asarray(ref_kv))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out))


def test_iterated_state_threading():
    """Run the disaggregated step repeatedly, feeding state back."""
    def step(s, x):
        return s * 0.9 + x, s.sum()

    s = jnp.ones((8,))
    x = jnp.full((8,), 0.5)
    tg = analyze(step, s, x, state_argnums=(0,))
    p = planner.plan(tg.graph, DEVS, cache=False)
    exe = build_executable(tg, p)
    s_ref = s
    for _ in range(5):
        s, out = exe(s, x)
        s_ref, out_ref = jax.jit(step)(s_ref, x)
        np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                                   rtol=1e-6)


def test_scan_inside_function():
    def f(x):
        def body(c, _):
            return jnp.tanh(c @ c), c.sum()
        c, ys = jax.lax.scan(body, x, None, length=4)
        return c, ys

    _check(f, jnp.eye(6) * 0.5)


def test_markers_execute_as_identity(small_mlp):
    fn, args = small_mlp
    # direct (non-disaggregated) jit must also work with markers inline
    out = jax.jit(fn)(*args)
    assert out.shape == args[0].shape


def test_stage_device_assignment_matches_plan(small_mlp):
    fn, args = small_mlp
    tg = analyze(fn, *args)
    p = planner.plan(tg.graph, DEVS, cache=False)
    exe = build_executable(tg, p)
    for cs in exe.stages:
        assert cs.stage.device == p.labels[cs.stage.node_ids[0]]
        for k in cs.stage.node_ids:
            assert p.labels[k] == cs.stage.device


def test_grad_through_marked_model(small_mlp):
    """Markers must be transparent to AD (training-path compatibility)."""
    fn, (x, params) = small_mlp

    def loss(params, x):
        return fn(x, params).sum()

    g = jax.grad(loss)(params, x)
    assert all(jnp.isfinite(w).all() for pair in g for w in pair)


# --------------------------------------------------------------------- #
def test_pipelined_runner_outputs_match(small_mlp):
    fn, (x, params) = small_mlp
    tg = analyze(fn, x, params)
    p = planner.plan(tg.graph, DEVS, cache=False)
    exe = build_executable(tg, p)
    for sched in ("priority", "naive"):
        runner = PipelinedRunner(exe, max_inflight=3, scheduling=sched)
        reqs = [((x + i, params), {}) for i in range(5)]
        outs, stats = runner.run(reqs)
        assert stats.completed == 5
        for i, o in enumerate(outs):
            np.testing.assert_allclose(
                np.asarray(o), np.asarray(jax.jit(fn)(x + i, params)),
                rtol=1e-5)


def test_straggler_reexecution_path(small_mlp):
    """Deadline of 0 forces the straggler path on every stage; the result
    must still be correct (pure stages are idempotent)."""
    fn, (x, params) = small_mlp
    tg = analyze(fn, x, params)
    p = planner.plan(tg.graph, DEVS, cache=False)
    exe = build_executable(tg, p)
    runner = PipelinedRunner(exe, max_inflight=2,
                             straggler_deadline=1e-9,
                             fallback_device=jax.devices()[0])
    outs, stats = runner.run([((x, params), {})])
    assert stats.straggler_reexecs > 0
    np.testing.assert_allclose(np.asarray(outs[0]),
                               np.asarray(jax.jit(fn)(x, params)),
                               rtol=1e-5)
