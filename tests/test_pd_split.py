"""Phase-split (prefill/decode) serving across replica groups.

Covers the PD-disaggregation acceptance criteria:
  * the KV-transfer edge is a first-class DES event whose time lands in
    TTFT, and phase-split replays are bit-deterministic,
  * ``export_kv``/``import_kv`` round-trip a request between two real
    engines with decode bit-identical to a single-engine run, for all
    four kernel families,
  * rate matching bounds the decode pool's resident-KV queue (decode
    saturation throttles prefill admission),
  * SLO admission control sheds doomed requests and goodput is
    reported next to throughput,
  * phase-split routing beats colocated JSED on a heterogeneous mix
    (the benchmark gate, at test scale).
"""
import dataclasses

import numpy as np
import pytest

from conftest import random_dag
import repro.configs as configs
from repro.core.monitor import MonitorConfig
from repro.core.simulator import (KV_TRANSFER, ClusterRequest,
                                  Interconnect)
from repro.models import model as M
from repro.serving.cluster import TesseraCluster
from repro.serving.engine import Request, ServingEngine
from repro.serving.router import JSEDRouter, PDRouter, make_router
from repro.serving.workload import assign_slos, poisson_trace

HET_GROUPS = [["h100", "rtxpro6000"], ["a100", "l40s"],
              ["a100", "l40s"], ["a100", "l40s"]]


def pd_dag(n: int = 24, seed: int = 2, decode_weight: float = 8.0):
    """Random DAG whose first half is the prefill phase and second half
    the (heavier, repeated) decode phase — the shape request_graph
    produces from real models."""
    g = random_dag(n, seed=seed)
    nodes = []
    for node in g.nodes:
        if node.idx < n // 2:
            nodes.append(dataclasses.replace(node, phase="prefill"))
        else:
            nodes.append(dataclasses.replace(
                node, phase="decode",
                flops=node.flops * decode_weight,
                bytes_accessed=node.bytes_accessed * decode_weight))
    g2 = type(g)(nodes, dict(g.edges), name=g.name + ".pd")
    g2.validate()
    return g2


@pytest.fixture(scope="module")
def pd_cluster():
    return TesseraCluster(pd_dag(), HET_GROUPS,
                          base_prompt=1024, base_output=128,
                          anneal_iters=300,
                          monitor_cfg=MonitorConfig(window=0.010),
                          model_cfg=configs.get("llama3_8b"))


def stable_rate(cluster) -> float:
    """A rate the colocated router can serve without divergence."""
    sat = cluster.simulate(poisson_trace(10 * cluster.capacity, 60,
                                         seed=3),
                           JSEDRouter()).throughput
    return 0.7 * sat


# ===================================================================== #
# Phase decomposition + decode_only admission (DES level)
# ===================================================================== #
def test_phase_service_decomposes(pd_cluster):
    """prefill-phase + decode-phase service == colocated service: the
    unit split loses no work."""
    probe = ClusterRequest(rid=0, arrival=0.0, scale_prompt=1.3,
                           scale_output=0.7)
    for rep in pd_cluster.build_replicas():
        tp = rep.predicted_phase_service(probe, "prefill")
        td = rep.predicted_phase_service(probe, "decode")
        assert tp > 0 and td > 0
        assert tp + td == pytest.approx(rep.predicted_service(probe))


def test_decode_only_admission_starts_after_kv(pd_cluster):
    """A decode_only submission must not start before the imported KV
    arrives (not_before), and must schedule no prefill-only units."""
    rep = pd_cluster.build_replicas()[0]
    req = ClusterRequest(rid=7, arrival=0.0)
    events = []
    finish = rep.submit(req, events, phase="decode", not_before=0.5)
    assert finish > 0.5
    assert events and all(t0 >= 0.5 for (_, _, _, _, t0, _) in events)
    # decode phase runs strictly less work than the full request
    rep2 = pd_cluster.build_replicas()[0]
    full = rep2.submit(ClusterRequest(rid=8, arrival=0.0))
    assert finish - 0.5 < full


def test_ttft_includes_kv_transfer_time(pd_cluster):
    """The transfer edge is in the event log and its duration (bytes /
    fabric bw + latency) is part of TTFT."""
    tr = [dataclasses.replace(r, session=None)
          for r in poisson_trace(5.0, 1, seed=0)]
    creq = pd_cluster.to_cluster_request(tr[0])
    assert creq.kv_bytes > 0
    router = PDRouter(prefill_pool=[0], decode_pool=[1])

    def run(bw):
        ic = Interconnect(default_bw=bw, base_latency=1e-5)
        pd_cluster.interconnect = ic
        return pd_cluster.simulate_pd(tr, router), ic

    try:
        res_fast, ic_fast = run(100e9)
        res_slow, ic_slow = run(1e9)
    finally:
        pd_cluster.interconnect = Interconnect()
    for res, ic in ((res_fast, ic_fast), (res_slow, ic_slow)):
        xfer = [e for e in res.events if e[2] == KV_TRANSFER]
        assert len(xfer) == 1
        dst, rid, kind, src, t0, t1 = xfer[0]
        assert (dst, src) == (1, 0)
        assert t1 - t0 == pytest.approx(
            ic.transfer_time(creq.kv_bytes, 0, 1))
        # first token cannot precede KV arrival: TTFT includes transfer
        assert res.ttfts[0] == pytest.approx(t1 - creq.arrival)
    slow_delta = ic_slow.transfer_time(creq.kv_bytes, 0, 1) \
        - ic_fast.transfer_time(creq.kv_bytes, 0, 1)
    assert res_slow.ttfts[0] - res_fast.ttfts[0] == \
        pytest.approx(slow_delta)
    assert res_slow.transfer_seconds > res_fast.transfer_seconds


def test_pd_event_log_deterministic(pd_cluster):
    tr = assign_slos(poisson_trace(stable_rate(pd_cluster), 80, seed=11),
                     base=5.0, ttft=0.5)
    r1 = pd_cluster.simulate_pd(tr, PDRouter())
    r2 = pd_cluster.simulate_pd(tr, PDRouter())
    assert r1.events == r2.events
    assert r1.latencies == r2.latencies
    assert r1.ttfts == r2.ttfts
    assert r1.makespan == r2.makespan
    assert any(e[2] == KV_TRANSFER for e in r1.events)


def test_pd_completes_all_and_counts_once(pd_cluster):
    """A split request completes exactly once (on its decode group)."""
    tr = poisson_trace(stable_rate(pd_cluster), 60, seed=5)
    res = pd_cluster.simulate_pd(
        tr, PDRouter(prefill_pool=[0], decode_pool=[1, 2, 3]))
    assert res.completed == 60
    assert sum(res.per_replica_completed) == 60
    assert res.per_replica_completed[0] == 0      # prefill-only group
    assert res.transfers == 60
    assert all(a in (1, 2, 3) for a in res.assignments)


# ===================================================================== #
# PDRouter: classification + rate matching
# ===================================================================== #
def test_pd_router_classifies_disjoint_pools(pd_cluster):
    router = PDRouter(prefill_frac=0.25)
    pre, dec = router.pools(pd_cluster.build_replicas())
    assert pre and dec
    assert not set(pre) & set(dec)
    assert sorted(pre + dec) == [0, 1, 2, 3]
    # single-replica cluster degenerates to colocated routing
    single = PDRouter().pools(pd_cluster.build_replicas()[:1])
    assert single == ([0], [0])


def test_pd_router_registry():
    assert isinstance(make_router("pd_split"), PDRouter)


def test_rate_matching_bounds_kv_queue(pd_cluster):
    """Decode-pool saturation must throttle prefill admission: the
    throttled router's resident-KV peak stays bounded as the trace
    grows, the unthrottled one's grows without bound.  The decode pool
    is a single group so decode (not prefill) is the saturated side —
    exactly the case rate matching exists for.  Monitors are disabled
    to isolate admission control from policy adaptation (the replica
    plans come from the planner's cache, so this cluster is cheap)."""
    cluster = TesseraCluster(pd_dag(), HET_GROUPS,
                             base_prompt=1024, base_output=128,
                             anneal_iters=300, monitor_cfg=None,
                             model_cfg=configs.get("llama3_8b"))
    rate = 2.0 * stable_rate(cluster)

    def peak(max_kv_lag, n):
        tr = poisson_trace(rate, n, seed=13)
        router = PDRouter(prefill_pool=[0], decode_pool=[1],
                          max_kv_lag=max_kv_lag)
        return cluster.simulate_pd(tr, router).peak_kv_bytes

    unthrottled_1x, unthrottled_4x = (peak(float("inf"), n)
                                      for n in (100, 400))
    throttled_1x, throttled_4x = (peak(0.2, n) for n in (100, 400))
    assert throttled_1x < unthrottled_1x
    # unbounded: peak keeps growing with the trace
    assert unthrottled_4x > 2.0 * unthrottled_1x
    # bounded: 4x the trace leaves the peak unchanged (the admission
    # governor reached its steady state)
    assert throttled_4x == pytest.approx(throttled_1x, rel=0.1)


def test_rate_matching_delays_admission(pd_cluster):
    """The rate-matched decision carries admit_at > now when the decode
    group is backlogged."""
    router = PDRouter(prefill_pool=[0], decode_pool=[1],
                      max_kv_lag=0.05)
    replicas = pd_cluster.build_replicas()
    req = ClusterRequest(rid=0, arrival=0.0)
    for _ in range(10):                        # saturate decode group
        replicas[1].submit(ClusterRequest(rid=99, arrival=0.0))
    p, d, admit_at = router.route(req, replicas, 0.0)
    assert (p, d) == (0, 1)
    assert admit_at == pytest.approx(
        replicas[1].backlog(0.0) - 0.05)
    assert admit_at > 0.0


# ===================================================================== #
# Admission control + goodput
# ===================================================================== #
def test_slo_shedding_under_overload(pd_cluster):
    rate = 10.0 * stable_rate(pd_cluster)
    tr = assign_slos(poisson_trace(rate, 200, seed=7), base=0.1)
    keep = pd_cluster.simulate(tr, JSEDRouter())
    shed = pd_cluster.simulate(tr, JSEDRouter(slo_shed=True))
    assert keep.shed == 0
    assert shed.shed > 0
    assert shed.completed + shed.shed == 200
    assert shed.assignments.count(-1) == shed.shed
    # shedding doomed requests must not reduce goodput
    assert shed.goodput >= keep.goodput
    assert len(shed.latencies) == shed.completed


def test_goodput_counts_both_slo_components(pd_cluster):
    tr = poisson_trace(stable_rate(pd_cluster), 40, seed=9)
    loose = assign_slos(tr, base=1e9, ttft=1e9)
    res = pd_cluster.simulate(loose, JSEDRouter())
    assert res.slo_ok == res.completed
    assert res.goodput == pytest.approx(res.throughput)
    tight = assign_slos(tr, base=1e9, ttft=1e-9)   # impossible TTFT
    res2 = pd_cluster.simulate(tight, JSEDRouter())
    assert res2.slo_ok == 0
    assert res2.goodput == 0.0


def test_phase_split_beats_colocated_on_hetero_mix(pd_cluster):
    """The acceptance-criterion comparison at test scale: at stable
    load with interactivity SLOs, phase-split must win goodput and
    TTFT while keeping throughput."""
    pd_cluster.interconnect = Interconnect(default_bw=100e9)
    tr = assign_slos(poisson_trace(stable_rate(pd_cluster), 150, seed=17),
                     base=8.0, per_output_token=0.02, ttft=0.5)
    co = pd_cluster.simulate(tr, JSEDRouter())
    pd = pd_cluster.simulate_pd(
        tr, PDRouter(prefill_pool=[0], decode_pool=[1, 2, 3],
                     max_kv_lag=1.0))
    assert pd.mean_ttft < co.mean_ttft
    assert pd.goodput >= co.goodput
    assert pd.throughput > 0.9 * co.throughput


# ===================================================================== #
# Real-engine state handoff: export_kv / import_kv
# ===================================================================== #
ARCHS = ("llama3_8b", "gpt_oss_20b", "rwkv6_3b", "zamba2_7b")


def _smoke(arch):
    return dataclasses.replace(configs.get_smoke(arch), dtype="float32")


@pytest.mark.parametrize("arch", ARCHS)
def test_handoff_bit_identical_to_single_engine(arch):
    """prefill on engine P -> export -> import -> decode on engine D
    must produce the same greedy tokens as one engine doing both, for
    every kernel family (dense / moe / ssm / hybrid)."""
    cfg = _smoke(arch)
    params = M.init_params(cfg)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (6, 3)]

    singles = [Request(rid=i, prompt=p.copy(), max_new_tokens=6,
                       arrival=0.0) for i, p in enumerate(prompts)]
    ref = ServingEngine(cfg, params, slots=2, max_len=32, sync_every=2)
    ref.run(singles)

    splits = [Request(rid=i, prompt=p.copy(), max_new_tokens=6,
                      arrival=0.0) for i, p in enumerate(prompts)]
    pre = ServingEngine(cfg, params, slots=2, max_len=32)
    dec = ServingEngine(cfg, params, slots=2, max_len=32, sync_every=2)
    for req in splits:
        h = pre.prefill_handoff(req, 0.0)
        assert h["kv_bytes"] > 0
        assert dec.admit_handoff(req, h, 0.0)
    while dec._any_active():
        dec.step(0.0)
    dec.sync(0.0)
    assert dec.stats.prefill_batches == 0          # decode_only engine
    assert dec.stats.completed == len(splits)
    assert [r.output for r in splits] == [r.output for r in singles]


def test_export_import_round_trips_cache_slot():
    """Model-level inverse property on a freshly prefixed cache."""
    cfg = _smoke("llama3_8b")
    params = M.init_params(cfg)
    cache = M.init_cache(cfg, 3, 16)
    import jax.numpy as jnp
    toks = jnp.arange(12, dtype=jnp.int32).reshape(3, 4) % cfg.vocab_size
    _, cache = M.prefill(params, cfg, toks, cache)
    state = M.export_kv(cfg, cache, 1, 4)
    assert M.kv_state_bytes(state) > 0
    blank = M.init_cache(cfg, 2, 16)
    filled = M.import_kv(cfg, blank, 0, state)
    np.testing.assert_array_equal(
        np.asarray(filled["kv"]["k"][:, 0, :4]),
        np.asarray(cache["kv"]["k"][:, 1, :4]))
    np.testing.assert_array_equal(
        np.asarray(filled["kv"]["v"][:, 0, :4]),
        np.asarray(cache["kv"]["v"][:, 1, :4]))


def test_prefill_handoff_finishes_one_token_requests():
    """max_new_tokens=1 completes at prefill; the handoff is marked
    done (nothing to ship) and a decode engine rejects it loudly —
    a caller retrying it until admission would livelock."""
    cfg = _smoke("llama3_8b")
    params = M.init_params(cfg)
    rng = np.random.default_rng(3)
    req = Request(rid=0, prompt=rng.integers(
        0, cfg.vocab_size, size=4).astype(np.int32), max_new_tokens=1)
    pre = ServingEngine(cfg, params, slots=1, max_len=16)
    h = pre.prefill_handoff(req, 0.0)
    assert h["done"]
    assert h["kv_bytes"] == 0 and h["state"] is None
    assert pre.stats.completed == 1
    assert len(req.output) == 1
    dec = ServingEngine(cfg, params, slots=1, max_len=16)
    with pytest.raises(ValueError, match="finished at prefill"):
        dec.admit_handoff(req, h, 0.0)


def test_handoff_ttft_stamped_at_decode_admission():
    """TTFT accounting matches the simulator's KV-transfer edge: the
    first token streams only once the state lands on the decode
    engine, so admit_handoff (not prefill_handoff) stamps it."""
    cfg = _smoke("llama3_8b")
    params = M.init_params(cfg)
    rng = np.random.default_rng(5)
    req = Request(rid=0, prompt=rng.integers(
        0, cfg.vocab_size, size=5).astype(np.int32), max_new_tokens=4)
    pre = ServingEngine(cfg, params, slots=1, max_len=16)
    h = pre.prefill_handoff(req, now=1.0)
    assert req.ttft == -1.0                  # not stamped yet
    dec = ServingEngine(cfg, params, slots=1, max_len=16)
    assert dec.admit_handoff(req, h, now=3.5)
    assert req.ttft == 3.5


# ===================================================================== #
# Workload SLO helper + kv size model
# ===================================================================== #
def test_assign_slos_sizes_with_output():
    tr = poisson_trace(10.0, 20, seed=0)
    slos = assign_slos(tr, base=1.0, per_output_token=0.01, ttft=0.25)
    for orig, req in zip(tr, slos):
        assert req.slo == pytest.approx(1.0 + 0.01 * orig.output_tokens)
        assert req.slo_ttft == 0.25
        assert (req.rid, req.arrival) == (orig.rid, orig.arrival)


def test_kv_bytes_matches_config(pd_cluster):
    cfg = configs.get("llama3_8b")
    want = (2 * cfg.num_layers * cfg.num_kv_heads * cfg.head_dim
            * cfg.jnp_dtype.itemsize * 1000)
    assert pd_cluster.kv_bytes(1000) == pytest.approx(want)


@pytest.mark.parametrize("arch", ("llama3_8b", "rwkv6_3b", "zamba2_7b"))
def test_kv_bytes_matches_real_export(arch):
    """The DES charges the interconnect exactly what export_kv ships:
    the cost-model formula and the real cache layout must not drift
    apart (dense per-token KV, ssm fixed-size state, hybrid both)."""
    cfg = _smoke(arch)
    plen = 6
    cluster = TesseraCluster.__new__(TesseraCluster)   # formula only
    cluster.model_cfg = cfg
    want = cluster.kv_bytes(plen)
    cache = M.init_cache(cfg, 2, 16)
    state = M.export_kv(cfg, cache, 0, plen)
    assert M.kv_state_bytes(state) == want
