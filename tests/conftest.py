"""Shared test fixtures.

NOTE: XLA_FLAGS --xla_force_host_platform_device_count is deliberately NOT
set here — smoke tests and benchmarks must see the single real device.
Multi-device tests spawn subprocesses that set the flag themselves
(tests/test_multidevice.py), and the dry-run sets it as its first lines.
"""
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.graph import KernelGraph, KernelNode


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)


def random_dag(n: int, seed: int = 0, p: float = 0.3,
               pin_frac: float = 0.0, num_devices: int = 2) -> KernelGraph:
    """Connected random DAG with plausible kernel costs."""
    import dataclasses
    rng = random.Random(seed)
    nodes = [KernelNode(
        idx=i,
        name=rng.choice(["dot_general", "exp", "reduce_sum", "add"]),
        flops=rng.uniform(1e6, 5e9),
        bytes_accessed=rng.uniform(1e4, 1e8),
        out_bytes=rng.uniform(1e3, 1e7),
        eqn_ids=(i,)) for i in range(n)]
    edges = {}
    for j in range(1, n):
        i = rng.randrange(j)
        edges[(i, j)] = rng.uniform(1e3, 1e7)
        for i2 in range(j):
            if rng.random() < p and (i2, j) not in edges:
                edges[(i2, j)] = rng.uniform(1e3, 1e7)
    if pin_frac:
        for i in rng.sample(range(n), int(n * pin_frac)):
            nodes[i] = dataclasses.replace(
                nodes[i], pinned=rng.randrange(num_devices))
    g = KernelGraph(nodes, edges, name=f"rand{n}s{seed}")
    g.validate()
    return g


@pytest.fixture
def small_mlp():
    """(fn, args) tiny MLP used by analyzer/executor tests."""
    from repro.core import marker

    def model(x, params):
        for i, (w1, w2) in enumerate(params):
            x = marker.wrap(lambda y, a=w1, b=w2: jax.nn.gelu(y @ a) @ b,
                            block="ffn", layer=i)(x)
        return jnp.tanh(x)

    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 9)
    params = [(jax.random.normal(ks[2 * i], (32, 64)) * 0.1,
               jax.random.normal(ks[2 * i + 1], (64, 32)) * 0.1)
              for i in range(4)]
    x = jax.random.normal(ks[8], (4, 32))
    return model, (x, params)
