"""Regression suite for the vectorized trace generators.

The generators in ``repro.serving.workload`` were rewritten to draw
their uniforms in blocks (MT19937 state transplanted into numpy) and
evaluate the arrival/length recurrences as array expressions.  The
contract is absolute: **identical sequences for identical seeds** — the
same ``random.Random(f"{seed}:...")`` streams, consumed in the same
order, through bit-identical float expressions.

The scalar generators below are frozen copies of the pre-vectorization
implementations (the "before" of this refactor).  They are the oracle:
every trace kind, seed, size and kwarg combination must match them
field-for-field, bit-for-bit.  Do NOT "fix" or modernise these copies —
their obsolescence is the point.
"""
import math
import random

import pytest

from repro.serving.workload import (DEFAULT_MIX, RequestClass,
                                    WorkloadRequest, bursty_trace,
                                    diurnal_trace, make_trace,
                                    poisson_trace)

_MAX_PROMPT = 16384
_MAX_OUTPUT = 4096


# --------------------------------------------------------------------- #
# Frozen scalar reference (pre-vectorization implementation, verbatim)
# --------------------------------------------------------------------- #
def _ref_sample_lengths(rng, mix):
    r = rng.random() * sum(c.weight for c in mix)
    acc = 0.0
    cls = mix[-1]
    for c in mix:
        acc += c.weight
        if r <= acc:
            cls = c
            break
    prompt = int(cls.prompt_median * math.exp(
        rng.gauss(0.0, cls.prompt_sigma)))
    output = 1 + int(-cls.output_mean * math.log(max(rng.random(), 1e-12)))
    return (max(1, min(prompt, _MAX_PROMPT)),
            max(1, min(output, _MAX_OUTPUT)))


def _ref_attach_sessions(rng, n, follow_prob):
    sessions, live, next_sid = [], [], 0
    for _ in range(n):
        if live and rng.random() < follow_prob:
            sessions.append(rng.choice(live))
        else:
            sessions.append(next_sid)
            live.append(next_sid)
            if len(live) > 64:
                live.pop(0)
            next_sid += 1
    return sessions


def _ref_finish(arrivals, seed, mix, session_follow):
    rng = random.Random(f"{seed}:lengths")
    sessions = _ref_attach_sessions(random.Random(f"{seed}:sessions"),
                                    len(arrivals), session_follow)
    out = []
    for i, t in enumerate(sorted(arrivals)):
        p, o = _ref_sample_lengths(rng, mix)
        out.append(WorkloadRequest(rid=i, arrival=t, prompt_tokens=p,
                                   output_tokens=o, session=sessions[i]))
    return out


def _ref_poisson(rate, num_requests, seed=0, mix=DEFAULT_MIX,
                 session_follow=0.3):
    rng = random.Random(f"{seed}:poisson")
    t, arrivals = 0.0, []
    for _ in range(num_requests):
        t += rng.expovariate(rate)
        arrivals.append(t)
    return _ref_finish(arrivals, seed, mix, session_follow)


def _ref_bursty(rate, num_requests, seed=0, burst_factor=6.0,
                on_fraction=0.1, period=0.0, mix=DEFAULT_MIX,
                session_follow=0.3):
    rng = random.Random(f"{seed}:bursty")
    period = period or 20.0 / rate
    on_rate = burst_factor * rate
    off_rate = rate * (1.0 - burst_factor * on_fraction) \
        / (1.0 - on_fraction)
    t, arrivals = 0.0, []
    on = True
    state_end = rng.expovariate(1.0 / (period * on_fraction))
    while len(arrivals) < num_requests:
        lam = on_rate if on else off_rate
        dt = rng.expovariate(lam)
        if t + dt >= state_end:
            t = state_end
            on = not on
            mean_len = period * (on_fraction if on else 1 - on_fraction)
            state_end = t + rng.expovariate(1.0 / mean_len)
            continue
        t += dt
        arrivals.append(t)
    return _ref_finish(arrivals, seed, mix, session_follow)


def _ref_diurnal(rate, num_requests, seed=0, period=0.0, amplitude=0.8,
                 mix=DEFAULT_MIX, session_follow=0.3):
    rng = random.Random(f"{seed}:diurnal")
    period = period or 50.0 / rate
    peak = rate * (1.0 + amplitude)
    t, arrivals = 0.0, []
    while len(arrivals) < num_requests:
        t += rng.expovariate(peak)
        lam = rate * (1.0 + amplitude * math.sin(2 * math.pi * t / period))
        if rng.random() < lam / peak:
            arrivals.append(t)
    return _ref_finish(arrivals, seed, mix, session_follow)


_REF = {"poisson": _ref_poisson, "bursty": _ref_bursty,
        "diurnal": _ref_diurnal}
_NEW = {"poisson": poisson_trace, "bursty": bursty_trace,
        "diurnal": diurnal_trace}

CUSTOM_MIX = (
    RequestClass("tiny", 0.5, prompt_median=32, prompt_sigma=0.4,
                 output_mean=16),
    RequestClass("huge", 0.5, prompt_median=8192, prompt_sigma=1.2,
                 output_mean=2048),
)


def _assert_traces_equal(got, want):
    assert len(got) == len(want)
    for g, w in zip(got, want):
        assert g == w, f"first divergence at rid {g.rid}: {g} != {w}"


@pytest.mark.parametrize("kind", ["poisson", "bursty", "diurnal"])
@pytest.mark.parametrize("seed", [0, 1, 7, 123])
def test_bit_identical_to_frozen_reference(kind, seed):
    for rate, n in ((8.0, 50), (120.0, 500)):
        _assert_traces_equal(_NEW[kind](rate, n, seed=seed),
                             _REF[kind](rate, n, seed=seed))


@pytest.mark.parametrize("kind,kw", [
    ("bursty", dict(burst_factor=3.0, on_fraction=0.25)),
    ("bursty", dict(period=2.5)),
    ("diurnal", dict(amplitude=0.3)),
    ("diurnal", dict(period=10.0, amplitude=0.95)),
    ("poisson", dict(session_follow=0.0)),
    ("poisson", dict(session_follow=0.9)),
])
def test_kwargs_preserved(kind, kw):
    _assert_traces_equal(_NEW[kind](40.0, 200, seed=3, **kw),
                         _REF[kind](40.0, 200, seed=3, **kw))


@pytest.mark.parametrize("n", [0, 1, 2, 3])
def test_tiny_traces(n):
    for kind in _NEW:
        _assert_traces_equal(_NEW[kind](10.0, n, seed=5),
                             _REF[kind](10.0, n, seed=5))


def test_custom_mix_and_single_class():
    _assert_traces_equal(poisson_trace(20.0, 150, seed=9, mix=CUSTOM_MIX),
                         _ref_poisson(20.0, 150, seed=9, mix=CUSTOM_MIX))
    one = (DEFAULT_MIX[0],)
    _assert_traces_equal(diurnal_trace(20.0, 150, seed=9, mix=one),
                         _ref_diurnal(20.0, 150, seed=9, mix=one))


def test_make_trace_dispatch_unchanged():
    _assert_traces_equal(make_trace("bursty", 30.0, 80, seed=4),
                         _ref_bursty(30.0, 80, seed=4))
    with pytest.raises(ValueError, match="unknown trace kind"):
        make_trace("weekly", 1.0, 1)
    with pytest.raises(ValueError, match="positive"):
        make_trace("poisson", 0.0, 1)


def test_determinism_across_calls():
    a = diurnal_trace(50.0, 300, seed=11)
    b = diurnal_trace(50.0, 300, seed=11)
    assert a == b
    assert a != diurnal_trace(50.0, 300, seed=12)
