"""Contended-fabric suite: topology lowering, channel invariants,
scheduler behavior, DES integration, straggle detection.

Covers the fabric subsystem (serving/fabric.py) at three levels:

* **Channel unit invariants** — byte conservation, non-overlapping
  committed spans, within-class completion-order monotonicity, and the
  priority-vs-FIFO head semantics, under hypothesis-driven random
  interleavings of urgent commits and bulk enqueues.
* **Topology** — validation (duplicate groups, undeclared hosts,
  unreachable islands, duplex conflicts), JSON round-trip through
  ``DeploymentSpec.fabric`` including unknown-key rejection, and the
  planner-facing contended-bandwidth lowering.
* **DES integration** — determinism of reference-vs-fast walks under
  contention, an uncontended mirror topology matching the point-to-
  point math, checkpoint shipping riding the fabric (with
  ``recovered``-parity when uncontended), and the straggle detector
  tripping breakers with no injected-fault declaration.
"""
import dataclasses
import math

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # collect without hypothesis (tier-1 guard)
    from _hypothesis_stub import given, settings, strategies as st

from conftest import random_dag
from repro.core.planner import contended_bw
from repro.core.simulator import FABRIC_BULK
from repro.serving.fabric import (BULK, HOST, URGENT, Crossing,
                                  FabricState, Island, LiveChannel,
                                  LiveFabric, Topology, TransferScheduler)
from repro.serving.faults import (FaultPlan, GroupHealth, RecoveryConfig,
                                  StraggleDetector)
from repro.serving.router import PDRouter
from repro.serving.spec import DeploymentSpec
from repro.serving.workload import diurnal_trace, poisson_trace

GROUPS = [["a100", "l40s"], ["h100", "h100"], ["a100", "l40s"]]
SLOS = {"base": 2.0, "per_output_token": 0.05, "ttft": 1.5}
ANNEAL = 150
EPS = 1e-9


def _phased(g):
    nodes = [dataclasses.replace(
        node, phase="prefill" if node.idx < len(g.nodes) // 2 else "decode")
        for node in g.nodes]
    g2 = type(g)(nodes, dict(g.edges), name=g.name + ".des")
    g2.validate()
    return g2


@pytest.fixture(scope="module")
def graph():
    return _phased(random_dag(24, seed=2))


def two_island_topology(scheduler="priority", bw=2e8, host="pre"):
    return Topology(
        islands=(Island("pre", groups=(0,), bw=600e9),
                 Island("dec", groups=(1, 2), bw=600e9)),
        crossings=(Crossing("pre", "dec", bw=bw, latency=50e-6,
                            duplex="half"),),
        host_island=host, scheduler=scheduler)


def fabric_dict(scheduler="priority", bw=2e8):
    return two_island_topology(scheduler, bw).to_dict()


def mirror_dict(n_groups):
    """Per-group islands with fat point-to-point crossings at the
    legacy Interconnect defaults (100 GB/s, 20 us)."""
    return {
        "islands": [{"name": f"g{i}", "groups": [i]}
                    for i in range(n_groups)],
        "crossings": [{"src": f"g{i}", "dst": f"g{j}",
                       "bw": 100e9, "latency": 20e-6}
                      for i in range(n_groups) for j in range(n_groups)
                      if i != j],
        "host_island": "g0", "scheduler": "priority",
    }


# ===================================================================== #
# Topology: validation + JSON round-trip
# ===================================================================== #
def test_topology_lowering_and_roundtrip():
    t = two_island_topology()
    assert t.channel_key(1, 2) == ("isl", "dec")      # same island
    assert t.channel_key(0, 0) is None                # same group
    assert t.channel_key(0, 1) == ("x", "pre", "dec")
    # half-duplex: the reverse direction shares the SAME channel key
    assert t.channel_key(1, 0) == ("x", "pre", "dec")
    assert t.channel_key(1, HOST) == ("x", "pre", "dec")
    assert t.channel_params(("x", "pre", "dec")) == (2e8, 50e-6)
    t2 = Topology.from_dict(t.to_dict())
    assert t2 == t


@pytest.mark.parametrize("mutate,err", [
    (lambda d: d["islands"][0].update(groups=[0, 1]), "both"),
    (lambda d: d.update(host_island="nope"), "not a declared"),
    (lambda d: d["crossings"].clear(), "no crossing"),
    (lambda d: d["crossings"][0].update(duplex="simplex"), "duplex"),
    (lambda d: d["crossings"][0].update(bogus=1), "unknown"),
    (lambda d: d["islands"][0].update(bogus=1), "unknown"),
    (lambda d: d.update(bogus=1), "unknown"),
    (lambda d: d.update(scheduler="lifo"), "scheduler"),
])
def test_topology_validation_rejects(mutate, err):
    d = fabric_dict()
    mutate(d)
    with pytest.raises((ValueError, TypeError), match=err):
        Topology.from_dict(d)


def test_spec_validates_fabric_eagerly(graph):
    # every group must sit on an island
    bad = fabric_dict()
    bad["islands"][1]["groups"] = [1]           # group 2 unmapped
    with pytest.raises(ValueError, match="not on any island"):
        DeploymentSpec(groups=GROUPS, fabric=bad)
    ok = DeploymentSpec(groups=GROUPS, fabric=fabric_dict())
    assert ok.make_topology() is not None
    assert DeploymentSpec(groups=GROUPS).make_topology() is None


# ===================================================================== #
# Channel invariants (hypothesis)
# ===================================================================== #
def _drive(policy, ops, bw=1e6, latency=1e-4):
    """Replay (kind, gap, nbytes) ops at non-decreasing watermarks on
    one channel; returns (channel, urgent spans, bulk slices, enqueued
    bulk bytes).  Mirrors the DES contract: urgent ready values and
    bulk enqueue times never move backwards, and the channel is
    materialized at each watermark before new work books."""
    ch = TransferScheduler(policy).make_channel(("x", "a", "b"),
                                               bw, latency)
    slices = []
    ch_sink = lambda s, d, r, t0, t1: slices.append((t0, t1))
    urgent, bulk_bytes, now = [], 0.0, 0.0
    for i, (kind, gap, nbytes) in enumerate(ops):
        now += gap
        ch.materialize(now, ch_sink)
        if kind == "u":
            s = max(now, ch.head())
            e = s + ch.duration(nbytes)
            ch.commit_urgent([(s, e)], now, nbytes)
            urgent.append((s, e))
        else:
            ch.enqueue_bulk(now, nbytes, ("b", i), 1, 0, i, ch_sink)
            bulk_bytes += nbytes
    ch.materialize(math.inf, ch_sink)
    return ch, urgent, slices, bulk_bytes


@settings(max_examples=60, deadline=None)
@given(seedlist=st.lists(st.tuples(st.booleans(),
                                   st.floats(min_value=0.0,
                                             max_value=2.0),
                                   st.integers(min_value=0,
                                               max_value=500_000)),
                         min_size=1, max_size=24),
       policy=st.sampled_from(["priority", "fifo"]))
def test_channel_conservation_and_no_overlap(seedlist, policy):
    ops = [("u" if u else "b", gap, nb) for u, gap, nb in seedlist]
    bw, latency = 1e6, 1e-4
    ch, urgent, slices, bulk_bytes = _drive(policy, ops, bw, latency)
    # byte conservation: every enqueued bulk byte is on the wire
    # (zero-byte transfers complete instantly, no wire time)
    n_bulk = sum(1 for k, _, nb in ops if k == "b" and nb > 0)
    wire = sum(e - s for s, e in slices)
    expect = bulk_bytes / bw + n_bulk * latency
    assert wire == pytest.approx(expect, rel=1e-9, abs=1e-9)
    # committed spans never overlap (one wire, one transfer at a time)
    spans = sorted([s for s in urgent if s[1] > s[0]]
                   + [s for s in slices if s[1] > s[0]])
    for (s0, e0), (s1, e1) in zip(spans, spans[1:]):
        assert e0 <= s1 + EPS, (policy, spans)
    # every bulk transfer completed; within the class, completion never
    # inverts enqueue order (priority backfill serves strictly in
    # (ready, seq) order, one segment at a time)
    done = [ch.done_at(("b", i)) for i, (k, _, _) in enumerate(ops)
            if k == "b"]
    assert all(d is not None for d in done)
    wired = [ch.done_at(("b", i)) for i, (k, _, nb) in enumerate(ops)
             if k == "b" and nb > 0]
    assert wired == sorted(wired)


def test_priority_head_unaffected_by_bulk():
    """A queued bulk transfer delays urgent work under FIFO but not
    under the priority scheduler — the core of the benchmark claim."""
    heads = {}
    for policy in ("fifo", "priority"):
        ch = TransferScheduler(policy).make_channel(("x", "a", "b"),
                                                    1e6, 0.0)
        ch.enqueue_bulk(0.0, 1_000_000, ("b", 0), 1, 0, 0, None)
        ch.materialize(0.5)
        heads[policy] = ch.head()
    assert heads["fifo"] == pytest.approx(1.0)    # behind the 1s bulk
    assert heads["priority"] == 0.0               # urgent head clear


def test_priority_backfills_urgent_gaps():
    ch = TransferScheduler("priority").make_channel(("x", "a", "b"),
                                                    1e6, 0.0)
    # urgent occupies [2, 3): the idle [0, 2) becomes settled gap
    ch.commit_urgent([(2.0, 3.0)], 0.0, 1_000_000)
    slices = []
    ch.enqueue_bulk(0.0, 500_000, ("b", 0), 1, 0, 0,
                    lambda s, d, r, t0, t1: slices.append((t0, t1)))
    ch.materialize(10.0, lambda s, d, r, t0, t1: slices.append((t0, t1)))
    assert slices == [(0.0, 0.5)]                 # inside the gap
    assert ch.done_at(("b", 0)) == pytest.approx(0.5)


def test_cancel_src_drops_pending_bulk():
    fs = FabricState(two_island_topology(), 3)
    fs.enqueue_bulk(1, HOST, 7, 1e6, 0.0, ("ckpt", 0, 1))
    fs.enqueue_bulk(2, HOST, 8, 1e6, 0.0, ("ckpt", 1, 1))
    assert fs.cancel_src(1, 0.0) == 1             # group 1's ship dies
    fs.flush()
    ch = fs.channel(1, HOST)
    assert ch.done_at(("ckpt", 0, 1)) is None
    assert ch.done_at(("ckpt", 1, 1)) is not None
    assert fs.ckpt_completed() == 1


# ===================================================================== #
# Router: queued transfer tail
# ===================================================================== #
def test_pd_router_charges_queued_tail():
    req = type("R", (), {"kv_bytes": 1e6})()
    r = PDRouter(kv_chunks=1)
    fs = FabricState(two_island_topology(bw=1e6), 3)
    r.bind_fabric(fs)
    unloaded = r._transfer_tail(req, 0, 1, now=0.0)
    assert unloaded == pytest.approx(50e-6 + 1.0)
    # urgent traffic already booked to t=3 on the shared crossing:
    # the estimate must charge the wait behind it
    ch = fs.channel(0, 1)
    ch.commit_urgent([(0.0, 3.0)], 0.0, 3e6)
    assert r._transfer_tail(req, 0, 1, now=0.0) \
        == pytest.approx(3.0 + unloaded)
    # same group -> no fabric hop, no tail
    assert r._transfer_tail(req, 1, 1, now=0.0) == 0.0
    # chunked: only the last chunk's tail rides on the queue
    rc = PDRouter(kv_chunks=4)
    rc.bind_fabric(fs)
    assert rc._transfer_tail(req, 0, 1, now=0.0) \
        == pytest.approx(3.0 + 50e-6 + 0.25)


# ===================================================================== #
# Planner: contended bandwidth lowering
# ===================================================================== #
def test_contended_bw_and_planner_lowering(graph):
    assert contended_bw(100e9, 2) == 50e9
    assert contended_bw(100e9, 0) == 100e9        # degenerate: no split
    t = two_island_topology()
    assert t.planner_bw(0) == 600e9               # alone on its island
    assert t.planner_bw(1) == 300e9               # shares with group 2
    # the deployment threads per-group contended bw into the planner
    dep = DeploymentSpec(groups=GROUPS, fabric=fabric_dict(),
                         anneal_iters=ANNEAL).compile(graph)
    assert dep.cluster().bw_overrides == [600e9, 300e9, 300e9]


# ===================================================================== #
# DES integration
# ===================================================================== #
def _simulate(graph, fabric=None, reference=False, sim_kw=None,
              **spec_kw):
    kw = dict(groups=GROUPS, router="pd_split", slos=SLOS, pd=True,
              kv_chunks=4, anneal_iters=ANNEAL, **spec_kw)
    dep = DeploymentSpec(**kw, fabric=fabric).compile(graph)
    trace = diurnal_trace(40.0, 300, seed=0)
    return dep.simulate(trace, reference=reference, **(sim_kw or {}))


@pytest.mark.parametrize("router,pd,kv_chunks", [
    ("jsed", False, 1), ("round_robin", False, 1),
    ("least_loaded", False, 1),
    ("pd_split", True, 1), ("pd_split", True, 4),
])
def test_ref_vs_fast_parity_under_contention(graph, router, pd,
                                             kv_chunks):
    """The reference and fast DES walks must stay bit-identical when
    transfers queue on contended shared channels."""
    kw = dict(groups=GROUPS, router=router, slos=SLOS, pd=pd,
              kv_chunks=kv_chunks, anneal_iters=ANNEAL,
              fabric=fabric_dict(bw=2e7))
    trace = diurnal_trace(40.0, 300, seed=0)
    ref = DeploymentSpec(**kw).compile(graph).simulate(
        trace, reference=True)
    fast = DeploymentSpec(**kw).compile(graph).simulate(trace)
    assert ref.events == fast.events
    assert ref.latencies == fast.latencies
    assert ref.ttfts == fast.ttfts
    assert ref.assignments == fast.assignments
    assert ref.fabric_wait_seconds == fast.fabric_wait_seconds


def test_contended_fabric_determinism(graph):
    a = _simulate(graph, fabric=fabric_dict(bw=2e7))
    b = _simulate(graph, fabric=fabric_dict(bw=2e7))
    assert a.events == b.events
    assert a.latencies == b.latencies
    assert a.fabric_wait_seconds == b.fabric_wait_seconds
    # contention is real on this thin crossing
    assert a.fabric_wait_seconds > 0.0


def test_mirror_topology_matches_point_to_point(graph):
    """An uncontended per-group-island topology at the Interconnect's
    default rates reproduces the point-to-point latencies on a trace
    sparse enough that transfers never queue."""
    kw = dict(groups=GROUPS, router="pd_split", slos=SLOS, pd=True,
              kv_chunks=4, anneal_iters=ANNEAL)
    trace = poisson_trace(rate=2.0, num_requests=60, seed=3)
    plain = DeploymentSpec(**kw).compile(graph).simulate(trace)
    mirror = DeploymentSpec(**kw, fabric=mirror_dict(len(GROUPS))
                            ).compile(graph).simulate(trace)
    assert mirror.completed == plain.completed
    assert mirror.shed == plain.shed
    for a, b in zip(plain.latencies, mirror.latencies):
        assert a == pytest.approx(b, rel=1e-9, abs=1e-12)
    for a, b in zip(plain.ttfts, mirror.ttfts):
        assert a == pytest.approx(b, rel=1e-9, abs=1e-12)


def test_fabric_bulk_events_recorded(graph):
    res = _simulate(graph, fabric=fabric_dict(bw=2e7),
                    sim_kw=dict(faults=FaultPlan(seed=3),
                                recovery=RecoveryConfig(interval=2e-3),
                                health=GroupHealth(len(GROUPS))))
    bulk = [e for e in res.events if e[2] == FABRIC_BULK]
    assert res.ckpt_shipped > 0
    assert bulk, "checkpoint ships must emit FABRIC_BULK events"
    assert res.fabric_bulk_bytes > 0.0
    for e in bulk:
        assert e[5] >= e[4]                     # well-formed [t0, t1)


def test_uncontended_fabric_recovery_parity(graph):
    """Satellite: checkpoint shipping through an UNCONTENDED fabric
    must not change what crash recovery restores."""
    # the full-outage blip from test_faults.py: every group crashes at
    # mid-trace under 1.5x overload, so victims hold checkpointed
    # in-flight decode state when the lights go out
    groups = [["h100", "rtxpro6000"], ["a100", "l40s"], ["a100", "l40s"]]
    kw = dict(groups=groups, anneal_iters=200)
    dep = DeploymentSpec(**kw).compile(graph)
    trace = poisson_trace(rate=1.5 * dep.cluster().capacity,
                          num_requests=150, seed=5)
    mid = trace[len(trace) // 2].arrival
    plan = FaultPlan(seed=1)
    for g in range(len(groups)):
        plan.crash(mid, group=g, recover_at=mid + 0.01)
    sim = dict(faults=plan, recovery=RecoveryConfig(interval=1e-5),
               health=GroupHealth(len(groups)))
    plain = dep.simulate(trace, **sim)
    fab = DeploymentSpec(**kw, fabric=mirror_dict(len(groups))
                         ).compile(graph).simulate(trace, **sim)
    assert plain.recovered > 0
    assert fab.recovered == plain.recovered
    assert fab.dropped == plain.dropped
    assert fab.completed == plain.completed
    assert fab.ckpt_shipped > 0                 # ships really ran
    # restore points now come from actual wire completions (ships in
    # flight at crash time don't count), so per-request latencies can
    # shift by the channel's microsecond setup cost — but only there:
    # the schedule itself must stay put
    assert plain.mean_latency == pytest.approx(fab.mean_latency,
                                               rel=1e-2)
    assert plain.makespan == pytest.approx(fab.makespan, rel=1e-2)


# ===================================================================== #
# Straggle detection (no declared fault)
# ===================================================================== #
def test_straggle_detector_catches_undeclared_straggle(graph):
    dep = DeploymentSpec(groups=GROUPS, router="jsed", slos=SLOS,
                         anneal_iters=ANNEAL).compile(graph)
    trace = diurnal_trace(40.0, 400, seed=0)
    plan = FaultPlan(seed=0).straggle(2.0, 6.0, group=0, factor=5.0)
    h = GroupHealth(len(GROUPS))
    det = StraggleDetector(h, interval=0.5)
    res = dep.simulate(trace, faults=plan, health=h, controller=det)
    # caught: the right group, inside (or shortly after) the window
    assert det.detections
    t, g, ratio = det.detections[0]
    assert g == 0
    assert 2.0 <= t <= 7.0
    assert ratio > det.threshold
    # routed around: the straggler takes less load than it does when
    # nobody watches the signals
    blind = dep.simulate(trace, faults=plan)
    load = res.assignments.count(0)
    blind_load = blind.assignments.count(0)
    assert load < blind_load
    assert res.completed >= blind.completed


def test_straggle_detector_clean_run_no_false_positive(graph):
    dep = DeploymentSpec(groups=GROUPS, router="jsed", slos=SLOS,
                         anneal_iters=ANNEAL).compile(graph)
    trace = diurnal_trace(40.0, 400, seed=0)
    h = GroupHealth(len(GROUPS))
    det = StraggleDetector(h, interval=0.5)
    watched = dep.simulate(trace, health=h, controller=det)
    assert det.detections == []
    # watching healthy signals must not perturb the schedule
    plain = dep.simulate(trace)
    assert watched.events == plain.events
    assert watched.latencies == plain.latencies


def test_group_health_suspect_semantics():
    h = GroupHealth(2)
    assert h.allow(0, 0.0)
    h.suspect(0, 1.0)
    assert h.state(0, 1.0) == "half_open"
    assert h.penalty(0, 1.0) > 0.0              # routers steer away
    h.record_ok(0, 1.5)
    assert h.state(0, 1.5) == "closed"
    # suspect never downgrades an OPEN breaker
    h.trip(1, 1.0)
    h.trip(1, 1.0)
    state = h.state(1, 1.0)
    h.suspect(1, 1.0)
    assert h.state(1, 1.0) == state


# ===================================================================== #
# Live accounting twin
# ===================================================================== #
def test_live_channel_wrap_counts_stamped_shards():
    from repro.serving.kvpool import KvSlice
    ch = LiveChannel(("x", "a", "b"), 1e9, 1e-5)
    shards = [KvSlice(rid=1, component="kv", layer=0, nbytes=100),
              KvSlice(rid=1, component="kv", layer=1, nbytes=200,
                      klass=BULK)]
    out = list(ch.wrap(iter(shards + ["cursor"])))
    assert out == shards + ["cursor"]           # pass-through
    assert ch.bytes_by_class[URGENT] == 100
    assert ch.bytes_by_class[BULK] == 200
    assert ch.modeled_seconds(URGENT) == pytest.approx(1e-5 + 100 / 1e9)


def test_live_fabric_ckpt_accounting():
    fab = LiveFabric(two_island_topology(), 3)
    fab.account_ckpt(1, 1000)                   # dec -> host crossing
    fab.account_ckpt(2, 500)
    st_ = fab.stats()
    assert st_["bulk_bytes"] == 1500
    assert st_["urgent_bytes"] == 0


def test_kvslice_klass_default_and_legacy_roundtrip():
    from repro.serving.kvpool import KvSlice
    sl = KvSlice(rid=1, component="kv", layer=0, nbytes=10)
    assert sl.klass == URGENT                   # wire-compat default
    # legacy dict format carries no class and restores the default
    assert KvSlice.from_legacy(sl.to_legacy()).klass == URGENT
