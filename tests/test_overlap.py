"""Overlapped KV streaming: chunked prefill + layer-streamed handoff.

Covers the pipelined-handoff acceptance criteria:
  * chunked prefill == whole-prompt prefill (logits parity) for every
    decoder family, at several chunk sizes,
  * streamed (layer, chunk) handoff decodes bit-identically to the
    serial handoff AND to a single engine that never split, for all
    four families,
  * the DES's overlapped KV arrival is NEVER later than the serial
    transfer edge (the sender's serial fallback), while an interior
    chunk count strictly beats both extremes when transfers are
    latency-amortizable,
  * chunked colocated admission interleaves decode steps between
    prefill chunks (the long-prompt head-of-line fix) without changing
    any output token,
  * PDRouter decode-session affinity reuses the decode group's
    resident state for follow-up turns (transfers_avoided accounting),
  * admit_handoff stamps wall-clock-mode times through the engine
    clock (regression: a literal 0.0 fallback).
"""
import dataclasses

import numpy as np
import pytest

from conftest import random_dag
import repro.configs as configs
from repro.core.monitor import MonitorConfig
from repro.core.simulator import (KV_TRANSFER, Interconnect,
                                  _stream_kv)
from repro.models import model as M
from repro.serving.cluster import TesseraCluster
from repro.serving.engine import Request, ServingEngine
from repro.serving.router import PDRouter
from repro.serving.workload import poisson_trace

ARCHS = ("llama3_8b", "gpt_oss_20b", "rwkv6_3b", "zamba2_7b")

HET_GROUPS = [["h100", "rtxpro6000"], ["a100", "l40s"],
              ["a100", "l40s"], ["a100", "l40s"]]


def _smoke(arch):
    return dataclasses.replace(configs.get_smoke(arch), dtype="float32")


def pd_dag(n: int = 24, seed: int = 2, decode_weight: float = 8.0):
    g = random_dag(n, seed=seed)
    nodes = []
    for node in g.nodes:
        if node.idx < n // 2:
            nodes.append(dataclasses.replace(node, phase="prefill"))
        else:
            nodes.append(dataclasses.replace(
                node, phase="decode",
                flops=node.flops * decode_weight,
                bytes_accessed=node.bytes_accessed * decode_weight))
    g2 = type(g)(nodes, dict(g.edges), name=g.name + ".pd")
    g2.validate()
    return g2


@pytest.fixture(scope="module")
def pd_cluster():
    return TesseraCluster(pd_dag(), HET_GROUPS,
                          base_prompt=1024, base_output=128,
                          anneal_iters=300,
                          monitor_cfg=MonitorConfig(window=0.010),
                          model_cfg=configs.get("llama3_8b"))


# ===================================================================== #
# Model level: chunked prefill == whole prefill
# ===================================================================== #
@pytest.mark.parametrize("arch", ARCHS)
def test_chunked_prefill_matches_whole(arch):
    """prefill_chunked must reproduce the whole-prompt prefill's
    last-position logits for every family and chunk size (including
    per-row last_pos selection across chunk boundaries)."""
    import jax.numpy as jnp
    cfg = _smoke(arch)
    params = M.init_params(cfg)
    rng = np.random.default_rng(0)
    B, S, T = 2, 7, 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(B, S)),
                       jnp.int32)
    last = jnp.asarray([S - 1, 4], jnp.int32)
    lg_w, cache_w = M.prefill(params, cfg, toks,
                              M.init_cache(cfg, B, T), last_pos=last)
    for cs in (1, 2, 3, 5):
        lg_c, cache_c = M.prefill_chunked(
            params, cfg, toks, M.init_cache(cfg, B, T),
            chunk_size=cs, last_pos=last)
        np.testing.assert_allclose(np.asarray(lg_c), np.asarray(lg_w),
                                   rtol=2e-5, atol=2e-5)
        # the filled cache must match too (exported handoffs come from
        # it); attention KV compared over the filled prefix only
        for key in cache_w:
            a = M.export_kv(cfg, cache_w, 0, S)[key]
            b = M.export_kv(cfg, cache_c, 0, S)[key]
            for la, lb in zip(*(map(
                    lambda t: __import__("jax").tree_util.tree_leaves(t),
                    (a, b)))):
                np.testing.assert_allclose(np.asarray(lb), np.asarray(la),
                                           rtol=2e-5, atol=2e-5)


def test_layer_shards_reassemble_whole_export():
    """Installing every (layer, chunk) shard == import_kv of the whole
    export, and the summed shard bytes match the monolithic payload."""
    import jax
    cfg = _smoke("zamba2_7b")        # hybrid: kv AND mamba components
    params = M.init_params(cfg)
    rng = np.random.default_rng(1)
    S, T = 6, 16
    toks = __import__("jax").numpy.asarray(
        rng.integers(0, cfg.vocab_size, size=(2, S)), dtype="int32")
    _, cache = M.prefill(params, cfg, toks, M.init_cache(cfg, 2, T))
    whole = M.import_kv(cfg, M.init_cache(cfg, 1, T), 0,
                        M.export_kv(cfg, cache, 1, S))
    sharded = M.init_cache(cfg, 1, T)
    total = 0
    for key, L in M.cache_layer_counts(cache).items():
        for layer in range(L):
            if key == "kv" and cfg.sliding_window is None:
                for t0 in range(0, S, 2):
                    sh = M.export_kv_shard(cfg, cache, 1, key, layer,
                                           t0, min(t0 + 2, S))
                    total += M.kv_state_bytes(sh)
                    sharded = M.import_kv_shard(cfg, sharded, 0, key,
                                                layer, sh, t0)
            else:
                sh = M.export_kv_shard(cfg, cache, 1, key, layer)
                total += M.kv_state_bytes(sh)
                sharded = M.import_kv_shard(cfg, sharded, 0, key, layer,
                                            sh)
    assert total == M.kv_state_bytes(M.export_kv(cfg, cache, 1, S))
    for a, b in zip(jax.tree_util.tree_leaves(whole),
                    jax.tree_util.tree_leaves(sharded)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_mamba2_state_carries_across_chunks():
    """Regression for the latent SSD bug chunking exposed: mamba2 with
    an incoming state and S > 1 must CONTINUE that state, not restart
    from zeros."""
    import jax, jax.numpy as jnp
    from repro.models import ssm as S
    cfg = _smoke("zamba2_7b")
    p = S.init_mamba2(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model),
                          jnp.float32)
    fresh = lambda: {k: v[0] for k, v in          # noqa: E731
                     S.make_mamba2_state(cfg, 2).items()}
    y_whole, st_w = S.mamba2(p, x, cfg, state=fresh())
    st = fresh()
    y1, st = S.mamba2(p, x[:, :3], cfg, state=st)
    y2, st = S.mamba2(p, x[:, 3:], cfg, state=st)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([y1, y2], axis=1)),
        np.asarray(y_whole), rtol=2e-5, atol=2e-5)
    for k in st_w:
        np.testing.assert_allclose(np.asarray(st[k]), np.asarray(st_w[k]),
                                   rtol=2e-5, atol=2e-5)


# ===================================================================== #
# Engine level: streamed handoff + chunked colocated admission
# ===================================================================== #
@pytest.mark.parametrize("arch", ARCHS)
def test_streamed_handoff_bit_identical(arch):
    """prefill_handoff_stream -> admit_handoff_stream must produce the
    same greedy tokens as a single engine that never split, for every
    family (ring-buffer SWA falls back to per-layer streaming)."""
    cfg = _smoke(arch)
    params = M.init_params(cfg)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (9, 5)]
    singles = [Request(rid=i, prompt=p.copy(), max_new_tokens=6)
               for i, p in enumerate(prompts)]
    ref = ServingEngine(cfg, params, slots=2, max_len=32, sync_every=2)
    ref.run(singles)

    splits = [Request(rid=i, prompt=p.copy(), max_new_tokens=6)
              for i, p in enumerate(prompts)]
    pre = ServingEngine(cfg, params, slots=2, max_len=32)
    dec = ServingEngine(cfg, params, slots=2, max_len=32, sync_every=2)
    for req in splits:
        shards = []
        def spy(gen):
            for item in gen:
                shards.append(item)
                yield item
        assert dec.admit_handoff_stream(
            req, spy(pre.prefill_handoff_stream(req, 0.0, chunk_size=3)),
            0.0)
        header = shards[-1]
        assert header["header"] and not header["done"]
        # every pre-header item is a shard with a payload; chunked
        # families carry (layer, chunk) token ranges on their kv shards
        body = shards[:-1]
        assert body and all("state" in it and it["bytes"] > 0
                            for it in body)
        kv = [it for it in body if it["key"] == "kv"]
        if kv and cfg.sliding_window is None:
            expect = {(t0, min(t0 + 3, len(req.prompt)))
                      for t0 in range(0, len(req.prompt), 3)}
            assert {(it["t0"], it["t1"]) for it in kv} == expect
        assert header["kv_bytes"] == sum(it["bytes"] for it in body)
    assert dec.stats.prefill_batches == 0
    while dec._any_active():
        dec.step(0.0)
    dec.sync(0.0)
    assert [r.output for r in splits] == [r.output for r in singles]


def test_streamed_handoff_done_at_prefill_releases_slot():
    """A 1-token request finishes at prefill AFTER its shards already
    streamed: the done header must release the reserved decode slot
    and the producer finalizes the request (no retry livelock)."""
    cfg = _smoke("llama3_8b")
    params = M.init_params(cfg)
    rng = np.random.default_rng(3)
    req = Request(rid=0, prompt=rng.integers(
        0, cfg.vocab_size, size=6).astype(np.int32), max_new_tokens=1)
    pre = ServingEngine(cfg, params, slots=1, max_len=16)
    dec = ServingEngine(cfg, params, slots=1, max_len=16)
    assert dec.admit_handoff_stream(
        req, pre.prefill_handoff_stream(req, 0.0, chunk_size=2), 0.0)
    assert dec.active == [None]              # slot released
    assert not dec._any_active()
    assert pre.stats.completed == 1 and len(req.output) == 1


def test_streamed_handoff_full_engine_rejects_without_consuming():
    """No free slot -> False, and the producer generator must NOT have
    been advanced (nothing prefilled, nothing lost)."""
    cfg = _smoke("llama3_8b")
    params = M.init_params(cfg)
    rng = np.random.default_rng(4)
    mk = lambda rid: Request(rid=rid, prompt=rng.integers(  # noqa: E731
        0, cfg.vocab_size, size=5).astype(np.int32), max_new_tokens=4)
    pre = ServingEngine(cfg, params, slots=2, max_len=16)
    dec = ServingEngine(cfg, params, slots=1, max_len=16)
    first = mk(0)
    assert dec.admit_handoff_stream(
        first, pre.prefill_handoff_stream(first, 0.0, chunk_size=2), 0.0)
    blocked = mk(1)
    gen = pre.prefill_handoff_stream(blocked, 0.0, chunk_size=2)
    before = pre.stats.prefill_batches
    assert not dec.admit_handoff_stream(blocked, gen, 0.0)
    assert pre.stats.prefill_batches == before   # generator untouched
    assert blocked.output == []


def test_streamed_handoff_oversized_releases_slot():
    """An oversized handoff must fail WITHOUT leaking the reserved
    slot: the engine keeps serving afterwards."""
    cfg = _smoke("llama3_8b")
    params = M.init_params(cfg)
    rng = np.random.default_rng(6)
    big = Request(rid=0, prompt=rng.integers(
        0, cfg.vocab_size, size=20).astype(np.int32), max_new_tokens=4)
    pre = ServingEngine(cfg, params, slots=1, max_len=32)
    dec = ServingEngine(cfg, params, slots=1, max_len=16)   # too small
    with pytest.raises(AssertionError, match="max_len"):
        dec.admit_handoff_stream(
            big, pre.prefill_handoff_stream(big, 0.0, chunk_size=4),
            0.0)
    assert dec.active == [None]              # slot not leaked
    ok = Request(rid=1, prompt=rng.integers(
        0, cfg.vocab_size, size=5).astype(np.int32), max_new_tokens=3)
    assert dec.admit_handoff_stream(
        ok, pre.prefill_handoff_stream(ok, 0.0, chunk_size=2), 0.0)
    while dec._any_active():
        dec.step(0.0)
    dec.sync(0.0)
    assert dec.stats.completed == 1


@pytest.mark.parametrize("arch", ("llama3_8b", "rwkv6_3b"))
def test_chunked_admission_interleaves_decode(arch):
    """With prefill_chunk set, a long admitted prompt must let live
    decode slots step between chunks — and change no output token."""
    cfg = _smoke(arch)
    params = M.init_params(cfg)
    rng = np.random.default_rng(11)
    short = Request(rid=0, prompt=rng.integers(
        0, cfg.vocab_size, size=4).astype(np.int32), max_new_tokens=12)
    long_p = rng.integers(0, cfg.vocab_size, size=20).astype(np.int32)

    def run(chunk):
        eng = ServingEngine(cfg, params, slots=2, max_len=40,
                            sync_every=1, prefill_chunk=chunk)
        a = dataclasses.replace(short, output=[])
        eng.admit(a, 0.0)
        b = Request(rid=1, prompt=long_p.copy(), max_new_tokens=4)
        steps_before = eng.stats.decode_steps
        eng.admit(b, 0.0)
        interleaved = eng.stats.decode_steps - steps_before
        while eng._any_active():
            eng.step(0.0)
        eng.sync(0.0)
        return a.output, b.output, interleaved

    out_a0, out_b0, il0 = run(None)
    out_a1, out_b1, il1 = run(4)
    assert il0 == 0                  # serial prefill: decode frozen
    assert il1 > 0                   # chunked: decode streamed between
    assert (out_a1, out_b1) == (out_a0, out_b0)


def test_admit_handoff_uses_engine_clock_when_now_is_none():
    """Regression: admit_handoff(now=None) must stamp wall-clock-mode
    times through the engine clock, not a literal 0.0."""
    cfg = _smoke("llama3_8b")
    params = M.init_params(cfg)
    rng = np.random.default_rng(5)
    req = Request(rid=0, prompt=rng.integers(
        0, cfg.vocab_size, size=5).astype(np.int32), max_new_tokens=3)
    pre = ServingEngine(cfg, params, slots=1, max_len=16)
    h = pre.prefill_handoff(req, 0.0)
    dec = ServingEngine(cfg, params, slots=1, max_len=16, sync_every=1)
    dec._clock = lambda: 7.5                 # wall-clock mode
    try:
        assert dec.admit_handoff(req, h, now=None)
        assert req.ttft == 7.5
        while dec._any_active():
            dec.step(None)
        dec.sync(None)
    finally:
        dec._clock = None
    assert req.finished == 7.5               # not stamped at t=0


# ===================================================================== #
# DES level: overlapped transfer model
# ===================================================================== #
def test_stream_kv_never_later_than_serial():
    """Property: for ANY (bytes, bandwidth, latency, prefill span,
    chunk count), the streamed KV arrival <= the serial edge — the
    sender falls back to one deferred transfer when chunking loses."""
    for bw in (1e8, 1e9, 100e9):
        for base in (0.0, 1e-5, 5e-3):
            for nbytes in (1e3, 1e6, 1e9):
                for span in (1e-4, 0.05, 2.0):
                    ic = Interconnect(default_bw=bw, base_latency=base)
                    serial = span + ic.transfer_time(nbytes, 0, 1)
                    for n in (1, 2, 4, 8, 32, 128):
                        kv_at, evs, busy = _stream_kv(
                            ic, nbytes, 0, 1, 0.0, span, n)
                        assert kv_at <= serial + 1e-12
                        assert evs[-1][1] == pytest.approx(kv_at)
                        assert all(e1 >= e0 for e0, e1 in evs)
                        assert busy >= 0.0


def test_stream_kv_interior_optimum_exists():
    """With per-transfer base latency, a moderate chunk count beats
    BOTH extremes (1 chunk defers all bytes past prefill-end; huge n
    drowns in base latency and falls back to serial)."""
    ic = Interconnect(default_bw=1e9, base_latency=2e-4)
    nbytes, span = 8e6, 0.02
    kv = {n: _stream_kv(ic, nbytes, 0, 1, 0.0, span, n)[0]
          for n in (1, 8, 4096)}
    assert kv[8] < kv[1]                       # overlap hides transfer
    serial = span + ic.transfer_time(nbytes, 0, 1)
    assert kv[1] == pytest.approx(serial)
    assert kv[4096] == pytest.approx(serial)   # fallback kicked in
    # compute-bound best case: only the last chunk's tail remains
    assert kv[8] >= span + ic.base_latency + (nbytes / 8) / 1e9 - 1e-12


def test_overlap_ttft_never_later_end_to_end(pd_cluster):
    """Every request's TTFT under kv_chunks=n must be <= the serial
    split's TTFT, and per-chunk KV_TRANSFER events appear in the log."""
    pd_cluster.interconnect = Interconnect(default_bw=2e9,
                                           base_latency=1e-5)
    try:
        tr = poisson_trace(3.0, 40, seed=21)
        mk = lambda: PDRouter(prefill_pool=[0],       # noqa: E731
                              decode_pool=[1, 2, 3], max_kv_lag=1.0)
        serial = pd_cluster.simulate_pd(tr, mk())
        for n in (2, 8, 32):
            ov = pd_cluster.simulate_pd(tr, mk(), kv_chunks=n)
            assert len(ov.ttfts) == len(serial.ttfts)
            for a, b in zip(ov.ttfts, serial.ttfts):
                assert a <= b + 1e-9
            assert ov.transfers == serial.transfers
            xfer = [e for e in ov.events if e[2] == KV_TRANSFER]
            # at least one request streamed in >1 chunk
            assert len(xfer) > serial.transfers
        # determinism of the overlapped replay
        r1 = pd_cluster.simulate_pd(tr, mk(), kv_chunks=8)
        r2 = pd_cluster.simulate_pd(tr, mk(), kv_chunks=8)
        assert r1.events == r2.events and r1.ttfts == r2.ttfts
    finally:
        pd_cluster.interconnect = Interconnect()


def test_pd_session_affinity_avoids_transfers(pd_cluster):
    """Follow-up turns of a session run on the decode group holding
    their resident state: no new KV transfer, counted per run."""
    tr = poisson_trace(3.0, 80, seed=9, session_follow=0.5)
    splits = sum(1 for r in tr)
    mk = lambda **kw: PDRouter(prefill_pool=[0],      # noqa: E731
                               decode_pool=[1, 2, 3],
                               max_kv_lag=1.0, **kw)
    base = pd_cluster.simulate_pd(tr, mk())
    assert base.transfers == splits and base.transfers_avoided == 0
    router = mk(session_affinity=True)
    aff = pd_cluster.simulate_pd(tr, router)
    assert aff.transfers_avoided > 0
    assert aff.transfers + aff.transfers_avoided == splits
    assert aff.completed == len(tr)
    # the counter reports the PER-RUN delta even when a router is
    # reused (its session map persists, so the replay finds every
    # session already resident — more avoided, never double-counted)
    aff2 = pd_cluster.simulate_pd(tr, router)
    assert aff.transfers_avoided <= aff2.transfers_avoided <= len(tr)
    fresh = pd_cluster.simulate_pd(tr, mk(session_affinity=True))
    assert fresh.transfers_avoided == aff.transfers_avoided
    # affinity_break=0 migrates instead of joining a backlogged home:
    # strictly fewer avoided transfers than always-stay
    strict = pd_cluster.simulate_pd(
        tr, mk(session_affinity=True, affinity_break=0.0))
    assert strict.transfers_avoided <= aff.transfers_avoided


def test_session_affinity_does_not_bypass_slo_shed(pd_cluster):
    """A follow-up turn whose home group cannot meet its SLO must be
    shed like any other request — affinity is not an admission-control
    bypass (and a shed follow-up is not counted as avoided)."""
    from repro.core.simulator import ClusterRequest
    router = PDRouter(prefill_pool=[0], decode_pool=[1, 2, 3],
                      max_kv_lag=1.0, session_affinity=True,
                      slo_shed=True)
    replicas = pd_cluster.build_replicas()
    first = ClusterRequest(rid=0, arrival=0.0, session=42)
    decision = router.route(first, replicas, 0.0)
    assert isinstance(decision, tuple)
    home = decision[1]
    doomed = ClusterRequest(rid=1, arrival=0.0, session=42,
                            slo_ttft=1e-12)
    assert router.route(doomed, replicas, 0.0) == -1
    assert router.transfers_avoided == 0
    fine = ClusterRequest(rid=2, arrival=0.0, session=42, slo_ttft=1e9)
    assert router.route(fine, replicas, 0.0) == home
    assert router.transfers_avoided == 1


def test_pd_router_shed_accounts_transfer_tail(pd_cluster):
    """With an interconnect, the shed estimate includes the KV-transfer
    tail, and overlapped streaming (kv_chunks>1) projects the EARLIER
    effective arrival — a request doomed by the serial transfer clears
    admission under streaming."""
    from repro.core.simulator import ClusterRequest
    ic = Interconnect(default_bw=1e8, base_latency=1e-4)
    replicas = pd_cluster.build_replicas()
    n = 16
    tail_serial = ic.transfer_time(8e6, 0, 1)
    tail_overlap = ic.base_latency + (8e6 / n) / 1e8
    assert tail_overlap < tail_serial
    tp = replicas[0].predicted_phase_service(
        ClusterRequest(rid=0, arrival=0.0), "prefill")
    slo = tp + (tail_serial + tail_overlap) / 2.0
    req = ClusterRequest(rid=0, arrival=0.0, kv_bytes=8e6, slo_ttft=slo)
    mk = lambda **kw: PDRouter(prefill_pool=[0],      # noqa: E731
                               decode_pool=[1, 2, 3], max_kv_lag=1.0,
                               slo_shed=True, interconnect=ic, **kw)
    assert mk().route(req, replicas, 0.0) == -1              # serial
    decision = mk(kv_chunks=n).route(req, replicas, 0.0)     # streamed
    assert isinstance(decision, tuple) and decision[0] == 0
    # without an interconnect the tail is unknown -> not charged
    no_ic = PDRouter(prefill_pool=[0], decode_pool=[1, 2, 3],
                     max_kv_lag=1.0, slo_shed=True)
    assert isinstance(no_ic.route(req, replicas, 0.0), tuple)
