"""Closed-loop autoscaling (serving/controller.py) + control-path
edges: the controller's DES decision loop (scale up under pressure,
down in troughs, hysteresis/cooldown, time-weighted billing), the
live-engine scale path (loss-free drain, bit-identical migrated
sessions, warm-up gating), sizing-history exactness, and failure
edges (t=0 apocalypse, draining the last group).
"""
import dataclasses

import numpy as np
import pytest

from conftest import random_dag
from repro.core.costmodel import CATALOG
from repro.serving.controller import (AutoscaleConfig, AutoscalePolicy,
                                      goodput_per_dollar)
from repro.serving.sizing import search_composition
from repro.serving.spec import DeploymentSpec
from repro.serving.workload import diurnal_trace, poisson_trace

SLOS = {"base": 2.0, "per_output_token": 0.02, "ttft": 0.5}
ANNEAL = 200


@pytest.fixture(scope="module")
def graph():
    return random_dag(24, seed=1)


def _ctl():
    return AutoscalePolicy(
        AutoscaleConfig(interval=0.05, window=0.2, cooldown=0.1,
                        warmup=0.05, queue_hi=0.5, queue_lo=0.15,
                        util_lo=0.6),
        inventory=[["a100", "l40s"], ["l40s"]])


@pytest.fixture(scope="module")
def elastic(graph):
    """(deployment, diurnal trace, static baseline result) shared by
    the controller tests — the spec is the static same-shape anchor."""
    spec = DeploymentSpec(groups=[["a100", "l40s"]],
                          router="jsed",
                          router_kwargs={"slo_shed": True},
                          slos=SLOS, budget=20.0, anneal_iters=ANNEAL)
    dep = spec.compile(graph)
    # peak demand ~3.4x the founding group's capacity; one full
    # diurnal cycle so the trough exercises scale-down
    rate = 2.0 * dep.cluster().capacity
    n = 3000
    trace = diurnal_trace(rate, n, seed=7, amplitude=0.7,
                          period=n / rate)
    return dep, trace, dep.simulate(trace)


# ===================================================================== #
# Controller configuration / binding
# ===================================================================== #
def test_autoscale_config_validation():
    with pytest.raises(ValueError, match="interval"):
        AutoscaleConfig(interval=0.0)
    with pytest.raises(ValueError, match="window"):
        AutoscaleConfig(interval=1.0, window=0.5)


def test_controller_requires_bind():
    ctl = _ctl()
    with pytest.raises(ValueError, match="bind"):
        ctl.begin(0.0)


def test_controller_rejects_second_deployment(graph):
    ctl = _ctl()
    d1 = DeploymentSpec(groups=[["l40s"]],
                        anneal_iters=ANNEAL).compile(graph)
    d2 = DeploymentSpec(groups=[["l40s"]],
                        anneal_iters=ANNEAL).compile(graph)
    ctl.bind(d1)
    ctl.bind(d1)                               # idempotent
    with pytest.raises(ValueError, match="already bound"):
        ctl.bind(d2)


# ===================================================================== #
# Closed loop on the DES backend
# ===================================================================== #
def test_controller_scales_up_under_pressure_and_down_in_trough(elastic):
    dep, trace, static = elastic
    ctl = _ctl()
    res = dep.simulate(trace, controller=ctl)
    kinds = {d.action for d in ctl.decisions}
    assert "up" in kinds, "no scale-up despite shed at the peak"
    assert "down" in kinds, "no scale-down despite the trough"
    # activating reserves under load must convert sheds into goodput
    assert res.shed < static.shed
    assert res.goodput > static.goodput
    # decisions respect the cooldown
    times = [d.time for d in ctl.decisions]
    assert all(b - a >= ctl.cfg.cooldown - 1e-9
               for a, b in zip(times, times[1:]))
    # reserves stay within the spec budget while active
    assert all(d.price_rate <= dep.spec.budget + 1e-9
               for d in ctl.decisions)


def test_controller_run_is_deterministic_and_replayable(elastic):
    dep, trace, _ = elastic
    ctl = _ctl()
    a = dep.simulate(trace, controller=ctl)
    first = list(ctl.decisions)
    # same policy object replayed on the same deployment: state resets
    b = dep.simulate(trace, controller=ctl)
    assert a.events == b.events and a.latencies == b.latencies
    assert first == ctl.decisions


def test_controller_billing_is_time_weighted(elastic):
    dep, trace, _ = elastic
    ctl = _ctl()
    res = dep.simulate(trace, controller=ctl)
    billed = ctl.billed_dollars()
    assert billed > 0.0
    # upper bound: everything (founders + the whole reserve pool)
    # provisioned for the whole run
    full_rate = dep.spec.price_rate + sum(
        CATALOG[n].price for g in ctl.inventory for n in g)
    assert billed <= full_rate * res.makespan / 3600.0 + 1e-9
    # lower bound: the founders alone for the whole run
    assert billed >= dep.spec.price_rate * res.makespan / 3600.0 - 1e-9
    # reserves that were never activated accrue nothing
    ctl2 = AutoscalePolicy(
        AutoscaleConfig(interval=0.05, window=0.2, shed_hi=10.0,
                        queue_hi=1e9, util_lo=-1.0),   # decide nothing
        inventory=[["l40s"]])
    res2 = dep.simulate(poisson_trace(1.0, 5, seed=0), controller=ctl2)
    assert not ctl2.decisions
    assert ctl2.billed_dollars() == pytest.approx(
        dep.spec.price_rate * res2.makespan / 3600.0)


def test_goodput_per_dollar_static_reduces_to_sizing_objective(elastic):
    _, _, static = elastic
    gpd = goodput_per_dollar(static)
    assert gpd == pytest.approx(
        static.slo_ok / (static.price_rate * static.makespan / 3600.0))


# ===================================================================== #
# Control-path edges
# ===================================================================== #
def test_failure_at_time_zero(graph):
    """A group that is dead before the first arrival: survivors take
    everything, nothing routes to the corpse, nothing crashes."""
    dep = DeploymentSpec(groups=[["a100", "l40s"], ["a100", "l40s"]],
                         anneal_iters=ANNEAL).compile(graph)
    trace = poisson_trace(rate=dep.cluster().capacity,
                          num_requests=60, seed=5)
    res = dep.simulate(trace, failures=[(0.0, 0)])
    assert res.completed == len(trace) and res.dropped == 0
    assert 0 not in res.assignments


def test_every_group_down_simultaneously(graph):
    """All groups dead at t=0: every request is shed (or dropped),
    none complete, and the DES terminates cleanly."""
    dep = DeploymentSpec(groups=[["a100", "l40s"], ["l40s"]],
                         anneal_iters=ANNEAL).compile(graph)
    trace = poisson_trace(rate=10.0, num_requests=30, seed=2)
    res = dep.simulate(trace, failures=[(0.0, 0), (0.0, 1)])
    assert res.completed == 0
    assert res.shed + res.dropped == len(trace)


def test_scale_remove_last_eligible_group_rejected(graph):
    dep = DeploymentSpec(groups=[["l40s"]],
                         anneal_iters=ANNEAL).compile(graph)
    with pytest.raises(ValueError, match="no eligible"):
        dep.scale(remove=[0], at=1.0)
    # scheduling the replacement FIRST makes the same drain legal —
    # provided its warm-up completes by the drain instant
    dep.scale(add=[["a100"]], at=0.0, warmup=0.5)
    dep.scale(remove=[0], at=1.0)
    trace = poisson_trace(rate=5.0, num_requests=40, seed=3)
    res = dep.simulate(trace)
    assert res.completed + res.shed + res.dropped == len(trace)
    # and a drain scheduled before the replacement is warm still raises
    dep2 = DeploymentSpec(groups=[["l40s"]],
                          anneal_iters=ANNEAL).compile(graph)
    dep2.scale(add=[["a100"]], at=0.0, warmup=2.0)
    with pytest.raises(ValueError, match="no eligible"):
        dep2.scale(remove=[0], at=1.0)


# ===================================================================== #
# Sizing history exactness (regression)
# ===================================================================== #
def test_sizing_history_counts_infeasible_iterations(graph):
    """Regression: infeasible mutations (budget/inventory reject the
    candidate) used to skip their history row, so plots and
    convergence checks silently mis-indexed.  With a one-template
    inventory, add/drop moves are always infeasible, yet history must
    still hold exactly iters + 1 rows."""
    inventory = {"l40s": 1}
    budget = CATALOG["l40s"].price + 0.01
    trace = poisson_trace(rate=20.0, num_requests=30, seed=1)
    sr = search_composition(inventory, budget, trace, graph,
                            iters=12, seed=0,
                            spec_kwargs={"slos": SLOS,
                                         "anneal_iters": 150})
    assert len(sr.history) == 13
    assert [row[0] for row in sr.history] == list(range(13))
    # best column never regresses
    bests = [row[2] for row in sr.history]
    assert bests == sorted(bests) or all(
        b2 >= b1 for b1, b2 in zip(bests, bests[1:]))


# ===================================================================== #
# Live-engine elasticity (real ServingEngines)
# ===================================================================== #
def _smoke_cfg():
    import repro.configs as configs
    return dataclasses.replace(configs.get_smoke("llama3_8b"),
                               dtype="float32")


def test_live_scale_drains_loss_free_bit_identical():
    """Draining a live engine mid-decode migrates every resident
    session (export_kv/import_kv) into survivors: zero drops, greedy
    tokens bit-identical to never having scaled, and the scaled-in
    engine is jit-primed before it becomes routable."""
    from repro.models import model as M
    from repro.serving.engine import Request, ServingEngine
    cfg = _smoke_cfg()
    params = M.init_params(cfg)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (6, 3, 9, 5)]

    def mk():
        return [Request(rid=i, prompt=p.copy(), max_new_tokens=8,
                        arrival=0.0) for i, p in enumerate(prompts)]

    singles = mk()
    ServingEngine(cfg, params, slots=4, max_len=32, sync_every=2) \
        .run(singles)
    want = [r.output for r in singles]

    ekw = {"slots": 2, "max_len": 32, "sync_every": 2}
    ld = DeploymentSpec(groups=[["h100"], ["l40s"]], arch="llama3_8b",
                        engine=ekw).compile().launch(cfg, params)
    ld.scale(add=[["a100"]], at=0.0)   # replacement first
    ld.scale(remove=[0], at=0.0)       # drain with sessions in flight
    split = mk()
    out = ld.run(split)
    assert [r.output for r in split] == want
    assert out["migrations"] >= 1 and out["wire_bytes"] > 0
    assert out["engine"]["completed"] == len(split)
    assert out["routable"] == [False, True, True]
    assert all(r.finished >= 0 for r in split), "dropped request"
    # migration must not restamp TTFT: the first token's stamp from
    # the source engine survives the move
    assert all(0 <= r.ttft <= r.finished for r in split)


def test_live_export_import_direct_bit_identical():
    """The migration primitives themselves: export mid-decode, import
    on a fresh engine, finish — outputs match an unmigrated run."""
    from repro.models import model as M
    from repro.serving.engine import Request, ServingEngine
    cfg = _smoke_cfg()
    params = M.init_params(cfg)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (4, 7)]

    def mk():
        return [Request(rid=i, prompt=p.copy(), max_new_tokens=6,
                        arrival=0.0) for i, p in enumerate(prompts)]

    ref = mk()
    ServingEngine(cfg, params, slots=2, max_len=32, sync_every=2) \
        .run(ref)
    want = [r.output for r in ref]

    src = ServingEngine(cfg, params, slots=2, max_len=32, sync_every=2)
    dst = ServingEngine(cfg, params, slots=2, max_len=32, sync_every=2)
    moved = mk()
    src.admit_batch(moved, 0.0)
    for _ in range(3):
        src.step(0.0)
    handoffs = src.export_sessions(0.0)
    assert not src._any_active(), "export left residents behind"
    for req, h in handoffs:
        assert h["kv_bytes"] > 0 and not h["done"]
        assert dst.import_session(req, h, 0.0)
    while dst._any_active():
        dst.step(0.0)
    dst.sync(0.0)
    assert [r.output for r in moved] == want


def test_live_scale_validation():
    from repro.models import model as M
    cfg = _smoke_cfg()
    params = M.init_params(cfg)
    ekw = {"slots": 2, "max_len": 32, "sync_every": 2}
    ld = DeploymentSpec(groups=[["h100"]], arch="llama3_8b",
                        engine=ekw).compile().launch(cfg, params)
    with pytest.raises(ValueError, match="last routable"):
        ld.scale(remove=[0])
    with pytest.raises(ValueError, match="cannot remove"):
        ld.scale(remove=[3])
    pd = DeploymentSpec(groups=[["h100"], ["l40s"]], pd=True,
                        arch="llama3_8b", engine=ekw) \
        .compile().launch(cfg, params)
    with pytest.raises(ValueError, match="pd"):
        pd.scale(add=[["a100"]])
