"""Declarative deployment API: spec validation + round trip, shim
parity (legacy entry points == Deployment, bit-identical event logs),
replica-level fault injection, autoscaling drain/warm-up, cost-model
calibration, composition search, and the real-engine launch backend.
"""
import dataclasses
import json

import numpy as np
import pytest

from conftest import random_dag
import repro.configs as configs
from repro.core.costmodel import (CATALOG, Calibration, ScaledDevice,
                                  calibrate)
from repro.core.simulator import ControlEvent, Interconnect
from repro.serving.cluster import TesseraCluster
from repro.serving.router import (ROUTERS, JSEDRouter, LeastLoadedRouter,
                                  PDRouter, RoundRobinRouter, Router,
                                  make_router, register_router)
from repro.serving.sizing import (group_templates, search_composition,
                                  uniform_composition)
from repro.serving.spec import Deployment, DeploymentSpec
from repro.serving.workload import poisson_trace

GROUPS = [["h100", "rtxpro6000"], ["a100", "l40s"], ["a100", "l40s"]]
ANNEAL = 200


def pd_graph(n: int = 24, seed: int = 2):
    """Random DAG, first half prefill / second half decode (the shape
    request_graph produces from real models)."""
    g = random_dag(n, seed=seed)
    nodes = [dataclasses.replace(
        node, phase="prefill" if node.idx < n // 2 else "decode")
        for node in g.nodes]
    g2 = type(g)(nodes, dict(g.edges), name=g.name + ".dep")
    g2.validate()
    return g2


@pytest.fixture(scope="module")
def graph():
    return pd_graph()


@pytest.fixture(scope="module")
def legacy_cluster(graph):
    return TesseraCluster(graph, GROUPS, anneal_iters=ANNEAL)


@pytest.fixture(scope="module")
def deployment(graph):
    return DeploymentSpec(groups=GROUPS,
                          anneal_iters=ANNEAL).compile(graph)


def loaded_trace(deployment, n=150, load=1.5, seed=5):
    return poisson_trace(rate=load * deployment.cluster().capacity,
                         num_requests=n, seed=seed)


def loaded_trace_from(cluster, n=150, load=1.5, seed=5):
    return poisson_trace(rate=load * cluster.capacity,
                         num_requests=n, seed=seed)


# ===================================================================== #
# Spec validation + serialization
# ===================================================================== #
def test_spec_validation_errors():
    with pytest.raises(ValueError, match="unknown device"):
        DeploymentSpec(groups=[["v100"]])
    with pytest.raises(ValueError, match="non-empty"):
        DeploymentSpec(groups=[])
    with pytest.raises(ValueError, match="non-empty"):
        DeploymentSpec(groups=[[]])
    with pytest.raises(ValueError, match="unknown router"):
        DeploymentSpec(groups=[["h100"]], router="oracle")
    with pytest.raises(ValueError, match="requires pd"):
        DeploymentSpec(groups=[["h100"]], kv_chunks=4)
    with pytest.raises(ValueError, match="unknown slo keys"):
        DeploymentSpec(groups=[["h100"]], slos={"p99": 1.0})
    with pytest.raises(ValueError, match="positive deadline"):
        DeploymentSpec(groups=[["h100"]], slos={"base": 0.0})
    with pytest.raises(ValueError, match="unknown interconnect keys"):
        DeploymentSpec(groups=[["h100"]], interconnect={"bw_gbps": 1})
    with pytest.raises(ValueError, match="src-dst"):
        DeploymentSpec(groups=[["h100"]],
                       interconnect={"bw": {"a-b": 1e9}})
    with pytest.raises(ValueError, match="initial_policy"):
        DeploymentSpec(groups=[["h100"]], initial_policy="balanced")
    with pytest.raises(ValueError, match="over the"):
        DeploymentSpec(groups=[["b200", "b200"]], budget=5.0)
    with pytest.raises(ValueError, match="unknown engine keys"):
        DeploymentSpec(groups=[["h100"]], engine={"max_length": 256})
    # within budget is fine
    DeploymentSpec(groups=[["a100", "l40s"]], budget=5.0)


def test_spec_json_round_trip(tmp_path):
    spec = DeploymentSpec(
        groups=GROUPS, arch="llama3_8b", base_prompt=512, base_output=64,
        router="pd_split",
        router_kwargs={"prefill_pool": [0], "decode_pool": [1, 2],
                       "session_affinity": True, "affinity_break": 0.1},
        pd=True, kv_chunks=8,
        interconnect={"default_bw": 50e9, "bw": {"0-1": 200e9}},
        slos={"base": 2.0, "per_output_token": 0.02, "ttft": 0.3},
        budget=20.0,
        calibration={"ttft_wall_over_model": 3.0,
                     "tpot_wall_over_model": 2.0},
        monitor={"window": 0.05}, anneal_iters=300,
        engine={"slots": 2, "max_len": 32})
    assert DeploymentSpec.from_json(spec.to_json()) == spec
    path = tmp_path / "spec.json"
    spec.save(path)
    assert DeploymentSpec.load(path) == spec
    with pytest.raises(ValueError, match="unknown DeploymentSpec"):
        DeploymentSpec.from_json(json.dumps(
            {"groups": [["h100"]], "routr": "jsed"}))
    ic = spec.make_interconnect()
    assert ic.bandwidth(0, 1) == 200e9 and ic.bandwidth(1, 2) == 50e9


def test_spec_price_rate():
    spec = DeploymentSpec(groups=[["h100", "rtxpro6000"], ["l40s"]])
    assert spec.price_rate == pytest.approx(
        CATALOG["h100"].price + CATALOG["rtxpro6000"].price
        + CATALOG["l40s"].price)


# ===================================================================== #
# Shim parity: legacy entry points == Deployment, bit-identical
# ===================================================================== #
@pytest.mark.parametrize("router_name", ["round_robin", "least_loaded",
                                         "jsed"])
def test_colocated_parity_all_routers(graph, legacy_cluster, deployment,
                                      router_name):
    trace = loaded_trace(deployment)
    old = legacy_cluster.simulate(trace, make_router(router_name))
    spec = DeploymentSpec(groups=GROUPS, router=router_name,
                          anneal_iters=ANNEAL)
    new = spec.compile(graph).simulate(trace)
    assert old.events == new.events
    assert old.latencies == new.latencies
    assert old.ttfts == new.ttfts
    assert old.assignments == new.assignments
    assert old.makespan == new.makespan


@pytest.mark.parametrize("kv_chunks", [1, 4])
def test_pd_parity_split_router(graph, legacy_cluster, kv_chunks):
    trace = loaded_trace_from(legacy_cluster)
    kw = dict(prefill_frac=0.34, max_kv_lag=1.0)
    old = legacy_cluster.simulate_pd(
        trace, PDRouter(interconnect=Interconnect(),
                        kv_chunks=kv_chunks, **kw),
        kv_chunks=kv_chunks)
    spec = DeploymentSpec(groups=GROUPS, router="pd_split",
                          router_kwargs=kw, pd=True,
                          kv_chunks=kv_chunks, anneal_iters=ANNEAL)
    new = spec.compile(graph).simulate(trace)
    assert old.events == new.events
    assert old.ttfts == new.ttfts
    assert old.transfers == new.transfers
    assert old.peak_kv_bytes == new.peak_kv_bytes
    assert old.transfer_seconds == new.transfer_seconds


def test_pd_parity_colocated_router_through_pd_path(graph,
                                                    legacy_cluster):
    """simulate_cluster_pd with an int-returning router must equal the
    plain colocated path (the two legacy loops collapsed into one)."""
    trace = loaded_trace_from(legacy_cluster)
    a = legacy_cluster.simulate(trace, JSEDRouter())
    b = legacy_cluster.simulate_pd(trace, JSEDRouter())
    assert a.events == b.events
    assert a.latencies == b.latencies


# ===================================================================== #
# Fault injection
# ===================================================================== #
def test_failure_reroutes_in_flight(deployment):
    trace = loaded_trace(deployment)
    t_fail = trace[70].arrival
    res = deployment.simulate(trace, failures=[(t_fail, 0)])
    assert res.completed == len(trace)
    assert res.rerouted > 0
    assert res.dropped == 0
    ordered = sorted(trace, key=lambda r: (r.arrival, r.rid))
    after = [a for r, a in zip(ordered, res.assignments)
             if r.arrival > t_fail]
    assert after and 0 not in after, \
        "router must stop sending work to a dead group"
    # recovery is visible but bounded: everything still completes on
    # the survivors, at higher latency than the no-failure run
    base = deployment.simulate(trace)
    assert res.mean_latency >= base.mean_latency


def test_failure_of_every_group_drops_and_sheds(deployment):
    trace = loaded_trace(deployment, n=60)
    t_fail = trace[30].arrival
    res = deployment.simulate(
        trace, failures=[(t_fail, g) for g in range(3)])
    # arrivals after the apocalypse are shed; in-flight victims with
    # no survivor to re-route to are dropped
    assert res.shed > 0
    assert res.completed + res.shed + res.dropped == len(trace)
    assert res.completed == len([r for r in res.assignments if r >= 0])


def test_failure_event_log_deterministic(deployment):
    trace = loaded_trace(deployment)
    t_fail = trace[50].arrival
    a = deployment.simulate(trace, failures=[(t_fail, 1)])
    b = deployment.simulate(trace, failures=[(t_fail, 1)])
    assert a.events == b.events and a.latencies == b.latencies
    assert a.rerouted == b.rerouted


def test_failure_in_pd_deployment_recovers(graph):
    """Killing a decode-pool group mid-trace under phase-split routing:
    victims re-route (their resident-KV intervals end at the failure,
    not at their phantom finish), the pool collapses onto survivors,
    and everything completes."""
    spec = DeploymentSpec(groups=GROUPS, router="pd_split",
                          router_kwargs={"prefill_pool": [0],
                                         "decode_pool": [1, 2],
                                         "max_kv_lag": 1.0},
                          pd=True, anneal_iters=ANNEAL)
    dep = spec.compile(graph)
    trace = loaded_trace(dep)
    base = dep.simulate(trace)
    t_fail = trace[70].arrival
    res = dep.simulate(trace, failures=[(t_fail, 1)])
    assert res.completed == len(trace) and res.dropped == 0
    assert res.rerouted > 0
    ordered = sorted(trace, key=lambda r: (r.arrival, r.rid))
    after = [a for r, a in zip(ordered, res.assignments)
             if r.arrival > t_fail]
    assert 1 not in after
    # dead-group residency is truncated at the failure instant, so the
    # failure cannot inflate peak resident KV past base + one re-routed
    # handoff's worth of double-booking window
    assert res.peak_kv_bytes <= base.peak_kv_bytes * 3 + 1e-9


def test_control_event_validation(deployment):
    with pytest.raises(ValueError, match="unknown control-event"):
        ControlEvent(0.0, "explode", 0)
    with pytest.raises(ValueError, match="cannot fail group"):
        deployment.simulate(loaded_trace(deployment, n=10),
                            failures=[(0.0, 9)])


# ===================================================================== #
# Autoscaling: drain + warm-up
# ===================================================================== #
def test_drain_is_loss_free(graph):
    spec = DeploymentSpec(groups=GROUPS, anneal_iters=ANNEAL)
    dep = spec.compile(graph)
    trace = loaded_trace(dep)
    t_mid = trace[70].arrival
    dep.scale(remove=[2], at=t_mid)
    res = dep.simulate(trace)
    assert res.completed == len(trace), "drain dropped accepted requests"
    assert res.dropped == 0 and res.shed == 0
    ordered = sorted(trace, key=lambda r: (r.arrival, r.rid))
    after = [a for r, a in zip(ordered, res.assignments)
             if r.arrival > t_mid]
    assert 2 not in after, "drained group took new requests"
    # requests routed to group 2 before the drain still completed there
    before = [a for r, a in zip(ordered, res.assignments)
              if r.arrival <= t_mid]
    assert 2 in before


def test_warmup_gates_added_group(graph):
    spec = DeploymentSpec(groups=GROUPS[:2], anneal_iters=ANNEAL)
    dep = spec.compile(graph)
    trace = loaded_trace(dep, n=200)
    t_mid = trace[60].arrival
    warm = 0.5 * (trace[-1].arrival - t_mid)
    dep.scale(add=[["h100", "rtxpro6000"]], at=t_mid, warmup=warm)
    res = dep.simulate(trace)
    ordered = sorted(trace, key=lambda r: (r.arrival, r.rid))
    pre_warm = [a for r, a in zip(ordered, res.assignments)
                if r.arrival <= t_mid + warm]
    post_warm = [a for r, a in zip(ordered, res.assignments)
                 if r.arrival > t_mid + warm]
    assert 2 not in pre_warm, "group served before its warm-up finished"
    assert 2 in post_warm, "warmed-up group never became eligible"
    assert res.dropped == 0
    assert dep.price_rate > spec.price_rate


def test_scale_validation(graph):
    dep = DeploymentSpec(groups=GROUPS, anneal_iters=ANNEAL).compile(graph)
    with pytest.raises(ValueError, match="cannot remove"):
        dep.scale(remove=[7])
    with pytest.raises(ValueError, match="unknown device"):
        dep.scale(add=[["v100"]])


def test_pd_pool_collapses_onto_survivors(graph):
    """Draining the whole prefill pool must not strand the decode pool:
    survivors serve both phases colocated, loss-free."""
    spec = DeploymentSpec(groups=GROUPS, router="pd_split",
                          router_kwargs={"prefill_pool": [0],
                                         "decode_pool": [1, 2],
                                         "max_kv_lag": 1.0},
                          pd=True, anneal_iters=ANNEAL)
    dep = spec.compile(graph)
    trace = loaded_trace(dep)
    t_mid = trace[70].arrival
    dep.scale(remove=[0], at=t_mid)
    res = dep.simulate(trace)
    assert res.completed == len(trace) and res.dropped == 0
    ordered = sorted(trace, key=lambda r: (r.arrival, r.rid))
    after = [a for r, a in zip(ordered, res.assignments)
             if r.arrival > t_mid]
    assert after and 0 not in after


# ===================================================================== #
# Cost-model calibration
# ===================================================================== #
def test_calibrate_parses_line_dict_and_aliases():
    line = ('CALIBRATION {"modeled_ttft_s": 1e-4, "wall_ttft_s": 3e-4, '
            '"ttft_wall_over_model": 3.0, "tpot_wall_over_model": 2.0}')
    for payload in (line, json.loads(line[len("CALIBRATION"):]),
                    {"prefill_scale": 3.0, "decode_scale": 2.0}):
        cal = calibrate(payload)
        assert cal.prefill_scale == 3.0 and cal.decode_scale == 2.0
        assert cal.scale == pytest.approx((3.0 * 2.0) ** 0.5)
    with pytest.raises(ValueError, match="neither"):
        calibrate({"foo": 1.0})
    with pytest.raises(ValueError, match="positive"):
        Calibration(prefill_scale=-1.0)


def test_scaled_device_is_phase_aware(graph):
    cal = Calibration(prefill_scale=3.0, decode_scale=2.0)
    dev = ScaledDevice(CATALOG["h100"], cal)
    pre = next(n for n in graph.nodes if n.phase == "prefill")
    dec = next(n for n in graph.nodes if n.phase == "decode")
    base = CATALOG["h100"]
    assert dev.kernel_time(pre) == pytest.approx(base.kernel_time(pre) * 3)
    assert dev.kernel_time(dec) == pytest.approx(base.kernel_time(dec) * 2)
    assert dev.transfer_time(1e6, CATALOG["a100"]) == \
        base.transfer_time(1e6, CATALOG["a100"])
    assert dev.name != base.name        # distinct plan-cache identity
    assert dev.price == base.price


def test_spec_calibration_slows_des(graph):
    trace = poisson_trace(rate=500.0, num_requests=60, seed=3)
    plain = DeploymentSpec(groups=GROUPS[:1], anneal_iters=ANNEAL)
    cal = DeploymentSpec(groups=GROUPS[:1], anneal_iters=ANNEAL,
                         calibration={"ttft_wall_over_model": 4.0,
                                      "tpot_wall_over_model": 4.0})
    r_plain = plain.compile(graph).simulate(trace)
    r_cal = cal.compile(graph).simulate(trace)
    assert r_cal.mean_latency > 2.0 * r_plain.mean_latency


# ===================================================================== #
# Router registry
# ===================================================================== #
def test_register_router_roundtrip(graph):
    class FirstRouter(Router):
        name = "always_first"

        def route(self, req, replicas, now):
            return 0

    try:
        register_router(FirstRouter)
        assert isinstance(make_router("always_first"), FirstRouter)
        spec = DeploymentSpec(groups=GROUPS[:2], router="always_first",
                              anneal_iters=ANNEAL)
        res = spec.compile(graph).simulate(
            poisson_trace(rate=100.0, num_requests=20, seed=1))
        assert set(res.assignments) == {0}
    finally:
        ROUTERS.pop("always_first", None)
    with pytest.raises(ValueError, match="distinct class-level"):
        register_router(Router)


def test_routers_skip_ineligible():
    class Stub:
        def __init__(self, eligible=True):
            self.eligible = eligible

        def backlog(self, now):
            return 0.0

        def predicted_service(self, req):
            return 1.0

    reps = [Stub(False), Stub(True), Stub(False)]
    req = None
    rr = RoundRobinRouter()
    assert [rr.route(req, reps, 0.0) for _ in range(3)] == [1, 1, 1]
    assert LeastLoadedRouter().route(req, reps, 0.0) == 1
    none = [Stub(False)]
    assert RoundRobinRouter().route(req, none, 0.0) == -1
    assert LeastLoadedRouter().route(req, none, 0.0) == -1


# ===================================================================== #
# Composition search (sizing)
# ===================================================================== #
INVENTORY = {"h100": 2, "rtxpro6000": 2, "a100": 3, "l40s": 4}
BUDGET = 9.0


def test_group_templates_respect_inventory():
    ts = group_templates({"h100": 1, "l40s": 2})
    assert ("h100",) in ts and ("l40s", "l40s") in ts
    assert ("h100", "h100") not in ts   # only one in stock
    with pytest.raises(ValueError, match="unknown device"):
        group_templates({"v100": 1})


def test_uniform_composition_fits_budget(graph):
    comp = uniform_composition(INVENTORY, BUDGET, graph,
                               anneal_iters=150)
    assert comp and len({tuple(g) for g in comp}) == 1
    price = sum(CATALOG[n].price for g in comp for n in g)
    assert price <= BUDGET + 1e-9


def test_search_composition_beats_or_matches_seed(graph):
    trace = poisson_trace(rate=2000.0, num_requests=80, seed=9)
    slos = {"base": 2.0, "per_output_token": 0.02, "ttft": 0.3}
    sr = search_composition(
        INVENTORY, BUDGET, trace, graph, iters=10, seed=0,
        spec_kwargs={"slos": slos, "anneal_iters": 150})
    assert sr.score >= sr.seed_score
    assert sr.spec.price_rate <= BUDGET + 1e-9
    from collections import Counter
    used = Counter(n for g in sr.spec.groups for n in g)
    assert all(used[n] <= INVENTORY[n] for n in used)
    assert sr.evals >= 1 and len(sr.history) >= 1
    # deterministic in (inventory, budget, trace, seed)
    sr2 = search_composition(
        INVENTORY, BUDGET, trace, graph, iters=10, seed=0,
        spec_kwargs={"slos": slos, "anneal_iters": 150})
    assert sr.spec == sr2.spec and sr.score == sr2.score
    assert sr.history == sr2.history


# ===================================================================== #
# Launch backend: real engines from the spec
# ===================================================================== #
def _smoke(arch):
    return dataclasses.replace(configs.get_smoke(arch), dtype="float32")


def test_launch_matches_single_engine_all_backends():
    """The spec's three launch shapes — single engine, serial PD
    handoff, streamed PD handoff — must produce bit-identical greedy
    tokens (the acceptance criterion for subsuming the example flow)."""
    from repro.serving.engine import Request, ServingEngine
    cfg = _smoke("llama3_8b")
    from repro.models import model as M
    params = M.init_params(cfg)
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (6, 3, 9)]

    def mk():
        return [Request(rid=i, prompt=p.copy(), max_new_tokens=6,
                        arrival=0.0) for i, p in enumerate(prompts)]

    singles = mk()
    ServingEngine(cfg, params, slots=2, max_len=32, sync_every=2) \
        .run(singles)
    want = [r.output for r in singles]
    ekw = {"slots": 2, "max_len": 32, "sync_every": 2}

    solo = mk()
    out = DeploymentSpec(groups=[["h100"]], arch="llama3_8b",
                         engine=ekw).compile() \
        .launch(cfg, params).run(solo)
    assert [r.output for r in solo] == want
    assert out["wire_bytes"] == 0

    serial = mk()
    out = DeploymentSpec(groups=[["h100"], ["l40s"]], pd=True,
                         arch="llama3_8b", engine=ekw).compile() \
        .launch(cfg, params).run(serial)
    assert [r.output for r in serial] == want
    assert out["wire_bytes"] > 0 and out["shards"] == 0
    assert out["engine"]["prefill_batches"] == 0    # decode-only side

    streamed = mk()
    out = DeploymentSpec(groups=[["h100"], ["l40s"]], pd=True,
                         kv_chunks=8, arch="llama3_8b",
                         engine=ekw).compile() \
        .launch(cfg, params).run(streamed)
    assert [r.output for r in streamed] == want
    assert out["shards"] > 0


def test_launch_without_graph_simulate_raises():
    dep = DeploymentSpec(groups=[["h100"]], arch="llama3_8b").compile()
    with pytest.raises(ValueError, match="compile\\(graph\\)"):
        dep.simulate(poisson_trace(rate=10.0, num_requests=5, seed=0))
