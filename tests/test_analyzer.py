"""Kernel analyzer: DDG extraction, costs, tags, fusion, state detection."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import marker
from repro.core.analyzer import analyze, _dot_general_flops
from repro.core.graph import KernelGraph, KernelNode


def test_raw_dependency_extraction():
    def f(x, w):
        a = x @ w          # 0
        b = a + 1.0        # fused into consumer
        c = b @ w          # 1
        return c

    tg = analyze(f, jnp.ones((8, 8)), jnp.ones((8, 8)))
    g = tg.graph
    dots = [n for n in g.nodes if n.name == "dot_general"]
    assert len(dots) == 2
    # the two dots must be connected by a RAW edge carrying 8*8*4 bytes
    byte_map = {(i, j): b for (i, j), b in g.edges.items()}
    assert any(b == 8 * 8 * 4 for b in byte_map.values())


def test_dot_general_flops_exact():
    def f(x, w):
        return x @ w

    tg = analyze(f, jnp.ones((16, 32)), jnp.ones((32, 64)), fuse=False)
    dot = [n for n in tg.graph.nodes if n.name == "dot_general"][0]
    assert dot.flops == 2 * 16 * 32 * 64


def test_batched_dot_flops():
    def f(x, w):
        return jnp.einsum("bij,bjk->bik", x, w)

    tg = analyze(f, jnp.ones((4, 8, 16)), jnp.ones((4, 16, 32)), fuse=False)
    dot = [n for n in tg.graph.nodes if n.name == "dot_general"][0]
    assert dot.flops == 2 * 4 * 8 * 16 * 32


def test_marker_tags_and_removal():
    def f(x, w1, w2):
        a = marker.wrap(lambda y: y @ w1, block="attention", layer=3)(x)
        b = marker.wrap(lambda y: y @ w2, block="ffn", layer=3)(a)
        return b

    tg = analyze(f, jnp.ones((4, 8)), jnp.ones((8, 8)), jnp.ones((8, 8)))
    blocks = {n.block for n in tg.graph.nodes}
    assert blocks == {"attention", "ffn"}
    assert all(n.layer == 3 for n in tg.graph.nodes)
    assert all(n.name != marker.MARKER_NAME for n in tg.graph.nodes)
    # dataflow through markers must be preserved as an edge
    assert tg.graph.num_edges >= 1


def test_nested_markers_restore_outer_tag():
    def f(x, w):
        x, close = marker.tag(x, phase="decode")
        x = marker.wrap(lambda y: y @ w, block="attention")(x)
        x = x @ w          # still inside "decode", no block
        return close(x)

    tg = analyze(f, jnp.ones((4, 8)), jnp.ones((8, 8)))
    dots = [n for n in tg.graph.nodes if n.name == "dot_general"]
    assert dots[0].block == "attention" and dots[0].phase == "decode"
    assert dots[1].block == "" and dots[1].phase == "decode"


def test_fusion_reduces_elementwise_nodes():
    def f(x, w):
        h = x @ w
        h = jnp.tanh(h) * 2.0 + 1.0
        return h @ w

    raw = analyze(f, jnp.ones((8, 8)), jnp.ones((8, 8)), fuse=False)
    fused = analyze(f, jnp.ones((8, 8)), jnp.ones((8, 8)), fuse=True)
    assert len(fused.graph) < len(raw.graph)
    # flops conserved by fusion
    assert np.isclose(fused.graph.total_flops(), raw.graph.total_flops())


def test_scan_cost_scales_with_length():
    def body(c, _):
        return jnp.tanh(c @ c), None

    def f(x):
        out, _ = jax.lax.scan(body, x, None, length=7)
        return out

    tg = analyze(f, jnp.ones((8, 8)), fuse=False)
    scan_node = [n for n in tg.graph.nodes if n.name == "scan"][0]
    # 7 iterations of an 8x8x8 matmul plus tanh
    assert scan_node.flops >= 7 * 2 * 8 * 8 * 8


def test_state_reader_writer_detection():
    def step(kv, x):
        read = kv[0] + x.sum()            # reads state
        new_kv = kv.at[0].set(x.sum())    # writes state
        return new_kv, read

    kv = jnp.zeros((4,))
    x = jnp.ones((3,))
    tg = analyze(step, kv, x, state_argnums=(0,))
    assert tg.state_readers, "kernels reading KV state must be detected"
    assert tg.state_writers, "kernels writing KV state must be detected"


def test_shape_dtype_struct_inputs():
    def f(x, w):
        return jax.nn.relu(x @ w)

    tg = analyze(f, jax.ShapeDtypeStruct((128, 256), jnp.bfloat16),
                 jax.ShapeDtypeStruct((256, 512), jnp.bfloat16))
    dot = [n for n in tg.graph.nodes if n.name == "dot_general"][0]
    assert dot.flops == 2 * 128 * 256 * 512


def test_graph_validate_catches_bad_edge():
    g = KernelGraph(
        [KernelNode(0, "a", 1, 1, 1), KernelNode(1, "b", 1, 1, 1)],
        {(1, 0): 4.0})
    with pytest.raises(AssertionError):
        g.validate()


def test_layer_signature_groups_fold_identical_layers():
    def f(x, params):
        for i, w in enumerate(params):
            x = marker.wrap(lambda y, a=w: jnp.tanh(y @ a), layer=i)(x)
        return x

    params = [jnp.ones((8, 8))] * 5
    tg = analyze(f, jnp.ones((4, 8)), params)
    groups = tg.graph.layer_signature_groups()
    sizes = sorted(len(v) for v in groups.values())
    assert sizes[-1] == 5, "5 identical layers must share one signature"
