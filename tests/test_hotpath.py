"""Fast-path parity: the indexed/fused dispatch program must be
bit-identical to the reference execution, across all four kernel
families, and must place weights exactly once per device."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.core import analyzer, planner
from repro.core.costmodel import GPU_A100, GPU_L40S
from repro.core.executor import build_executable
from repro.core.pipeline import PipelinedRunner
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.mamba2_ssd.ref import ssd_ref
from repro.kernels.moe_gmm.ref import gmm_ref
from repro.kernels.rwkv6.ref import wkv_ref
from repro.models import model as M
from repro.serving.engine import Request, ServingEngine

DEVS = [GPU_A100, GPU_L40S]
KEY = jax.random.PRNGKey(0)


# --------------------------------------------------------------------- #
# One representative computation per kernel family.  Each mixes the
# family's core op with surrounding elementwise/matmul work so the
# planner produces a multi-stage decomposition worth fusing.
# --------------------------------------------------------------------- #
def _flash_attention_case():
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (1, 2, 64, 32))
    k = jax.random.normal(ks[1], (1, 2, 64, 32))
    v = jax.random.normal(ks[2], (1, 2, 64, 32))
    w = jax.random.normal(ks[3], (32, 32)) * 0.2

    def fn(q, k, v, w):
        o = attention_ref(q, k, v, causal=True)
        return jnp.tanh(o @ w).sum(axis=1), o.mean()
    return fn, (q, k, v, w)


def _moe_gmm_case():
    sizes = jnp.asarray([10, 22, 0, 16], jnp.int32)
    T, d, E, f = 48, 16, 4, 32
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], (T, d))
    w = jax.random.normal(ks[1], (E, d, f)) * 0.1

    def fn(x, w):
        h = gmm_ref(x, w, sizes)
        return jax.nn.gelu(h).sum(axis=-1), h.max()
    return fn, (x, w)


def _rwkv6_case():
    ks = jax.random.split(KEY, 6)
    B, S, H, P = 1, 16, 2, 8
    r = jax.random.normal(ks[0], (B, S, H, P)) * 0.5
    k = jax.random.normal(ks[1], (B, S, H, P)) * 0.5
    v = jax.random.normal(ks[2], (B, S, H, P)) * 0.5
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H, P)))
    u = jax.random.normal(ks[4], (H, P)) * 0.1
    s0 = jax.random.normal(ks[5], (B, H, P, P)) * 0.1

    def fn(r, k, v, w, u, s0):
        y, sT = wkv_ref(r, k, v, w, u, s0)
        return jnp.tanh(y), sT
    return fn, (r, k, v, w, u, s0)


def _mamba2_ssd_case():
    ks = jax.random.split(KEY, 4)
    B, S, H, P, N = 1, 32, 2, 8, 4
    xh = jax.random.normal(ks[0], (B, S, H, P)) * 0.5
    B_ = jax.random.normal(ks[1], (B, S, N)) * 0.5
    C_ = jax.random.normal(ks[2], (B, S, N)) * 0.5
    a_log = -jax.nn.softplus(jax.random.normal(ks[3], (B, S, H)))

    def fn(xh, B_, C_, a_log):
        y, hT = ssd_ref(xh, B_, C_, a_log)
        return y.sum(axis=-1), hT
    return fn, (xh, B_, C_, a_log)


CASES = {
    "flash_attention": _flash_attention_case,
    "moe_gmm": _moe_gmm_case,
    "rwkv6": _rwkv6_case,
    "mamba2_ssd": _mamba2_ssd_case,
}


def _assert_bit_identical(got, want):
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)), got, want)


@pytest.mark.parametrize("family", sorted(CASES))
@pytest.mark.parametrize("policy", ["throughput", "latency"])
def test_fast_path_bit_identical(family, policy):
    """Indexed-env (fused) executor == reference per-stage walk ==
    plain fn(*args), bitwise."""
    fn, args = CASES[family]()
    tg = analyzer.analyze(fn, *args)
    p = planner.plan(tg.graph, DEVS, policy=policy, cache=False)
    exe = build_executable(tg, p)
    fast = exe(*args)
    ref = exe.call_reference(*args)
    want = jax.jit(fn)(*args)
    _assert_bit_identical(fast, ref)
    _assert_bit_identical(fast, want)


@pytest.mark.parametrize("family", sorted(CASES))
def test_pipelined_runner_fast_path_parity(family):
    fn, args = CASES[family]()
    tg = analyzer.analyze(fn, *args)
    p = planner.plan(tg.graph, DEVS, cache=False)
    exe = build_executable(tg, p)
    runner = PipelinedRunner(exe, max_inflight=3)
    outs, stats = runner.run([(args, {})] * 3)
    assert stats.completed == 3
    # fusion must not dispatch more units than plan stages
    assert stats.stage_dispatches == 3 * exe.num_units
    assert exe.num_units <= len(exe.stages)
    want = jax.jit(fn)(*args)
    for o in outs:
        _assert_bit_identical(o, want)


def test_weights_placed_exactly_once_across_calls():
    """Repeated calls with identical params must not re-place weights;
    the cache key is (arg slot, device index) — stable across GC."""
    fn, args = CASES["flash_attention"]()
    tg = analyzer.analyze(fn, *args)
    p = planner.plan(tg.graph, DEVS, cache=False)
    exe = build_executable(tg, p)
    exe(*args)
    placed_after_first = exe.weight_places
    for _ in range(3):
        exe(*args)
    assert exe.weight_places == placed_after_first
    # per (slot, device) pair at most one cache entry
    assert len(exe._weight_cache) <= len(exe.program.arg_slots) * max(
        1, len(exe._devices))
    # changed weights must be re-placed (identity check, not id())
    new_args = tuple(a + 0 for a in args)
    exe(*new_args)
    assert exe.weight_places >= placed_after_first


def test_fusion_reduces_dispatch_on_single_device():
    """On one physical device every stage fuses into a single unit."""
    fn, args = CASES["moe_gmm"]()
    tg = analyzer.analyze(fn, *args)
    p = planner.plan(tg.graph, DEVS, cache=False)
    exe = build_executable(tg, p)     # default map: one physical device
    if len(exe.stages) > 1:
        assert exe.num_units == 1


# --------------------------------------------------------------------- #
# Sync-free engine semantics
# --------------------------------------------------------------------- #
def _engine_cfg():
    return dataclasses.replace(configs.get_smoke("qwen3_1_7b"),
                               dtype="float32")


def test_engine_sync_every_invariant():
    """Token streams must not depend on the sync cadence."""
    cfg = _engine_cfg()
    params = M.init_params(cfg)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (4, 7, 5, 6, 4)]

    def run(sync_every):
        reqs = [Request(rid=i, prompt=p, max_new_tokens=6, arrival=0.0)
                for i, p in enumerate(prompts)]
        eng = ServingEngine(cfg, params, slots=3, max_len=32,
                            sync_every=sync_every)
        stats = eng.run(reqs)
        assert stats.completed == len(reqs)
        return [r.output for r in reqs]

    base = run(1)
    for k in (2, 4, 16):
        assert run(k) == base


def test_engine_batched_prefill_matches_sequential():
    """Padded multi-request prefill must reproduce batch-1 prefills."""
    cfg = _engine_cfg()
    params = M.init_params(cfg)
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (3, 9, 6)]         # deliberately ragged

    def naive(prompt, n):
        cache = M.init_cache(cfg, 1, 64)
        logits, cache = M.prefill(params, cfg,
                                  jnp.asarray(prompt)[None], cache)
        toks = [int(jnp.argmax(logits, -1)[0])]
        pos = len(prompt)
        for _ in range(n - 1):
            lg, cache = M.decode_step(
                params, cfg, jnp.asarray([[toks[-1]]], jnp.int32),
                cache, jnp.asarray([pos], jnp.int32))
            toks.append(int(jnp.argmax(lg, -1)[0]))
            pos += 1
        return toks

    want = [naive(p, 5) for p in prompts]
    reqs = [Request(rid=i, prompt=p, max_new_tokens=5, arrival=0.0)
            for i, p in enumerate(prompts)]
    eng = ServingEngine(cfg, params, slots=3, max_len=64, sync_every=4)
    stats = eng.run(reqs)
    assert stats.prefill_batches == 1      # ONE padded admission batch
    assert [r.output for r in reqs] == want


def test_engine_ssm_family_matches_sequential():
    """Recurrent families must NOT be length-padded at prefill: the
    state integrates every input token, so engine output must equal the
    sequential reference for prompts of awkward (non-multiple-of-8)
    lengths."""
    cfg = dataclasses.replace(configs.get_smoke("rwkv6_3b"),
                              dtype="float32")
    params = M.init_params(cfg)
    rng = np.random.default_rng(9)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (6, 3, 6)]

    def naive(prompt, n):
        cache = M.init_cache(cfg, 1, 32)
        logits, cache = M.prefill(params, cfg,
                                  jnp.asarray(prompt)[None], cache)
        toks = [int(jnp.argmax(logits, -1)[0])]
        pos = len(prompt)
        for _ in range(n - 1):
            lg, cache = M.decode_step(
                params, cfg, jnp.asarray([[toks[-1]]], jnp.int32),
                cache, jnp.asarray([pos], jnp.int32))
            toks.append(int(jnp.argmax(lg, -1)[0]))
            pos += 1
        return toks

    want = [naive(p, 5) for p in prompts]
    reqs = [Request(rid=i, prompt=p, max_new_tokens=5, arrival=0.0)
            for i, p in enumerate(prompts)]
    eng = ServingEngine(cfg, params, slots=3, max_len=32, sync_every=4)
    stats = eng.run(reqs)
    assert stats.completed == 3
    # equal-length prompts batch together; the odd one gets its own
    assert stats.prefill_batches == 2
    assert [r.output for r in reqs] == want


def test_engine_eos_at_prefill_frees_slot():
    """A request whose FIRST (prefill-sampled) token is EOS must be
    finalized AND its device slot deactivated — no ghost decoding."""
    cfg = _engine_cfg()
    params = M.init_params(cfg)
    rng = np.random.default_rng(21)
    prompt = rng.integers(0, cfg.vocab_size, size=5).astype(np.int32)
    # discover what greedy sampling emits at prefill, then make it EOS
    cache = M.init_cache(cfg, 1, 32)
    logits, _ = M.prefill(params, cfg, jnp.asarray(prompt)[None], cache)
    first_tok = int(jnp.argmax(logits, -1)[0])

    req = Request(rid=0, prompt=prompt, max_new_tokens=8, arrival=0.0)
    eng = ServingEngine(cfg, params, slots=2, max_len=32,
                        eos_id=first_tok, sync_every=4)
    stats = eng.run([req])
    assert stats.completed == 1
    assert req.output == [first_tok]
    assert not np.asarray(eng.active_mask).any()
    assert eng.active == [None, None]


def test_engine_midwindow_admission_keeps_tokens():
    """admit()/admit_batch() mid-window must flush the buffered sync
    window first — otherwise the new slot's tokens hide behind the old
    idle markers and are dropped at the next sync."""
    cfg = _engine_cfg()
    params = M.init_params(cfg)
    rng = np.random.default_rng(17)
    p1, p2 = (rng.integers(0, cfg.vocab_size, size=5).astype(np.int32)
              for _ in range(2))
    eng = ServingEngine(cfg, params, slots=2, max_len=32, sync_every=8)
    r1 = Request(rid=0, prompt=p1, max_new_tokens=8, arrival=0.0)
    r2 = Request(rid=1, prompt=p2, max_new_tokens=4, arrival=0.0)
    assert eng.admit(r1, 0.0)
    for _ in range(3):
        eng.step(0.0)                     # slot 1 idle: 3 buffered -1s
    assert eng.admit(r2, 0.0)             # must flush the window
    while eng._any_active():
        eng.step(0.0)
    eng.sync(0.0)
    assert len(r1.output) == 8
    assert len(r2.output) == 4            # tokens not lost to -1 prefix


def test_engine_ttft_is_stamped_after_prefill():
    """TTFT must be >= the arrival->prefill-materialization gap (never
    the dispatch-time stamp the old engine recorded)."""
    cfg = _engine_cfg()
    params = M.init_params(cfg)
    rng = np.random.default_rng(11)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, size=6)
                    .astype(np.int32),
                    max_new_tokens=3, arrival=0.0) for i in range(2)]
    eng = ServingEngine(cfg, params, slots=2, max_len=32)
    stats = eng.run(reqs)
    assert stats.completed == 2
    for r in reqs:
        assert r.ttft > 0.0                 # real elapsed time, not 0
        assert r.finished >= r.ttft


def test_engine_trace_driven():
    """serving.workload traces drive the real engine end to end."""
    from repro.serving.engine import requests_from_trace
    from repro.serving.workload import poisson_trace

    cfg = _engine_cfg()
    params = M.init_params(cfg)
    trace = poisson_trace(rate=50.0, num_requests=6, seed=2)
    reqs = requests_from_trace(trace, cfg.vocab_size, max_prompt=8,
                               max_new=4, time_scale=0.1)
    eng = ServingEngine(cfg, params, slots=2, max_len=32, sync_every=4)
    stats = eng.run(reqs)
    assert stats.completed == 6
    assert all(len(r.output) >= 1 for r in reqs)
    assert stats.summary()["mean_tpot"] >= 0.0
