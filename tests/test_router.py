"""Cluster serving layer: trace generators, routers, composed DES.

Covers the acceptance criteria for the cluster subsystem: the composed
simulator scales to 16 devices, the workload-aware router beats
round-robin on a heterogeneous mix, decode-session affinity holds, and
identical (seed, trace, plan) reproduce a bit-identical event log.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # collect without hypothesis (tier-1 guard)
    from _hypothesis_stub import given, settings, strategies as st

from conftest import random_dag
from repro.core.costmodel import (GPU_A100, GPU_B200, GPU_H100, GPU_L40S,
                                  GPU_RTX6000)
from repro.core.monitor import MonitorConfig
from repro.core.simulator import ClusterRequest
from repro.serving.cluster import TesseraCluster
from repro.serving.router import (JSEDRouter, LeastLoadedRouter,
                                  RoundRobinRouter, make_router)
from repro.serving.workload import (WorkloadRequest, bursty_trace,
                                    diurnal_trace, make_trace,
                                    poisson_trace, trace_stats)

HET_GROUPS = [[GPU_A100, GPU_L40S], [GPU_H100, GPU_RTX6000],
              [GPU_B200, GPU_H100]]


@pytest.fixture(scope="module")
def het_cluster():
    g = random_dag(24, seed=1)
    return TesseraCluster(g, HET_GROUPS, base_prompt=1024, base_output=128,
                          anneal_iters=300,
                          monitor_cfg=MonitorConfig(window=0.010))


# ===================================================================== #
# Workload traces
# ===================================================================== #
def test_traces_deterministic_and_sorted():
    for kind in ("poisson", "bursty", "diurnal"):
        a = make_trace(kind, 100.0, 80, seed=3)
        b = make_trace(kind, 100.0, 80, seed=3)
        c = make_trace(kind, 100.0, 80, seed=4)
        assert a == b, f"{kind} trace must be seed-deterministic"
        assert a != c, f"{kind} trace must vary with seed"
        arr = [r.arrival for r in a]
        assert arr == sorted(arr)
        assert [r.rid for r in a] == list(range(80))


def test_trace_rate_near_nominal():
    for kind in ("poisson", "bursty", "diurnal"):
        tr = make_trace(kind, 200.0, 400, seed=0)
        s = trace_stats(tr)
        assert 200.0 / 3 < s["rate"] < 200.0 * 3, (kind, s["rate"])


def test_bursty_is_burstier_than_poisson():
    p = trace_stats(poisson_trace(200.0, 200, seed=0))
    b = trace_stats(bursty_trace(200.0, 200, seed=0))
    d = trace_stats(diurnal_trace(200.0, 200, seed=0))
    assert b["cv_interarrival"] > 1.5 * p["cv_interarrival"]
    assert d["cv_interarrival"] > p["cv_interarrival"]


def test_trace_lengths_bounded_and_mixed():
    tr = poisson_trace(100.0, 300, seed=2)
    assert all(1 <= r.prompt_tokens <= 16384 for r in tr)
    assert all(1 <= r.output_tokens <= 4096 for r in tr)
    assert len({r.prompt_tokens for r in tr}) > 10   # actually mixed


def test_sessions_follow_probability():
    lonely = poisson_trace(100.0, 100, seed=1, session_follow=0.0)
    chatty = poisson_trace(100.0, 100, seed=1, session_follow=0.9)
    assert len({r.session for r in lonely}) == 100
    assert len({r.session for r in chatty}) < 50


def test_make_trace_unknown_kind():
    with pytest.raises(ValueError, match="unknown trace kind"):
        make_trace("lunar", 1.0, 1)


# ===================================================================== #
# Routers
# ===================================================================== #
def test_round_robin_cycles(het_cluster):
    tr = poisson_trace(100.0, 9, seed=0)
    res = het_cluster.simulate(tr, RoundRobinRouter())
    assert res.assignments == [0, 1, 2, 0, 1, 2, 0, 1, 2]
    assert res.per_replica_completed == [3, 3, 3]


def test_make_router_registry():
    assert isinstance(make_router("jsed"), JSEDRouter)
    assert isinstance(make_router("round_robin"), RoundRobinRouter)
    assert isinstance(make_router("least_loaded"), LeastLoadedRouter)
    with pytest.raises(ValueError, match="unknown router"):
        make_router("oracle")


def test_jsed_beats_round_robin_on_heterogeneous_mix(het_cluster):
    """The acceptance-criterion comparison, at test scale: overload a
    3-way heterogeneous mix; workload-aware routing must win on both
    throughput and mean latency (verified margins are ~1.4x)."""
    rate = 1.3 * het_cluster.capacity
    for kind in ("poisson", "bursty"):
        tr = make_trace(kind, rate, 300, seed=5)
        rr = het_cluster.simulate(tr, RoundRobinRouter())
        js = het_cluster.simulate(tr, JSEDRouter())
        assert js.throughput > rr.throughput * 1.05, kind
        assert js.mean_latency < rr.mean_latency, kind


def test_jsed_prefers_idle_fast_replica(het_cluster):
    """First request (empty cluster) must go to the replica with the
    smallest predicted service time."""
    tr = [WorkloadRequest(rid=0, arrival=0.0, prompt_tokens=1024,
                          output_tokens=128, session=None)]
    res = het_cluster.simulate(tr, JSEDRouter())
    replicas = het_cluster.build_replicas()
    creq = het_cluster.to_cluster_request(tr[0])
    best = min(range(3),
               key=lambda i: replicas[i].predicted_service(creq))
    assert res.assignments == [best]


def test_session_affinity_sticks(het_cluster):
    """All requests of one session land on one replica (KV locality)."""
    tr = [WorkloadRequest(rid=i, arrival=0.001 * i,
                          prompt_tokens=512, output_tokens=64,
                          session=i % 4) for i in range(40)]
    res = het_cluster.simulate(tr, JSEDRouter())
    by_session = {}
    for req, rep in zip(tr, res.assignments):
        by_session.setdefault(req.session, set()).add(rep)
    assert all(len(reps) == 1 for reps in by_session.values())


def test_session_affinity_breaks_under_imbalance(het_cluster):
    """A finite affinity_break lets a session migrate off a replica
    whose backlog exploded."""
    tr = [WorkloadRequest(rid=i, arrival=0.0001 * i,
                          prompt_tokens=4096, output_tokens=512,
                          session=0) for i in range(60)]
    sticky = het_cluster.simulate(tr, JSEDRouter())
    mobile = het_cluster.simulate(
        tr, JSEDRouter(affinity_break=1e-4))
    assert len(set(sticky.assignments)) == 1
    assert len(set(mobile.assignments)) > 1
    assert mobile.makespan < sticky.makespan


# ===================================================================== #
# Cluster simulator composition
# ===================================================================== #
def test_cluster_16_devices_scales():
    g = random_dag(24, seed=1)
    small = TesseraCluster(g, [HET_GROUPS[i % 3] for i in range(2)],
                           anneal_iters=300)
    big = TesseraCluster(g, [HET_GROUPS[i % 3] for i in range(8)],
                         anneal_iters=300)
    assert big.num_devices == 16
    rate = 2.0 * small.capacity
    tr = poisson_trace(rate, 240, seed=9)
    r_small = small.simulate(tr, JSEDRouter())
    r_big = big.simulate(tr, JSEDRouter())
    assert r_small.completed == r_big.completed == 240
    assert sum(r_big.per_replica_completed) == 240
    assert r_big.throughput > r_small.throughput
    assert r_big.mean_latency < r_small.mean_latency


def test_cluster_deterministic_event_log(het_cluster):
    """Identical seed + trace + plans -> bit-identical event log,
    makespan, and latencies (routers are freshly constructed, so no
    state leaks between runs)."""
    tr = bursty_trace(1.2 * het_cluster.capacity, 150, seed=11)
    r1 = het_cluster.simulate(tr, JSEDRouter())
    r2 = het_cluster.simulate(tr, JSEDRouter())
    assert r1.events == r2.events
    assert r1.makespan == r2.makespan
    assert r1.latencies == r2.latencies
    assert r1.assignments == r2.assignments
    assert len(r1.events) >= 150          # >= one unit per request


def test_cluster_monitor_triggers_policy_switch(het_cluster):
    """Overload must flip at least one replica's monitor to the
    throughput policy (elastic re-planning via the plan cache)."""
    tr = poisson_trace(1.3 * het_cluster.capacity, 300, seed=5)
    res = het_cluster.simulate(tr, JSEDRouter())
    assert res.switches >= 1


def test_cluster_price_accounting(het_cluster):
    tr = poisson_trace(100.0, 20, seed=0)
    res = het_cluster.simulate(tr, RoundRobinRouter())
    expect = sum(d.price for grp in HET_GROUPS for d in grp)
    assert res.price_rate == pytest.approx(expect)
    assert res.cost_efficiency > 0


def test_replica_backlog_and_queue(het_cluster):
    rep = het_cluster.build_replicas()[0]
    assert rep.backlog(0.0) == 0.0
    assert rep.queue_len(0.0) == 0
    creq = ClusterRequest(rid=0, arrival=0.0)
    finish = rep.submit(creq)
    assert finish > 0.0
    assert rep.backlog(0.0) == pytest.approx(finish)
    assert rep.queue_len(0.0) == 1
    assert rep.queue_len(finish + 1.0) == 0
    # second submission queues behind the first on shared resources
    finish2 = rep.submit(ClusterRequest(rid=1, arrival=0.0))
    assert finish2 > finish


def test_replica_scaled_requests_cost_more(het_cluster):
    rep = het_cluster.build_replicas()[0]
    small = ClusterRequest(rid=0, arrival=0.0, scale_prompt=0.5,
                           scale_output=0.5)
    big = ClusterRequest(rid=1, arrival=0.0, scale_prompt=4.0,
                         scale_output=4.0)
    assert rep.predicted_service(big) > rep.predicted_service(small)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), n_req=st.integers(1, 50),
       kind=st.sampled_from(["poisson", "bursty", "diurnal"]))
def test_property_cluster_completes_all(het_cluster, seed, n_req, kind):
    tr = make_trace(kind, 500.0, n_req, seed=seed)
    res = het_cluster.simulate(tr, JSEDRouter())
    assert res.completed == n_req
    assert sum(res.per_replica_completed) == n_req
    assert all(l >= 0 for l in res.latencies)
    assert all(0 <= a < 3 for a in res.assignments)
    assert len(res.events) >= n_req


# ===================================================================== #
# Shed / affinity interleaving (regression: a shed must not mutate
# session state)
# ===================================================================== #
class _StubReplica:
    """Minimal ReplicaModel surface the routers read."""

    def __init__(self, backlog=0.0, service=1.0, eligible=True):
        self._backlog = backlog
        self._service = service
        self.eligible = eligible

    def backlog(self, now):
        return self._backlog

    def predicted_service(self, req):
        return self._service

    def predicted_phase_service(self, req, phase):
        return self._service / 2.0


def test_jsed_shed_leaves_session_home_unchanged():
    """Regression: when a session's home group drained, JSED dropped
    the home entry BEFORE admission control ran — one shed turn
    silently stripped affinity from every later turn of the session."""
    router = JSEDRouter(slo_shed=True)
    reps = [_StubReplica(service=1.0), _StubReplica(service=2.0)]
    first = ClusterRequest(rid=0, arrival=0.0, session=7, slo=100.0)
    assert router.route(first, reps, 0.0) == 0
    assert router._session_home[7] == 0
    reps[0].eligible = False           # home drains
    doomed = ClusterRequest(rid=1, arrival=1.0, session=7, slo=1e-6)
    assert router.route(doomed, reps, 1.0) == -1          # shed
    assert router._session_home[7] == 0, \
        "shed request mutated session affinity"
    # the home only moves when a request is actually ADMITTED
    ok = ClusterRequest(rid=2, arrival=2.0, session=7, slo=100.0)
    assert router.route(ok, reps, 2.0) == 1
    assert router._session_home[7] == 1


def test_pd_shed_leaves_session_decode_home_unchanged():
    """Regression: the PD router deleted a session's decode home on the
    migrate (and stale-home) path before the SLO check could shed the
    request — same invariant as JSED: shed leaves state untouched."""
    from repro.serving.router import PDRouter
    router = PDRouter(prefill_pool=[0], decode_pool=[1, 2],
                      slo_shed=True, session_affinity=True,
                      affinity_break=1.0)
    reps = [_StubReplica(service=0.1),
            _StubReplica(backlog=10.0, service=0.1),   # overloaded home
            _StubReplica(service=0.1)]
    router._session_decode[5] = 1
    # stay - best = 10 > affinity_break -> migrate path; impossible SLO
    # -> shed.  The home entry must survive the shed.
    doomed = ClusterRequest(rid=0, arrival=0.0, session=5, slo=1e-6)
    assert router.route(doomed, reps, 0.0) == -1
    assert router._session_decode[5] == 1, \
        "shed request mutated PD session home"
    # an admitted follow-up re-homes onto the migration target
    ok = ClusterRequest(rid=1, arrival=1.0, session=5, slo=100.0)
    out = router.route(ok, reps, 1.0)
    assert isinstance(out, tuple) and out[1] == 2
    assert router._session_decode[5] == 2
