"""Multi-device tests run in subprocesses so they can set
--xla_force_host_platform_device_count without polluting this process
(conftest deliberately leaves the flag unset)."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]


def _run(code: str, devices: int = 4) -> str:
    prog = f"import os\n" \
        f"os.environ['XLA_FLAGS'] = " \
        f"'--xla_force_host_platform_device_count={devices}'\n" \
        + textwrap.dedent(code)
    # JAX_PLATFORMS must survive the env strip: without it jax probes
    # non-CPU platform plugins on first backend init, which blocks for
    # ~8 minutes per subprocess in offline containers.
    r = subprocess.run([sys.executable, "-c", prog],
                       capture_output=True, text=True,
                       env={"PYTHONPATH": str(REPO / "src"),
                            "PATH": "/usr/bin:/bin",
                            "HOME": "/root",
                            "JAX_PLATFORMS":
                                os.environ.get("JAX_PLATFORMS", "cpu")},
                       timeout=600)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


def test_disaggregated_execution_across_real_devices():
    """Stages placed on distinct host devices must still reproduce the
    reference output — exercising real cross-device transfers."""
    out = _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import analyzer, planner, marker
    from repro.core.costmodel import GPU_A100, GPU_L40S
    from repro.core.executor import StagedExecutable

    def model(x, params):
        for i, (w1, w2) in enumerate(params):
            x = marker.wrap(lambda y, a=w1, b=w2: jax.nn.gelu(y @ a) @ b,
                            layer=i)(x)
        return jnp.tanh(x)

    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 9)
    params = [(jax.random.normal(ks[2*i], (32, 64)) * 0.1,
               jax.random.normal(ks[2*i+1], (64, 32)) * 0.1)
              for i in range(4)]
    x = jax.random.normal(ks[8], (4, 32))
    traced = analyzer.analyze(model, x, params)
    plan = planner.plan(traced.graph, [GPU_A100, GPU_L40S],
                        policy="throughput", cache=False)
    devs = jax.devices()
    assert len(devs) == 4, devs
    exe = StagedExecutable(traced, plan, [devs[0], devs[1]])
    got = exe(x, params)
    want = jax.jit(model)(x, params)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)
    # outputs of stages must actually live on their assigned devices
    used = {cs.device for cs in exe.stages}
    assert len(used) == 2, used
    print("MULTIDEVICE_OK", len(exe.stages))
    """)
    assert "MULTIDEVICE_OK" in out


def test_pipelined_runner_across_devices():
    out = _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import analyzer, planner
    from repro.core.costmodel import GPU_A100, GPU_L40S
    from repro.core.executor import StagedExecutable
    from repro.core.pipeline import PipelinedRunner

    def fn(x, w):
        for _ in range(6):
            x = jnp.tanh(x @ w)
        return x
    x = jnp.ones((8, 16)); w = jnp.eye(16) * 0.7
    traced = analyzer.analyze(fn, x, w)
    plan = planner.plan(traced.graph, [GPU_A100, GPU_L40S], cache=False)
    devs = jax.devices()
    exe = StagedExecutable(traced, plan, [devs[0], devs[1]])
    runner = PipelinedRunner(exe, max_inflight=3)
    outs, stats = runner.run([((x + i, w), {}) for i in range(5)])
    for i, o in enumerate(outs):
        np.testing.assert_allclose(np.asarray(o),
                                   np.asarray(jax.jit(fn)(x + i, w)),
                                   rtol=1e-5)
    print("PIPELINE_OK", stats.completed)
    """)
    assert "PIPELINE_OK 5" in out


def test_straggler_override_preserves_slot_devices():
    """Re-running a unit on a fallback device must leave its exports
    committed to the unit's HOME device: later same-device units read
    those slots directly, and jit rejects mixed-device inputs."""
    out = _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import analyzer, planner
    from repro.core.costmodel import GPU_A100, GPU_L40S
    from repro.core.executor import StagedExecutable

    def fn(x, w):
        for _ in range(6):
            x = jnp.tanh(x @ w)
        return x
    x = jnp.ones((8, 16)); w = jnp.eye(16) * 0.7
    traced = analyzer.analyze(fn, x, w)
    plan = planner.plan(traced.graph, [GPU_A100, GPU_L40S], cache=False)
    devs = jax.devices()
    exe = StagedExecutable(traced, plan, [devs[0], devs[1]])
    assert exe.num_units > 1, exe.num_units
    slots = exe.init_slots(x, w)
    for i in range(exe.num_units):
        # every unit is a straggler: rerun all on a third device
        exe.run_unit(slots, i, device_override=devs[3])
        for v, fs in zip(exe.unit_outputs(slots, i),
                         [exe.program.fused[i]] * 99):
            assert v.devices() == {fs.device}, (i, v.devices())
    got = exe.collect_slots(slots)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(jax.jit(fn)(x, w)), rtol=1e-5)
    print("OVERRIDE_PLACEMENT_OK")
    """)
    assert "OVERRIDE_PLACEMENT_OK" in out


def test_pjit_mesh_train_step_runs():
    """A sharded train step must actually execute on an 8-device host
    mesh (not just compile) — validates the sharding rules end to end."""
    out = _run("""
    import jax, jax.numpy as jnp, numpy as np, dataclasses
    from jax.sharding import NamedSharding, PartitionSpec as P
    import repro.configs as C
    from repro.models import model as M
    from repro.train import optim
    from repro.distributed import sharding as SH
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((2, 4), ("data", "model"))
    cfg = dataclasses.replace(C.get_smoke("llama3_8b"), dtype="float32",
                              num_heads=4, num_kv_heads=4, d_ff=128)
    ocfg = optim.AdamWConfig(warmup_steps=1, total_steps=4)
    params = M.init_params(cfg)
    opt = optim.init(ocfg, params)
    batch = {"tokens": jnp.zeros((4, 16), jnp.int32),
             "targets": jnp.zeros((4, 16), jnp.int32)}
    p_sh = SH.param_shardings(params, mesh, SH.TRAIN_RULES)
    rep = NamedSharding(mesh, P())
    o_sh = optim.AdamWState(step=rep, mu=p_sh, nu=p_sh, master=p_sh)
    b_sh = {k: NamedSharding(mesh, P("data")) for k in batch}

    def train_step(params, opt_state, batch):
        def lf(p):
            return M.loss_fn(p, cfg, batch["tokens"], batch["targets"])
        loss, grads = jax.value_and_grad(lf)(params)
        p2, o2 = optim.apply(ocfg, grads, opt_state, params)
        return p2, o2, loss

    with mesh:
        step = jax.jit(train_step, in_shardings=(p_sh, o_sh, b_sh),
                       out_shardings=(p_sh, o_sh, rep))
        params = jax.device_put(params, p_sh)
        opt = jax.device_put(opt, o_sh)
        batch = jax.device_put(batch, b_sh)
        l0 = None
        for i in range(3):
            params, opt, loss = step(params, opt, batch)
            l0 = l0 if l0 is not None else float(loss)
        assert float(loss) < l0, (float(loss), l0)
    print("PJIT_TRAIN_OK", float(loss))
    """, devices=8)
    assert "PJIT_TRAIN_OK" in out


def test_gradient_compression_crosspod_allreduce():
    """EF-int8 compressed gradient all-reduce via shard_map over a pod
    axis: the mean of decompressed shards must track the true mean."""
    out = _run("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.launch.mesh import make_mesh
    from repro.train.compress import quantize_int8, dequantize_int8

    mesh = make_mesh((4,), ("pod",))

    def compressed_allreduce(g):
        q, s = quantize_int8(g)
        y = dequantize_int8(q, s)       # wire format
        return jax.lax.pmean(y, "pod")

    f = shard_map(compressed_allreduce, mesh=mesh,
                  in_specs=P("pod"), out_specs=P("pod"))
    g = jax.random.normal(jax.random.PRNGKey(0), (8, 32))
    out = f(g)
    true_mean = jnp.tile(g.reshape(4, 2, 32).mean(0), (4, 1))
    err = float(jnp.abs(out - true_mean).max())
    assert err < 0.05, err
    print("COMPRESS_ALLREDUCE_OK", err)
    """)
    assert "COMPRESS_ALLREDUCE_OK" in out
