"""Parity suite for the vectorized DES fast core.

The fast path (compiled ``UnitProgram`` walks, dot-product scoring,
O(1) backlog, segmented-cumsum walks for long programs) must reproduce
the historical reference walk bit-identically: same event logs, same
latencies/TTFTs, same assignments, same busy accounting — across all
four routers, pd on/off, kv_chunks on/off, controller on/off and
failure injection.  ``reference=True`` flips ONLY the replica walk and
probe implementations; everything upstream (trace prep, routing code,
monitor) is shared, so equality here is exact, not approximate.

Also covers the ``events`` recording modes: ``"agg"`` must equal the
reduction of a ``"full"`` log bit-identically, and ``events=None``
must leave every metric unchanged.
"""
import dataclasses

import pytest

from conftest import random_dag
from repro.core.simulator import EventAggregate, ReplicaUnit, compile_units
from repro.serving.controller import AutoscaleConfig, AutoscalePolicy
from repro.serving.spec import DeploymentSpec
from repro.serving.workload import diurnal_trace

GROUPS = [["a100", "l40s"], ["h100", "h100"], ["a100", "l40s"]]
SLOS = {"base": 2.0, "per_output_token": 0.05, "ttft": 1.5}
ANNEAL = 150


def _phased(g, pin_alternating=False):
    nodes = [dataclasses.replace(
        node, phase="prefill" if node.idx < len(g.nodes) // 2 else "decode",
        pinned=(node.idx % 2 if pin_alternating else node.pinned))
        for node in g.nodes]
    g2 = type(g)(nodes, dict(g.edges), name=g.name + ".des")
    g2.validate()
    return g2


@pytest.fixture(scope="module")
def graph():
    return _phased(random_dag(24, seed=2))


@pytest.fixture(scope="module")
def deep_graph():
    """Alternating pinned devices force one stage per node, so every
    policy's program is far past _VECTOR_WALK_MIN — the segmented
    cumsum walk, not the scalar loop, handles these requests."""
    return _phased(random_dag(80, seed=2), pin_alternating=True)


def _trace(n=400, rate=40.0, seed=0):
    return diurnal_trace(rate, n, seed=seed)


def _pair(graph, spec_kwargs, sim_kwargs=None):
    """(reference result, fast result) for one configuration."""
    trace = _trace()
    sim_kwargs = sim_kwargs or {}
    kw = dict(anneal_iters=ANNEAL, **spec_kwargs)
    ref = DeploymentSpec(**kw).compile(graph).simulate(
        trace, reference=True, **sim_kwargs)
    fast = DeploymentSpec(**kw).compile(graph).simulate(
        trace, **sim_kwargs)
    return ref, fast


def _assert_same(ref, fast):
    assert ref.events == fast.events
    assert ref.latencies == fast.latencies
    assert ref.ttfts == fast.ttfts
    assert ref.assignments == fast.assignments
    assert ref.per_replica_busy == fast.per_replica_busy
    assert ref.per_replica_completed == fast.per_replica_completed
    assert ref.makespan == fast.makespan
    assert ref.shed == fast.shed
    assert ref.slo_ok == fast.slo_ok
    assert ref.switches == fast.switches


@pytest.mark.parametrize("router", ["jsed", "round_robin",
                                    "least_loaded"])
def test_parity_colocated_routers(graph, router):
    ref, fast = _pair(graph, dict(groups=GROUPS, router=router,
                                  slos=SLOS))
    _assert_same(ref, fast)


@pytest.mark.parametrize("kv_chunks", [1, 4])
def test_parity_pd_split(graph, kv_chunks):
    ref, fast = _pair(graph, dict(groups=GROUPS, router="pd_split",
                                  slos=SLOS, pd=True,
                                  kv_chunks=kv_chunks))
    _assert_same(ref, fast)
    assert ref.transfers == fast.transfers
    assert ref.transfer_seconds == fast.transfer_seconds
    assert ref.peak_kv_bytes == fast.peak_kv_bytes


@pytest.mark.parametrize("failures", [None, [(5.0, 1)]])
def test_parity_controller_and_failures(graph, failures):
    trace = _trace()
    results = []
    for reference in (True, False):
        dep = DeploymentSpec(groups=GROUPS, router="jsed", slos=SLOS,
                             budget=60.0,
                             anneal_iters=ANNEAL).compile(graph)
        ctl = AutoscalePolicy(
            AutoscaleConfig(interval=0.05, window=0.2, cooldown=0.1,
                            warmup=0.05, queue_hi=0.5, queue_lo=0.15,
                            util_lo=0.6),
            inventory=[["l40s"], ["a100"]])
        results.append(dep.simulate(trace, controller=ctl,
                                    failures=failures,
                                    reference=reference))
    ref, fast = results
    _assert_same(ref, fast)
    assert ref.rerouted == fast.rerouted
    assert ref.dropped == fast.dropped


def test_agg_equals_full_reduction(graph):
    dep = DeploymentSpec(groups=GROUPS, router="jsed", slos=SLOS,
                         anneal_iters=ANNEAL).compile(graph)
    trace = _trace()
    full = dep.simulate(trace)                      # events="full"
    agg = dep.simulate(trace, events="agg")
    reduced = EventAggregate.from_events(full.events)
    assert agg.event_agg is not None
    assert agg.event_agg.counts == reduced.counts
    assert agg.event_agg.seconds == reduced.seconds
    assert agg.events == []
    assert agg.latencies == full.latencies
    assert full.event_agg is None


def test_events_none_drops_log_keeps_metrics(graph):
    dep = DeploymentSpec(groups=GROUPS, router="jsed", slos=SLOS,
                         anneal_iters=ANNEAL).compile(graph)
    trace = _trace()
    full = dep.simulate(trace)
    none = dep.simulate(trace, events=None)
    assert none.events == [] and none.event_agg is None
    assert none.latencies == full.latencies
    assert none.ttfts == full.ttfts
    assert none.per_replica_busy == full.per_replica_busy


def test_events_mode_validated(graph):
    dep = DeploymentSpec(groups=GROUPS,
                         anneal_iters=ANNEAL).compile(graph)
    with pytest.raises(ValueError, match="events"):
        dep.simulate(_trace(n=5), events="everything")


def test_vector_walk_parity_long_programs(deep_graph):
    kw = dict(groups=[["a100", "l40s"], ["h100", "h100"]],
              router="jsed", slos=SLOS, anneal_iters=50)
    trace = _trace(n=300, rate=60.0)
    dep = DeploymentSpec(**kw).compile(deep_graph)
    units = dep.cluster().build_replicas()[0].unit_sets
    assert all(len(us) >= 48 for us in units.values()), \
        "fixture no longer exercises the vector walk"
    ref = DeploymentSpec(**kw).compile(deep_graph).simulate(
        trace, reference=True)
    fast = dep.simulate(trace)
    _assert_same(ref, fast)
    agg = DeploymentSpec(**kw).compile(deep_graph).simulate(
        trace, events="agg")
    reduced = EventAggregate.from_events(fast.events)
    assert agg.event_agg.counts == reduced.counts
    assert agg.event_agg.seconds == reduced.seconds


def test_backlog_fast_matches_reference_scan(graph):
    dep = DeploymentSpec(groups=GROUPS, router="jsed", slos=SLOS,
                         anneal_iters=ANNEAL).compile(graph)
    reps = dep.cluster().build_replicas()
    creqs = dep.prepare(_trace(n=200))
    for i, req in enumerate(creqs):
        rep = reps[i % len(reps)]
        rep.submit(req)
        fast = rep.backlog(req.arrival)
        rep.reference = True
        assert rep.backlog(req.arrival) == fast
        rep.reference = False


def test_program_cache_keys_by_content():
    us1 = [ReplicaUnit(1, 0, 0.5, 0.3), ReplicaUnit(0, 1, 0.1, 1.0)]
    us2 = [ReplicaUnit(1, 0, 0.5, 0.3), ReplicaUnit(0, 1, 0.1, 1.0)]
    assert compile_units(us1) is compile_units(us2)
    assert compile_units(us1).service(2.0, 3.0) == sum(
        u.scaled(2.0, 3.0) for u in us1)


# ===================================================================== #
# Subsample-then-confirm sizing
# ===================================================================== #
def _sizing(graph, **kw):
    from repro.serving.sizing import search_composition
    trace = _trace(n=240, rate=30.0, seed=4)
    return search_composition(
        {"a100": 2, "l40s": 2}, 30.0, trace, graph, iters=10, seed=0,
        spec_kwargs={"slos": SLOS, "anneal_iters": ANNEAL}, **kw)


def test_sizing_subsample_is_deterministic(graph):
    a = _sizing(graph, subsample=80)
    b = _sizing(graph, subsample=80)
    assert a.composition == b.composition
    assert a.score == b.score
    assert a.history == b.history
    assert a.confirmed == b.confirmed and a.confirmed >= 1


def test_sizing_subsample_scores_on_full_trace(graph):
    """The returned score/result come from a full-trace replay of the
    confirmed incumbent, never from the subsampled prefix."""
    full = _sizing(graph)
    sub = _sizing(graph, subsample=80)
    assert full.confirmed == 0
    assert sub.result.completed + sub.result.shed \
        + sub.result.dropped == 240
    assert sub.result.events, "final replay must keep the event log"
    # same evaluation budget notion: history rows == iters + 1
    assert len(sub.history) == len(full.history) == 11
