"""Fabric contention: priority KV scheduling vs FIFO on a congested
crossing.

  PYTHONPATH=src python benchmarks/fabric_contention.py [--quick] \
      [--out BENCH_fabric.json] [--check]

A prefill island and a decode island share ONE half-duplex crossing, so
every byte between them fights for the same wire: decode-blocking KV
handoffs (prefill -> decode, URGENT class) and periodic checkpoint
snapshots shipping to the host store (decode -> host, with the host on
the prefill island — BULK class, the reverse direction of the same
half-duplex channel).  The same deployment, trace and checkpoint plan
replay twice, changing ONLY ``Topology.scheduler``:

  * ``fifo``      — one shared timeline; bulk snapshots book the channel
                    the moment they are due and KV handoffs queue behind
                    them (the "one TCP flow per transfer" baseline).
  * ``priority``  — the :class:`~repro.serving.fabric.TransferScheduler`
                    books decode-blocking KV at the urgent head of the
                    channel and lazily backfills bulk into the gaps the
                    urgent timeline leaves.

Headline: goodput (SLO-satisfying completions / makespan) and the TTFT
tail.  ``--check`` gates the scheduler strictly beating FIFO on slo_ok
while both runs ship a comparable number of snapshots (the win must not
come from silently doing less bulk work), plus an uncontended sanity
run where both schedulers match.
"""
from __future__ import annotations

import sys
import time
from pathlib import Path
from typing import List

sys.path.insert(0, str(Path(__file__).resolve().parent))

from common import (Row, bench_parser, maybe_profile, print_rows,
                    request_graph, write_bench_json)
from repro.serving.faults import FaultPlan, GroupHealth, RecoveryConfig
from repro.serving.spec import DeploymentSpec
from repro.serving.workload import poisson_trace

ARCH = "llama3_8b"
# one compute-rich prefill group, one decode group — the classic pd
# pair, placed on DIFFERENT islands so every handoff crosses the fabric
GROUPS = [["h100", "rtxpro6000"], ["a100", "l40s"]]
LOAD_X = 0.6                    # offered load vs annealed capacity
SLOS = {"base": 4.0, "per_output_token": 0.05, "ttft": 0.080}


def _p95(xs) -> float:
    xs = sorted(xs)
    if not xs:
        return 0.0
    return xs[min(int(0.95 * len(xs)), len(xs) - 1)]


def topology(scheduler: str, crossing_bw: float) -> dict:
    """Two islands, one thin HALF-duplex crossing, host store on the
    prefill island — so KV (pre->dec) and checkpoint ships (dec->host)
    share one channel in opposite directions."""
    return {
        "islands": [{"name": "pre", "groups": [0], "bw": 600e9},
                    {"name": "dec", "groups": [1], "bw": 600e9}],
        "crossings": [{"src": "pre", "dst": "dec", "bw": crossing_bw,
                       "latency": 50e-6, "duplex": "half"}],
        "host_island": "pre",
        "scheduler": scheduler,
    }


def run_once(graph, trace, scheduler: str, crossing_bw: float,
             ship_interval, quick: bool):
    """One replay.  ``ship_interval=None`` disables checkpoint
    shipping entirely (no bulk traffic on the fabric) — the
    uncontended control where both schedulers must agree exactly."""
    dep = DeploymentSpec(
        groups=GROUPS, router="pd_split", pd=True, kv_chunks=4,
        slos=SLOS,
        router_kwargs={"slo_shed": True},
        anneal_iters=150 if quick else 400,
        fabric=topology(scheduler, crossing_bw)).compile(graph)
    kw = {}
    if ship_interval is not None:
        # an empty (crash-free) fault plan activates the recovery
        # machinery, so periodic snapshots ship to the host store as
        # bulk traffic without perturbing the request schedule
        kw = dict(faults=FaultPlan(seed=3),
                  recovery=RecoveryConfig(interval=ship_interval),
                  health=GroupHealth(len(GROUPS)))
    t0 = time.perf_counter()
    res = dep.simulate(trace, **kw)
    return res, time.perf_counter() - t0


def main() -> int:
    ap = bench_parser(
        description=__doc__.split("\n")[0],
        check_help="gate: the priority scheduler strictly beats FIFO "
                   "on slo_ok under bulk checkpoint contention while "
                   "shipping a comparable snapshot count, and matches "
                   "FIFO when the crossing is uncontended")
    args = ap.parse_args()
    quick = args.quick
    n = 200 if quick else 600

    # trace is sized off a FABRIC-LESS twin so both schedulers (and the
    # uncontended control) replay the identical workload
    graph = request_graph(ARCH, prompt=512, n_out=64, layers=2)
    ref = DeploymentSpec(groups=GROUPS, router="pd_split", pd=True,
                         anneal_iters=150 if quick else 400
                         ).compile(graph)
    trace = poisson_trace(rate=LOAD_X * ref.cluster().capacity,
                          num_requests=n, seed=11)

    # the contended crossing: thin enough that a serial KV handoff
    # (≈2.6ms at 100 MB/s) lands inside the TTFT budget, with a
    # checkpoint snapshot due every 2ms of decode adding steady bulk
    # pressure on the same wire
    crossing_bw = 1e8
    ship_interval = 2e-3

    rows: List[Row] = []
    results: dict = {"requests": n, "crossing_bw": crossing_bw,
                     "ship_interval": ship_interval}
    with maybe_profile(args.profile):
        for tag, sched, bw, iv in (
                ("fifo", "fifo", crossing_bw, ship_interval),
                ("priority", "priority", crossing_bw, ship_interval),
                ("fifo_uncontended", "fifo", 100e9, None),
                ("priority_uncontended", "priority", 100e9, None)):
            res, dt = run_once(graph, trace, sched, bw, iv, quick)
            rows.append((f"fabric_{tag}", dt * 1e6,
                         f"slo_ok={res.slo_ok}/{n} "
                         f"goodput={res.goodput:.2f}req/s "
                         f"p95ttft={_p95(res.ttfts) * 1e3:.1f}ms "
                         f"shed={res.shed} ships={res.ckpt_shipped} "
                         f"wait={res.fabric_wait_seconds * 1e3:.1f}ms"))
            results[tag] = {
                "slo_ok": res.slo_ok, "goodput": res.goodput,
                "completed": res.completed, "shed": res.shed,
                "mean_ttft": res.mean_ttft,
                "p95_ttft": _p95(res.ttfts),
                "ckpt_shipped": res.ckpt_shipped,
                "fabric_wait_seconds": res.fabric_wait_seconds,
                "fabric_bulk_bytes": res.fabric_bulk_bytes,
                "makespan": res.makespan,
            }
    print_rows(rows)
    write_bench_json(args.out, results)

    if args.check:
        pri, fifo = results["priority"], results["fifo"]
        if pri["slo_ok"] <= fifo["slo_ok"]:
            print(f"CHECK FAILED: priority slo_ok {pri['slo_ok']} does "
                  f"not beat FIFO {fifo['slo_ok']}", file=sys.stderr)
            return 1
        if pri["goodput"] <= fifo["goodput"]:
            print(f"CHECK FAILED: priority goodput {pri['goodput']:.3f} "
                  f"does not beat FIFO {fifo['goodput']:.3f}",
                  file=sys.stderr)
            return 1
        # the win must come from scheduling, not from shipping less:
        # FIFO books every due snapshot unconditionally, so priority
        # must still complete a comparable amount of bulk work
        if pri["ckpt_shipped"] < 0.5 * fifo["ckpt_shipped"]:
            print(f"CHECK FAILED: priority shipped "
                  f"{pri['ckpt_shipped']} snapshots vs FIFO "
                  f"{fifo['ckpt_shipped']} — win is starvation, not "
                  f"scheduling", file=sys.stderr)
            return 1
        pu = results["priority_uncontended"]
        fu = results["fifo_uncontended"]
        if pu["slo_ok"] != fu["slo_ok"]:
            print(f"CHECK FAILED: uncontended runs diverge "
                  f"(priority {pu['slo_ok']} vs fifo {fu['slo_ok']})",
                  file=sys.stderr)
            return 1
        print(f"CHECK OK: priority slo_ok {pri['slo_ok']} > fifo "
              f"{fifo['slo_ok']} (goodput {pri['goodput']:.2f} vs "
              f"{fifo['goodput']:.2f} req/s, p95 TTFT "
              f"{pri['p95_ttft'] * 1e3:.1f}ms vs "
              f"{fifo['p95_ttft'] * 1e3:.1f}ms) with ships "
              f"{pri['ckpt_shipped']} vs {fifo['ckpt_shipped']}; "
              f"uncontended runs match", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
