"""Paged KV block pool: admitted-session capacity + affinity goodput.

  PYTHONPATH=src python benchmarks/paged_kv.py [--quick] \
      [--out BENCH_paged.json] [--check]

Two measured claims, one per backend:

**Capacity (real engines, equal memory).**  A fixed-slot engine's
session capacity is its slot count: ``slots * max_len`` tokens of KV
are committed per-slot whether a session needs them or not.  The paged
engine spends the SAME byte budget as a shared block pool (plus a
small active-slot working set) and admits sessions against free
BLOCKS: short sessions hold only the blocks they need, so many more
sessions are resident concurrently — parked sessions time-slice
through the decode slots.  Both engines run an identical workload to
completion (paged greedy tokens are bit-identical; asserted in
tests/test_paged_kv.py).  ``--check`` gates: the paged engine must
hold >= 2x the fixed-slot engine's concurrent sessions at equal
memory.

**Affinity goodput (DES, multi-turn chat).**  With per-group KV-block
occupancy and prefix-cache hits modeled (``KvPoolModel``), a
follow-up turn routed to its session's resident group skips
re-prefilling the cached context.  On a prefill-heavy chat trace
(accumulating prompts, tight TTFT SLO) decode-session affinity ON
must yield strictly higher goodput than affinity OFF — the measured
benefit that used to be a modeling assumption.  ``--check`` gates
ON > OFF.
"""
from __future__ import annotations

import dataclasses
import sys
import time
from pathlib import Path
from typing import List

sys.path.insert(0, str(Path(__file__).resolve().parent))

import numpy as np

from common import (Row, bench_parser, maybe_profile, print_rows,
                    request_graph, write_bench_json)
import repro.configs as configs
from repro.models import model as M
from repro.serving.engine import Request, ServingEngine
from repro.serving.router import JSEDRouter
from repro.serving.spec import DeploymentSpec
from repro.serving.workload import make_trace

ARCH = "llama3_8b"
FIXED_SLOTS = 8
MAX_LEN = 32
BLOCK_TOKENS = 8
PAGED_SLOTS = 2
# equal memory: pool tokens + paged active-slot tokens == fixed-slot
# tokens  (24*8 + 2*32 == 8*32)
POOL_BLOCKS = (FIXED_SLOTS * MAX_LEN - PAGED_SLOTS * MAX_LEN) \
    // BLOCK_TOKENS
N_SESSIONS = POOL_BLOCKS                # 1 block each (short sessions)

SLOS = {"base": 0.3, "per_output_token": 0.002, "ttft": 0.02}
KV_ENGINE = {"kv_block_tokens": 16, "max_len": 64, "slots": 4,
             "kv_pool_blocks": 8192}
LOAD_X = 2.5            # offered load, multiples of annealed capacity
AFFINITY_BREAK = 0.02   # abandon a backlogged home group past this cost


def _requests(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=4).astype(np.int32),
                    max_new_tokens=2, arrival=0.0)
            for i in range(n)]


def capacity_part(rows: List[Row], results: dict) -> None:
    cfg = dataclasses.replace(configs.get_smoke(ARCH), dtype="float32")
    params = M.init_params(cfg)

    fixed = ServingEngine(cfg, params, slots=FIXED_SLOTS,
                          max_len=MAX_LEN, sync_every=2)
    reqs_f = _requests(cfg, N_SESSIONS)
    t0 = time.perf_counter()
    fixed.run(reqs_f)
    t_fixed = time.perf_counter() - t0
    assert fixed.stats.completed == N_SESSIONS

    paged = ServingEngine(cfg, params, slots=PAGED_SLOTS,
                          max_len=MAX_LEN, sync_every=2,
                          kv_block_tokens=BLOCK_TOKENS,
                          kv_pool_blocks=POOL_BLOCKS)
    reqs_p = _requests(cfg, N_SESSIONS)
    t0 = time.perf_counter()
    admitted = paged.admit_batch(reqs_p, 0.0)
    resident = sum(1 for s in paged.active if s is not None) \
        + len(paged._paged.parked())
    while paged._any_active():
        paged.step(0.0)
        paged.sync(0.0)
    t_paged = time.perf_counter() - t0
    assert paged.stats.completed == N_SESSIONS
    for a, b in zip(reqs_f, reqs_p):
        assert a.output == b.output, "paged decode diverged"

    kv_tokens = FIXED_SLOTS * MAX_LEN
    rows.append((f"fixed_slots[{kv_tokens}tok]", t_fixed * 1e6,
                 f"resident={FIXED_SLOTS}"))
    rows.append((f"paged[{kv_tokens}tok]", t_paged * 1e6,
                 f"resident={resident}"))
    results["capacity"] = {
        "kv_token_budget": kv_tokens,
        "fixed_resident": FIXED_SLOTS,
        "paged_admitted": admitted,
        "paged_resident": resident,
        "sessions": N_SESSIONS,
        "fixed_s": t_fixed, "paged_s": t_paged,
    }


def affinity_part(rows: List[Row], results: dict, quick: bool) -> None:
    g = request_graph(ARCH, prompt=1024, n_out=128, layers=2)
    spec = DeploymentSpec(groups=[["a100", "l40s"]] * 4,
                          anneal_iters=200 if quick else 500,
                          slos=SLOS, engine=KV_ENGINE)
    dep = spec.compile(g)
    cap = dep.cluster().capacity
    n = 800 if quick else 2000
    trace = make_trace("chat", LOAD_X * cap, n, seed=7, think_mean=5.0,
                       first_prompt_mean=1024, new_tokens_mean=512,
                       output_mean=16)
    runs = {}
    for tag, aff in (("affinity_off", False), ("affinity_on", True)):
        t0 = time.perf_counter()
        res = dep.simulate(trace,
                           router=JSEDRouter(
                               session_affinity=aff,
                               affinity_break=AFFINITY_BREAK),
                           events=None)
        dt = time.perf_counter() - t0
        runs[tag] = res
        rows.append((tag, dt * 1e6,
                     f"goodput={res.slo_ok}/{n} hits={res.kv_hits}"))
        results[tag] = {
            "goodput": res.slo_ok, "requests": n,
            "kv_hits": res.kv_hits,
            "kv_hit_tokens": res.kv_hit_tokens,
            "kv_delayed": res.kv_delayed,
            "kv_evictions": res.kv_evictions,
            "peak_kv_blocks": list(res.peak_kv_blocks),
        }
    results["affinity_gain"] = (runs["affinity_on"].slo_ok
                                - runs["affinity_off"].slo_ok)


def main() -> int:
    ap = bench_parser(
        description=__doc__.split("\n")[0],
        check_help="gate: paged resident sessions >= 2x fixed-slot "
                   "capacity at equal memory, AND chat-trace goodput "
                   "with session affinity ON strictly beats OFF")
    args = ap.parse_args()
    rows: List[Row] = []
    results: dict = {}
    with maybe_profile(args.profile):
        capacity_part(rows, results)
        affinity_part(rows, results, args.quick)
    print_rows(rows)
    write_bench_json(args.out, results)
    if args.check:
        cap = results["capacity"]
        if cap["paged_resident"] < 2 * cap["fixed_resident"]:
            print(f"CHECK FAILED: paged resident "
                  f"{cap['paged_resident']} < 2x fixed "
                  f"{cap['fixed_resident']}", file=sys.stderr)
            return 1
        gain = results["affinity_gain"]
        if gain <= 0:
            print(f"CHECK FAILED: affinity ON goodput does not beat "
                  f"OFF (gain={gain})", file=sys.stderr)
            return 1
        print(f"CHECK OK: paged resident {cap['paged_resident']} vs "
              f"fixed {cap['fixed_resident']} at equal memory; "
              f"affinity goodput gain +{gain}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
