"""Phase-split (prefill/decode) vs colocated serving across P/D ratios.

  PYTHONPATH=src python benchmarks/pd_split.py [--quick] [--overlap] \
      [--out BENCH_pd_split.json] [--check]

Reproduces the paper's headline heterogeneous scenario on the
discrete-event model: a request's prefill runs on a compute-rich
replica group, its KV state crosses the inter-replica fabric (an
explicit, cost-modeled transfer edge), and decode continues on a
bandwidth-oriented group (router.PDRouter + simulator
.simulate_cluster_pd).  The sweep varies the prefill:decode pool ratio
over heterogeneous mixes and compares against colocated JSED routing
on four axes:

  * mean TTFT — phase-split isolates prefill from decode head-of-line
    blocking, so first-token latency drops by an order of magnitude at
    stable load,
  * goodput — completions within BOTH the TTFT and the size-
    proportional completion SLO, the metric rate-matched P/D serving
    optimizes ("Beyond the Buzz", arXiv 2506.05508),
  * saturated throughput — at the matched pool ratio the shorter
    per-replica unit chains also lift the overload ceiling,
  * cost efficiency — req/$ with the groups' rental prices.

Arrival rates are calibrated per mix from a short deep-overload run
(the DES's serial-chain capacity sits well below the plan-bottleneck
``cluster.capacity`` upper bound, so rates derived from the latter
would drive every router super-critical and flatten the comparison).

The pool sweep includes a REVERSED orientation (``1:3r``: prefill on a
cheap group, the fastest group kept in the decode pool) — on mixes
where one group dominates, fencing it into prefill wastes it, and the
reversed split is what flips hetero-b200+3h100 from a loss to a win.

``--overlap`` additionally sweeps chunked KV streaming
(``simulate_cluster_pd(kv_chunks=n)``): per-chunk transfers overlap
the remaining prefill compute, so only the tail of the transfer lands
in TTFT.  An optimal chunk count exists (few chunks defer bytes past
prefill-end; many chunks drown in per-transfer ``base_latency``), and
a session-affinity variant reports transfers avoided by running
follow-up turns on the decode group that holds their resident state.

Output follows the repo CSV contract: ``name,us_per_call,derived``
with mean request latency (us) in the middle column and the headline
quantity in ``derived``.  ``--check`` gates the acceptance criteria:
phase-split must beat colocated goodput AND TTFT on at least one
heterogeneous mix (>= 95% of colocated saturated throughput); with
``--overlap`` it additionally requires that streaming never regresses
TTFT past the serial split and that, on at least one heterogeneous
mix, overlap removes >= 50% of the per-request transfer seconds from
mean TTFT.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path
from typing import Dict, List, Sequence, Tuple

sys.path.insert(0, str(Path(__file__).resolve().parent))

from common import (Row, bench_parser, print_rows, request_graph,
                    write_bench_json)
import repro.configs as configs
from repro.core.monitor import MonitorConfig
from repro.core.simulator import Interconnect
from repro.serving.cluster import TesseraCluster
from repro.serving.router import JSEDRouter, PDRouter
from repro.serving.workload import assign_slos, make_trace

ARCH = "llama3_8b"
LAYERS = 2                      # traced layers (costs are per-layer exact)
BASE_PROMPT, BASE_OUT = 1024, 128
SLO_TTFT = 0.3                  # interactivity deadline (s)
SLO_BASE, SLO_PER_TOK = 2.0, 0.02   # completion deadline (s)

# Heterogeneous mixes: group lists cycled to n_replicas.  The paper's
# scenario pairs one compute-rich group (prefill pool) with cheaper
# bandwidth-oriented groups (decode pool); the homogeneous high-end mix
# is the baseline phase-split must beat on cost efficiency.
MIXES = {
    "hetero-h100+3a100": [("h100", "rtxpro6000"), ("a100", "l40s"),
                          ("a100", "l40s"), ("a100", "l40s")],
    "hetero-b200+3h100": [("b200", "h100"), ("h100", "rtxpro6000"),
                          ("h100", "rtxpro6000"), ("h100", "rtxpro6000")],
    "homog-4xh100": [("h100", "rtxpro6000")] * 4,
}
# prefill:decode pool splits swept per mix (group indices); "1:3r" is
# the reversed orientation — prefill on the LAST group, so the fastest
# group (index 0) serves the heavier decode pool instead of being
# fenced into prefill
PD_RATIOS = {
    "1:3": ([0], [1, 2, 3]),
    "1:3r": ([3], [0, 1, 2]),
    "2:2": ([0, 1], [2, 3]),
    "3:1": ([0, 1, 2], [3]),
}
# kv_chunks counts swept in --overlap mode
KV_CHUNKS = (2, 4, 8, 16, 32)


def build_cluster(mix: Sequence[Tuple[str, str]],
                  anneal: int) -> TesseraCluster:
    g = request_graph(ARCH, prompt=BASE_PROMPT, n_out=BASE_OUT,
                      layers=LAYERS)
    return TesseraCluster(g, [list(p) for p in mix],
                          base_prompt=BASE_PROMPT, base_output=BASE_OUT,
                          monitor_cfg=MonitorConfig(window=0.050),
                          anneal_iters=anneal,
                          model_cfg=configs.get(ARCH),
                          interconnect=Interconnect(default_bw=100e9))


def saturated_throughput(cluster: TesseraCluster, n_req: int) -> float:
    """Short deep-overload calibration run: the DES's real capacity."""
    trace = make_trace("poisson", 10.0 * cluster.capacity, n_req, seed=3)
    return cluster.simulate(trace, JSEDRouter()).throughput


def run_mix(mix_name: str, mix, quick: bool, overlap: bool = False
            ) -> Tuple[List[Row], Dict]:
    rows: List[Row] = []
    n_req = 120 if quick else 300
    cluster = build_cluster(mix, 300 if quick else 800)
    sat = saturated_throughput(cluster, 80 if quick else 150)

    stable = assign_slos(
        make_trace("poisson", 0.8 * sat, n_req, seed=17),
        base=SLO_BASE, per_output_token=SLO_PER_TOK, ttft=SLO_TTFT)
    overload = make_trace("poisson", 1.5 * sat, n_req, seed=17)

    def record(tag: str, r, extra: str = "") -> None:
        rows.append((f"pd.{mix_name}.{tag}", r.mean_latency * 1e6,
                     f"thr={r.throughput:.2f}req/s|good={r.goodput:.2f}"
                     f"|ttft={r.mean_ttft * 1e3:.1f}ms"
                     f"|cost={r.cost_efficiency:.1f}req/$" + extra))

    # colocated baseline (workload-aware JSED, with admission control)
    co = cluster.simulate(stable, JSEDRouter())
    co_shed = cluster.simulate(stable, JSEDRouter(slo_shed=True))
    co_sat = cluster.simulate(overload, JSEDRouter())
    record("colocated.stable", co)
    record("colocated+shed.stable", co_shed,
           f"|shed={co_shed.shed}")
    record("colocated.overload", co_sat)

    # phase-split across P/D pool ratios + the automatic classifier.
    # Factories hand out a FRESH router per run (session-affinity and
    # pool-classification state must not leak between replays).
    best = None
    factories = {
        f"split-{k}": (lambda p=p, d=d, **kw: PDRouter(
            prefill_pool=p, decode_pool=d, max_kv_lag=1.0, **kw))
        for k, (p, d) in PD_RATIOS.items()}
    factories["split-auto"] = lambda **kw: PDRouter(
        prefill_frac=0.25, max_kv_lag=1.0, **kw)
    pd_sat_best = 0.0
    for tag, mk in factories.items():
        r = cluster.simulate_pd(stable, mk())
        record(f"{tag}.stable", r,
               f"|kvpeak={r.peak_kv_bytes / 1e6:.0f}MB"
               f"|xfer={r.transfers}")
        if best is None or r.goodput > best[1].goodput:
            best = (tag, r)
        r_sat = cluster.simulate_pd(overload, mk())
        record(f"{tag}.overload", r_sat)
        pd_sat_best = max(pd_sat_best, r_sat.throughput)

    tag, r = best
    summary = {
        "mix": mix_name,
        "colocated": {"throughput": co.throughput, "goodput": co.goodput,
                      "ttft": co.mean_ttft,
                      "cost_eff": co.cost_efficiency,
                      "sat_throughput": co_sat.throughput},
        "phase_split_best": {"ratio": tag, "throughput": r.throughput,
                             "goodput": r.goodput, "ttft": r.mean_ttft,
                             "cost_eff": r.cost_efficiency,
                             "sat_throughput": pd_sat_best,
                             "peak_kv_mb": r.peak_kv_bytes / 1e6},
        "goodput_ratio": r.goodput / max(co.goodput, 1e-12),
        "ttft_ratio": co.mean_ttft / max(r.mean_ttft, 1e-12),
        "sat_throughput_ratio":
            pd_sat_best / max(co_sat.throughput, 1e-12),
    }
    rows.append((f"pd.{mix_name}.split_over_colocated", 0.0,
                 f"good_x{summary['goodput_ratio']:.3f}"
                 f"|ttft_x{summary['ttft_ratio']:.3f}"
                 f"|sat_x{summary['sat_throughput_ratio']:.3f}"))

    if overlap:
        # chunked KV streaming at the best split: sweep kv_chunks and
        # measure how much of the serial transfer leaves TTFT
        mk = factories[tag]
        xfer_per = r.transfer_seconds / max(r.completed, 1)
        best_n, best_r = 1, r
        for n in KV_CHUNKS:
            ro = cluster.simulate_pd(stable, mk(), kv_chunks=n)
            record(f"{tag}.overlap-n{n}.stable", ro)
            if ro.mean_ttft < best_r.mean_ttft:
                best_n, best_r = n, ro
        removed = r.mean_ttft - best_r.mean_ttft
        frac = removed / max(xfer_per, 1e-12)
        rows.append((f"pd.{mix_name}.overlap_transfer_removed", 0.0,
                     f"chunks={best_n}|removed={removed * 1e3:.3f}ms"
                     f"|xfer_per_req={xfer_per * 1e3:.3f}ms"
                     f"|frac={frac:.2f}"))
        summary["overlap"] = {
            "chunks": best_n, "ttft": best_r.mean_ttft,
            "serial_ttft": r.mean_ttft, "goodput": best_r.goodput,
            "transfer_per_req": xfer_per,
            "frac_transfer_removed": frac,
        }
        # decode-session affinity: follow-up turns reuse the decode
        # group's resident state (no re-transfer); the backlog break
        # keeps a hot home group from absorbing unbounded prefill work
        ra = cluster.simulate_pd(
            stable, mk(session_affinity=True, affinity_break=0.1),
            kv_chunks=best_n)
        record(f"{tag}.overlap+affinity.stable", ra,
               f"|avoided={ra.transfers_avoided}")
        summary["affinity"] = {
            "ttft": ra.mean_ttft, "goodput": ra.goodput,
            "transfers": ra.transfers,
            "transfers_avoided": ra.transfers_avoided,
        }
    return rows, summary


def main() -> None:
    ap = bench_parser(
        "phase-split vs colocated serving across P/D ratios",
        check_help="fail unless phase-split beats colocated on a "
                   "heterogeneous mix (the acceptance gate); with "
                   "--overlap also gate transfer-overlap wins")
    ap.add_argument("--overlap", action="store_true",
                    help="also sweep chunked KV streaming (kv_chunks) "
                         "and the session-affinity variant")
    args = ap.parse_args()

    all_rows: List[Row] = []
    summaries = []
    for mix_name, mix in MIXES.items():
        rows, summary = run_mix(mix_name, mix, args.quick, args.overlap)
        summaries.append(summary)
        all_rows += rows
    print_rows(all_rows)

    hetero = [s for s in summaries if s["mix"].startswith("hetero")]
    wins = [s for s in hetero
            if s["goodput_ratio"] >= 1.0 and s["ttft_ratio"] > 1.0
            and s["sat_throughput_ratio"] >= 0.95]
    gate: Dict = {"hetero_wins": [s["mix"] for s in wins],
                  "passed": bool(wins)}
    if args.overlap:
        # overlap gates: streaming must never regress TTFT past the
        # serial split (the sender's serial fallback guarantees the
        # per-request property; this checks it end to end), and on at
        # least one hetero mix it must strip >= 50% of the per-request
        # transfer seconds out of mean TTFT
        regress = [s["mix"] for s in summaries
                   if s["overlap"]["ttft"]
                   > s["overlap"]["serial_ttft"] + 1e-9]
        recovered = [s["mix"] for s in hetero
                     if s["overlap"]["frac_transfer_removed"] >= 0.5]
        gate["overlap_no_regression"] = not regress
        gate["overlap_recovered_hetero"] = recovered
        gate["passed"] = bool(gate["passed"] and not regress
                              and recovered)
    write_bench_json(args.out, {"bench": "pd_split", "quick": args.quick,
                                "overlap": args.overlap,
                                "mixes": summaries, "gate": gate})
    if args.check:
        assert wins, (
            "phase-split failed to beat colocated routing on every "
            f"heterogeneous mix: {json.dumps(hetero, indent=2)}")
        if args.overlap:
            assert gate["overlap_no_regression"], (
                "overlapped KV streaming regressed mean TTFT past the "
                f"serial split on {regress}")
            assert recovered, (
                "overlap failed to remove >=50% of transfer seconds "
                "from TTFT on any heterogeneous mix: "
                + json.dumps([s["overlap"] for s in hetero], indent=2))
        print(f"# CHECK OK: phase-split beats colocated on "
              f"{[s['mix'] for s in wins]}", file=sys.stderr)


if __name__ == "__main__":
    main()
