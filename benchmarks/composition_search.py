"""Composition search vs naive uniform provisioning under one budget.

  PYTHONPATH=src python benchmarks/composition_search.py [--quick] \
      [--out BENCH_composition.json] [--check]

The paper's cluster-scale claim is that CHOOSING the composition —
which devices group into which replica — beats just buying the big
homogeneous pairs.  This benchmark puts that to the gate on the
deployment DES: given a heterogeneous device inventory and a $/hr
budget,

  * the **uniform** baseline fills the budget with copies of the single
    highest-modeled-capacity group template (the "just buy the best
    pair" strategy, ``sizing.uniform_composition``),
  * the **searched** composition comes from
    ``sizing.search_composition`` (greedy capacity-per-$ seed +
    simulated-annealing mutations over group compositions, every
    candidate scored by a full DES replay).

Both serve the SAME open-loop trace with the same SLOs and the same
workload-aware router; the score is goodput per dollar (requests
served within both SLO components per $ of rental).  The demand rate
is calibrated to 0.9x the uniform baseline's measured saturated
throughput — just under its ceiling, the most favorable stable
operating point the naive strategy has — so a smarter spend of the
same budget wins on merit, not on pushing the baseline into queueing
collapse.

Output follows the repo CSV contract (``name,us_per_call,derived``).
``--check`` gates the ROADMAP acceptance criterion: the searched
composition must beat the uniform one on goodput/$.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path
from typing import List

sys.path.insert(0, str(Path(__file__).resolve().parent))

from common import (Row, bench_parser, print_rows, request_graph,
                    write_bench_json)
from repro.serving.sizing import search_composition, uniform_composition
from repro.serving.spec import DeploymentSpec
from repro.serving.workload import make_trace

ARCH = "llama3_8b"
LAYERS = 2                      # traced layers (costs are per-layer exact)
BASE_PROMPT, BASE_OUT = 1024, 128
SLOS = {"base": 2.0, "per_output_token": 0.02, "ttft": 0.3}

# A heterogeneous inventory shaped like the paper's lab: a few high-end
# parts, a deeper pool of cheap bandwidth-oriented ones.  The budget
# affords ~2 of the best pairs — or a smarter mix.
INVENTORY = {"h100": 2, "rtxpro6000": 2, "a100": 4, "l40s": 6}
BUDGET = 12.0                   # $/hr


def run(quick: bool):
    anneal = 300 if quick else 800
    iters = 18 if quick else 48
    n_req = 150 if quick else 350
    graph = request_graph(ARCH, prompt=BASE_PROMPT, n_out=BASE_OUT,
                          layers=LAYERS)
    spec_kwargs = dict(slos=SLOS, anneal_iters=anneal,
                       base_prompt=BASE_PROMPT, base_output=BASE_OUT)

    uniform = uniform_composition(INVENTORY, BUDGET, graph,
                                  anneal_iters=anneal)
    u_spec = DeploymentSpec(groups=uniform, budget=BUDGET, **spec_kwargs)
    u_dep = u_spec.compile(graph)
    # calibrate demand to the uniform baseline's measured ceiling (the
    # DES's serial-chain capacity sits well below the plan-bottleneck
    # upper bound, same reasoning as benchmarks/pd_split.py)
    sat = u_dep.simulate(make_trace("poisson", 10.0 *
                                    u_dep.cluster().capacity,
                                    80 if quick else 150,
                                    seed=3)).throughput
    trace = make_trace("poisson", 0.9 * sat, n_req, seed=17)

    u_res = u_dep.simulate(trace)
    u_score = u_res.goodput * 3600.0 / max(u_spec.price_rate, 1e-12)

    sr = search_composition(INVENTORY, BUDGET, trace, graph,
                            iters=iters, seed=0,
                            spec_kwargs=spec_kwargs)

    rows: List[Row] = []

    def record(tag: str, spec, res, score: float) -> None:
        comp = "|".join("+".join(g) for g in spec.groups)
        rows.append((f"composition.{tag}", res.mean_latency * 1e6,
                     f"good={res.goodput:.2f}req/s"
                     f"|price=${spec.price_rate:.1f}/hr"
                     f"|goodput_per_dollar={score:.0f}req/$"
                     f"|comp={comp}"))

    record("uniform", u_spec, u_res, u_score)
    record("searched", sr.spec, sr.result, sr.score)
    ratio = sr.score / max(u_score, 1e-12)
    rows.append(("composition.searched_over_uniform", 0.0,
                 f"goodput_per_dollar_x{ratio:.3f}"
                 f"|seed_x{sr.seed_score / max(u_score, 1e-12):.3f}"
                 f"|evals={sr.evals}"))

    summary = {
        "inventory": INVENTORY, "budget": BUDGET,
        "demand_rate": 0.9 * sat,
        "uniform": {"groups": u_spec.groups,
                    "price_rate": u_spec.price_rate,
                    "goodput": u_res.goodput,
                    "goodput_per_dollar": u_score},
        "searched": {"groups": sr.spec.groups,
                     "price_rate": sr.spec.price_rate,
                     "goodput": sr.result.goodput,
                     "goodput_per_dollar": sr.score,
                     "seed_goodput_per_dollar": sr.seed_score,
                     "evals": sr.evals},
        "ratio": ratio,
    }
    return rows, summary


def main() -> None:
    args = bench_parser(
        "replica-composition search vs uniform same-budget provisioning",
        check_help="fail unless the searched composition beats the "
                   "uniform same-budget one on goodput/$ (the ROADMAP "
                   "sizing acceptance gate)").parse_args()
    rows, summary = run(args.quick)
    print_rows(rows)
    gate = {"passed": summary["ratio"] > 1.0}
    write_bench_json(args.out, {"bench": "composition_search",
                                "quick": args.quick,
                                "summary": summary, "gate": gate})
    if args.check:
        assert gate["passed"], (
            "searched composition failed to beat the uniform "
            "same-budget composition on goodput/$: "
            + json.dumps(summary, indent=2))
        print(f"# CHECK OK: searched beats uniform by "
              f"x{summary['ratio']:.3f} goodput/$", file=sys.stderr)


if __name__ == "__main__":
    main()
