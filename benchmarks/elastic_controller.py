"""Closed-loop autoscaling vs every static same-budget composition.

  PYTHONPATH=src python benchmarks/elastic_controller.py [--quick] \
      [--out BENCH_elastic.json] [--check]

Static provisioning must pick ONE composition for a diurnal day: sized
for the mean it sheds the peak, sized for the peak it idles (and
bills) through the trough.  This benchmark replays one diurnal trace
against four same-budget strategies on the deployment DES:

  * **uniform**      — fill the budget with copies of the best single
                       group template (``sizing.uniform_composition``),
  * **search@mean**  — ``sizing.search_composition`` winner sized for
                       the mean demand rate,
  * **search@peak**  — the search winner sized for the peak rate,
  * **controller**   — one founding group plus a parked reserve pool,
                       driven by ``controller.AutoscalePolicy``:
                       reserves activate (behind a modeled warm-up)
                       when the windowed shed rate or queue depth
                       breaches, groups drain in the trough, at most
                       one action per cooldown.

The controller runs the SAME hardware as the search@peak winner —
founded on its cheapest group, everything else parked in reserve — so
elasticity is the only variable between the two: identical capacity
when fully scaled, strictly less billing through the trough.

Scored by **goodput per dollar** — requests served within BOTH SLO
components per rental dollar.  Statics bill ``price_rate x makespan``
(always-on); the controller bills time-weighted
(``AutoscalePolicy.billed_dollars``: activation decision -> drain,
warm-up paid).  All four see the same trace, SLOs and router policy.

Admission shedding is OFF: capacity is the only SLO mechanism, as on
a serving stack without an admission controller.  An undersized
composition queues, and queueing blows the TTFT component for every
admitted request — so the knee-sized comps that ride a shed gate to
artificially clean SLOs (saturated cheap group + aggressive shedding
~= its capacity/$ ratio, unbeatable by ANY marginal capacity) are not
available here; provisioning has to clear demand, and clearing the
peak means billing idle capacity through the trough — exactly the gap
a closed-loop controller closes.  ``--check`` gates: the controller
must beat every static on goodput/$.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path
from typing import List

sys.path.insert(0, str(Path(__file__).resolve().parent))

from common import (Row, bench_parser, print_rows, request_graph,
                    write_bench_json)
from repro.serving.controller import (AutoscaleConfig, AutoscalePolicy,
                                      goodput_per_dollar)
from repro.serving.sizing import (group_price, modeled_capacity,
                                  search_composition, uniform_composition)
from repro.serving.spec import DeploymentSpec
from repro.serving.workload import diurnal_trace, make_trace

ARCH = "llama3_8b"
LAYERS = 2                      # traced layers (costs are per-layer exact)
BASE_PROMPT, BASE_OUT = 1024, 128
SLOS = {"base": 2.0, "per_output_token": 0.02, "ttft": 0.3}
INVENTORY = {"h100": 2, "rtxpro6000": 2, "a100": 4, "l40s": 6}
BUDGET = 12.0                   # $/hr, shared by every strategy
AMPLITUDE = 0.9                 # diurnal swing: peak = 1.9x mean,
#                                 trough = 0.1x mean
MEAN_OVER_BLOCK = 0.9           # mean demand in multiples of the best
#                                 capacity/$ single-device template's
#                                 modeled capacity: one group cannot
#                                 clear the mean, several clear the
#                                 peak — the static sizing dilemma


def run(quick: bool):
    anneal = 300 if quick else 800
    iters = 12 if quick else 40
    n_req = 400 if quick else 1000
    graph = request_graph(ARCH, prompt=BASE_PROMPT, n_out=BASE_OUT,
                          layers=LAYERS)
    spec_kwargs = dict(slos=SLOS, anneal_iters=anneal,
                       base_prompt=BASE_PROMPT, base_output=BASE_OUT,
                       router="jsed", router_kwargs={"slo_shed": False})

    # ---- demand: one diurnal cycle calibrated to the planner's own
    # capacity unit — the best modeled capacity/$ single-device
    # template ("block").  The mean sits just under one block, the
    # peak needs two or three: no single group clears the day, and
    # whatever clears the peak idles through the trough. --------------
    block = max(([n] for n in INVENTORY),
                key=lambda t: modeled_capacity(tuple(t), graph, anneal)
                / group_price(tuple(t)))
    mean_rate = MEAN_OVER_BLOCK * modeled_capacity(
        tuple(block), graph, anneal)
    peak_rate = mean_rate * (1.0 + AMPLITUDE)
    span = n_req / mean_rate
    trace = diurnal_trace(mean_rate, n_req, seed=17,
                          amplitude=AMPLITUDE, period=span)

    uniform = uniform_composition(INVENTORY, BUDGET, graph,
                                  anneal_iters=anneal)
    u_spec = DeploymentSpec(groups=uniform, budget=BUDGET, **spec_kwargs)

    # ---- static baselines, all at the SAME budget --------------------
    sized_mean = search_composition(
        INVENTORY, BUDGET,
        make_trace("poisson", mean_rate, n_req // 2, seed=5), graph,
        iters=iters, seed=0, spec_kwargs=spec_kwargs)
    sized_peak = search_composition(
        INVENTORY, BUDGET,
        make_trace("poisson", peak_rate, n_req // 2, seed=5), graph,
        iters=iters, seed=0, spec_kwargs=spec_kwargs)

    statics = {
        "uniform": u_spec,
        "search_mean": sized_mean.spec,
        "search_peak": sized_peak.spec,
    }
    results = {}
    for tag, spec in statics.items():
        res = spec.compile(graph).simulate(trace)
        results[tag] = {"spec": spec, "res": res,
                        "billed": spec.price_rate * res.makespan / 3600.0,
                        "gpd": goodput_per_dollar(res)}

    # ---- the controller: the search@peak winner made elastic — its
    # cheapest group founds, every other group parks in reserve, so
    # static-vs-elastic is the ONLY difference against search_peak ----
    peak_comp = sorted((list(g) for g in sized_peak.spec.groups),
                       key=lambda g: (group_price(tuple(g)), g))
    base, reserves = peak_comp[:1], peak_comp[1:]
    if not reserves:             # degenerate single-group winner: park
        #                          a second copy of the same template
        reserves = [list(g) for g in base]
    c_spec = DeploymentSpec(groups=base, budget=BUDGET, **spec_kwargs)
    c_dep = c_spec.compile(graph)
    # reaction latency is a property of the CONTROLLER, not of how
    # long the replayed day is: absolute seconds, same in both modes
    # (queues build in absolute time, so a span-relative epoch would
    # blow TTFTs on the morning ramp of any longer trace)
    interval = 5.0
    ctl = AutoscalePolicy(
        AutoscaleConfig(interval=interval, window=4 * interval,
                        cooldown=interval, warmup=10.0,
                        queue_hi=0.5 * SLOS["ttft"], queue_lo=0.1,
                        util_lo=0.6),
        inventory=reserves)
    c_res = c_dep.simulate(trace, controller=ctl)
    billed = ctl.billed_dollars()
    c_gpd = goodput_per_dollar(c_res, billed)

    rows: List[Row] = []

    def record(tag, comp, res, billed_, gpd) -> None:
        rows.append((f"elastic.{tag}", res.mean_latency * 1e6,
                     f"good={res.goodput:.2f}req/s"
                     f"|slo_ok={res.slo_ok}|shed={res.shed}"
                     f"|billed=${billed_ * 3600.0 / res.makespan:.2f}/hr"
                     f"|goodput_per_dollar={gpd:.0f}req/$"
                     f"|comp={'|'.join('+'.join(g) for g in comp)}"))

    for tag, r in results.items():
        record(tag, r["spec"].groups, r["res"], r["billed"], r["gpd"])
    record("controller", base + reserves, c_res, billed, c_gpd)
    best_static = max(results, key=lambda t: results[t]["gpd"])
    ratio = c_gpd / max(results[best_static]["gpd"], 1e-12)
    ups = sum(1 for d in ctl.decisions if d.action == "up")
    downs = sum(1 for d in ctl.decisions if d.action == "down")
    rows.append(("elastic.controller_over_best_static", 0.0,
                 f"goodput_per_dollar_x{ratio:.3f}"
                 f"|best_static={best_static}"
                 f"|ups={ups}|downs={downs}"))

    summary = {
        "inventory": INVENTORY, "budget": BUDGET,
        "calibration_block": block,
        "mean_rate": mean_rate, "peak_rate": peak_rate,
        "amplitude": AMPLITUDE, "n_requests": n_req,
        "statics": {
            tag: {"groups": r["spec"].groups,
                  "price_rate": r["spec"].price_rate,
                  "goodput": r["res"].goodput,
                  "slo_ok": r["res"].slo_ok, "shed": r["res"].shed,
                  "goodput_per_dollar": r["gpd"]}
            for tag, r in results.items()},
        "controller": {
            "base_groups": base, "reserve_groups": reserves,
            "base_price_rate": c_spec.price_rate,
            "billed_dollars": billed,
            "mean_billed_rate": billed * 3600.0 / c_res.makespan,
            "goodput": c_res.goodput, "slo_ok": c_res.slo_ok,
            "shed": c_res.shed, "goodput_per_dollar": c_gpd,
            "decisions": [[d.time, d.action, d.group, d.reason]
                          for d in ctl.decisions]},
        "best_static": best_static, "ratio": ratio,
    }
    return rows, summary


def main() -> None:
    args = bench_parser(
        "closed-loop autoscaling vs static same-budget compositions "
        "on a diurnal trace",
        check_help="fail unless the controller beats EVERY static "
                   "same-budget composition on goodput/$").parse_args()
    rows, summary = run(args.quick)
    print_rows(rows)
    worst = min(summary["controller"]["goodput_per_dollar"]
                / max(s["goodput_per_dollar"], 1e-12)
                for s in summary["statics"].values())
    gate = {"passed": worst > 1.0}
    write_bench_json(args.out, {"bench": "elastic_controller",
                                "quick": args.quick,
                                "summary": summary, "gate": gate})
    if args.check:
        assert gate["passed"], (
            "controller failed to beat a static same-budget composition "
            "on goodput/$: " + json.dumps(summary["statics"], indent=2)
            + json.dumps(summary["controller"], indent=2))
        print(f"# CHECK OK: controller beats every static "
              f"(worst margin x{worst:.3f} goodput/$)", file=sys.stderr)


if __name__ == "__main__":
    main()
