"""Hot-path benchmark: decode throughput + stage-dispatch overhead.

Measures the two layers the fast-path overhaul rebuilt:

  1. **Decode loop** — tokens/s of the sync-free continuous-batching
     engine (device-resident state, fused sampling, host sync every K
     steps, batched prefill) against a frozen copy of the pre-overhaul
     engine (batch-1 prefills, per-token ``np.asarray`` + Python slot
     loop).  Target: >= 2x decode tokens/s on CPU.
  2. **Executor dispatch** — per-call latency and dispatch counts of the
     indexed/fused dispatch program against the legacy per-stage dict
     walk, plus PipelinedRunner dispatch totals.

Writes ``BENCH_hotpath.json`` so later PRs have a perf trajectory.
Absolute tokens/s are machine-dependent, so the regression gate
(``--check``) compares the *speedup ratios* (fast vs reference on the
same machine) against the committed baseline and fails when a ratio
drops below 80% of its baseline value.

  PYTHONPATH=src python benchmarks/engine_hotpath.py --quick \
      --check benchmarks/BENCH_hotpath_baseline.json
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.core import analyzer, planner
from repro.core.costmodel import GPU_A100, GPU_L40S
from repro.core.executor import build_executable
from repro.core.pipeline import PipelinedRunner
from repro.models import model as M
from repro.serving.engine import Request, ServingEngine


# ===================================================================== #
# Frozen pre-overhaul engine (the "before" in before/after): batch-1
# prefill, per-token host sync + Python slot loop.  Kept verbatim so the
# comparison stays honest as the live engine evolves.
# ===================================================================== #
class ReferenceEngine:
    def __init__(self, cfg, params, *, slots=4, max_len=256,
                 eos_id=None, temperature=0.0, seed=0):
        self.cfg, self.params = cfg, params
        self.slots, self.max_len = slots, max_len
        self.eos_id, self.temperature = eos_id, temperature
        self.key = jax.random.PRNGKey(seed)
        self.completed = 0
        self.decode_steps = 0
        self.cache = M.init_cache(cfg, slots, max_len)
        self.active: List[Optional[Request]] = [None] * slots
        self.pos = np.zeros(slots, np.int32)
        self.budget = np.zeros(slots, np.int32)
        self.last_tok = np.zeros(slots, np.int32)
        self._decode = jax.jit(
            lambda p, c, t, pos: M.decode_step(p, cfg, t, c, pos))
        self._prefill1 = jax.jit(
            lambda p, c, t: M.prefill(p, cfg, t, c))

    def _write_slot(self, slot, cache1):
        def upd(full, one):
            return jax.lax.dynamic_update_slice_in_dim(
                full, one.astype(full.dtype), slot, axis=1)
        self.cache = jax.tree_util.tree_map(upd, self.cache, cache1)

    def _sample(self, logits):
        if self.temperature <= 0.0:
            return np.asarray(jnp.argmax(logits, axis=-1))
        self.key, sub = jax.random.split(self.key)
        return np.asarray(jax.random.categorical(
            sub, logits / self.temperature, axis=-1))

    def admit(self, req, now):
        try:
            slot = self.active.index(None)
        except ValueError:
            return False
        S = len(req.prompt)
        cache1 = M.init_cache(self.cfg, 1, self.max_len)
        toks = jnp.asarray(req.prompt, jnp.int32)[None]
        logits, cache1 = self._prefill1(self.params, cache1, toks)
        self._write_slot(slot, cache1)
        tok = self._sample(logits)[0]
        req.ttft = now
        req.output.append(int(tok))
        self.active[slot] = req
        self.pos[slot] = S
        self.budget[slot] = req.max_new_tokens - 1
        self.last_tok[slot] = int(tok)
        return True

    def step(self, now):
        if not any(r is not None for r in self.active):
            return
        toks = jnp.asarray(self.last_tok, jnp.int32)[:, None]
        pos = jnp.asarray(self.pos, jnp.int32)
        logits, self.cache = self._decode(self.params, self.cache, toks,
                                          pos)
        nxt = self._sample(logits)
        self.decode_steps += 1
        for s, req in enumerate(self.active):
            if req is None:
                continue
            self.pos[s] += 1
            tok = int(nxt[s])
            req.output.append(tok)
            self.budget[s] -= 1
            done = (self.budget[s] <= 0
                    or (self.eos_id is not None and tok == self.eos_id)
                    or self.pos[s] >= self.max_len - 1)
            if done:
                req.finished = now
                self.completed += 1
                self.active[s] = None
            else:
                self.last_tok[s] = tok

    def run(self, requests):
        t0 = time.perf_counter()
        pending = sorted(requests, key=lambda r: r.arrival)
        while pending or any(r is not None for r in self.active):
            now = time.perf_counter() - t0
            while pending and pending[0].arrival <= now:
                if not self.admit(pending[0], now):
                    break
                pending.pop(0)
            self.step(time.perf_counter() - t0)


# ===================================================================== #
def _make_requests(cfg, n, prompt_len, max_new, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        size=prompt_len).astype(np.int32),
                    max_new_tokens=max_new, arrival=0.0)
            for i in range(n)]


def bench_decode(quick: bool) -> Dict[str, Any]:
    # Deliberately tiny model: the quantity under test is hot-path
    # overhead (dispatch, host syncs, Python bookkeeping), which on a
    # real accelerator is what caps utilization; a large model would
    # bury it under matmul time and measure the CPU's GEMM throughput
    # instead.
    cfg = dataclasses.replace(
        configs.get_smoke("qwen3_1_7b"), dtype="float32",
        num_layers=2, d_model=32, num_heads=2, num_kv_heads=1,
        head_dim=16, d_ff=64, vocab_size=128)
    params = M.init_params(cfg)
    slots, max_len, prompt_len = 4, 96, 8
    n_req = 8 if quick else 16
    max_new = 32 if quick else 48
    repeats = 3                      # median filters scheduler noise

    def timed(make_engine):
        eng = make_engine()
        # warm the per-instance jit caches with an identical-shape run
        eng.run(_make_requests(cfg, slots, prompt_len, 4, seed=7))
        tps = []
        for rep in range(repeats):
            if hasattr(eng, "stats"):
                # report counters for ONE measured run, not cumulative
                eng.stats = type(eng.stats)()
            reqs = _make_requests(cfg, n_req, prompt_len, max_new)
            t0 = time.perf_counter()
            eng.run(reqs)
            wall = time.perf_counter() - t0
            decode_tokens = sum(len(r.output) for r in reqs) - len(reqs)
            tps.append(decode_tokens / wall)
        return float(np.median(tps)), reqs, eng

    ref_tps, ref_reqs, _ = timed(
        lambda: ReferenceEngine(cfg, params, slots=slots,
                                max_len=max_len))
    fast_tps, fast_reqs, fast_eng = timed(
        lambda: ServingEngine(cfg, params, slots=slots, max_len=max_len,
                              sync_every=8))
    match = float(np.mean([a.output == b.output for a, b in
                           zip(ref_reqs, fast_reqs)]))
    return {
        "ref_tokens_per_s": ref_tps,
        "fast_tokens_per_s": fast_tps,
        "speedup": fast_tps / ref_tps,
        "output_match_fraction": match,
        "host_syncs": fast_eng.stats.host_syncs,
        "decode_steps": fast_eng.stats.decode_steps,
        "prefill_batches": fast_eng.stats.prefill_batches,
        "requests": n_req, "max_new": max_new, "slots": slots,
    }


def bench_executor(quick: bool) -> Dict[str, Any]:
    def fn(x, params):
        for w1, w2 in params:
            x = jax.nn.gelu(x @ w1) @ w2
        return jnp.tanh(x)

    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 13)
    params = [(jax.random.normal(ks[2 * i], (64, 128)) * 0.1,
               jax.random.normal(ks[2 * i + 1], (128, 64)) * 0.1)
              for i in range(6)]
    x = jax.random.normal(ks[12], (8, 64))
    traced = analyzer.analyze(fn, x, params)
    plan = planner.plan(traced.graph, [GPU_A100, GPU_L40S],
                        policy="throughput", cache=False)
    exe = build_executable(traced, plan)

    iters = 50 if quick else 200
    jax.block_until_ready(exe(x, params))            # compile fast path
    jax.block_until_ready(exe.call_reference(x, params))   # + ref path

    t0 = time.perf_counter()
    for _ in range(iters):
        out = exe(x, params)
    jax.block_until_ready(out)
    fast_s = (time.perf_counter() - t0) / iters

    t0 = time.perf_counter()
    for _ in range(iters):
        out = exe.call_reference(x, params)
    jax.block_until_ready(out)
    ref_s = (time.perf_counter() - t0) / iters

    runner = PipelinedRunner(exe, max_inflight=4)
    n_pipe = 8
    _, pstats = runner.run([((x, params), {}) for _ in range(n_pipe)])
    return {
        "plan_stages": len(exe.stages),
        "dispatch_units": exe.num_units,
        "ref_ms_per_call": ref_s * 1e3,
        "fast_ms_per_call": fast_s * 1e3,
        "call_speedup": ref_s / fast_s,
        "pipeline_dispatches_per_request":
            pstats.stage_dispatches / n_pipe,
        "pipeline_dispatch_overhead_s": pstats.dispatch_overhead(),
    }


# ===================================================================== #
def check_regression(result: Dict[str, Any], baseline_path: str) -> int:
    with open(baseline_path) as f:
        base = json.load(f)
    failures = []
    checks = [
        ("decode.speedup", result["decode"]["speedup"],
         base["decode"]["speedup"]),
        ("executor.call_speedup", result["executor"]["call_speedup"],
         base["executor"]["call_speedup"]),
    ]
    for name, cur, ref in checks:
        if cur < 0.8 * ref:
            failures.append(f"{name}: {cur:.2f} < 80% of baseline "
                            f"{ref:.2f}")
    if failures:
        print("PERF REGRESSION:\n  " + "\n  ".join(failures))
        return 1
    print("perf check ok: " + ", ".join(
        f"{n}={c:.2f} (baseline {r:.2f})" for n, c, r in checks))
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller workload (CI perf-smoke)")
    ap.add_argument("--out", default="BENCH_hotpath.json")
    ap.add_argument("--check", default=None, metavar="BASELINE_JSON",
                    help="fail if speedups regress >20%% vs baseline")
    args = ap.parse_args()

    print("== decode hot loop ==")
    decode = bench_decode(args.quick)
    for k, v in decode.items():
        print(f"  {k}: {v:.3f}" if isinstance(v, float) else
              f"  {k}: {v}")
    print("== executor dispatch ==")
    executor = bench_executor(args.quick)
    for k, v in executor.items():
        print(f"  {k}: {v:.3f}" if isinstance(v, float) else
              f"  {k}: {v}")

    result = {
        "meta": {
            "quick": args.quick,
            "jax": jax.__version__,
            "backend": jax.default_backend(),
        },
        "decode": decode,
        "executor": executor,
    }
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(f"wrote {args.out}")

    if args.check:
        rc = check_regression(result, args.check)
        if rc != 0:
            # shared CI runners are noisy; re-measure once before
            # declaring a regression
            print("re-measuring once before failing ...")
            result["decode"] = bench_decode(args.quick)
            result["executor"] = bench_executor(args.quick)
            with open(args.out, "w") as f:
                json.dump(result, f, indent=2)
            rc = check_regression(result, args.check)
        return rc
    return 0


if __name__ == "__main__":
    sys.exit(main())
