"""Shared benchmark machinery: request DDGs, coarse-grained baselines.

All benchmarks operate on kernel graphs traced from the REAL model code
at full width (ShapeDtypeStruct tracing, no allocation), with per-kernel
costs from the device catalog — the same costs the planner optimizes, so
planner-vs-baseline comparisons are apples-to-apples.  Performance
numbers come from the discrete-event simulator (DESIGN.md §9: no
heterogeneous hardware in this container).

A *request graph* models one serving request: a prefill pass followed by
``n_out`` decode iterations (decode kernel costs and internal edges are
scaled by ``n_out`` for the planner; the simulator replays decode stages
``n_out`` times).
"""
from __future__ import annotations

import argparse
import contextlib
import cProfile
import dataclasses
import json
import pstats
import sys
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.core import analyzer
from repro.core.costmodel import CATALOG
from repro.core.graph import KernelGraph, KernelNode
from repro.models import model as M

# --------------------------------------------------------------------- #
# Shared CLI / report boilerplate (the repo CSV contract:
# ``name,us_per_call,derived`` — mean latency us in the middle column,
# the headline quantity in ``derived``).
# --------------------------------------------------------------------- #
Row = Tuple[str, float, str]


def bench_parser(description: str = "",
                 check_help: Optional[str] = None
                 ) -> argparse.ArgumentParser:
    """The flags every cluster-model benchmark shares: ``--quick``
    (CI-sized sweep), ``--out JSON`` (machine-readable results) and —
    when ``check_help`` is given — ``--check`` (the acceptance gate)."""
    ap = argparse.ArgumentParser(description=description)
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized sweep (fewer requests, less anneal)")
    ap.add_argument("--out", default=None, metavar="JSON",
                    help="write machine-readable results")
    if check_help is not None:
        ap.add_argument("--check", action="store_true", help=check_help)
    ap.add_argument("--profile", action="store_true",
                    help="wrap the measured runs in cProfile and print "
                         "the top-20 cumulative entries")
    return ap


# --------------------------------------------------------------------- #
# Shared fabric-topology presets (serving.fabric).  Benches opt in with
# ``add_topology_flag(ap)`` + ``topology_preset(args.topology, n)`` and
# pass the dict as ``DeploymentSpec(fabric=...)``.
# --------------------------------------------------------------------- #
TOPOLOGY_PRESETS = ("mirror", "congested-crossing")


def add_topology_flag(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--topology", default=None, choices=TOPOLOGY_PRESETS,
                    metavar="PRESET",
                    help="route KV/bulk traffic over a shared-channel "
                         "fabric preset: 'mirror' (per-group islands, "
                         "fat point-to-point crossings at the legacy "
                         "Interconnect rates) or 'congested-crossing' "
                         "(group 0 alone on one island, the rest behind "
                         "a thin half-duplex crossing)")


def topology_preset(name: Optional[str], n_groups: int) -> Optional[Dict]:
    """``DeploymentSpec.fabric`` dict for a named preset over
    ``n_groups`` replica groups (None passes through)."""
    if name is None:
        return None
    if name == "mirror":
        # one island per group; every ordered pair gets its own
        # full-duplex crossing at the legacy Interconnect defaults
        # (100 GB/s, 20 us) — uncontended, so queueing only appears
        # when transfers actually overlap on one directed edge
        islands = [{"name": f"g{i}", "groups": [i]}
                   for i in range(n_groups)]
        crossings = [{"src": f"g{i}", "dst": f"g{j}",
                      "bw": 100e9, "latency": 20e-6}
                     for i in range(n_groups) for j in range(n_groups)
                     if i != j]
        return {"islands": islands, "crossings": crossings,
                "host_island": "g0", "scheduler": "priority"}
    if name == "congested-crossing":
        # group 0 (the prefill-ish island) alone; every other group
        # shares one island behind a single thin HALF-duplex crossing,
        # so KV handoffs, checkpoint ships and migrations all fight
        # for the same wire in both directions
        islands = [{"name": "pre", "groups": [0]},
                   {"name": "dec",
                    "groups": list(range(1, n_groups))}]
        crossings = [{"src": "pre", "dst": "dec",
                      "bw": 10e9, "latency": 50e-6, "duplex": "half"}]
        return {"islands": islands, "crossings": crossings,
                "host_island": "pre", "scheduler": "priority"}
    raise ValueError(f"unknown topology preset {name!r}; "
                     f"pick from {TOPOLOGY_PRESETS}")


@contextlib.contextmanager
def maybe_profile(enabled: bool) -> Iterator[None]:
    """``with maybe_profile(args.profile): ...`` around the measured
    section.  No-op (zero overhead) unless ``--profile`` was given —
    profiled timings are for finding hotspots, not for reporting, so
    benchmarks should still print their numbers from unprofiled runs
    where possible."""
    if not enabled:
        yield
        return
    prof = cProfile.Profile()
    prof.enable()
    try:
        yield
    finally:
        prof.disable()
        pstats.Stats(prof).sort_stats("cumulative").print_stats(20)


def print_rows(rows: Sequence[Row]) -> None:
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived}")


def write_bench_json(path: Optional[str], payload: Dict) -> None:
    if not path:
        return
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {path}", file=sys.stderr)

# Paper §V-A workloads mapped onto this repo's model zoo.  Stable
# Diffusion 3.5 is outside the assigned architecture pool — noted as
# not-reproduced; zamba2 stands in for Mamba-Codestral (SSM family).
WORKLOADS = {
    "LM": "llama3_8b",        # Llama-3 8B
    "GT": "gpt_oss_20b",      # GPT-oss 20B
    "MB": "zamba2_7b",        # Mamba-family (SSM) stand-in
    "QW": "qwen2_vl_7b",      # Qwen2-VL 7B
}

_GRAPH_CACHE: Dict[Tuple, KernelGraph] = {}


def _trace(arch: str, kind: str, batch: int, seq: int,
           layers: Optional[int] = None) -> analyzer.TracedGraph:
    cfg = configs.get(arch)
    if layers:
        kw = dict(num_layers=layers)
        if cfg.family == "encdec":
            kw["encoder_layers"] = layers
        if cfg.family == "hybrid":
            kw = dict(num_layers=layers * cfg.hybrid_attn_every)
        cfg = dataclasses.replace(cfg, **kw)
    params = jax.eval_shape(lambda: M.init_params(cfg))
    cache = jax.eval_shape(
        lambda: M.init_cache(cfg, batch, seq,
                             enc_len=seq if cfg.family == "encdec"
                             else None))
    if kind == "prefill":
        toks = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
        kw = _extras(cfg, batch, seq, decode=False)
        keys = sorted(kw)

        def fn(p, c, t, *extra):
            return M.prefill(p, cfg, t, c, scan_layers=False,
                             **dict(zip(keys, extra)))
        return analyzer.analyze(fn, params, cache, toks,
                                *[kw[k] for k in keys],
                                state_argnums=(1,), name=f"{arch}.prefill")
    else:
        toks = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
        pos = jax.ShapeDtypeStruct((batch,), jnp.int32)
        kw = _extras(cfg, batch, 1, decode=True)
        keys = sorted(kw)

        def fn(p, c, t, q, *extra):
            return M.decode_step(p, cfg, t, c, q, scan_layers=False,
                                 **dict(zip(keys, extra)))
        return analyzer.analyze(fn, params, cache, toks, pos,
                                *[kw[k] for k in keys],
                                state_argnums=(1,), name=f"{arch}.decode")


def _extras(cfg, batch, seq, decode: bool):
    kw = {}
    if cfg.family == "vlm":
        if not decode:
            kw["patch_embeds"] = jax.ShapeDtypeStruct(
                (batch, min(cfg.num_patches, seq), cfg.d_model),
                cfg.jnp_dtype)
        kw["positions3"] = jax.ShapeDtypeStruct(
            (3, batch, seq), jnp.int32)
    if cfg.family == "encdec" and not decode:
        kw["enc_embeds"] = jax.ShapeDtypeStruct(
            (batch, seq, cfg.d_model), cfg.jnp_dtype)
    return kw


def request_graph(arch: str, *, batch: int = 1, prompt: int = 1024,
                  n_out: int = 128, layers: Optional[int] = 4,
                  ) -> KernelGraph:
    """Combined prefill + (scaled) decode DDG for one serving request.

    Node tags: phase in {prefill, decode}; block tags come from the
    model's region markers.  Decode kernels appear once with costs x
    ``n_out`` (the planner's view); the simulator replays them n_out
    times unscaled.
    """
    key = (arch, batch, prompt, n_out, layers)
    if key in _GRAPH_CACHE:
        return _GRAPH_CACHE[key]
    tg_p = _trace(arch, "prefill", batch, prompt, layers)
    tg_d = _trace(arch, "decode", batch, prompt + n_out, layers)

    nodes: List[KernelNode] = []
    edges: Dict[Tuple[int, int], float] = {}
    for n in tg_p.graph.nodes:
        nodes.append(dataclasses.replace(n, phase="prefill"))
    off = len(nodes)
    for n in tg_d.graph.nodes:
        nodes.append(dataclasses.replace(
            n, idx=n.idx + off, phase="decode",
            flops=n.flops * n_out, bytes_accessed=n.bytes_accessed * n_out,
            repeat=n_out,
            eqn_ids=tuple(e + 10_000_000 for e in n.eqn_ids)))
    edges.update(tg_p.graph.edges)
    for (i, j), b in tg_d.graph.edges.items():
        edges[(i + off, j + off)] = b * n_out
    # KV handoff: prefill's last writer feeds decode's first readers.
    cfg = configs.get(arch)
    kv_bytes = float(cfg.num_kv_heads * cfg.head_dim * 2 * 2 * prompt) \
        if cfg.num_kv_heads else float(cfg.d_model * 4)
    edges[(off - 1, off)] = edges.get((off - 1, off), 0.0) + kv_bytes
    g = KernelGraph(nodes, edges, name=f"{arch}.request")
    g.validate()
    _GRAPH_CACHE[key] = g
    return g


# --------------------------------------------------------------------- #
# Coarse-grained baselines (paper §V-A)
# --------------------------------------------------------------------- #
def pd_labels(graph: KernelGraph, prefill_dev: int = 0,
              decode_dev: int = 1) -> Optional[List[int]]:
    """Prefill-decode disaggregation (DistServe-style): whole phases."""
    if not any(n.phase == "decode" for n in graph.nodes):
        return None                      # inapplicable (paper's red X)
    return [prefill_dev if n.phase != "decode" else decode_dev
            for n in graph.nodes]


def af_labels(graph: KernelGraph, attn_dev: int = 0,
              ffn_dev: int = 1) -> Optional[List[int]]:
    """Attention-FFN disaggregation (MegaScale-Infer-style): blocks.
    Inapplicable to SSM / attention-free architectures."""
    blocks = {n.block for n in graph.nodes}
    if "ssm" in blocks or not ({"attention"} & blocks):
        return None
    return [attn_dev if n.block == "attention" else ffn_dev
            for n in graph.nodes]


def plan_from_labels(graph: KernelGraph, labels: List[int], devices,
                     policy_name: str):
    from repro.core.makespan import MakespanProblem
    from repro.core.planner import _finalize
    prob = MakespanProblem(graph, devices)
    return _finalize(graph, devices, labels, prob.objective(labels),
                     policy_name, None, 0.0)


def devices_for(pair: Tuple[str, str]):
    return [CATALOG[pair[0]], CATALOG[pair[1]]]
