"""Chaos day: typed fault plans vs checkpoint recovery, DES + live.

  PYTHONPATH=src python benchmarks/chaos_serving.py [--quick] \
      [--out BENCH_chaos.json] [--check]

Three measured claims:

**Chaos day (DES).**  A seeded ``FaultPlan`` — a full-outage blip
(every group crashes mid-trace and recovers), plus a straggle window
on one group — replays against the same Poisson trace twice: *naive*
(faults only: crash victims are dropped and re-arrivals shed while
groups are down) and *recovery* (checkpoint store + health-aware
routing: victims park, restore from their last checkpoint on an "up",
and replay only the lost suffix).  ``--check`` gates: recovery drops
ZERO accepted sessions and strictly beats naive on goodput
(completions).

**Flaky fabric (DES, pd).**  Seeded per-chunk KV-transfer failures on
every directed group pair.  A benign fault rate is absorbed by
exponential-backoff retransmits (retries charge fabric time, nothing
lost); a hostile link (retry budget exhausted, deadline blown) aborts
the handoff and the request re-prefills on the decode group instead of
being dropped.  ``--check`` gates: hostile refills > 0 with
dropped == 0.

**Crash recovery (live engines).**  A two-engine colocated pool runs
real greedy decode; one engine crashes mid-decode and recovers.  With
a ``CheckpointStore`` every victim restores on the survivor and the
final tokens are bit-identical to the fault-free run.  ``--check``
gates: lost == recovered > 0 and bit-identity holds.
"""
from __future__ import annotations

import dataclasses
import sys
import time
from pathlib import Path
from typing import List

sys.path.insert(0, str(Path(__file__).resolve().parent))

import numpy as np

from common import (Row, bench_parser, maybe_profile, print_rows,
                    request_graph, write_bench_json)
import repro.configs as configs
from repro.models import model as M
from repro.serving.engine import Request
from repro.serving.faults import FaultPlan, GroupHealth, RecoveryConfig
from repro.serving.spec import DeploymentSpec
from repro.serving.workload import poisson_trace

ARCH = "llama3_8b"
GROUPS = [["h100", "rtxpro6000"], ["a100", "l40s"], ["a100", "l40s"]]
LOAD_X = 1.5            # offered load, multiples of annealed capacity


def _graph():
    return request_graph(ARCH, prompt=512, n_out=64, layers=2)


def _trace(dep, n, seed=7):
    return poisson_trace(rate=LOAD_X * dep.cluster().capacity,
                         num_requests=n, seed=seed)


def chaos_day_part(rows: List[Row], results: dict, quick: bool) -> None:
    dep = DeploymentSpec(groups=GROUPS,
                         anneal_iters=150 if quick else 500
                         ).compile(_graph())
    n = 250 if quick else 800
    trace = _trace(dep, n)
    mid = trace[n // 2].arrival
    plan = FaultPlan(seed=1)
    for g in range(len(GROUPS)):        # full-outage blip
        plan.crash(mid, group=g, recover_at=mid + 0.01)
    plan.straggle(mid * 0.2, mid * 0.6, group=0, factor=4.0)

    runs = {}
    for tag, kw in (
            ("naive", {}),
            # checkpoint interval well under this toy-scale DES's
            # sub-millisecond decode times, so victims have progress
            ("recovery", dict(recovery=RecoveryConfig(interval=1e-5),
                              health=GroupHealth()))):
        t0 = time.perf_counter()
        res = dep.simulate(trace, faults=plan, **kw)
        dt = time.perf_counter() - t0
        runs[tag] = res
        rows.append((f"chaos_day_{tag}", dt * 1e6,
                     f"completed={res.completed}/{n} "
                     f"dropped={res.dropped} shed={res.shed} "
                     f"recovered={res.recovered}"))
        results[f"chaos_{tag}"] = {
            "requests": n, "completed": res.completed,
            "dropped": res.dropped, "shed": res.shed,
            "recovered": res.recovered, "makespan": res.makespan,
        }
    results["chaos_goodput_gain"] = (runs["recovery"].completed
                                     - runs["naive"].completed)


def flaky_part(rows: List[Row], results: dict, quick: bool) -> None:
    dep = DeploymentSpec(groups=GROUPS, router="pd_split", pd=True,
                         kv_chunks=4,
                         anneal_iters=150 if quick else 500
                         ).compile(_graph())
    n = 250 if quick else 800
    trace = _trace(dep, n)

    def all_links(seed, **kw):
        plan = FaultPlan(seed=seed)
        for s in range(len(GROUPS)):
            for d in range(len(GROUPS)):
                if s != d:
                    plan.flaky_link(s, d, **kw)
        return plan

    for tag, plan in (
            ("benign", all_links(5, p=0.05, max_retries=8,
                                 deadline=10.0)),
            ("hostile", all_links(5, p=0.9, max_retries=1,
                                  deadline=1e-6))):
        t0 = time.perf_counter()
        res = dep.simulate(trace, faults=plan)
        dt = time.perf_counter() - t0
        rows.append((f"flaky_{tag}", dt * 1e6,
                     f"retries={res.kv_retries} "
                     f"refills={res.kv_refills} dropped={res.dropped}"))
        results[f"flaky_{tag}"] = {
            "requests": n, "kv_retries": res.kv_retries,
            "kv_refills": res.kv_refills, "dropped": res.dropped,
            "completed": res.completed, "shed": res.shed,
        }


def live_part(rows: List[Row], results: dict) -> None:
    cfg = dataclasses.replace(configs.get_smoke(ARCH), dtype="float32")
    params = M.init_params(cfg)
    spec = DeploymentSpec(groups=[["h100"], ["a100"]], arch=ARCH,
                          engine={"slots": 4, "max_len": 64})
    rng = np.random.default_rng(0)

    def reqs():
        return [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab_size,
                                            size=s).astype(np.int32),
                        max_new_tokens=12, arrival=0.0)
                for i, s in enumerate((12, 9, 17))]

    rng = np.random.default_rng(0)
    ref = reqs()
    t0 = time.perf_counter()
    spec.compile().launch(cfg, params).run(ref)
    t_ref = time.perf_counter() - t0

    rng = np.random.default_rng(0)
    chaos = reqs()
    dep = spec.compile().launch(cfg, params)
    dep.inject(FaultPlan(seed=4).crash(0.25, group=0, recover_at=0.6),
               recovery=RecoveryConfig(interval=0.02,
                                       min_dirty_tokens=1))
    t0 = time.perf_counter()
    stats = dep.run(chaos)
    t_chaos = time.perf_counter() - t0

    ident = all(a.output == b.output for a, b in zip(ref, chaos))
    rows.append(("live_fault_free", t_ref * 1e6,
                 f"sessions={len(ref)}"))
    rows.append(("live_crash_recovery", t_chaos * 1e6,
                 f"lost={stats['lost_sessions']} "
                 f"recovered={stats['recovered_sessions']} "
                 f"bit_identical={ident}"))
    results["live"] = {
        "sessions": len(ref),
        "lost": stats["lost_sessions"],
        "recovered": stats["recovered_sessions"],
        "checkpoints": stats["checkpoints"],
        "bit_identical": ident,
        "fault_free_s": t_ref, "chaos_s": t_chaos,
    }


def main() -> int:
    ap = bench_parser(
        description=__doc__.split("\n")[0],
        check_help="gate: recovery drops zero accepted sessions and "
                   "beats naive chaos-day goodput; hostile flaky links "
                   "refill instead of drop; live crash victims all "
                   "recover bit-identically")
    args = ap.parse_args()
    rows: List[Row] = []
    results: dict = {}
    with maybe_profile(args.profile):
        chaos_day_part(rows, results, args.quick)
        flaky_part(rows, results, args.quick)
        live_part(rows, results)
    print_rows(rows)
    write_bench_json(args.out, results)
    if args.check:
        rec, nai = results["chaos_recovery"], results["chaos_naive"]
        if rec["dropped"] != 0:
            print(f"CHECK FAILED: recovery dropped "
                  f"{rec['dropped']} accepted sessions",
                  file=sys.stderr)
            return 1
        if rec["completed"] <= nai["completed"]:
            print(f"CHECK FAILED: recovery goodput {rec['completed']} "
                  f"does not beat naive {nai['completed']}",
                  file=sys.stderr)
            return 1
        host = results["flaky_hostile"]
        if host["dropped"] != 0 or host["kv_refills"] <= 0:
            print(f"CHECK FAILED: hostile flaky links must refill "
                  f"(got {host['kv_refills']}) and never drop "
                  f"(got {host['dropped']})", file=sys.stderr)
            return 1
        live = results["live"]
        if not (live["lost"] > 0
                and live["recovered"] == live["lost"]
                and live["bit_identical"]):
            print(f"CHECK FAILED: live crash recovery "
                  f"lost={live['lost']} recovered={live['recovered']} "
                  f"bit_identical={live['bit_identical']}",
                  file=sys.stderr)
            return 1
        print(f"CHECK OK: chaos-day goodput {rec['completed']} vs "
              f"naive {nai['completed']} with 0 dropped; hostile "
              f"links refilled {host['kv_refills']}; live recovered "
              f"{live['recovered']}/{live['lost']} bit-identically",
              file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
