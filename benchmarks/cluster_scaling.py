"""Cluster scaling: throughput + cost-efficiency vs replica count & mix.

  PYTHONPATH=src python benchmarks/cluster_scaling.py

Reproduces the paper's cluster-scale claim shape on the discrete-event
model: aggregate throughput and cost-efficiency (req/$) as a function
of the number of heterogeneous replica groups (up to 16 devices) and of
the heterogeneity mix, for round-robin vs workload-aware (JSED)
routing.  The workload-aware router must beat round-robin on the
cross-heterogeneous mixes: round-robin gives every group equal load, so
the slowest group queues without bound while fast groups idle; JSED
rate-matches load to capability (see repro/serving/router.py for the
scoring policy).

Output follows the repo CSV contract: ``name,us_per_call,derived`` with
mean request latency (us) in the middle column and the headline
quantity (throughput req/s, cost-eff req/$, speedup ratios) in
``derived``.
"""
from __future__ import annotations

import sys
from pathlib import Path
from typing import List, Sequence, Tuple

sys.path.insert(0, str(Path(__file__).resolve().parent))

from common import (Row, add_topology_flag, bench_parser, print_rows,
                    request_graph, topology_preset, write_bench_json)
from repro.core.monitor import MonitorConfig
from repro.serving.cluster import TesseraCluster
from repro.serving.router import JSEDRouter, RoundRobinRouter
from repro.serving.workload import assign_slos, make_trace

ARCH = "llama3_8b"
LAYERS = 2                      # traced layers (costs are per-layer exact)
BASE_PROMPT, BASE_OUT = 1024, 128
N_REQ = 400
SLO_BASE, SLO_PER_TOK = 4.0, 0.05   # completion deadline (s)

# Heterogeneity mixes: each entry is the device-pair cycle replicas are
# drawn from.  "paper-pairs" interleaves the paper's three local pairs —
# maximum cross-replica heterogeneity.
MIXES = {
    "a100-l40s": [("a100", "l40s")],
    "a100-l40s+h100-rtx": [("a100", "l40s"), ("h100", "rtxpro6000")],
    "paper-pairs": [("a100", "l40s"), ("h100", "rtxpro6000"),
                    ("b200", "h100")],
}
REPLICA_COUNTS = (1, 2, 4, 8)           # x2 devices each -> up to 16


def build_cluster(mix: Sequence[Tuple[str, str]], n_replicas: int,
                  anneal: int = 800,
                  topology: str = None) -> TesseraCluster:
    groups = [list(mix[i % len(mix)]) for i in range(n_replicas)]
    g = request_graph(ARCH, prompt=BASE_PROMPT, n_out=BASE_OUT,
                      layers=LAYERS)
    bw_overrides = None
    if topology is not None:
        # kernel placement sees the fabric: each group plans against
        # its island's CONTENDED bandwidth, not the nameplate rate
        from repro.serving.fabric import Topology
        topo = Topology.from_dict(topology_preset(topology, n_replicas))
        bw_overrides = [topo.planner_bw(i) for i in range(n_replicas)]
    return TesseraCluster(g, groups, base_prompt=BASE_PROMPT,
                          base_output=BASE_OUT,
                          monitor_cfg=MonitorConfig(window=0.050),
                          anneal_iters=anneal,
                          bw_overrides=bw_overrides)


def run_mix(mix_name: str, mix, trace_kind: str = "poisson",
            load: float = 1.1, quick: bool = False,
            topology: str = None) -> List[Row]:
    rows: List[Row] = []
    n_req = 150 if quick else N_REQ
    counts = REPLICA_COUNTS[:2] if quick else REPLICA_COUNTS
    for n_rep in counts:
        cluster = build_cluster(mix, n_rep, 300 if quick else 800,
                                topology=topology)
        rate = load * cluster.capacity
        trace = assign_slos(
            make_trace(trace_kind, rate, n_req, seed=17),
            base=SLO_BASE, per_output_token=SLO_PER_TOK)
        res = {}
        for router in (RoundRobinRouter(), JSEDRouter()):
            r = cluster.simulate(trace, router)
            res[router.name] = r
            tag = (f"cluster.{mix_name}.{trace_kind}.r{n_rep}"
                   f".g{cluster.num_devices}.{router.name}")
            rows.append((f"{tag}.throughput", r.mean_latency * 1e6,
                         f"{r.throughput:.2f}req/s"
                         f"|good={r.goodput:.2f}"))
            rows.append((f"{tag}.cost_eff", r.p(0.95) * 1e6,
                         f"{r.cost_efficiency:.1f}req/$"))
        ratio = (res["jsed"].throughput
                 / max(res["round_robin"].throughput, 1e-12))
        good_ratio = (res["jsed"].goodput
                      / max(res["round_robin"].goodput, 1e-12))
        lat_ratio = (res["round_robin"].mean_latency
                     / max(res["jsed"].mean_latency, 1e-12))
        rows.append((f"cluster.{mix_name}.{trace_kind}.r{n_rep}"
                     f".jsed_over_rr", 0.0,
                     f"thr_x{ratio:.3f}|good_x{good_ratio:.3f}"
                     f"|lat_x{lat_ratio:.3f}"))
    return rows


def cluster_scaling(quick: bool = False,
                    topology: str = None) -> List[Row]:
    rows: List[Row] = []
    for mix_name, mix in MIXES.items():
        rows += run_mix(mix_name, mix, "poisson", quick=quick,
                        topology=topology)
    # burstiness stresses the router + monitor on the most hetero mix
    rows += run_mix("paper-pairs", MIXES["paper-pairs"], "bursty",
                    quick=quick, topology=topology)
    return rows


def main() -> None:
    ap = bench_parser("cluster throughput/cost-eff scaling")
    add_topology_flag(ap)
    args = ap.parse_args()
    rows = cluster_scaling(args.quick, topology=args.topology)
    print_rows(rows)
    write_bench_json(args.out, {
        "bench": "cluster_scaling", "quick": args.quick,
        "topology": args.topology,
        "rows": [{"name": n, "us_per_call": us, "derived": d}
                 for n, us, d in rows]})


if __name__ == "__main__":
    main()
