"""One benchmark per paper table/figure (DESIGN.md §8 index).

Each function returns a list of CSV rows (name, us_per_call, derived).
``us_per_call`` is the modeled per-request/step service time in
microseconds where meaningful; ``derived`` carries the figure's headline
quantity (ratios, fractions, counts).
"""
from __future__ import annotations

import sys
import time
from pathlib import Path
from typing import List, Tuple

sys.path.insert(0, str(Path(__file__).resolve().parent))

import numpy as np

from common import (WORKLOADS, af_labels, devices_for, pd_labels,
                    plan_from_labels, request_graph)
from repro.core import planner
from repro.core.costmodel import CATALOG, PAPER_PAIRS, graph_time_on
from repro.core.monitor import MonitorConfig, OnlineMonitor
from repro.core.simulator import simulate_offline, simulate_online

Row = Tuple[str, float, str]

N_REQ = 48
PAIR_MAIN = ("a100", "l40s")


# ===================================================================== #
# Fig 2: kernel heterogeneity (CDF of ratios + time-weighted share)
# ===================================================================== #
def fig2_kernel_heterogeneity() -> List[Row]:
    rows: List[Row] = []
    a, b = devices_for(PAIR_MAIN)
    for tag, arch in WORKLOADS.items():
        g = request_graph(arch)
        ratios = []
        t_a_total = 0.0
        t_faster_on_b = 0.0
        for n in g.nodes:
            ta, tb = a.kernel_time(n), b.kernel_time(n)
            ratios.append(tb / ta)
            t_a_total += ta
            if tb < ta:
                t_faster_on_b += ta
        frac_count = float(np.mean(np.array(ratios) < 1.0))
        frac_time = t_faster_on_b / t_a_total
        rows.append((f"fig2.{tag}.kernels_faster_on_l40s_count",
                     0.0, f"{frac_count:.3f}"))
        rows.append((f"fig2.{tag}.time_weighted_share",
                     t_a_total * 1e6, f"{frac_time:.3f}"))
    return rows


# ===================================================================== #
# Fig 3: phase- and block-grouped kernel preferences (GPT-oss)
# ===================================================================== #
def fig3_phase_block() -> List[Row]:
    rows: List[Row] = []
    a, b = devices_for(PAIR_MAIN)
    g = request_graph(WORKLOADS["GT"])
    for group_by, keys in (("phase", ("prefill", "decode")),
                           ("block", ("attention", "moe", "ffn"))):
        for key in keys:
            nodes = [n for n in g.nodes
                     if getattr(n, group_by) == key]
            if not nodes:
                continue
            frac = float(np.mean([b.kernel_time(n) < a.kernel_time(n)
                                  for n in nodes]))
            rows.append((f"fig3.{group_by}.{key}.frac_faster_l40s",
                         0.0, f"{frac:.3f}"))
    return rows


# ===================================================================== #
# Fig 6 + Table III: offline throughput & cost efficiency
# ===================================================================== #
def fig6_offline_throughput() -> List[Row]:
    rows: List[Row] = []
    for pair in PAPER_PAIRS:
        devs = devices_for(pair)
        price = devs[0].price + devs[1].price
        for tag, arch in WORKLOADS.items():
            g = request_graph(arch)
            results = {}
            # homogeneous baselines (single device serves everything)
            for i, d in enumerate(devs):
                t = graph_time_on(g, d)
                results[f"homo_{d.name}"] = 1.0 / t
            # Tessera: kernel-granularity plan + pipelined DES
            plan = planner.plan(g, devs, policy="throughput")
            sim = simulate_offline(g, plan, devs, num_requests=N_REQ)
            results["tessera"] = sim.throughput
            # PD / AF coarse baselines, best device assignment
            for name, lblfn in (("pd", pd_labels), ("af", af_labels)):
                best = None
                for flip in (False, True):
                    lbl = lblfn(g, int(flip), int(not flip))
                    if lbl is None:
                        break
                    p = plan_from_labels(g, lbl, devs, name)
                    s = simulate_offline(g, p, devs, num_requests=N_REQ)
                    best = max(best or 0.0, s.throughput)
                results[name] = best       # None = inapplicable (red X)
            base = results["tessera"]
            for name, thr in results.items():
                if thr is None:
                    rows.append((f"fig6.{pair[0]}+{pair[1]}.{tag}.{name}",
                                 0.0, "inapplicable"))
                    continue
                rows.append((f"fig6.{pair[0]}+{pair[1]}.{tag}.{name}",
                             1e6 / thr, f"{thr:.3f}req/s"))
            for name in ("pd", "af"):
                if results.get(name):
                    rows.append(
                        (f"fig6.{pair[0]}+{pair[1]}.{tag}."
                         f"tessera_over_{name}", 0.0,
                         f"{base / results[name]:.2f}x"))
            # Table III: Perf/$ normalized to homogeneous left
            left = results[f"homo_{devs[0].name}"] / devs[0].price
            rows.append((f"tab3.{pair[0]}+{pair[1]}.{tag}."
                         f"tessera_perf_per_dollar", 0.0,
                         f"{(base / price) / left:.3f}"))
    return rows


# ===================================================================== #
# Fig 7: online normalized latency vs request rate
# ===================================================================== #
def fig7_online_latency() -> List[Row]:
    rows: List[Row] = []
    arch = WORKLOADS["GT"]
    g = request_graph(arch)
    devs = devices_for(PAIR_MAIN)
    p_thr = planner.plan(g, devs, policy="throughput")
    p_lat = planner.plan(g, devs, policy="latency")
    base = p_lat.unpipelined_latency
    for rate_x in (0.2, 0.5, 0.8, 1.1):
        rate = rate_x / base
        for name, plans in (("tessera_lat", {"latency": p_lat}),
                            ("tessera_thr", {"latency": p_thr})):
            sim = simulate_online(g, plans, devs, rate=rate,
                                  num_requests=80,
                                  iters_per_request=1)
            rows.append((f"fig7.rate{rate_x}.{name}",
                         sim.mean_latency * 1e6,
                         f"p90={sim.p(0.9) * 1e3:.2f}ms"))
    return rows


# ===================================================================== #
# Fig 8: cluster scale — 3-GPU asymmetric MILP + TP-composed pairs
# ===================================================================== #
def fig8_cluster_scale() -> List[Row]:
    rows: List[Row] = []
    g = request_graph(WORKLOADS["GT"])
    devs3 = [CATALOG["a100"], CATALOG["a100"], CATALOG["l40s"]]
    plan3 = planner.plan(g, devs3, policy="throughput")
    sim3 = simulate_offline(g, plan3, devs3, num_requests=N_REQ)
    rows.append(("fig8.2a100+1l40s.tessera", 1e6 / sim3.throughput,
                 f"{sim3.throughput:.3f}req/s"))
    # PD on 3 GPUs: prefill -> l40s, decode -> each a100
    lbl = pd_labels(g, prefill_dev=2, decode_dev=0)
    p = plan_from_labels(g, lbl, devs3, "pd")
    simp = simulate_offline(g, p, devs3, num_requests=N_REQ)
    rows.append(("fig8.2a100+1l40s.pd", 1e6 / simp.throughput,
                 f"{sim3.throughput / simp.throughput:.2f}x_tessera"))
    # TP-composed heterogeneous pairs (B200+H100) x 8: per-pair plan,
    # collectives stay on the homogeneous group (paper §IV).
    pair = devices_for(("b200", "h100"))
    plan_pair = planner.plan(g, pair, policy="throughput")
    simpair = simulate_offline(g, plan_pair, pair, num_requests=N_REQ)
    rows.append(("fig8.8x(b200+h100).tessera_per_pair",
                 1e6 / simpair.throughput,
                 f"aggregate={simpair.throughput * 8:.3f}req/s"))
    return rows


# ===================================================================== #
# Fig 9: pipelined request processing ablation
# ===================================================================== #
def fig9_pipeline_ablation() -> List[Row]:
    rows: List[Row] = []
    g = request_graph(WORKLOADS["GT"])
    devs = devices_for(PAIR_MAIN)
    plan = planner.plan(g, devs, policy="throughput")
    opt = plan.steady_state_throughput
    for name, kw in (("none", dict(pipelined=False)),
                     ("naive", dict(scheduling="fifo")),
                     ("priority", dict(scheduling="priority"))):
        sim = simulate_offline(g, plan, devs, num_requests=N_REQ, **kw)
        rows.append((f"fig9.{name}", 1e6 / sim.throughput,
                     f"{sim.throughput / opt:.3f}of_optimal"))
        # Fig 9b: time breakdown on the bottleneck device
        bdev = int(np.argmax(plan.T))
        busy = sim.busy_fraction(bdev)
        rows.append((f"fig9b.{name}.bottleneck_busy", 0.0,
                     f"{busy:.3f}"))
    return rows


# ===================================================================== #
# Fig 10: online monitor sensitivity (W, beta)
# ===================================================================== #
def fig10_monitor_sensitivity() -> List[Row]:
    rows: List[Row] = []
    g = request_graph(WORKLOADS["GT"], layers=2)
    devs = devices_for(PAIR_MAIN)
    p_thr = planner.plan(g, devs, policy="throughput")
    p_lat = planner.plan(g, devs, policy="latency")
    plans = {"latency": p_lat, "throughput": p_thr}
    base = p_lat.unpipelined_latency
    rate = 1.5 / base
    for W_ms in (30, 300, 1500):
        mon = OnlineMonitor(MonitorConfig(window=W_ms / 1e3, beta=1.5))
        sim = simulate_online(g, plans, devs, rate=rate,
                              num_requests=150, monitor=mon)
        rows.append((f"fig10.W{W_ms}ms", sim.mean_latency * 1e6,
                     f"switches={sim.switches}"))
    for beta in (1.1, 1.5, 3.0):
        mon = OnlineMonitor(MonitorConfig(window=0.3, beta=beta))
        sim = simulate_online(g, plans, devs, rate=rate,
                              num_requests=150, monitor=mon)
        rows.append((f"fig10.beta{beta}", sim.mean_latency * 1e6,
                     f"switches={sim.switches}"))
    return rows


# ===================================================================== #
# Fig 11a: robustness to slow interconnect
# ===================================================================== #
def fig11a_bandwidth() -> List[Row]:
    rows: List[Row] = []
    g = request_graph(WORKLOADS["GT"])
    devs = devices_for(PAIR_MAIN)
    thr200 = None
    for gbps in (200, 100, 50, 25):
        bw = gbps / 8 * 1e9
        plan = planner.plan(g, devs, policy="throughput",
                            bw_override=bw)
        sim = simulate_offline(g, plan, devs, num_requests=N_REQ,
                               bw_override=bw)
        thr200 = thr200 or sim.throughput
        rows.append((f"fig11a.offline.{gbps}gbps",
                     1e6 / sim.throughput,
                     f"{sim.throughput / thr200:.3f}of_200gbps"))
        pl = planner.plan(g, devs, policy="latency", bw_override=bw)
        rows.append((f"fig11a.latpolicy.{gbps}gbps.cut_edges", 0.0,
                     f"{pl.cut_edges}"))
    # graceful degeneration: ~zero bandwidth -> single device, no cliff
    p0 = planner.plan(g, devs, policy="latency", bw_override=1e3)
    t_single = min(graph_time_on(g, d) for d in devs)
    rows.append(("fig11a.degenerate.single_device_gap", 0.0,
                 f"{p0.objective / t_single:.3f}x"))
    return rows


# ===================================================================== #
# Fig 11b: planner scalability (+ layer folding)
# ===================================================================== #
def fig11b_planner_scaling() -> List[Row]:
    import sys as _sys
    _sys.path.insert(0, str(Path(__file__).resolve().parents[1] /
                            "tests"))
    from conftest import random_dag
    rows: List[Row] = []
    devs = devices_for(PAIR_MAIN)
    for n in (200, 500, 1000, 1500):
        g = random_dag(n, seed=1, p=min(0.02, 40.0 / n))
        t0 = time.perf_counter()
        planner.plan(g, devs, policy="latency", cache=False)
        dt_lat = time.perf_counter() - t0
        t0 = time.perf_counter()
        planner.plan(g, devs, policy="throughput", cache=False,
                     anneal_iters=1000)
        dt_thr = time.perf_counter() - t0
        rows.append((f"fig11b.K{n}.latency_mincut", dt_lat * 1e6,
                     f"{dt_lat * 1e3:.1f}ms"))
        rows.append((f"fig11b.K{n}.throughput_heuristic", dt_thr * 1e6,
                     f"{dt_thr * 1e3:.1f}ms"))
    for nG in (2, 3, 4):
        devs_n = [CATALOG[n] for n in
                  ("a100", "l40s", "h100", "rtxpro6000")][:nG]
        g = random_dag(500, seed=2, p=0.02)
        t0 = time.perf_counter()
        planner.plan(g, devs_n, policy="latency", cache=False)
        dt = time.perf_counter() - t0
        rows.append((f"fig11b.G{nG}.latency", dt * 1e6,
                     f"{dt * 1e3:.1f}ms"))
    # layer folding speedup on a real layered model graph
    g = request_graph(WORKLOADS["LM"], layers=8)
    t0 = time.perf_counter()
    planner.plan(g, devs, policy="throughput", cache=False,
                 use_folding=False, anneal_iters=1000)
    t_full = time.perf_counter() - t0
    t0 = time.perf_counter()
    planner.plan(g, devs, policy="throughput", cache=False,
                 use_folding=True, anneal_iters=1000)
    t_fold = time.perf_counter() - t0
    rows.append(("fig11b.folding_speedup", t_fold * 1e6,
                 f"{t_full / max(t_fold, 1e-9):.2f}x"))
    return rows


ALL_FIGURES = [
    fig2_kernel_heterogeneity,
    fig3_phase_block,
    fig6_offline_throughput,
    fig7_online_latency,
    fig8_cluster_scale,
    fig9_pipeline_ablation,
    fig10_monitor_sensitivity,
    fig11a_bandwidth,
    fig11b_planner_scaling,
]
