"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (scaffold contract).  Kernel
micro-benchmarks (wall time of the jitted CPU reference ops) are included
for completeness; the paper-figure numbers are cost-model + DES driven
(no heterogeneous hardware in this container — DESIGN.md §9).
"""
from __future__ import annotations

import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))


def _kernel_micro():
    """Wall-clock micro-bench of the jitted reference ops on CPU."""
    import jax
    import jax.numpy as jnp
    from repro.kernels.flash_attention.ref import attention_ref
    from repro.kernels.mamba2_ssd.ref import ssd_ref

    rows = []
    key = jax.random.PRNGKey(0)

    def bench(fn, *args, iters=5):
        fn_j = jax.jit(fn)
        jax.block_until_ready(fn_j(*args))
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn_j(*args)
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / iters * 1e6

    q = jax.random.normal(key, (1, 4, 512, 64), jnp.float32)
    us = bench(lambda q: attention_ref(q, q, q, causal=True), q)
    rows.append(("micro.attention_ref.s512", us, "cpu_wall"))

    xh = jax.random.normal(key, (1, 256, 4, 32))
    B_ = jax.random.normal(key, (1, 256, 16))
    al = -jax.nn.softplus(jax.random.normal(key, (1, 256, 4)))
    us = bench(lambda a, b, c: ssd_ref(a, b, c, al)[0], xh, B_, B_)
    rows.append(("micro.ssd_ref.s256", us, "cpu_wall"))
    return rows


def main() -> None:
    from paper_figures import ALL_FIGURES

    print("name,us_per_call,derived")
    for row in _kernel_micro():
        print(f"{row[0]},{row[1]:.2f},{row[2]}")
    for fig in ALL_FIGURES:
        t0 = time.perf_counter()
        rows = fig()
        dt = time.perf_counter() - t0
        for name, us, derived in rows:
            print(f"{name},{us:.2f},{derived}")
        print(f"_timing.{fig.__name__},{dt * 1e6:.0f},harness_wall")


if __name__ == "__main__":
    main()
