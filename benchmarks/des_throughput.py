"""DES fast-core benchmark: replay throughput + sizing-search speed.

Measures the vectorized DES fast core against the frozen reference walk
(``reference=True`` — the pre-vectorization per-unit object walk with
per-replay trace prep), on the regime the fast core was built for: deep
compiled programs (a full-width llama3 request DDG at 16 layers, several
hundred stage units per policy) spread over many two-device groups.

  1. **Single replay** — req/s on diurnal traces of 10k / 100k / 1M
     requests, in each event-recording mode (``full`` / ``agg`` /
     ``None``); the reference walk is timed on a shorter trace (req/s
     is size-independent: the DES is linear in requests).
  2. **Sizing search** — ``search_composition`` wall-clock, reference
     vs fast (shared prep + ``events=None`` + subsample-then-confirm),
     asserting the confirmed incumbent's full-trace goodput/$ does not
     drop.

Writes ``BENCH_des.json``.  Absolute req/s is machine-dependent, so
``--check`` gates on *ratios*: fast/reference replay speedup (agg mode,
100k trace) >= 10x, sizing speedup >= 5x at unchanged incumbent
quality, plus >= 80% of the committed baseline ratios
(``BENCH_des_baseline.json``), re-measuring once before failing — the
BENCH_hotpath contract.  The 1M-request replay runs even under
``--quick``: finishing it inside the CI perf-smoke budget is itself an
acceptance criterion.

  PYTHONPATH=src python benchmarks/des_throughput.py --quick --check
"""
from __future__ import annotations

import json
import os
import sys
import time
from typing import Any, Dict

from common import bench_parser, maybe_profile, request_graph, \
    write_bench_json
from repro.serving.sizing import (group_templates, modeled_capacity,
                                  search_composition)
from repro.serving.spec import DeploymentSpec
from repro.serving.workload import diurnal_trace

BASELINE = os.path.join(os.path.dirname(__file__),
                        "BENCH_des_baseline.json")

ARCH = "llama3_8b"
LAYERS = 16                     # ~350-unit throughput programs
GROUPS = [["a100", "l40s"]] * 4 + [["h100", "h100"]] * 2 \
    + [["rtxpro6000", "l40s"]] * 2
SLOS = {"base": 2.0, "per_output_token": 0.05, "ttft": 1.5}
ANNEAL = 200


def _graph():
    return request_graph(ARCH, layers=LAYERS)


def _dep(graph):
    return DeploymentSpec(groups=GROUPS, router="jsed", slos=SLOS,
                          anneal_iters=ANNEAL).compile(graph)


def _rate(dep, load=1.2):
    return load * dep.cluster().capacity


def bench_replay(quick: bool, profile: bool = False) -> Dict[str, Any]:
    graph = _graph()
    dep = _dep(graph)
    rate = _rate(dep)

    def run(n: int, events, reference=False) -> Dict[str, Any]:
        trace = diurnal_trace(rate, n, seed=0)
        # prep is INSIDE the timed window: the reference path preps
        # per-replay too (inside simulate), so req/s stays end-to-end
        # comparable; only trace generation is excluded
        t0 = time.perf_counter()
        with maybe_profile(profile):
            prep = None if reference else dep.prepare(trace)
            res = dep.simulate(None if prep else trace, events=events,
                               reference=reference, prepared=prep)
        wall = time.perf_counter() - t0
        assert res.completed + res.shed + res.dropped == n
        return {"wall_s": wall, "req_s": n / wall}

    ref_n = 5_000 if quick else 20_000
    out: Dict[str, Any] = {
        "config": {"arch": ARCH, "layers": LAYERS,
                   "groups": len(GROUPS), "rate": rate,
                   "ref_trace_n": ref_n},
        "reference": run(ref_n, "full", reference=True),
    }
    ref_rps = out["reference"]["req_s"]
    sizes = {"10k": 10_000, "100k": 100_000, "1m": 1_000_000}
    for name, n in sizes.items():
        if name == "1m":
            modes = {"none": None}   # the CI-completion criterion
        elif name == "100k" and quick:
            modes = {"agg": "agg", "none": None}
        else:
            modes = {"full": "full", "agg": "agg", "none": None}
        out[name] = {m: run(n, ev) for m, ev in modes.items()}
    out["speedup_100k_agg"] = out["100k"]["agg"]["req_s"] / ref_rps
    out["speedup_100k_none"] = out["100k"]["none"]["req_s"] / ref_rps
    out["speedup_1m_none"] = out["1m"]["none"]["req_s"] / ref_rps
    return out


def bench_sizing(quick: bool, profile: bool = False) -> Dict[str, Any]:
    graph = _graph()
    n = 2_500 if quick else 6_000
    iters = 14 if quick else 20
    rate = _rate(_dep(graph), load=1.0)
    trace = diurnal_trace(rate, n, seed=3)
    # min_group=2: every candidate group is a device pair — real
    # multi-hundred-unit programs, the regime the fast walk targets
    # (singles collapse to one-stage plans and measure nothing).  The
    # budget leaves inventory slack so annealing swaps stay feasible
    # and the search visits distinct compositions — replay volume, not
    # planner/compile overhead, is what this benchmark measures.
    inventory = {"a100": 6, "l40s": 6, "h100": 4, "rtxpro6000": 4}
    budget = 60.0
    kw = dict(iters=iters, seed=0, min_group=2,
              spec_kwargs={"slos": SLOS, "anneal_iters": ANNEAL})

    # Warm the process-wide plan cache for every template either search
    # can draw (greedy's modeled_capacity plans + both-policy candidate
    # plans) so neither timed run pays planner annealing the other one
    # already cached — the timed delta is pure replay/scoring work.
    for t in group_templates(inventory, 2, 2):
        modeled_capacity(t, graph)
        DeploymentSpec(groups=[list(t)], slos=SLOS,
                       anneal_iters=ANNEAL).compile(graph).cluster()

    def timed(**extra):
        t0 = time.perf_counter()
        with maybe_profile(profile):
            sr = search_composition(inventory, budget, trace, graph,
                                    **kw, **extra)
        return time.perf_counter() - t0, sr

    ref_wall, ref_sr = timed(reference=True)
    fast_wall, fast_sr = timed(subsample=max(200, n // 8))
    # quality: both incumbents are scored by identical full-trace fast
    # replays (walks are bit-identical), so goodput/$ is comparable
    quality = fast_sr.score / max(ref_sr.score, 1e-12)
    return {
        "trace_n": n, "iters": iters,
        "ref_wall_s": ref_wall, "fast_wall_s": fast_wall,
        "speedup": ref_wall / fast_wall,
        "ref_score": ref_sr.score, "fast_score": fast_sr.score,
        "quality_ratio": quality,
        "ref_evals": ref_sr.evals, "fast_evals": fast_sr.evals,
        "confirmed": fast_sr.confirmed,
        "ref_composition": ref_sr.composition,
        "fast_composition": fast_sr.composition,
    }


# --------------------------------------------------------------------- #
def check_gates(result: Dict[str, Any], baseline_path: str) -> int:
    failures = []
    rep, siz = result["replay"], result["sizing"]
    if rep["speedup_100k_agg"] < 10.0:
        failures.append(
            f"replay speedup (agg, 100k) {rep['speedup_100k_agg']:.1f}x"
            " < 10x floor")
    if siz["speedup"] < 5.0:
        failures.append(f"sizing speedup {siz['speedup']:.1f}x "
                        "< 5x floor")
    if siz["quality_ratio"] < 0.999:
        failures.append(
            f"sizing incumbent quality {siz['quality_ratio']:.4f} "
            "dropped vs reference search")
    if os.path.exists(baseline_path):
        with open(baseline_path) as f:
            base = json.load(f)
        ratios = [
            ("replay.speedup_100k_agg", rep["speedup_100k_agg"],
             base["replay"]["speedup_100k_agg"]),
            ("sizing.speedup", siz["speedup"],
             base["sizing"]["speedup"]),
        ]
        for name, cur, ref in ratios:
            if cur < 0.8 * ref:
                failures.append(f"{name}: {cur:.2f} < 80% of baseline "
                                f"{ref:.2f}")
    if failures:
        print("PERF REGRESSION:\n  " + "\n  ".join(failures))
        return 1
    print(f"perf check ok: replay {rep['speedup_100k_agg']:.1f}x, "
          f"sizing {siz['speedup']:.1f}x "
          f"(quality {siz['quality_ratio']:.4f})")
    return 0


def main() -> int:
    ap = bench_parser(
        description=__doc__.splitlines()[0],
        check_help="fail if replay < 10x / sizing < 5x, or either "
                   "ratio regresses >20% vs BENCH_des_baseline.json")
    args = ap.parse_args()

    print("== single replay ==")
    replay = bench_replay(args.quick, args.profile)
    print(f"  reference: {replay['reference']['req_s']:,.0f} req/s "
          f"({replay['config']['ref_trace_n']} reqs)")
    for size in ("10k", "100k", "1m"):
        for mode, r in replay[size].items():
            print(f"  fast {size:>4} events={mode:<4}: "
                  f"{r['req_s']:,.0f} req/s ({r['wall_s']:.2f}s)")
    print(f"  speedup@100k: agg {replay['speedup_100k_agg']:.1f}x, "
          f"none {replay['speedup_100k_none']:.1f}x")

    print("== sizing search ==")
    sizing = bench_sizing(args.quick, args.profile)
    print(f"  reference: {sizing['ref_wall_s']:.2f}s "
          f"({sizing['ref_evals']} evals)")
    print(f"  fast:      {sizing['fast_wall_s']:.2f}s "
          f"({sizing['fast_evals']} evals, "
          f"{sizing['confirmed']} confirmed)")
    print(f"  speedup {sizing['speedup']:.1f}x, "
          f"quality {sizing['quality_ratio']:.4f}")

    result = {"meta": {"quick": args.quick},
              "replay": replay, "sizing": sizing}
    write_bench_json(args.out or "BENCH_des.json", result)

    if args.check:
        rc = check_gates(result, BASELINE)
        if rc != 0:
            # shared CI runners are noisy; re-measure once
            print("re-measuring once before failing ...")
            result["replay"] = bench_replay(args.quick, args.profile)
            result["sizing"] = bench_sizing(args.quick, args.profile)
            write_bench_json(args.out or "BENCH_des.json", result)
            rc = check_gates(result, BASELINE)
        return rc
    return 0


if __name__ == "__main__":
    sys.exit(main())
