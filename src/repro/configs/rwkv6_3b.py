"""rwkv6-3b (Finch) [arXiv:2404.05892; hf] — attention-free, data-
dependent decay.

32L d_model=2560 d_ff=8960 vocab=65536.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="ssm",
    num_layers=32, d_model=2560, num_heads=0, num_kv_heads=0,
    d_ff=8960, vocab_size=65536, rwkv_head_dim=64,
)

SMOKE = dataclasses.replace(
    CONFIG, name="rwkv6-smoke", num_layers=2, d_model=64, num_heads=0,
    num_kv_heads=0, d_ff=128, vocab_size=256, rwkv_head_dim=16)
