"""dbrx-132b [hf:databricks/dbrx-base; unverified] — fine-grained MoE.

40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352, 16 experts top-4.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b", family="moe",
    num_layers=40, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=10752, vocab_size=100352,
    num_experts=16, experts_per_token=4, rope_theta=5e5,
)

SMOKE = dataclasses.replace(
    CONFIG, name="dbrx-smoke", num_layers=2, d_model=96, num_heads=6,
    num_kv_heads=2, d_ff=96, vocab_size=256, num_experts=4,
    experts_per_token=2, head_dim=0)
