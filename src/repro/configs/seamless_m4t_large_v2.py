"""seamless-m4t-large-v2 [arXiv:2308.11596; hf] — enc-dec backbone.

24L d_model=1024 16H (kv=16) d_ff=8192 vocab=256206.  The audio/vision
modality frontend is a STUB per the assignment: input_specs() provides
precomputed frame embeddings for the encoder.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="encdec",
    num_layers=24, encoder_layers=24,
    d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=8192, vocab_size=256206,
)

SMOKE = dataclasses.replace(
    CONFIG, name="seamless-smoke", num_layers=2, encoder_layers=2,
    d_model=64, num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=256,
    head_dim=0)
