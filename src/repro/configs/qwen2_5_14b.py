"""qwen2.5-14b [hf:Qwen/Qwen2.5; hf] — dense GQA with QKV bias.

48L d_model=5120 40H (GQA kv=8) d_ff=13824 vocab=152064.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b", family="dense",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=13824, vocab_size=152064, qkv_bias=True, rope_theta=1e6,
)

SMOKE = dataclasses.replace(
    CONFIG, name="qwen2.5-smoke", num_layers=2, d_model=80, num_heads=4,
    num_kv_heads=2, d_ff=160, vocab_size=256, head_dim=0)
