"""gemma-2b [arXiv:2403.08295; hf] — GeGLU, head_dim=256, MQA (kv=1).

18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=256000.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b", family="dense",
    num_layers=18, d_model=2048, num_heads=8, num_kv_heads=1,
    d_ff=16384, vocab_size=256000, head_dim=256,
    activation="geglu", tie_embeddings=True,
)

SMOKE = dataclasses.replace(
    CONFIG, name="gemma-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=1, d_ff=128, vocab_size=256, head_dim=32)
