"""qwen3-1.7b [hf:Qwen/Qwen3; hf] — qk_norm, GQA.

28L d_model=2048 16H (GQA kv=8) d_ff=6144 vocab=151936.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b", family="dense",
    num_layers=28, d_model=2048, num_heads=16, num_kv_heads=8,
    d_ff=6144, vocab_size=151936, qk_norm=True, rope_theta=1e6,
    head_dim=128,
)

SMOKE = dataclasses.replace(
    CONFIG, name="qwen3-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, d_ff=128, vocab_size=256, head_dim=16)
