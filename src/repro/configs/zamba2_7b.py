"""zamba2-7b [arXiv:2411.15242; unverified] — Mamba2 + shared attention.

81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000, ssm_state=64.
One shared-weight attention+MLP block applied every 3 mamba layers
(approximation of the published interleaving; see DESIGN.md).
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32,
    d_ff=14336, vocab_size=32000,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2,
    hybrid_attn_every=3,
)

SMOKE = dataclasses.replace(
    CONFIG, name="zamba2-smoke", num_layers=4, d_model=64, num_heads=4,
    num_kv_heads=4, d_ff=128, vocab_size=256, ssm_state=16,
    ssm_head_dim=16, hybrid_attn_every=2, head_dim=0)
