"""gpt-oss-20b [arXiv:2508.10925] — the paper's primary online workload.

MoE transformer: 24L d_model=2880 64H (GQA kv=8) d_ff=2880,
32 experts top-4, vocab ~201k (paper §V evaluates GPT-oss 20B heavily).
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gpt-oss-20b", family="moe",
    num_layers=24, d_model=2880, num_heads=64, num_kv_heads=8,
    d_ff=2880, vocab_size=201088, head_dim=64,
    num_experts=32, experts_per_token=4,
    sliding_window=4096,
)

SMOKE = dataclasses.replace(
    CONFIG, name="gptoss-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, d_ff=64, vocab_size=256, num_experts=4,
    experts_per_token=2, sliding_window=16, head_dim=16)
