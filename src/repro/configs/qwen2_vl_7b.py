"""qwen2-vl-7b [arXiv:2409.12191; hf] — M-RoPE, dynamic resolution VLM.

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.  Vision
frontend is a STUB: input_specs() provides precomputed patch embeddings
and 3D (t,h,w) M-RoPE position ids.
"""
import dataclasses

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b", family="vlm",
    num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4,
    d_ff=18944, vocab_size=152064,
    mrope=True, mrope_sections=(16, 24, 24), qkv_bias=True,
    num_patches=256, rope_theta=1e6,
)

SMOKE = dataclasses.replace(
    CONFIG, name="qwen2vl-smoke", num_layers=2, d_model=64, num_heads=4,
    num_kv_heads=2, d_ff=128, vocab_size=256, num_patches=8,
    mrope_sections=(4, 2, 2), head_dim=0)
