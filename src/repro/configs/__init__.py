"""Architecture registry: ``get(name)`` -> full config,
``get_smoke(name)`` -> reduced same-family config for CPU smoke tests."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

ARCHS: List[str] = [
    "mixtral_8x7b", "dbrx_132b", "granite_8b", "qwen2_5_14b",
    "gemma_2b", "qwen3_1_7b", "zamba2_7b", "seamless_m4t_large_v2",
    "qwen2_vl_7b", "rwkv6_3b",
    # paper workloads
    "gpt_oss_20b", "llama3_8b",
]

ASSIGNED: List[str] = ARCHS[:10]


def _norm(name: str) -> str:
    return name.replace("-", "_").replace(".", "_")


def get(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_norm(name)}")
    return mod.CONFIG


def get_smoke(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_norm(name)}")
    return mod.SMOKE


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get(a) for a in ARCHS}
