"""Serving engine: sync-free continuous batching over slot-based KV caches.

vLLM-shaped control plane on a JAX data plane:
  * fixed ``slots`` decode batch; idle slots are masked, arriving
    requests are admitted into free slots (continuous batching),
  * admissions are **batched**: all due arrivals that fit free slots go
    through ONE multi-request prefill — right-padded to a common length
    for attention families (exact under causal masking + per-row
    ``last_pos`` logit selection), grouped by exact prompt length for
    recurrent families (padding would pollute SSM state),
  * the decode hot loop is **sync-free**: ``last_tok``/``pos``/``budget``
    and the active mask live on device, sampling and termination logic
    are folded into the jitted decode step, and sampled tokens/done
    flags accumulate in device buffers that are fetched to the host only
    every ``sync_every`` steps — no per-token host round trip,
  * greedy / temperature sampling, per-slot positions, EOS/max-token
    termination, SLO accounting (TTFT / TPOT / normalized latency).
    TTFT is stamped only after the prefill logits are materialized
    (``block_until_ready``) — dispatch alone is not time-to-first-token,
  * optional Tessera integration: the decode step can be executed by a
    disaggregated StagedExecutable, with the OnlineMonitor switching
    between latency- and throughput-oriented plans (examples/
    serve_pipeline.py wires this up end to end),
  * prefill/decode disaggregation: ``prefill_handoff`` runs a prompt
    and exports the per-request KV/recurrent state; ``admit_handoff``
    on a second engine starts a decode_only session from the imported
    state (greedy decode is bit-identical to a single-engine run) —
    the real-engine analogue of the cluster simulator's KV-transfer
    edge,
  * PIPELINED handoff: ``prefill_handoff_stream`` processes the prompt
    in ``prefill_chunk``-sized chunks and yields (layer, chunk) KV
    shards as soon as they are computed, so the fabric transfer
    overlaps the remaining prefill compute instead of starting only
    after the whole prompt finishes; ``admit_handoff_stream`` installs
    the shards eagerly and starts decoding the moment the last shard
    lands (still bit-identical to the serial path),
  * chunked COLOCATED admission: with ``prefill_chunk`` set, a long
    admitted prompt no longer freezes the live decode slots for its
    whole prefill — decode steps are interleaved between prefill
    chunks.

Accounting note: completion times are observed at sync boundaries, so a
request's ``finished`` stamp can be up to ``sync_every - 1`` decode steps
late.  That is the deliberate trade of the sync-free loop; run with
``sync_every=1`` to recover per-token accounting (and per-token host
syncs).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.models.config import ModelConfig
from repro.serving.kvpool import (PagedKvCache, SessionManager,
                                  SessionState)

# Families whose prefill is exact under right-padding (causal attention
# never reads positions past the query).  Recurrent state (ssm/hybrid)
# integrates every input token, so padded rows would corrupt it.  (vlm
# is deliberately absent: the engine does not serve it, and patch-embed
# placement under padding is unvalidated.)
_PAD_SAFE_FAMILIES = ("dense", "moe")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (S,) int32
    max_new_tokens: int = 16
    arrival: float = 0.0
    priority: int = 0                   # preemption rank (higher wins)
    # filled by the engine:
    output: List[int] = dataclasses.field(default_factory=list)
    ttft: float = -1.0
    finished: float = -1.0


@dataclasses.dataclass
class EngineStats:
    completed: int = 0
    decode_steps: int = 0
    host_syncs: int = 0
    prefill_batches: int = 0
    ttft: List[float] = dataclasses.field(default_factory=list)
    tpot: List[float] = dataclasses.field(default_factory=list)
    latency_per_token: List[float] = dataclasses.field(
        default_factory=list)

    def summary(self) -> Dict[str, float]:
        return {
            "completed": self.completed,
            "decode_steps": self.decode_steps,
            "host_syncs": self.host_syncs,
            "prefill_batches": self.prefill_batches,
            "mean_ttft": float(np.mean(self.ttft)) if self.ttft else 0.0,
            "mean_tpot": float(np.mean(self.tpot)) if self.tpot else 0.0,
            "mean_norm_latency": float(np.mean(self.latency_per_token))
            if self.latency_per_token else 0.0,
        }


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params: Any, *, slots: int = 4,
                 max_len: int = 256, eos_id: Optional[int] = None,
                 temperature: float = 0.0, seed: int = 0,
                 decode_fn: Optional[Callable] = None,
                 prefill_fn: Optional[Callable] = None,
                 sync_every: int = 8,
                 prefill_chunk: Optional[int] = None,
                 kv_block_tokens: Optional[int] = None,
                 kv_pool_blocks: Optional[int] = None,
                 spill: bool = True,
                 preempt_priority: bool = True):
        assert cfg.family in ("dense", "moe", "ssm", "hybrid"), \
            "engine serves decoder-only families"
        assert sync_every >= 1
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.temperature = temperature
        self.sync_every = sync_every
        assert prefill_chunk is None or prefill_chunk >= 1
        self.prefill_chunk = prefill_chunk
        self.key = jax.random.PRNGKey(seed)
        self.stats = EngineStats()

        self.cache = M.init_cache(cfg, slots, max_len)
        self.active: List[Optional[Request]] = [None] * slots
        # Decode state is DEVICE-resident; the host only sees it at sync
        # boundaries.
        self.pos = jnp.zeros(slots, jnp.int32)        # next position
        self.budget = jnp.zeros(slots, jnp.int32)     # tokens remaining
        self.last_tok = jnp.zeros(slots, jnp.int32)
        self.active_mask = jnp.zeros(slots, bool)
        self._cols: List[jnp.ndarray] = []    # (2, slots) packed per step
        # upper bound on decode steps any live slot can still take
        # (recomputed whenever the host view is fresh)
        self._max_remaining = sync_every
        self._clock: Optional[Callable[[], float]] = None
        # Paged KV residency: sessions beyond the dense decode batch
        # park their state in a shared block pool (kvpool.PagedKvCache)
        # and time-slice through the slots at sync boundaries.  With
        # kv_block_tokens unset the engine is exactly the legacy
        # fixed-slot machine (self._paged is None everywhere).
        self.spill = spill
        self.preempt_priority = preempt_priority
        self._ran = [0] * slots         # decode steps since activation
        if kv_block_tokens is not None:
            pool_blocks = kv_pool_blocks if kv_pool_blocks is not None \
                else slots * (max_len // kv_block_tokens)
            self._paged: Optional[PagedKvCache] = PagedKvCache(
                cfg, pool_blocks, kv_block_tokens, max_len)
        else:
            assert kv_pool_blocks is None, \
                "kv_pool_blocks requires kv_block_tokens"
            self._paged = None
        self.sessions = SessionManager(self)

        eos = -1 if eos_id is None else int(eos_id)
        temp = float(temperature)
        greedy = temp <= 0.0

        def _sample(logits, key):
            if greedy:
                return jnp.argmax(logits, axis=-1).astype(jnp.int32), key
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits / temp, axis=-1)
            return tok.astype(jnp.int32), key

        def _post(logits, last_tok, pos, budget, active, key):
            """Sampling + termination, fused with the decode dispatch.

            ``packed`` is one (2, slots) int32 array — [emitted token or
            -1; done flag] — so each step leaves exactly one buffer for
            the sync to fetch (no eager stacking on the hot path).
            """
            tok, key = _sample(logits, key)
            new_pos = jnp.where(active, pos + 1, pos)
            new_budget = jnp.where(active, budget - 1, budget)
            done = active & ((new_budget <= 0) | (tok == eos)
                             | (new_pos >= max_len - 1))
            new_active = active & ~done
            new_last = jnp.where(new_active, tok, last_tok)
            emit = jnp.where(active, tok, -1)     # -1 = idle slot
            packed = jnp.stack([emit, done.astype(jnp.int32)])
            return new_last, new_pos, new_budget, new_active, packed, key

        self._post = jax.jit(_post)
        self._decode_custom = decode_fn
        if decode_fn is None:
            # params are engine-lifetime constants: close over them so
            # the hot loop does not re-flatten / re-validate the param
            # pytree on every dispatch.
            def _fused(c, last_tok, pos, budget, active, key):
                logits, c = M.decode_step(params, cfg, last_tok[:, None],
                                          c, pos)
                return (c,) + _post(logits, last_tok, pos, budget,
                                    active, key)
            self._step_fused = jax.jit(_fused)
        self._prefill_custom = prefill_fn
        if prefill_fn is None:
            self._prefill = jax.jit(
                lambda c, t, lp: M.prefill(params, cfg, t, c,
                                           last_pos=lp))
            # one chunk of an incremental prefill (offset is a traced
            # scalar, so every full-size chunk shares one compile)
            self._prefill_at = jax.jit(
                lambda c, t, off, lp: M.prefill(params, cfg, t, c,
                                                offset=off, last_pos=lp))

    # ------------------------------------------------------------------ #
    def _now(self, now: Optional[float]) -> float:
        if self._clock is not None:
            return self._clock()
        return now if now is not None else 0.0

    def _any_active(self) -> bool:
        if any(r is not None for r in self.active):
            return True
        return self._paged is not None and bool(self._paged.parked())

    def _write_slots(self, slots_: List[int], batch_cache: Any,
                     rows: int) -> None:
        """Scatter rows 0..rows of a prefill cache into engine slots —
        one scatter per cache leaf for the whole admission group."""
        idx = jnp.asarray(slots_, jnp.int32)

        def upd(full, grp):
            # full: (L, slots, ...); grp: (L, G_padded, ...)
            return full.at[:, idx].set(
                grp[:, :rows].astype(full.dtype))
        self.cache = jax.tree_util.tree_map(upd, self.cache, batch_cache)

    def _sample_host(self, logits: jnp.ndarray) -> np.ndarray:
        if self.temperature <= 0.0:
            return np.asarray(jnp.argmax(logits, axis=-1))
        self.key, sub = jax.random.split(self.key)
        return np.asarray(jax.random.categorical(
            sub, logits / self.temperature, axis=-1))

    # ------------------------------------------------------------------ #
    # Admission: batched multi-request prefill
    # ------------------------------------------------------------------ #
    def admit(self, req: Request, now: float) -> bool:
        """Single-request admission (compat wrapper over admit_batch)."""
        return self.admit_batch([req], now) == 1

    def admit_batch(self, reqs: Sequence[Request], now: float) -> int:
        """Admit up to len(free slots) requests through batched prefills.

        Returns the number admitted.  Attention families take ONE padded
        prefill for the whole batch; recurrent families are grouped by
        exact prompt length (right-padding would pollute SSM state).
        """
        # settle any buffered window first: admission must see fresh
        # slot state, and a slot re-filled mid-window would otherwise
        # have its new tokens hidden behind the old -1 idle markers
        self.sync(now)
        if self._paged is not None:
            # Paged admission runs in WAVES of up to ``slots`` requests:
            # each wave prefills into the dense batch, then parks into
            # the pool to free slots for the next wave — so concurrent
            # residency is bounded by free BLOCKS, not free slots.
            left = list(reqs)
            admitted = 0
            while left:
                pairs = self._paged_admit(left, now)
                if not pairs:
                    break
                for group in self._admission_groups(pairs):
                    self._admit_group(group, now)
                admitted += len(pairs)
                left = left[len(pairs):]
                if left:
                    self.sync(now)      # settle before parking
                    wave = {id(r) for _, r in pairs}
                    for s in range(self.slots):
                        if self.active[s] is not None \
                                and id(self.active[s]) in wave:
                            self._park_slot(s, self._now(now))
            self._recompute_remaining()
            return admitted

        free = [s for s in range(self.slots) if self.active[s] is None]
        take = list(reqs[:len(free)])
        for r in take:
            assert len(r.prompt) < self.max_len, \
                "prompt exceeds engine max_len"
        pairs = list(zip(free, take))
        if not pairs:
            return 0
        for group in self._admission_groups(pairs):
            self._admit_group(group, now)
        self._recompute_remaining()
        return len(pairs)

    def _admission_groups(self, pairs: List[Tuple[int, "Request"]]
                          ) -> List[List]:
        """Partition admitted (slot, req) pairs into prefill groups:
        one padded batch for attention families, exact-length groups
        for recurrent families, batch-1 for injected prefill."""
        if self._prefill_custom is not None:
            return [[p] for p in pairs]
        if self.cfg.family in _PAD_SAFE_FAMILIES:
            return [pairs]
        by_len: Dict[int, List] = {}
        for s, r in pairs:
            by_len.setdefault(len(r.prompt), []).append((s, r))
        return list(by_len.values())

    def _paged_admit(self, reqs: List[Request],
                     now: float) -> List[Tuple[int, Request]]:
        """Paged admission: gated by free-BLOCK pressure, not free
        slots.  Each request reserves blocks for its worst-case token
        capacity; under pressure idle parked sessions spill to host
        (LRU), and with ``preempt_priority`` a strictly lower-priority
        active session is parked (freeing its slot) and spilled
        (freeing its blocks).  Returns the admitted (slot, req) pairs.
        """
        t = self._now(now)
        taken: set = set()
        pairs: List[Tuple[int, Request]] = []

        def free_slot() -> Optional[int]:
            for s in range(self.slots):
                if self.active[s] is None and s not in taken:
                    return s
            return None

        for r in reqs:
            assert len(r.prompt) < self.max_len, \
                "prompt exceeds engine max_len"
            cap = min(len(r.prompt) + r.max_new_tokens, self.max_len)
            # blocks FIRST: a request that cannot reserve memory must
            # not disturb any resident's slot
            reserved = self._paged.reserve(r, cap, spill=self.spill)
            while not reserved and self.preempt_priority and self.spill:
                # block pressure: evict (park + spill) a strictly
                # lower-priority active — its blocks go to host
                victim = self._preempt_victim(r.priority, taken)
                if victim is None:
                    break
                vrid = self.active[victim].rid
                self._park_slot(victim, t)
                self._paged.spill(vrid)
                self._paged.preemptions += 1
                reserved = self._paged.reserve(r, cap,
                                               spill=self.spill)
            if not reserved:
                break
            slot = free_slot()
            if slot is None:
                # no free slot: park a resident of <= priority (equal
                # priority time-slices fairly; a strictly higher one
                # is never displaced by admission)
                victim = self._preempt_victim(r.priority, taken,
                                              allow_equal=True)
                if victim is None:
                    self._paged.release(r.rid)   # roll the blocks back
                    break
                self._park_slot(victim, t)
                self._paged.preemptions += 1
                slot = victim
            taken.add(slot)
            pairs.append((slot, r))
        return pairs

    def _preempt_victim(self, incoming_prio: int, taken=(),
                        allow_equal: bool = False) -> Optional[int]:
        """Slot of the lowest-priority active session below (or, with
        ``allow_equal``, at) ``incoming_prio`` — ties broken toward
        the longest-running (round-robin LRU).  Slots in ``taken``
        (assigned this admission, prefill still pending) are never
        victims.  None when nothing is preemptible."""
        if not self.preempt_priority and not allow_equal:
            return None
        cands = []
        for s in range(self.slots):
            req = self.active[s]
            if req is None or s in taken:
                continue
            if req.priority < incoming_prio or \
                    (allow_equal and req.priority <= incoming_prio):
                cands.append((req.priority, -self._ran[s], s))
        return min(cands)[2] if cands else None

    # ------------------------------------------------------------------ #
    # Paged scheduling: park / activate through the block pool
    # ------------------------------------------------------------------ #
    def _park_slot(self, slot: int, t: float) -> None:
        """Preempt an active slot into the pool: export its state at
        the current decode cursor and pack it into the session's
        reserved blocks.  Requires a settled window (call at sync
        boundaries only); the park -> activate round trip is exact, so
        resumed greedy decode is bit-identical."""
        assert not self._cols, "parking requires a settled window"
        req = self.active[slot]
        p = int(self.pos[slot])
        state = M.export_kv(self.cfg, self.cache, slot, p)
        self._paged.park(req.rid, state, int(self.last_tok[slot]), p,
                         int(self.budget[slot]), t)
        self.active[slot] = None
        self.active_mask = self.active_mask.at[slot].set(False)
        self._ran[slot] = 0

    def _activate_parked(self, rid: int, slot: int, t: float) -> None:
        """Resume a parked session into a free slot (prefetching from
        host spill if needed) and restore its decode cursor."""
        req = self._paged.resident[rid].req
        state, last_tok, pos, budget = self._paged.activate(rid, t)
        self.cache = M.import_kv(self.cfg, self.cache, slot, state)
        self.pos = self.pos.at[slot].set(pos)
        self.last_tok = self.last_tok.at[slot].set(last_tok)
        self.budget = self.budget.at[slot].set(budget)
        self.active_mask = self.active_mask.at[slot].set(True)
        self.active[slot] = req
        self._ran[slot] = 0

    def _schedule(self, now: Optional[float]) -> None:
        """Round-robin time slicing at sync boundaries: parked
        sessions activate into free slots FIFO; when none are free,
        actives that have used up their quantum (``sync_every`` decode
        steps) rotate out so every resident session makes progress."""
        runnable = self._paged.parked()
        if not runnable:
            return
        t = self._now(now)
        changed = False
        for s in range(self.slots):
            if not runnable:
                break
            if self.active[s] is None:
                self._activate_parked(runnable.pop(0), s, t)
                changed = True
        if runnable:
            expired = sorted(
                (s for s in range(self.slots)
                 if self.active[s] is not None
                 and self._ran[s] >= self.sync_every),
                key=lambda s: -self._ran[s])
            for s in expired[:len(runnable)]:
                self._park_slot(s, t)
                self._activate_parked(runnable.pop(0), s, t)
                changed = True
        if changed:
            self._recompute_remaining()

    def _admit_group(self, group: List, now: float) -> None:
        slots_ = [s for s, _ in group]
        reqs = [r for _, r in group]
        G = len(reqs)
        lens = [len(r.prompt) for r in reqs]
        # Pad sequence length to a multiple of 8 and batch to the next
        # power of two (padding rows are dummies): admission shapes are
        # bucketed, so the prefill jit compiles O(log slots) variants
        # instead of one per (batch, length) pair.  Length padding is
        # ONLY sound for causal-attention families — recurrent state
        # integrates every input token, pads included — so ssm/hybrid
        # groups (already exact-length) keep their exact length.
        if self.cfg.family in _PAD_SAFE_FAMILIES:
            S = min(-(-max(lens) // 8) * 8, self.max_len - 1)
        else:
            S = max(lens)
        Gp = min(1 << (G - 1).bit_length(), self.slots)
        toks = np.zeros((Gp, S), np.int32)
        for i, r in enumerate(reqs):
            toks[i, :lens[i]] = r.prompt
        cache_g = M.init_cache(self.cfg, Gp, self.max_len)
        if self._prefill_custom is not None:
            logits, cache_g = self._prefill_custom(
                self.params, cache_g,
                jnp.asarray(toks[:G, :max(lens)], jnp.int32))
        else:
            last = np.zeros(Gp, np.int32)
            last[:G] = np.asarray(lens) - 1
            if self._can_chunk(S):
                # chunked prefill: live decode slots keep streaming
                # between chunks instead of stalling for the whole
                # prompt (the colocated head-of-line fix)
                logits, cache_g = self._prefill_chunks(
                    cache_g, toks, last, now, interleave=True)
            else:
                logits, cache_g = self._prefill(
                    cache_g, jnp.asarray(toks, jnp.int32),
                    jnp.asarray(last, jnp.int32))
        self._write_slots(slots_, cache_g, G)
        # honest TTFT: the first token exists only once logits are real
        jax.block_until_ready(logits)
        t_ready = self._now(now)
        first = self._sample_host(logits)[:G]
        self.stats.prefill_batches += 1

        upd_slots = jnp.asarray(slots_, jnp.int32)
        self.pos = self.pos.at[upd_slots].set(
            jnp.asarray(lens, jnp.int32))
        self.last_tok = self.last_tok.at[upd_slots].set(
            jnp.asarray(first, jnp.int32))
        budgets = [r.max_new_tokens - 1 for r in reqs]
        self.budget = self.budget.at[upd_slots].set(
            jnp.asarray(budgets, jnp.int32))
        # a slot only becomes live if it still has budget AND the
        # prefill token was not EOS — otherwise the device mask would
        # keep a ghost slot decoding after the host finalized it
        live = [b > 0 and not (self.eos_id is not None
                               and int(t) == self.eos_id)
                for b, t in zip(budgets, first)]
        self.active_mask = self.active_mask.at[upd_slots].set(
            jnp.asarray(live))

        for i, (slot, req) in enumerate(group):
            tok = int(first[i])
            req.ttft = t_ready
            req.output.append(tok)
            if live[i]:
                self.active[slot] = req
            else:
                # completes at prefill (budget spent or EOS sampled)
                self._finalize(req, t_ready)

    # ------------------------------------------------------------------ #
    # Chunked prefill: incremental cache fill with decode interleaving
    # ------------------------------------------------------------------ #
    def _can_chunk(self, S: int) -> bool:
        """Chunked prefill needs the built-in prefill path and a
        non-ring cache (SWA slot layout wraps at the window), and only
        pays off when the prompt spans more than one chunk."""
        return (self.prefill_chunk is not None
                and self._prefill_custom is None
                and self.cfg.sliding_window is None
                and S > self.prefill_chunk)

    def _prefill_chunks(self, cache_g, toks: np.ndarray,
                        last: np.ndarray, now: Optional[float] = None,
                        interleave: bool = False):
        """Drive ``prefill(offset=...)`` over prefill_chunk-sized
        slices of the padded admission batch.  With ``interleave`` one
        decode step runs between chunks, so a long admitted prompt no
        longer freezes the live decode slots for its whole prefill.
        Returns (last-position logits, filled cache) — identical to
        one whole-prompt prefill."""
        S = toks.shape[1]
        logits = None
        for _, t1, logits, cache_g in M.iter_prefill_chunks(
                self.params, self.cfg, toks, cache_g,
                chunk_size=self.prefill_chunk, last_pos=last,
                prefill_call=self._chunk_call):
            if interleave and t1 < S and self._any_active():
                self.step(self._now(now))
        return logits, cache_g

    def _chunk_call(self, cache, toks, off, rel):
        return self._prefill_at(cache, jnp.asarray(toks, jnp.int32),
                                off, jnp.asarray(rel, jnp.int32))

    # ------------------------------------------------------------------ #
    # Legacy session-mover shims.  The implementation lives behind the
    # unified ``engine.sessions`` facade (kvpool.SessionManager); these
    # names remain for compatibility and translate to/from the old
    # wire dicts with bit-identical tokens, errors, and TTFT stamps.
    # New code should call ``engine.sessions`` directly.
    # ------------------------------------------------------------------ #
    def prefill_handoff(self, req: Request,
                        now: Optional[float] = None) -> Dict[str, Any]:
        """Deprecated shim over ``sessions.prefill``: run ``req``'s
        prompt here and package the state for a decode-only peer as
        the legacy handoff dict."""
        return self.sessions.prefill(req, now).to_legacy()

    def prefill_handoff_stream(self, req: Request,
                               now: Optional[float] = None,
                               chunk_size: Optional[int] = None):
        """Deprecated shim over ``sessions.stream``: yields the legacy
        per-(layer, chunk) shard dicts, then the header dict."""
        for item in self.sessions.stream(req, now, chunk_size):
            if isinstance(item, SessionState):
                yield item.to_legacy(header=True)
            else:
                yield item.to_legacy()

    def admit_handoff(self, req: Request, handoff: Dict[str, Any],
                      now: Optional[float] = None) -> bool:
        """Deprecated shim over ``sessions.restore`` with the first
        token pending: TTFT is stamped on admission.  Raises on a
        handoff that finished at prefill; returns False when no slot
        is free."""
        return self.sessions.restore(
            req, SessionState.from_legacy(handoff,
                                          first_token_pending=True),
            now)

    def admit_handoff_stream(self, req: Request, shards,
                             now: Optional[float] = None) -> bool:
        """Deprecated shim over ``sessions.receive`` (it accepts the
        legacy shard dicts directly)."""
        return self.sessions.receive(req, shards, now)

    def export_sessions(self, now: Optional[float] = None
                        ) -> List[Tuple[Request, Dict[str, Any]]]:
        """Deprecated shim over ``sessions.checkpoint``: drain every
        resident session as legacy (request, handoff-dict) pairs."""
        return [(r, st.to_legacy())
                for r, st in self.sessions.checkpoint(now)]

    def import_session(self, req: Request, handoff: Dict[str, Any],
                       now: Optional[float] = None) -> bool:
        """Deprecated shim over ``sessions.restore`` with the first
        token NOT pending: migration moves the session, not the
        client's clock."""
        return self.sessions.restore(
            req, SessionState.from_legacy(handoff,
                                          first_token_pending=False),
            now)

    def warmup(self) -> None:
        """Prime the jitted prefill and fused decode step (the common
        shape buckets) so a freshly scaled-in engine pays its compiles
        BEFORE it is marked routable, not on the first real request.
        Outputs are discarded; engine state is untouched (the decode
        probe runs fully masked, and the position-0 rows it touches
        are overwritten by any admission or import)."""
        if self._prefill_custom is None:
            cache1 = M.init_cache(self.cfg, 1, self.max_len)
            logits, _ = self._prefill(
                cache1, jnp.zeros((1, 8), jnp.int32),
                jnp.asarray([7], jnp.int32))
            jax.block_until_ready(logits)
        if self._decode_custom is None:
            out = self._step_fused(self.cache, self.last_tok, self.pos,
                                   self.budget, self.active_mask,
                                   self.key)
            jax.block_until_ready(out[1])

    # ------------------------------------------------------------------ #
    # Sync-free decode loop
    # ------------------------------------------------------------------ #
    def step(self, now: float) -> None:
        """One decode step over all active slots (idle slots masked).

        Dispatch only — sampled tokens and done flags accumulate on
        device and reach the host every ``sync_every`` steps.
        """
        if not any(r is not None for r in self.active):
            if self._paged is not None:
                # no slot decoding but sessions may be parked: settle
                # and let the scheduler rotate them in
                self.sync(now)
            if not any(r is not None for r in self.active):
                return
        if self._decode_custom is not None:
            logits, self.cache = self._decode_custom(
                self.params, self.cache, self.last_tok[:, None], self.pos)
            (self.last_tok, self.pos, self.budget, self.active_mask,
             packed, self.key) = self._post(
                logits, self.last_tok, self.pos, self.budget,
                self.active_mask, self.key)
        else:
            (self.cache, self.last_tok, self.pos, self.budget,
             self.active_mask, packed, self.key) = self._step_fused(
                self.cache, self.last_tok, self.pos,
                self.budget, self.active_mask, self.key)
        self._cols.append(packed)
        self.stats.decode_steps += 1
        if self._paged is not None:
            for s in range(self.slots):
                if self.active[s] is not None:
                    self._ran[s] += 1
        # sync at the cadence, or as soon as every live slot must have
        # exhausted its budget (avoids masked tail steps at drain)
        if len(self._cols) >= min(self.sync_every, self._max_remaining):
            self.sync(now)

    def sync(self, now: float) -> None:
        """Fetch buffered tokens/flags; settle completions on the host.
        On paged engines the settled boundary is also the scheduling
        point: parked sessions rotate into freed slots here."""
        if self._cols:
            # one stacked D2H fetch for the whole window, not one per
            # step
            cols = self._cols[0] if len(self._cols) == 1 else \
                jnp.stack(self._cols, axis=2)
            window = np.asarray(cols).reshape(2, self.slots, -1)
            toks, dones = window[0], window[1]             # (slots, k)
            self._cols = []
            self.stats.host_syncs += 1
            t_set = self._now(now)
            for s in range(self.slots):
                req = self.active[s]
                if req is None:
                    continue
                for k in range(toks.shape[1]):
                    t = int(toks[s, k])
                    if t < 0:       # slot went idle earlier in window
                        break
                    req.output.append(t)
                    if dones[s, k]:
                        self._finalize(req, t_set)
                        self.active[s] = None
                        break
            self._recompute_remaining()
        if self._paged is not None:
            self._schedule(now)

    def _recompute_remaining(self) -> None:
        rem = [r.max_new_tokens - len(r.output)
               for r in self.active if r is not None]
        self._max_remaining = max(rem) if rem else self.sync_every

    def _finalize(self, req: Request, now: float) -> None:
        req.finished = now
        if self._paged is not None:
            self._paged.release(req.rid)    # no-op if never reserved
        self.stats.completed += 1
        self.stats.ttft.append(req.ttft - req.arrival)
        self.stats.tpot.append(
            (now - req.ttft) / max(len(req.output) - 1, 1))
        self.stats.latency_per_token.append(
            (now - req.arrival) / max(len(req.output), 1))

    # ------------------------------------------------------------------ #
    def run(self, requests: List[Request]) -> EngineStats:
        """Process a workload to completion (arrival times honored via
        a virtual clock driven by wall time)."""
        t0 = time.perf_counter()
        self._clock = lambda: time.perf_counter() - t0
        try:
            pending = sorted(requests, key=lambda r: r.arrival)
            while pending or self._any_active():
                now = self._clock()
                if pending and pending[0].arrival <= now \
                        and (None in self.active
                             or self._paged is not None):
                    # admit every due arrival that fits (admit_batch
                    # settles the buffered window itself); a paged
                    # engine may also preempt for a due arrival
                    batch = []
                    nfree = self.active.count(None)
                    if self._paged is not None:
                        nfree = max(nfree, 1)
                    while (pending and len(batch) < nfree
                           and pending[0].arrival <= self._clock()):
                        batch.append(pending.pop(0))
                    if batch:
                        n = self.admit_batch(batch, self._clock())
                        if n < len(batch):
                            # paged pressure refused the tail: requeue
                            # (batch holds the earliest arrivals, so
                            # prepending preserves sort order)
                            pending = batch[n:] + pending
                if not self._any_active():
                    if pending:
                        # idle until the next arrival: sleep, don't spin
                        delay = pending[0].arrival - self._clock()
                        if delay > 0:
                            time.sleep(delay)
                    continue
                self.step(self._clock())
            self.sync(self._clock())
        finally:
            self._clock = None
        return self.stats


# --------------------------------------------------------------------- #
def requests_from_trace(trace, vocab_size: int, *,
                        max_prompt: Optional[int] = None,
                        max_new: Optional[int] = None,
                        time_scale: float = 1.0,
                        seed: int = 0) -> List[Request]:
    """Materialize ``serving.workload`` trace entries as engine Requests.

    Workload traces carry token *counts*; this synthesizes concrete
    prompts (uniform random ids) at those lengths, optionally clipped to
    engine-sized ``max_prompt``/``max_new`` and with arrivals compressed
    by ``time_scale`` (CPU smoke runs serve far fewer tok/s than the
    modeled accelerators).
    """
    rng = np.random.default_rng(seed)
    out = []
    for w in trace:
        p = w.prompt_tokens if max_prompt is None \
            else min(w.prompt_tokens, max_prompt)
        n = w.output_tokens if max_new is None \
            else min(w.output_tokens, max_new)
        out.append(Request(
            rid=w.rid,
            prompt=rng.integers(0, vocab_size, size=max(1, p))
            .astype(np.int32),
            max_new_tokens=max(1, n),
            arrival=w.arrival * time_scale))
    return out
