"""Serving engine: sync-free continuous batching over slot-based KV caches.

vLLM-shaped control plane on a JAX data plane:
  * fixed ``slots`` decode batch; idle slots are masked, arriving
    requests are admitted into free slots (continuous batching),
  * admissions are **batched**: all due arrivals that fit free slots go
    through ONE multi-request prefill — right-padded to a common length
    for attention families (exact under causal masking + per-row
    ``last_pos`` logit selection), grouped by exact prompt length for
    recurrent families (padding would pollute SSM state),
  * the decode hot loop is **sync-free**: ``last_tok``/``pos``/``budget``
    and the active mask live on device, sampling and termination logic
    are folded into the jitted decode step, and sampled tokens/done
    flags accumulate in device buffers that are fetched to the host only
    every ``sync_every`` steps — no per-token host round trip,
  * greedy / temperature sampling, per-slot positions, EOS/max-token
    termination, SLO accounting (TTFT / TPOT / normalized latency).
    TTFT is stamped only after the prefill logits are materialized
    (``block_until_ready``) — dispatch alone is not time-to-first-token,
  * optional Tessera integration: the decode step can be executed by a
    disaggregated StagedExecutable, with the OnlineMonitor switching
    between latency- and throughput-oriented plans (examples/
    serve_pipeline.py wires this up end to end),
  * prefill/decode disaggregation: ``prefill_handoff`` runs a prompt
    and exports the per-request KV/recurrent state; ``admit_handoff``
    on a second engine starts a decode_only session from the imported
    state (greedy decode is bit-identical to a single-engine run) —
    the real-engine analogue of the cluster simulator's KV-transfer
    edge,
  * PIPELINED handoff: ``prefill_handoff_stream`` processes the prompt
    in ``prefill_chunk``-sized chunks and yields (layer, chunk) KV
    shards as soon as they are computed, so the fabric transfer
    overlaps the remaining prefill compute instead of starting only
    after the whole prompt finishes; ``admit_handoff_stream`` installs
    the shards eagerly and starts decoding the moment the last shard
    lands (still bit-identical to the serial path),
  * chunked COLOCATED admission: with ``prefill_chunk`` set, a long
    admitted prompt no longer freezes the live decode slots for its
    whole prefill — decode steps are interleaved between prefill
    chunks.

Accounting note: completion times are observed at sync boundaries, so a
request's ``finished`` stamp can be up to ``sync_every - 1`` decode steps
late.  That is the deliberate trade of the sync-free loop; run with
``sync_every=1`` to recover per-token accounting (and per-token host
syncs).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.models.config import ModelConfig

# Families whose prefill is exact under right-padding (causal attention
# never reads positions past the query).  Recurrent state (ssm/hybrid)
# integrates every input token, so padded rows would corrupt it.  (vlm
# is deliberately absent: the engine does not serve it, and patch-embed
# placement under padding is unvalidated.)
_PAD_SAFE_FAMILIES = ("dense", "moe")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (S,) int32
    max_new_tokens: int = 16
    arrival: float = 0.0
    # filled by the engine:
    output: List[int] = dataclasses.field(default_factory=list)
    ttft: float = -1.0
    finished: float = -1.0


@dataclasses.dataclass
class EngineStats:
    completed: int = 0
    decode_steps: int = 0
    host_syncs: int = 0
    prefill_batches: int = 0
    ttft: List[float] = dataclasses.field(default_factory=list)
    tpot: List[float] = dataclasses.field(default_factory=list)
    latency_per_token: List[float] = dataclasses.field(
        default_factory=list)

    def summary(self) -> Dict[str, float]:
        return {
            "completed": self.completed,
            "decode_steps": self.decode_steps,
            "host_syncs": self.host_syncs,
            "prefill_batches": self.prefill_batches,
            "mean_ttft": float(np.mean(self.ttft)) if self.ttft else 0.0,
            "mean_tpot": float(np.mean(self.tpot)) if self.tpot else 0.0,
            "mean_norm_latency": float(np.mean(self.latency_per_token))
            if self.latency_per_token else 0.0,
        }


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params: Any, *, slots: int = 4,
                 max_len: int = 256, eos_id: Optional[int] = None,
                 temperature: float = 0.0, seed: int = 0,
                 decode_fn: Optional[Callable] = None,
                 prefill_fn: Optional[Callable] = None,
                 sync_every: int = 8,
                 prefill_chunk: Optional[int] = None):
        assert cfg.family in ("dense", "moe", "ssm", "hybrid"), \
            "engine serves decoder-only families"
        assert sync_every >= 1
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.temperature = temperature
        self.sync_every = sync_every
        assert prefill_chunk is None or prefill_chunk >= 1
        self.prefill_chunk = prefill_chunk
        self.key = jax.random.PRNGKey(seed)
        self.stats = EngineStats()

        self.cache = M.init_cache(cfg, slots, max_len)
        self.active: List[Optional[Request]] = [None] * slots
        # Decode state is DEVICE-resident; the host only sees it at sync
        # boundaries.
        self.pos = jnp.zeros(slots, jnp.int32)        # next position
        self.budget = jnp.zeros(slots, jnp.int32)     # tokens remaining
        self.last_tok = jnp.zeros(slots, jnp.int32)
        self.active_mask = jnp.zeros(slots, bool)
        self._cols: List[jnp.ndarray] = []    # (2, slots) packed per step
        # upper bound on decode steps any live slot can still take
        # (recomputed whenever the host view is fresh)
        self._max_remaining = sync_every
        self._clock: Optional[Callable[[], float]] = None

        eos = -1 if eos_id is None else int(eos_id)
        temp = float(temperature)
        greedy = temp <= 0.0

        def _sample(logits, key):
            if greedy:
                return jnp.argmax(logits, axis=-1).astype(jnp.int32), key
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits / temp, axis=-1)
            return tok.astype(jnp.int32), key

        def _post(logits, last_tok, pos, budget, active, key):
            """Sampling + termination, fused with the decode dispatch.

            ``packed`` is one (2, slots) int32 array — [emitted token or
            -1; done flag] — so each step leaves exactly one buffer for
            the sync to fetch (no eager stacking on the hot path).
            """
            tok, key = _sample(logits, key)
            new_pos = jnp.where(active, pos + 1, pos)
            new_budget = jnp.where(active, budget - 1, budget)
            done = active & ((new_budget <= 0) | (tok == eos)
                             | (new_pos >= max_len - 1))
            new_active = active & ~done
            new_last = jnp.where(new_active, tok, last_tok)
            emit = jnp.where(active, tok, -1)     # -1 = idle slot
            packed = jnp.stack([emit, done.astype(jnp.int32)])
            return new_last, new_pos, new_budget, new_active, packed, key

        self._post = jax.jit(_post)
        self._decode_custom = decode_fn
        if decode_fn is None:
            # params are engine-lifetime constants: close over them so
            # the hot loop does not re-flatten / re-validate the param
            # pytree on every dispatch.
            def _fused(c, last_tok, pos, budget, active, key):
                logits, c = M.decode_step(params, cfg, last_tok[:, None],
                                          c, pos)
                return (c,) + _post(logits, last_tok, pos, budget,
                                    active, key)
            self._step_fused = jax.jit(_fused)
        self._prefill_custom = prefill_fn
        if prefill_fn is None:
            self._prefill = jax.jit(
                lambda c, t, lp: M.prefill(params, cfg, t, c,
                                           last_pos=lp))
            # one chunk of an incremental prefill (offset is a traced
            # scalar, so every full-size chunk shares one compile)
            self._prefill_at = jax.jit(
                lambda c, t, off, lp: M.prefill(params, cfg, t, c,
                                                offset=off, last_pos=lp))

    # ------------------------------------------------------------------ #
    def _now(self, now: Optional[float]) -> float:
        if self._clock is not None:
            return self._clock()
        return now if now is not None else 0.0

    def _any_active(self) -> bool:
        return any(r is not None for r in self.active)

    def _write_slots(self, slots_: List[int], batch_cache: Any,
                     rows: int) -> None:
        """Scatter rows 0..rows of a prefill cache into engine slots —
        one scatter per cache leaf for the whole admission group."""
        idx = jnp.asarray(slots_, jnp.int32)

        def upd(full, grp):
            # full: (L, slots, ...); grp: (L, G_padded, ...)
            return full.at[:, idx].set(
                grp[:, :rows].astype(full.dtype))
        self.cache = jax.tree_util.tree_map(upd, self.cache, batch_cache)

    def _sample_host(self, logits: jnp.ndarray) -> np.ndarray:
        if self.temperature <= 0.0:
            return np.asarray(jnp.argmax(logits, axis=-1))
        self.key, sub = jax.random.split(self.key)
        return np.asarray(jax.random.categorical(
            sub, logits / self.temperature, axis=-1))

    # ------------------------------------------------------------------ #
    # Admission: batched multi-request prefill
    # ------------------------------------------------------------------ #
    def admit(self, req: Request, now: float) -> bool:
        """Single-request admission (compat wrapper over admit_batch)."""
        return self.admit_batch([req], now) == 1

    def admit_batch(self, reqs: Sequence[Request], now: float) -> int:
        """Admit up to len(free slots) requests through batched prefills.

        Returns the number admitted.  Attention families take ONE padded
        prefill for the whole batch; recurrent families are grouped by
        exact prompt length (right-padding would pollute SSM state).
        """
        # settle any buffered window first: admission must see fresh
        # slot state, and a slot re-filled mid-window would otherwise
        # have its new tokens hidden behind the old -1 idle markers
        self.sync(now)
        free = [s for s in range(self.slots) if self.active[s] is None]
        take = list(reqs[:len(free)])
        if not take:
            return 0
        for r in take:
            assert len(r.prompt) < self.max_len, \
                "prompt exceeds engine max_len"

        if self._prefill_custom is not None:
            # legacy injected prefill: per-request batch-1 path
            groups = [[(free[i], r)] for i, r in enumerate(take)]
        elif self.cfg.family in _PAD_SAFE_FAMILIES:
            groups = [list(zip(free, take))]
        else:
            by_len: Dict[int, List] = {}
            slot_iter = iter(free)
            for r in take:
                by_len.setdefault(len(r.prompt), []).append(
                    (next(slot_iter), r))
            groups = list(by_len.values())

        for group in groups:
            self._admit_group(group, now)
        self._recompute_remaining()
        return len(take)

    def _admit_group(self, group: List, now: float) -> None:
        slots_ = [s for s, _ in group]
        reqs = [r for _, r in group]
        G = len(reqs)
        lens = [len(r.prompt) for r in reqs]
        # Pad sequence length to a multiple of 8 and batch to the next
        # power of two (padding rows are dummies): admission shapes are
        # bucketed, so the prefill jit compiles O(log slots) variants
        # instead of one per (batch, length) pair.  Length padding is
        # ONLY sound for causal-attention families — recurrent state
        # integrates every input token, pads included — so ssm/hybrid
        # groups (already exact-length) keep their exact length.
        if self.cfg.family in _PAD_SAFE_FAMILIES:
            S = min(-(-max(lens) // 8) * 8, self.max_len - 1)
        else:
            S = max(lens)
        Gp = min(1 << (G - 1).bit_length(), self.slots)
        toks = np.zeros((Gp, S), np.int32)
        for i, r in enumerate(reqs):
            toks[i, :lens[i]] = r.prompt
        cache_g = M.init_cache(self.cfg, Gp, self.max_len)
        if self._prefill_custom is not None:
            logits, cache_g = self._prefill_custom(
                self.params, cache_g,
                jnp.asarray(toks[:G, :max(lens)], jnp.int32))
        else:
            last = np.zeros(Gp, np.int32)
            last[:G] = np.asarray(lens) - 1
            if self._can_chunk(S):
                # chunked prefill: live decode slots keep streaming
                # between chunks instead of stalling for the whole
                # prompt (the colocated head-of-line fix)
                logits, cache_g = self._prefill_chunks(
                    cache_g, toks, last, now, interleave=True)
            else:
                logits, cache_g = self._prefill(
                    cache_g, jnp.asarray(toks, jnp.int32),
                    jnp.asarray(last, jnp.int32))
        self._write_slots(slots_, cache_g, G)
        # honest TTFT: the first token exists only once logits are real
        jax.block_until_ready(logits)
        t_ready = self._now(now)
        first = self._sample_host(logits)[:G]
        self.stats.prefill_batches += 1

        upd_slots = jnp.asarray(slots_, jnp.int32)
        self.pos = self.pos.at[upd_slots].set(
            jnp.asarray(lens, jnp.int32))
        self.last_tok = self.last_tok.at[upd_slots].set(
            jnp.asarray(first, jnp.int32))
        budgets = [r.max_new_tokens - 1 for r in reqs]
        self.budget = self.budget.at[upd_slots].set(
            jnp.asarray(budgets, jnp.int32))
        # a slot only becomes live if it still has budget AND the
        # prefill token was not EOS — otherwise the device mask would
        # keep a ghost slot decoding after the host finalized it
        live = [b > 0 and not (self.eos_id is not None
                               and int(t) == self.eos_id)
                for b, t in zip(budgets, first)]
        self.active_mask = self.active_mask.at[upd_slots].set(
            jnp.asarray(live))

        for i, (slot, req) in enumerate(group):
            tok = int(first[i])
            req.ttft = t_ready
            req.output.append(tok)
            if live[i]:
                self.active[slot] = req
            else:
                # completes at prefill (budget spent or EOS sampled)
                self._finalize(req, t_ready)

    # ------------------------------------------------------------------ #
    # Chunked prefill: incremental cache fill with decode interleaving
    # ------------------------------------------------------------------ #
    def _can_chunk(self, S: int) -> bool:
        """Chunked prefill needs the built-in prefill path and a
        non-ring cache (SWA slot layout wraps at the window), and only
        pays off when the prompt spans more than one chunk."""
        return (self.prefill_chunk is not None
                and self._prefill_custom is None
                and self.cfg.sliding_window is None
                and S > self.prefill_chunk)

    def _prefill_chunks(self, cache_g, toks: np.ndarray,
                        last: np.ndarray, now: Optional[float] = None,
                        interleave: bool = False):
        """Drive ``prefill(offset=...)`` over prefill_chunk-sized
        slices of the padded admission batch.  With ``interleave`` one
        decode step runs between chunks, so a long admitted prompt no
        longer freezes the live decode slots for its whole prefill.
        Returns (last-position logits, filled cache) — identical to
        one whole-prompt prefill."""
        S = toks.shape[1]
        logits = None
        for _, t1, logits, cache_g in M.iter_prefill_chunks(
                self.params, self.cfg, toks, cache_g,
                chunk_size=self.prefill_chunk, last_pos=last,
                prefill_call=self._chunk_call):
            if interleave and t1 < S and self._any_active():
                self.step(self._now(now))
        return logits, cache_g

    def _chunk_call(self, cache, toks, off, rel):
        return self._prefill_at(cache, jnp.asarray(toks, jnp.int32),
                                off, jnp.asarray(rel, jnp.int32))

    # ------------------------------------------------------------------ #
    # Prefill/decode disaggregation: two-engine state handoff
    # ------------------------------------------------------------------ #
    def prefill_handoff(self, req: Request,
                        now: Optional[float] = None) -> Dict[str, Any]:
        """Run ``req``'s prompt on THIS engine and package the result
        for a decode-only peer (the real-engine analogue of the
        simulator's KV-transfer edge).

        The prefill runs in a private batch-1 cache — no decode slot is
        consumed on the prefill engine — and the returned handoff dict
        carries the per-request state (``export_kv``), the first sampled
        token, and the wire size.  Feed it to a second engine's
        :meth:`admit_handoff` to continue decoding there; greedy decode
        is bit-identical to never having left this engine.

        The request's TTFT is stamped by ``admit_handoff`` (the first
        token cannot stream before the state lands on the decode
        engine — same accounting as the simulator's KV-transfer edge)
        unless the request finishes at prefill, in which case it is
        finalized here.
        """
        assert len(req.prompt) < self.max_len, "prompt exceeds max_len"
        plen = len(req.prompt)
        # pad-safe families bucket the prefill length to a multiple of
        # 8 like admit_batch (exact under causal masking + last_pos
        # selection; the export below trims to the true length), so a
        # varied-length trace compiles O(log max_len) prefill variants
        # instead of one per distinct length.  Recurrent families must
        # stay exact-length.
        if self.cfg.family in _PAD_SAFE_FAMILIES:
            S = min(-(-plen // 8) * 8, self.max_len - 1)
        else:
            S = plen
        toks = np.zeros((1, S), np.int32)
        toks[0, :plen] = req.prompt
        cache1 = M.init_cache(self.cfg, 1, self.max_len)
        if self._prefill_custom is not None:
            logits, cache1 = self._prefill_custom(
                self.params, cache1,
                jnp.asarray(toks[:, :plen], jnp.int32))
        else:
            logits, cache1 = self._prefill(
                cache1, jnp.asarray(toks, jnp.int32),
                jnp.asarray([plen - 1], jnp.int32))
        jax.block_until_ready(logits)
        t_ready = self._now(now)
        first = int(self._sample_host(logits)[0])
        self.stats.prefill_batches += 1
        req.output.append(first)
        live = req.max_new_tokens > 1 and not (
            self.eos_id is not None and first == self.eos_id)
        if not live:        # done at prefill: nothing to hand off
            req.ttft = t_ready
            self._finalize(req, t_ready)
            return {"rid": req.rid, "state": None, "last_tok": first,
                    "pos": plen, "budget": 0, "kv_bytes": 0,
                    "done": True}
        state = M.export_kv(self.cfg, cache1, 0, plen)
        return {"rid": req.rid, "state": state, "last_tok": first,
                "pos": plen, "budget": req.max_new_tokens - 1,
                "kv_bytes": M.kv_state_bytes(state), "done": False}

    def prefill_handoff_stream(self, req: Request,
                               now: Optional[float] = None,
                               chunk_size: Optional[int] = None):
        """Pipelined handoff: a generator that prefills the prompt in
        chunks and yields (layer, chunk) KV shards the moment they are
        computed; the FINAL item is the header dict (the
        :meth:`prefill_handoff` schema with ``state=None`` — the state
        already went out as shards).

        A consumer that installs shards as they arrive
        (:meth:`admit_handoff_stream`, or a fabric DMA on real
        hardware) overlaps the KV transfer with the remaining prefill
        compute — the transfer no longer lands 1:1 in TTFT, which is
        the engine-side analogue of the simulator's per-chunk
        KV-transfer events.  Recurrent state (ssm / hybrid mamba) only
        means anything after the last token, so it streams per layer
        after the final chunk; ring-buffer SWA caches fall back to
        whole-prompt prefill and stream per layer only.  Greedy decode
        from the streamed shards is bit-identical to the serial path.

        Unlike the serial handoff, a request that finishes AT prefill
        (EOS / budget 1) has already streamed its shards by the time
        that is known; the ``done`` header tells the consumer to
        release the reserved slot (the honest cost of eager
        streaming).
        """
        assert len(req.prompt) < self.max_len, "prompt exceeds max_len"
        plen = len(req.prompt)
        C = chunk_size or self.prefill_chunk or plen
        cache1 = M.init_cache(self.cfg, 1, self.max_len)
        sent = 0

        def shard_item(key, layer, t0=None, t1=None):
            shard = M.export_kv_shard(self.cfg, cache1, 0, key, layer,
                                      t0, t1)
            return {"rid": req.rid, "key": key, "layer": layer,
                    "t0": t0, "t1": t1, "state": shard,
                    "bytes": M.kv_state_bytes(shard)}

        if (self._prefill_custom is None
                and self.cfg.sliding_window is None and C < plen):
            toks = np.asarray(req.prompt, np.int32).reshape(1, plen)
            n_kv = M.cache_layer_counts(cache1).get("kv", 0)
            logits = None
            for t0, t1, logits, cache1 in M.iter_prefill_chunks(
                    self.params, self.cfg, toks, cache1, chunk_size=C,
                    prefill_call=self._chunk_call):
                # this chunk's K/V planes are final for every layer the
                # moment the chunk completes: stream them now, while
                # later chunks still compute
                for layer in range(n_kv):
                    item = shard_item("kv", layer, t0, t1)
                    sent += item["bytes"]
                    yield item
            stream_kv_tail = False
        else:
            # serial fallback (ring-buffer SWA / injected prefill /
            # single-chunk prompt): same bucketing as prefill_handoff
            if self.cfg.family in _PAD_SAFE_FAMILIES:
                S = min(-(-plen // 8) * 8, self.max_len - 1)
            else:
                S = plen
            toks = np.zeros((1, S), np.int32)
            toks[0, :plen] = req.prompt
            if self._prefill_custom is not None:
                logits, cache1 = self._prefill_custom(
                    self.params, cache1,
                    jnp.asarray(toks[:, :plen], jnp.int32))
            else:
                logits, cache1 = self._prefill(
                    cache1, jnp.asarray(toks, jnp.int32),
                    jnp.asarray([plen - 1], jnp.int32))
            stream_kv_tail = True

        for key, L in M.cache_layer_counts(cache1).items():
            if key == "kv" and not stream_kv_tail:
                continue        # already streamed per chunk above
            for layer in range(L):
                if key == "kv" and self.cfg.sliding_window is None:
                    item = shard_item(key, layer, 0, plen)
                else:           # recurrent state / whole SWA ring
                    item = shard_item(key, layer)
                sent += item["bytes"]
                yield item

        jax.block_until_ready(logits)
        t_ready = self._now(now)
        first = int(self._sample_host(logits)[0])
        self.stats.prefill_batches += 1
        req.output.append(first)
        live = req.max_new_tokens > 1 and not (
            self.eos_id is not None and first == self.eos_id)
        if not live:            # done at prefill: producer finalizes
            req.ttft = t_ready
            self._finalize(req, t_ready)
            yield {"rid": req.rid, "header": True, "state": None,
                   "last_tok": first, "pos": plen, "budget": 0,
                   "kv_bytes": sent, "done": True}
            return
        yield {"rid": req.rid, "header": True, "state": None,
               "last_tok": first, "pos": plen,
               "budget": req.max_new_tokens - 1,
               "kv_bytes": sent, "done": False}

    def admit_handoff(self, req: Request, handoff: Dict[str, Any],
                      now: Optional[float] = None) -> bool:
        """decode_only admission: start a session from imported KV /
        recurrent state instead of a local prefill.  Returns False when
        no slot is currently free (retry after draining); raises on a
        handoff that already finished at prefill (retrying can never
        succeed).  TTFT is stamped HERE: only once the state lands on
        the decode engine can the first token stream to the client —
        the same accounting as the simulator's KV-transfer edge."""
        if handoff["done"]:
            raise ValueError(
                f"request {handoff['rid']} finished at prefill; "
                "there is no decode to admit")
        assert handoff["pos"] < self.max_len, \
            "imported state exceeds this engine's max_len"
        # route through sync's own _now resolution: substituting 0.0
        # here would stamp wall-clock-mode completions of the settled
        # window at t=0 instead of the engine clock
        self.sync(now)
        free = [s for s in range(self.slots) if self.active[s] is None]
        if not free:
            return False
        slot = free[0]
        self.cache = M.import_kv(self.cfg, self.cache, slot,
                                 handoff["state"])
        req.ttft = self._now(now)
        self.pos = self.pos.at[slot].set(handoff["pos"])
        self.last_tok = self.last_tok.at[slot].set(handoff["last_tok"])
        self.budget = self.budget.at[slot].set(handoff["budget"])
        self.active_mask = self.active_mask.at[slot].set(True)
        self.active[slot] = req
        self._recompute_remaining()
        return True

    def admit_handoff_stream(self, req: Request, shards,
                             now: Optional[float] = None) -> bool:
        """Consume a :meth:`prefill_handoff_stream`: reserve a slot,
        install every (layer, chunk) shard eagerly as it arrives, and
        start decoding the moment the header (the last item) lands.

        Pulling from the generator is what drives the producer's next
        prefill chunk, so installation genuinely interleaves with the
        remaining prefill compute.  Returns False — without consuming
        anything — when no slot is free (retry after draining);
        returns True once the stream is fully consumed, whether a
        decode session started or the request already finished at
        prefill on the producer (the ``done`` header releases the
        reserved slot, so no retry can ever be needed).  TTFT is
        stamped when the header lands: the first token streams only
        once the full state is resident, the same accounting as the
        simulator's overlapped KV-arrival time.
        """
        # validate BEFORE reserving or consuming anything: a failure
        # mid-install would otherwise leak the reserved slot
        assert len(req.prompt) < self.max_len, \
            "handoff prompt exceeds this engine's max_len"
        self.sync(now)
        free = [s for s in range(self.slots) if self.active[s] is None]
        if not free:
            return False
        slot = free[0]
        # host-side reservation only: active_mask stays False, so the
        # decode loop masks the slot until the header activates it
        self.active[slot] = req
        header = None
        # same-window attention-KV shards coalesce into ONE cache
        # update per chunk (per-shard installs rebuild the whole
        # batched cache O(layers x chunks) times); stale leftovers in
        # a released slot are harmless — causal masking hides them and
        # the next admission overwrites them
        pend: List = []
        pend_win = None

        def flush():
            nonlocal pend, pend_win
            if pend:
                self.cache = M.import_kv_window(
                    self.cfg, self.cache, slot, pend[0][0],
                    [s for _, s in pend], pend_win[0])
                pend, pend_win = [], None

        try:
            for item in shards:
                if item.get("header"):
                    header = item
                    break
                win = (item.get("t0") or 0, item.get("t1"))
                if (item["key"] == "kv"
                        and self.cfg.sliding_window is None):
                    if pend and (pend_win != win or
                                 item["layer"] != pend[0][0] + len(pend)):
                        flush()
                    pend.append((item["layer"], item["state"]))
                    pend_win = pend_win or win
                    continue
                flush()
                self.cache = M.import_kv_shard(
                    self.cfg, self.cache, slot, item["key"],
                    item["layer"], item["state"], win[0])
            flush()
            assert header is not None, \
                "handoff stream ended without header"
        except BaseException:
            self.active[slot] = None    # release the reserved slot
            raise
        if header["done"]:          # finished at prefill: free the slot
            self.active[slot] = None
            return True
        assert header["pos"] < self.max_len, \
            "imported state exceeds this engine's max_len"
        req.ttft = self._now(now)
        self.pos = self.pos.at[slot].set(header["pos"])
        self.last_tok = self.last_tok.at[slot].set(header["last_tok"])
        self.budget = self.budget.at[slot].set(header["budget"])
        self.active_mask = self.active_mask.at[slot].set(True)
        self._recompute_remaining()
        return True

    # ------------------------------------------------------------------ #
    # Live migration: drain / resume mid-decode sessions
    # ------------------------------------------------------------------ #
    def export_sessions(self, now: Optional[float] = None
                        ) -> List[Tuple[Request, Dict[str, Any]]]:
        """Drain this engine loss-free: settle the buffered window,
        then package every still-resident session as a migration
        handoff — the per-slot KV/recurrent state up to the current
        decode position (``export_kv``) plus the decode cursor
        (last sampled token, position, remaining budget) — and free
        the slots.  Feed each item to a peer's :meth:`import_session`;
        greedy decode continues bit-identically to never having moved
        (same params, same cache contents, same cursor).
        """
        self.sync(now)
        out: List[Tuple[Request, Dict[str, Any]]] = []
        if not self._any_active():
            return out
        pos = np.asarray(self.pos)
        last = np.asarray(self.last_tok)
        budget = np.asarray(self.budget)
        for slot in range(self.slots):
            req = self.active[slot]
            if req is None:
                continue
            state = M.export_kv(self.cfg, self.cache, slot,
                                int(pos[slot]))
            out.append((req, {
                "rid": req.rid, "state": state,
                "last_tok": int(last[slot]), "pos": int(pos[slot]),
                "budget": int(budget[slot]),
                "kv_bytes": M.kv_state_bytes(state), "done": False}))
            self.active[slot] = None
            self.active_mask = self.active_mask.at[slot].set(False)
        self._recompute_remaining()
        return out

    def import_session(self, req: Request, handoff: Dict[str, Any],
                       now: Optional[float] = None) -> bool:
        """Resume a migrated mid-decode session (an
        :meth:`export_sessions` item) on this engine.  Same slot
        mechanics as :meth:`admit_handoff`, but the request's TTFT is
        NOT restamped — its first token already streamed from the
        source engine; migration moves the session, not the client's
        clock.  Returns False when no slot is free (step/drain and
        retry)."""
        assert not handoff["done"], "finished session cannot migrate"
        assert handoff["pos"] < self.max_len, \
            "imported state exceeds this engine's max_len"
        self.sync(now)
        free = [s for s in range(self.slots) if self.active[s] is None]
        if not free:
            return False
        slot = free[0]
        self.cache = M.import_kv(self.cfg, self.cache, slot,
                                 handoff["state"])
        self.pos = self.pos.at[slot].set(handoff["pos"])
        self.last_tok = self.last_tok.at[slot].set(handoff["last_tok"])
        self.budget = self.budget.at[slot].set(handoff["budget"])
        self.active_mask = self.active_mask.at[slot].set(True)
        self.active[slot] = req
        self._recompute_remaining()
        return True

    def warmup(self) -> None:
        """Prime the jitted prefill and fused decode step (the common
        shape buckets) so a freshly scaled-in engine pays its compiles
        BEFORE it is marked routable, not on the first real request.
        Outputs are discarded; engine state is untouched (the decode
        probe runs fully masked, and the position-0 rows it touches
        are overwritten by any admission or import)."""
        if self._prefill_custom is None:
            cache1 = M.init_cache(self.cfg, 1, self.max_len)
            logits, _ = self._prefill(
                cache1, jnp.zeros((1, 8), jnp.int32),
                jnp.asarray([7], jnp.int32))
            jax.block_until_ready(logits)
        if self._decode_custom is None:
            out = self._step_fused(self.cache, self.last_tok, self.pos,
                                   self.budget, self.active_mask,
                                   self.key)
            jax.block_until_ready(out[1])

    # ------------------------------------------------------------------ #
    # Sync-free decode loop
    # ------------------------------------------------------------------ #
    def step(self, now: float) -> None:
        """One decode step over all active slots (idle slots masked).

        Dispatch only — sampled tokens and done flags accumulate on
        device and reach the host every ``sync_every`` steps.
        """
        if not self._any_active():
            return
        if self._decode_custom is not None:
            logits, self.cache = self._decode_custom(
                self.params, self.cache, self.last_tok[:, None], self.pos)
            (self.last_tok, self.pos, self.budget, self.active_mask,
             packed, self.key) = self._post(
                logits, self.last_tok, self.pos, self.budget,
                self.active_mask, self.key)
        else:
            (self.cache, self.last_tok, self.pos, self.budget,
             self.active_mask, packed, self.key) = self._step_fused(
                self.cache, self.last_tok, self.pos,
                self.budget, self.active_mask, self.key)
        self._cols.append(packed)
        self.stats.decode_steps += 1
        # sync at the cadence, or as soon as every live slot must have
        # exhausted its budget (avoids masked tail steps at drain)
        if len(self._cols) >= min(self.sync_every, self._max_remaining):
            self.sync(now)

    def sync(self, now: float) -> None:
        """Fetch buffered tokens/flags; settle completions on the host."""
        if not self._cols:
            return
        # one stacked D2H fetch for the whole window, not one per step
        cols = self._cols[0] if len(self._cols) == 1 else \
            jnp.stack(self._cols, axis=2)
        window = np.asarray(cols).reshape(2, self.slots, -1)
        toks, dones = window[0], window[1]                     # (slots, k)
        self._cols = []
        self.stats.host_syncs += 1
        now = self._now(now)
        for s in range(self.slots):
            req = self.active[s]
            if req is None:
                continue
            for k in range(toks.shape[1]):
                t = int(toks[s, k])
                if t < 0:           # slot went idle earlier in the window
                    break
                req.output.append(t)
                if dones[s, k]:
                    self._finalize(req, now)
                    self.active[s] = None
                    break
        self._recompute_remaining()

    def _recompute_remaining(self) -> None:
        rem = [r.max_new_tokens - len(r.output)
               for r in self.active if r is not None]
        self._max_remaining = max(rem) if rem else self.sync_every

    def _finalize(self, req: Request, now: float) -> None:
        req.finished = now
        self.stats.completed += 1
        self.stats.ttft.append(req.ttft - req.arrival)
        self.stats.tpot.append(
            (now - req.ttft) / max(len(req.output) - 1, 1))
        self.stats.latency_per_token.append(
            (now - req.arrival) / max(len(req.output), 1))

    # ------------------------------------------------------------------ #
    def run(self, requests: List[Request]) -> EngineStats:
        """Process a workload to completion (arrival times honored via
        a virtual clock driven by wall time)."""
        t0 = time.perf_counter()
        self._clock = lambda: time.perf_counter() - t0
        try:
            pending = sorted(requests, key=lambda r: r.arrival)
            while pending or self._any_active():
                now = self._clock()
                if pending and pending[0].arrival <= now \
                        and None in self.active:
                    # admit every due arrival that fits (admit_batch
                    # settles the buffered window itself)
                    batch = []
                    nfree = self.active.count(None)
                    while (pending and len(batch) < nfree
                           and pending[0].arrival <= self._clock()):
                        batch.append(pending.pop(0))
                    if batch:
                        self.admit_batch(batch, self._clock())
                if not self._any_active():
                    if pending:
                        # idle until the next arrival: sleep, don't spin
                        delay = pending[0].arrival - self._clock()
                        if delay > 0:
                            time.sleep(delay)
                    continue
                self.step(self._clock())
            self.sync(self._clock())
        finally:
            self._clock = None
        return self.stats


# --------------------------------------------------------------------- #
def requests_from_trace(trace, vocab_size: int, *,
                        max_prompt: Optional[int] = None,
                        max_new: Optional[int] = None,
                        time_scale: float = 1.0,
                        seed: int = 0) -> List[Request]:
    """Materialize ``serving.workload`` trace entries as engine Requests.

    Workload traces carry token *counts*; this synthesizes concrete
    prompts (uniform random ids) at those lengths, optionally clipped to
    engine-sized ``max_prompt``/``max_new`` and with arrivals compressed
    by ``time_scale`` (CPU smoke runs serve far fewer tok/s than the
    modeled accelerators).
    """
    rng = np.random.default_rng(seed)
    out = []
    for w in trace:
        p = w.prompt_tokens if max_prompt is None \
            else min(w.prompt_tokens, max_prompt)
        n = w.output_tokens if max_new is None \
            else min(w.output_tokens, max_new)
        out.append(Request(
            rid=w.rid,
            prompt=rng.integers(0, vocab_size, size=max(1, p))
            .astype(np.int32),
            max_new_tokens=max(1, n),
            arrival=w.arrival * time_scale))
    return out
