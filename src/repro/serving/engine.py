"""Serving engine: continuous batching over slot-based KV caches.

vLLM-shaped control plane on a JAX data plane:
  * fixed ``slots`` decode batch; idle slots are masked, arriving
    requests are admitted into free slots (continuous batching),
  * prefill runs per-request (batch 1) and its cache lines are written
    into the slot's row of the batched cache,
  * greedy / temperature sampling, per-slot positions, EOS/max-token
    termination, SLO accounting (TTFT / TPOT / normalized latency),
  * optional Tessera integration: the decode step can be executed by a
    disaggregated StagedExecutable, with the OnlineMonitor switching
    between latency- and throughput-oriented plans (examples/
    serve_pipeline.py wires this up end to end).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (S,) int32
    max_new_tokens: int = 16
    arrival: float = 0.0
    # filled by the engine:
    output: List[int] = dataclasses.field(default_factory=list)
    ttft: float = -1.0
    finished: float = -1.0


@dataclasses.dataclass
class EngineStats:
    completed: int = 0
    decode_steps: int = 0
    ttft: List[float] = dataclasses.field(default_factory=list)
    latency_per_token: List[float] = dataclasses.field(
        default_factory=list)

    def summary(self) -> Dict[str, float]:
        return {
            "completed": self.completed,
            "decode_steps": self.decode_steps,
            "mean_ttft": float(np.mean(self.ttft)) if self.ttft else 0.0,
            "mean_norm_latency": float(np.mean(self.latency_per_token))
            if self.latency_per_token else 0.0,
        }


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params: Any, *, slots: int = 4,
                 max_len: int = 256, eos_id: Optional[int] = None,
                 temperature: float = 0.0, seed: int = 0,
                 decode_fn: Optional[Callable] = None,
                 prefill_fn: Optional[Callable] = None):
        assert cfg.family in ("dense", "moe", "ssm", "hybrid"), \
            "engine serves decoder-only families"
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self.stats = EngineStats()

        self.cache = M.init_cache(cfg, slots, max_len)
        self.active: List[Optional[Request]] = [None] * slots
        self.pos = np.zeros(slots, np.int32)          # next position
        self.budget = np.zeros(slots, np.int32)       # tokens remaining
        self.last_tok = np.zeros(slots, np.int32)

        self._decode = decode_fn or jax.jit(
            lambda p, c, t, pos: M.decode_step(p, cfg, t, c, pos))
        self._prefill1 = prefill_fn or jax.jit(
            lambda p, c, t: M.prefill(p, cfg, t, c))

    # ------------------------------------------------------------------ #
    def _write_slot(self, slot: int, cache1: Any) -> None:
        """Copy a batch-1 cache into row ``slot`` of the engine cache."""
        def upd(full, one):
            # full: (L, slots, ...); one: (L, 1, ...)
            return jax.lax.dynamic_update_slice_in_dim(
                full, one.astype(full.dtype), slot, axis=1)
        self.cache = jax.tree_util.tree_map(upd, self.cache, cache1)

    def admit(self, req: Request, now: float) -> bool:
        try:
            slot = self.active.index(None)
        except ValueError:
            return False
        S = len(req.prompt)
        assert S < self.max_len, "prompt exceeds engine max_len"
        cache1 = M.init_cache(self.cfg, 1, self.max_len)
        toks = jnp.asarray(req.prompt, jnp.int32)[None]
        logits, cache1 = self._prefill1(self.params, cache1, toks)
        self._write_slot(slot, cache1)
        tok = self._sample(logits)[0]
        req.ttft = now
        req.output.append(int(tok))
        self.active[slot] = req
        self.pos[slot] = S
        self.budget[slot] = req.max_new_tokens - 1
        self.last_tok[slot] = int(tok)
        return True

    def _sample(self, logits: jnp.ndarray) -> np.ndarray:
        if self.temperature <= 0.0:
            return np.asarray(jnp.argmax(logits, axis=-1))
        self.key, sub = jax.random.split(self.key)
        return np.asarray(jax.random.categorical(
            sub, logits / self.temperature, axis=-1))

    def step(self, now: float) -> None:
        """One decode step over all active slots (idle slots masked)."""
        if not any(r is not None for r in self.active):
            return
        toks = jnp.asarray(self.last_tok, jnp.int32)[:, None]
        pos = jnp.asarray(self.pos, jnp.int32)
        logits, self.cache = self._decode(self.params, self.cache, toks,
                                          pos)
        nxt = self._sample(logits)
        self.stats.decode_steps += 1
        for s, req in enumerate(self.active):
            if req is None:
                continue
            self.pos[s] += 1
            tok = int(nxt[s])
            req.output.append(tok)
            self.budget[s] -= 1
            done = (self.budget[s] <= 0
                    or (self.eos_id is not None and tok == self.eos_id)
                    or self.pos[s] >= self.max_len - 1)
            if done:
                req.finished = now
                self.stats.completed += 1
                self.stats.ttft.append(req.ttft - req.arrival)
                per_tok = (now - req.arrival) / max(len(req.output), 1)
                self.stats.latency_per_token.append(per_tok)
                self.active[s] = None
            else:
                self.last_tok[s] = tok

    # ------------------------------------------------------------------ #
    def run(self, requests: List[Request]) -> EngineStats:
        """Process a workload to completion (arrival times honored via
        a virtual clock driven by wall time)."""
        t0 = time.perf_counter()
        pending = sorted(requests, key=lambda r: r.arrival)
        while pending or any(r is not None for r in self.active):
            now = time.perf_counter() - t0
            while pending and pending[0].arrival <= now:
                if not self.admit(pending[0], now):
                    break
                pending.pop(0)
            self.step(time.perf_counter() - t0)
        return self.stats
