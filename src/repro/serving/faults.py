"""Typed fault plans, health-aware routing primitives, and
checkpoint-based session recovery — one chaos layer over both serving
backends.

Disaggregation multiplies failure surfaces: more groups, more KV bytes
crossing contended links.  This module makes the failure model a
first-class, *typed and seeded* object instead of the old
``failures=[(t, g)]`` hard-kill list:

* :class:`FaultPlan` — JSON-round-tripping chaos schedule.
  ``crash(t, group, recover_at=...)`` is a "fail" ControlEvent that can
  come back via the existing "up" path (the timeline validator
  distinguishes a recovery-"up" from a warm-up-"up");
  ``straggle(t0, t1, group, factor)`` opens a transient service-time
  window (a "slow" ControlEvent — the DES inflates every stage unit
  and the routers' service predictions, so JSED/PD observe the
  straggler); ``flaky_link(src, dst, p)`` makes each KV chunk on that
  directed link fail independently with probability ``p`` under a
  bounded-retry/backoff/deadline policy (``_stream_kv_flaky`` in the
  DES, :class:`ChaosLink` + shard checksums on live engines).

* :class:`GroupHealth` — per-group error-rate EWMA + a
  closed/open/half-open circuit breaker.  Routers fold
  ``penalty(g, now)`` into their scores and skip groups whose breaker
  is open, so a flapping group sheds load *before* it fails; during a
  brown-out (any breaker not closed) requests below a priority floor
  are shed first.

* :class:`RecoveryConfig` / :class:`CheckpointStore` — periodic
  lightweight checkpoints of resident decode sessions to a host-side
  store (interval- and dirty-token-gated).  On a crash, accepted
  in-flight sessions restore on survivors from the last checkpoint and
  replay deterministically instead of landing in ``dropped``; the DES
  mirrors this with a replay-cost model (see
  ``simulate_deployment(faults=...)``), the live path restores real
  :class:`~repro.serving.kvpool.SessionState` snapshots with
  bit-identical greedy continuations.

Every random draw comes from a ``random.Random`` derived from the
plan's seed (no module-global state): same seed, same chaos —
regression-tested across both DES walks.
"""
from __future__ import annotations

import dataclasses
import json
import random
from typing import Any, Dict, Iterator, List, Optional, Tuple

import jax
import numpy as np

from repro.core.simulator import ControlEvent
from repro.serving.kvpool import KvSlice, SessionState, kv_checksum

__all__ = ["Crash", "Straggle", "FlakyLink", "FaultPlan", "FaultState",
           "RecoveryConfig", "BreakerConfig", "GroupHealth",
           "DeviceHealth", "ChaosLink", "CheckpointStore",
           "StraggleDetector"]


# ===================================================================== #
# Typed fault specs
# ===================================================================== #
@dataclasses.dataclass(frozen=True)
class Crash:
    """Hard group kill at ``t``; ``recover_at`` brings it back (the
    "up" path) — None is a permanent loss (the legacy ``failures=``
    semantics)."""
    t: float
    group: int
    recover_at: Optional[float] = None

    def validate(self) -> None:
        if self.group < 0:
            raise ValueError(f"crash group must be >= 0, got {self.group}")
        if self.recover_at is not None and self.recover_at <= self.t:
            raise ValueError(
                f"crash(t={self.t:g}) must recover strictly later, "
                f"got recover_at={self.recover_at:g}")


@dataclasses.dataclass(frozen=True)
class Straggle:
    """Transient service-time inflation: group ``group`` runs
    ``factor`` x slower over ``[t0, t1)``."""
    t0: float
    t1: float
    group: int
    factor: float

    def validate(self) -> None:
        if self.group < 0:
            raise ValueError(
                f"straggle group must be >= 0, got {self.group}")
        if self.t1 <= self.t0:
            raise ValueError(
                f"straggle window [{self.t0:g}, {self.t1:g}) is empty")
        if self.factor <= 0.0:
            raise ValueError(
                f"straggle factor must be > 0, got {self.factor}")


@dataclasses.dataclass(frozen=True)
class FlakyLink:
    """Per-chunk KV-transfer failure on the directed ``src -> dst``
    fabric link: each chunk fails independently with probability ``p``
    and is retried up to ``max_retries`` times with exponential
    backoff (``backoff * 2**attempt`` seconds between tries).  A chunk
    that exhausts its retries — or whose retry would start later than
    ``deadline`` seconds past prefill completion — aborts the handoff
    and the request re-prefills on the decode group."""
    src: int
    dst: int
    p: float
    seed: int = 0
    max_retries: int = 3
    backoff: float = 1e-3
    deadline: float = 1.0

    def validate(self) -> None:
        if self.src == self.dst:
            raise ValueError("flaky_link needs src != dst (same-group "
                             "handoffs never touch the fabric)")
        if min(self.src, self.dst) < 0:
            raise ValueError("flaky_link groups must be >= 0")
        if not 0.0 <= self.p <= 1.0:
            raise ValueError(f"flaky_link p must be in [0, 1], "
                             f"got {self.p}")
        if self.max_retries < 0 or self.backoff < 0.0 \
                or self.deadline <= 0.0:
            raise ValueError("flaky_link retry policy needs "
                             "max_retries >= 0, backoff >= 0, "
                             "deadline > 0")


@dataclasses.dataclass(frozen=True)
class RecoveryConfig:
    """Checkpoint-based session recovery knobs.

    ``interval`` — seconds between periodic checkpoints of resident
    decode sessions; a crash victim replays only the decode suffix
    after its last checkpoint.  ``min_dirty_tokens`` gates the LIVE
    store: a session is re-checkpointed only after generating that
    many tokens since its last snapshot (the DES replay-cost model
    uses ``interval`` alone).  ``restore_bw`` / ``base_latency`` price
    the host -> survivor restore the DES charges before replay."""
    interval: float = 0.25
    min_dirty_tokens: int = 1
    restore_bw: float = 2e9
    base_latency: float = 1e-3

    def validate(self) -> None:
        if self.interval <= 0.0 or self.min_dirty_tokens < 0 \
                or self.restore_bw <= 0.0 or self.base_latency < 0.0:
            raise ValueError(f"invalid RecoveryConfig {self}")


_PLAN_KEYS = frozenset({"seed", "crashes", "straggles", "flaky_links"})


@dataclasses.dataclass
class FaultPlan:
    """A seeded, typed, JSON-round-tripping chaos schedule.

    Builder verbs chain::

        plan = (FaultPlan(seed=7)
                .crash(3.0, group=1, recover_at=5.0)
                .straggle(1.0, 2.0, group=0, factor=3.0)
                .flaky_link(0, 1, p=0.05))

    ``Deployment.simulate(faults=plan)`` replays it in the DES;
    ``LaunchedDeployment.inject(plan)`` replays it against live
    engines.  ``bind()`` produces the fresh per-run mutable state
    (seeded RNGs, health breakers), so repeated runs of the same plan
    are bit-identical.
    """
    seed: int = 0
    crashes: List[Crash] = dataclasses.field(default_factory=list)
    straggles: List[Straggle] = dataclasses.field(default_factory=list)
    flaky_links: List[FlakyLink] = dataclasses.field(default_factory=list)

    # ------------------------------------------------------------ #
    # Builders
    # ------------------------------------------------------------ #
    def crash(self, t: float, group: int,
              recover_at: Optional[float] = None) -> "FaultPlan":
        c = Crash(float(t), int(group),
                  None if recover_at is None else float(recover_at))
        c.validate()
        self.crashes.append(c)
        return self

    def straggle(self, t0: float, t1: float, group: int,
                 factor: float) -> "FaultPlan":
        s = Straggle(float(t0), float(t1), int(group), float(factor))
        s.validate()
        for prev in self.straggles:
            if prev.group == s.group and s.t0 < prev.t1 \
                    and prev.t0 < s.t1:
                raise ValueError(
                    f"straggle windows overlap on group {s.group}: "
                    f"[{prev.t0:g},{prev.t1:g}) and [{s.t0:g},{s.t1:g})")
        self.straggles.append(s)
        return self

    def flaky_link(self, src: int, dst: int, p: float, seed: int = 0,
                   max_retries: int = 3, backoff: float = 1e-3,
                   deadline: float = 1.0) -> "FaultPlan":
        fl = FlakyLink(int(src), int(dst), float(p), int(seed),
                       int(max_retries), float(backoff),
                       float(deadline))
        fl.validate()
        if any(l.src == fl.src and l.dst == fl.dst
               for l in self.flaky_links):
            raise ValueError(f"duplicate flaky_link "
                             f"({fl.src} -> {fl.dst})")
        self.flaky_links.append(fl)
        return self

    def validate(self) -> None:
        for c in self.crashes:
            c.validate()
        for s in self.straggles:
            s.validate()
        for fl in self.flaky_links:
            fl.validate()

    # ------------------------------------------------------------ #
    # Timeline + per-run state
    # ------------------------------------------------------------ #
    def control_events(self) -> List[ControlEvent]:
        """The plan's crash/straggle schedule as ControlEvents (flaky
        links do not alter eligibility — they live on the KV path)."""
        evs: List[ControlEvent] = []
        for c in self.crashes:
            evs.append(ControlEvent(c.t, "fail", c.group))
            if c.recover_at is not None:
                evs.append(ControlEvent(c.recover_at, "up", c.group))
        for s in self.straggles:
            evs.append(ControlEvent(s.t0, "slow", s.group,
                                    factor=s.factor))
            evs.append(ControlEvent(s.t1, "slow", s.group, factor=1.0))
        return evs

    def bind(self, n_groups: int,
             recovery: Optional[RecoveryConfig] = None,
             health: Optional["GroupHealth"] = None) -> "FaultState":
        """Fresh per-run fault state: validated against the group
        count, with newly seeded per-link RNGs (same plan seed ->
        bit-identical chaos on every run)."""
        self.validate()
        for c in self.crashes:
            if c.group >= n_groups:
                raise ValueError(f"crash names group {c.group}; "
                                 f"deployment has {n_groups}")
        for s in self.straggles:
            if s.group >= n_groups:
                raise ValueError(f"straggle names group {s.group}; "
                                 f"deployment has {n_groups}")
        for fl in self.flaky_links:
            if max(fl.src, fl.dst) >= n_groups:
                raise ValueError(f"flaky_link ({fl.src} -> {fl.dst}) "
                                 f"exceeds {n_groups} groups")
        if recovery is not None:
            recovery.validate()
        if health is not None:
            health.bind(n_groups)
        return FaultState(self, n_groups, recovery, health)

    # ------------------------------------------------------------ #
    # JSON round trip
    # ------------------------------------------------------------ #
    def to_json(self) -> str:
        return json.dumps({
            "seed": self.seed,
            "crashes": [dataclasses.asdict(c) for c in self.crashes],
            "straggles": [dataclasses.asdict(s)
                          for s in self.straggles],
            "flaky_links": [dataclasses.asdict(fl)
                            for fl in self.flaky_links],
        }, indent=2, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "FaultPlan":
        d = json.loads(text)
        unknown = set(d) - _PLAN_KEYS
        if unknown:
            raise ValueError(f"unknown FaultPlan fields: "
                             f"{sorted(unknown)}")
        plan = cls(seed=int(d.get("seed", 0)),
                   crashes=[Crash(**c) for c in d.get("crashes", [])],
                   straggles=[Straggle(**s)
                              for s in d.get("straggles", [])],
                   flaky_links=[FlakyLink(**fl)
                                for fl in d.get("flaky_links", [])])
        plan.validate()
        return plan

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")

    @classmethod
    def load(cls, path: str) -> "FaultPlan":
        with open(path) as f:
            return cls.from_json(f.read())


class _LinkState:
    """Per-run mutable state of one flaky link: the seeded RNG plus
    the retry policy ``_stream_kv_flaky`` charges."""

    def __init__(self, plan_seed: int, fl: FlakyLink):
        self.p = fl.p
        self.max_retries = fl.max_retries
        self.backoff = fl.backoff
        self.deadline = fl.deadline
        self.rng = random.Random(
            f"{plan_seed}:link:{fl.src}:{fl.dst}:{fl.seed}")


class FaultState:
    """One run's bound fault state (see :meth:`FaultPlan.bind`).

    The DES reads ``link(src, dst)`` on every phase-split handoff and
    ``recovery`` / ``health`` at crash time; the live injector
    additionally builds :class:`ChaosLink` wrappers (``live_link``)
    and reads ``straggle_factor`` for pacing."""

    def __init__(self, plan: FaultPlan, n_groups: int,
                 recovery: Optional[RecoveryConfig],
                 health: Optional["GroupHealth"]):
        self.plan = plan
        self.n_groups = n_groups
        self.recovery = recovery
        self.health = health
        self._links = {(fl.src, fl.dst): _LinkState(plan.seed, fl)
                       for fl in plan.flaky_links}
        self._live: Dict[Tuple[int, int], ChaosLink] = {}

    def link(self, src: int, dst: int) -> Optional[_LinkState]:
        return self._links.get((src, dst))

    def live_link(self, src: int, dst: int) -> Optional["ChaosLink"]:
        """The live (shard-level) counterpart of ``link`` — cached so
        retry counters accumulate across handoffs."""
        key = (src, dst)
        if key not in self._live:
            fl = next((f for f in self.plan.flaky_links
                       if (f.src, f.dst) == key), None)
            if fl is None:
                return None
            self._live[key] = ChaosLink(self.plan.seed, fl)
        return self._live[key]

    def control_events(self) -> List[ControlEvent]:
        return self.plan.control_events()

    def straggle_factor(self, group: int, t: float) -> float:
        for s in self.plan.straggles:
            if s.group == group and s.t0 <= t < s.t1:
                return s.factor
        return 1.0


# ===================================================================== #
# Health: error-rate EWMA + circuit breaker
# ===================================================================== #
@dataclasses.dataclass(frozen=True)
class BreakerConfig:
    """Circuit-breaker tuning.  ``alpha`` weights each observation in
    the error EWMA; a closed breaker opens when the EWMA reaches
    ``open_threshold`` and stays open for ``cooldown`` seconds, then
    half-opens (probe traffic allowed); one error while half-open
    re-opens, one success closes.  ``penalty`` converts the error rate
    into seconds added to a router score (so a degrading group sheds
    load smoothly before its breaker ever trips)."""
    alpha: float = 0.3
    open_threshold: float = 0.5
    cooldown: float = 2.0
    penalty: float = 10.0


class GroupHealth:
    """Per-group error-rate EWMA + closed/open/half-open breaker.

    Wired twice: the DES records flaky-transfer errors and
    crash/recover flips (``simulate_deployment(faults=...)``), the
    live injector records shard corruption and engine crashes.  The
    SAME instance is handed to a router (``JSEDRouter(health=...)``,
    ``PDRouter(health=...)``) which skips open groups, penalizes
    degraded ones, and — given a ``brownout_priority`` floor — sheds
    low-priority requests while any breaker is not closed."""

    def __init__(self, n_groups: int = 0,
                 cfg: Optional[BreakerConfig] = None):
        self.cfg = cfg or BreakerConfig()
        self.bind(n_groups)

    def bind(self, n_groups: int) -> "GroupHealth":
        """Fresh state for ``n_groups`` groups (idempotent per run)."""
        self._rate = [0.0] * n_groups
        self._state = ["closed"] * n_groups
        self._until = [0.0] * n_groups      # open -> half_open time
        self._latched = [False] * n_groups  # hard failure: stays open
        return self

    def __len__(self) -> int:
        return len(self._state)

    def _tick(self, g: int, t: float) -> None:
        if self._state[g] == "open" and not self._latched[g] \
                and t >= self._until[g]:
            self._state[g] = "half_open"

    def record_error(self, g: int, t: float) -> None:
        self._tick(g, t)
        c = self.cfg
        self._rate[g] = (1.0 - c.alpha) * self._rate[g] + c.alpha
        if self._state[g] == "half_open" or (
                self._state[g] == "closed"
                and self._rate[g] >= c.open_threshold):
            self._state[g] = "open"
            self._until[g] = t + c.cooldown

    def record_ok(self, g: int, t: float) -> None:
        self._tick(g, t)
        self._rate[g] *= (1.0 - self.cfg.alpha)
        if self._state[g] == "half_open":
            self._state[g] = "closed"   # probe succeeded

    def trip(self, g: int, t: float) -> None:
        """Hard failure (group crash): latch the breaker open until
        :meth:`reset` (the recovery-"up")."""
        self._state[g] = "open"
        self._latched[g] = True
        self._rate[g] = 1.0

    def reset(self, g: int, t: float) -> None:
        """Recovery: unlatch — the group half-opens and must prove
        itself with a successful probe before closing."""
        self._latched[g] = False
        self._state[g] = "half_open"
        self._rate[g] *= 0.5

    def suspect(self, g: int, t: float) -> None:
        """Soft evidence of degradation with NO observed error (e.g. a
        straggle detector's inference from service-time drift):
        half-open the breaker so routers penalize the group and probe
        it, without latching.  A later :meth:`record_ok` closes it; a
        hard :meth:`trip` still overrides.  No-op while already
        open."""
        self._tick(g, t)
        if self._latched[g] or self._state[g] == "open":
            return
        self._state[g] = "half_open"
        self._rate[g] = max(self._rate[g], self.cfg.open_threshold)

    # -- router-facing probes -------------------------------------- #
    def state(self, g: int, t: float) -> str:
        self._tick(g, t)
        return self._state[g]

    def error_rate(self, g: int) -> float:
        return self._rate[g]

    def allow(self, g: int, t: float) -> bool:
        """False while the breaker is open (half-open allows probes)."""
        return self.state(g, t) != "open"

    def penalty(self, g: int, t: float) -> float:
        """Seconds added to a router score: proportional to the error
        EWMA, plus a surcharge while half-open (probe traffic only
        trickles back)."""
        p = self.cfg.penalty * self._rate[g]
        if self.state(g, t) == "half_open":
            p += self.cfg.penalty
        return p

    def degraded(self, t: float) -> bool:
        """True while ANY breaker is not closed — the brown-out signal
        priority shedding keys off."""
        return any(self.state(g, t) != "closed"
                   for g in range(len(self._state)))


@dataclasses.dataclass
class DeviceHealth:
    """Heartbeat-style device registry over :class:`GroupHealth`
    breakers: a failed device latches its breaker open (the runtime's
    hard-fail view — ``runtime/fault.py``'s ``ElasticExecutor`` routes
    through this)."""
    alive: List[bool]
    breakers: GroupHealth = dataclasses.field(default=None)  # type: ignore[assignment]

    def __post_init__(self):
        if self.breakers is None:
            self.breakers = GroupHealth(len(self.alive))

    def fail(self, idx: int) -> None:
        self.alive[idx] = False
        self.breakers.trip(idx, 0.0)

    def lost(self) -> set:
        return {i for i, a in enumerate(self.alive) if not a}


# ===================================================================== #
# Straggle detection: infer degradation nobody declared
# ===================================================================== #
class StraggleDetector:
    """Infers straggling groups from windowed DES signals and trips
    their :class:`GroupHealth` breakers to half-open — no injected
    fault required.

    Plugs into ``Deployment.simulate(controller=...)`` (the
    decision-epoch protocol): each epoch's :class:`ControlSignals`
    carries ``service_obs`` (service seconds the DES committed per
    group, straggle inflation included) and ``service_model`` (the
    same work priced by the group's un-degraded profile).  Their ratio
    is EWMA-smoothed per group; after ``min_epochs`` epochs with
    committed work, a ratio at or above ``threshold`` calls
    ``health.suspect`` (half-open: routers penalize and probe), and a
    flagged group whose smoothed ratio falls back to ``clear`` earns a
    ``health.record_ok`` (probe success: breaker closes).

    A healthy group's ratio is exactly 1.0 — the DES prices committed
    work with the same linear program the model uses — so false
    positives require an actual profile/behavior divergence, not
    noise.  Detections are recorded in ``self.detections`` as
    ``(time, group, smoothed_ratio)``.
    """

    def __init__(self, health: GroupHealth, *,
                 interval: float = 0.5,
                 threshold: float = 1.25,
                 clear: float = 1.05,
                 alpha: float = 0.5,
                 min_epochs: int = 2,
                 min_service: float = 1e-6):
        if interval <= 0.0:
            raise ValueError("interval must be > 0")
        if threshold <= clear:
            raise ValueError("threshold must exceed clear "
                             "(hysteresis band)")
        self.health = health
        self.interval = float(interval)
        self.threshold = float(threshold)
        self.clear = float(clear)
        self.alpha = float(alpha)
        self.min_epochs = int(min_epochs)
        self.min_service = float(min_service)
        self.detections: List[Tuple[float, int, float]] = []
        self._ewma: Dict[int, float] = {}
        self._epochs: Dict[int, int] = {}
        self.flagged: set = set()

    # controller protocol ------------------------------------------- #
    def bind(self, deployment) -> "StraggleDetector":
        return self

    def begin(self, t0: float) -> None:
        self._ewma.clear()
        self._epochs.clear()
        self.flagged.clear()
        self.detections = []

    def decide(self, sig):
        obs, mod = sig.service_obs, sig.service_model
        for g in range(len(obs)):
            if mod[g] <= self.min_service:
                continue                # no committed work this epoch
            ratio = obs[g] / mod[g]
            prev = self._ewma.get(g)
            ew = ratio if prev is None else \
                (1.0 - self.alpha) * prev + self.alpha * ratio
            self._ewma[g] = ew
            self._epochs[g] = self._epochs.get(g, 0) + 1
            if self._epochs[g] < self.min_epochs:
                continue
            if ew >= self.threshold:
                if g not in self.flagged:
                    self.flagged.add(g)
                    self.detections.append((sig.now, g, ew))
                self.health.suspect(g, sig.now)
            elif g in self.flagged and ew <= self.clear:
                self.flagged.discard(g)
                self.health.record_ok(g, sig.now)
        return ()

    def finish(self, t_end: float) -> None:
        return None


# ===================================================================== #
# Live-side chaos: flaky shard channel + checkpoint store
# ===================================================================== #
def corrupt_slice(sl: KvSlice) -> KvSlice:
    """Flip one byte of the shard's first leaf while KEEPING its
    original checksum — the receiver's :meth:`KvSlice.verify` must
    catch it."""
    leaves, treedef = jax.tree_util.tree_flatten(sl.state)
    arr = np.array(jax.device_get(leaves[0]), copy=True)
    flat = arr.reshape(-1).view(np.uint8)
    flat[0] ^= 0xFF
    state = jax.tree_util.tree_unflatten(treedef, [arr] + leaves[1:])
    return dataclasses.replace(sl, state=state)


class ChaosLink:
    """Seeded flaky channel over a :meth:`SessionManager.stream` shard
    generator — the live counterpart of the DES ``_stream_kv_flaky``.

    Each shard "transmission" fails independently with probability
    ``p`` and is retransmitted (counted in ``retries``) up to
    ``max_retries`` times; on exhaustion the link gives up
    retransmitting and delivers the shard CORRUPTED with its original
    checksum (counted in ``corrupted``) — the receiver detects the
    mismatch, rolls back, and the caller re-prefills on the decode
    engine."""

    def __init__(self, plan_seed: int, fl: FlakyLink):
        self.p = fl.p
        self.max_retries = fl.max_retries
        self.rng = random.Random(
            f"{plan_seed}:live:{fl.src}:{fl.dst}:{fl.seed}")
        self.retries = 0
        self.corrupted = 0

    def wrap(self, shards) -> Iterator[Any]:
        for item in shards:
            if isinstance(item, KvSlice) and self.p > 0.0:
                attempts = 0
                while self.rng.random() < self.p:
                    attempts += 1
                    if attempts > self.max_retries:
                        self.corrupted += 1
                        item = corrupt_slice(item)
                        break
                    self.retries += 1
            yield item


class CheckpointStore:
    """Host-side periodic checkpoint store for live engines.

    ``poll(engines, now)`` runs at most once per ``interval`` seconds:
    it takes a non-destructive :meth:`SessionManager.snapshot` of each
    engine and stores a host copy of every session that generated at
    least ``min_dirty_tokens`` tokens since its last checkpoint.  On a
    crash, ``restore(req, engine, now)`` truncates the request's
    output back to the checkpointed length and re-installs the saved
    state — greedy re-decode regenerates the truncated suffix
    bit-identically (the state is exact), so recovery is exact, not
    approximate."""

    def __init__(self, cfg: Optional[RecoveryConfig] = None):
        self.cfg = cfg or RecoveryConfig()
        self.cfg.validate()
        self._data: Dict[int, Dict[str, Any]] = {}
        self._next_t: Optional[float] = None
        self.checkpoints = 0            # snapshots actually stored
        self.stored_bytes = 0.0

    def __contains__(self, rid: int) -> bool:
        return rid in self._data

    def poll(self, engines, now: float, on_store=None) -> int:
        """``on_store(engine_idx, nbytes)`` (optional) observes every
        stored snapshot — the hook live fabric accounting uses to
        charge checkpoint shipping as bulk traffic."""
        if self._next_t is not None and now < self._next_t:
            return 0
        self._next_t = now + self.cfg.interval
        n = 0
        for gi, eng in enumerate(engines):
            sessions = eng.sessions if hasattr(eng, "sessions") else eng
            for req, st in sessions.snapshot(now):
                prev = self._data.get(st.rid)
                if prev is not None and \
                        st.pos - prev["pos"] < self.cfg.min_dirty_tokens:
                    continue
                self._data[st.rid] = {
                    "state": jax.device_get(st.state),
                    "last_tok": int(st.last_tok),
                    "pos": int(st.pos),
                    "budget": int(st.budget),
                    "nbytes": int(st.nbytes),
                    "out_len": len(req.output),
                }
                self.checkpoints += 1
                self.stored_bytes += float(st.nbytes)
                if on_store is not None:
                    on_store(gi, int(st.nbytes))
                n += 1
        return n

    def drop(self, rid: int) -> None:
        self._data.pop(rid, None)

    def restore(self, req, engine, now: Optional[float] = None) -> bool:
        """Re-install ``req``'s last checkpoint on ``engine``.  Rolls
        the request's client-visible output back to the checkpointed
        prefix (those tokens already streamed; the re-decoded suffix
        is bit-identical).  Returns False when no checkpoint exists or
        the engine cannot fit the session right now."""
        entry = self._data.get(req.rid)
        if entry is None:
            return False
        sessions = engine.sessions if hasattr(engine, "sessions") \
            else engine
        st = SessionState(
            rid=req.rid,
            state=jax.tree_util.tree_map(np.asarray, entry["state"]),
            last_tok=entry["last_tok"], pos=entry["pos"],
            budget=entry["budget"], nbytes=entry["nbytes"],
            done=False, first_token_pending=False,
            priority=getattr(req, "priority", 0))
        out_len = entry["out_len"]
        saved_tail = list(req.output[out_len:])
        del req.output[out_len:]
        if sessions.restore(req, st, now):
            return True
        req.output.extend(saved_tail)   # roll the truncation back
        return False
