"""Heterogeneous replica *sizing*: which devices to group, under $/hr.

The cluster layer routes across a GIVEN set of replica groups; this
module searches over the composition itself — which devices from an
inventory pair into which groups — for a rental budget, scored by the
deployment DES.  This is the ROADMAP item HexGen-2 solves with a
genetic scheduler; here the search is a greedy seed refined by
simulated-annealing mutations (swap / add / drop a group), which is
the same shape at the scale this repo simulates (inventories of a few
device types, compositions of 2–8 groups).

Objective: **goodput per dollar** — requests served within BOTH SLO
components per $ of rental (``ClusterResult.goodput * 3600 /
price_rate``).  A fixed open-loop trace is the demand; a composition
wins by serving that demand within SLOs on cheaper hardware, not by
provisioning capacity nobody asked for.  Every candidate is scored by
a full deterministic DES replay, so the search is reproducible
bit-for-bit from (inventory, budget, trace, seed).

Search moves reuse the process-wide plan cache: a candidate that
re-uses an already-planned group template costs only a DES replay
(milliseconds), so the annealing loop stays cheap even though every
score is a real simulation.
"""
from __future__ import annotations

import dataclasses
import math
import random
from collections import Counter
from itertools import combinations_with_replacement
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core import planner
from repro.core.costmodel import CATALOG
from repro.core.simulator import ClusterResult
from repro.serving.spec import DeploymentSpec

GroupTemplate = Tuple[str, ...]         # sorted device names, one group


@dataclasses.dataclass
class SizingResult:
    """Outcome of :func:`search_composition`."""
    spec: DeploymentSpec                # best composition found
    score: float                        # goodput/$ (req per dollar)
    result: ClusterResult               # DES result of the best spec
    seed_score: float                   # greedy seed's score
    evals: int                          # distinct compositions scored
    # (iter, candidate, best) — exactly iters + 1 rows, one per
    # iteration including the seed; infeasible mutations carry the
    # incumbent score forward instead of dropping the row
    history: List[Tuple[int, float, float]]
    # finalists re-scored on the full trace (0 unless ``subsample``
    # triggered the confirm tier)
    confirmed: int = 0

    @property
    def composition(self) -> List[List[str]]:
        return self.spec.groups


def group_price(template: Sequence[str]) -> float:
    return sum(CATALOG[n].price for n in template)


def group_templates(inventory: Dict[str, int],
                    max_group: int = 2,
                    min_group: int = 1) -> List[GroupTemplate]:
    """Candidate replica-group shapes drawable from the inventory:
    every multiset of min_group..max_group device types with enough
    stock (``min_group=2`` restricts the search to true multi-device
    groups — the paper's disaggregated deployments)."""
    if not 1 <= min_group <= max_group:
        raise ValueError(f"need 1 <= min_group <= max_group, got "
                         f"{min_group}..{max_group}")
    names = sorted(n for n, c in inventory.items() if c > 0)
    for n in names:
        if n not in CATALOG:
            raise ValueError(f"unknown device {n!r}; "
                             f"pick from {sorted(CATALOG)}")
    out: List[GroupTemplate] = []
    for k in range(min_group, max_group + 1):
        for combo in combinations_with_replacement(names, k):
            need = Counter(combo)
            if all(inventory[n] >= c for n, c in need.items()):
                out.append(tuple(combo))
    return out


def _fits(comp: Sequence[GroupTemplate], inventory: Dict[str, int],
          budget: float) -> bool:
    used: Counter = Counter()
    for t in comp:
        used.update(t)
    if any(used[n] > inventory.get(n, 0) for n in used):
        return False
    return sum(group_price(t) for t in comp) <= budget + 1e-9


def modeled_capacity(template: GroupTemplate, graph,
                     anneal_iters: int = 300) -> float:
    """Steady-state req/s ceiling of one group: 1 / pipelined
    bottleneck of its throughput plan (plan-cache backed)."""
    devices = [CATALOG[n] for n in template]
    plan = planner.plan(graph, devices, policy="throughput",
                        anneal_iters=anneal_iters)
    return 1.0 / max(plan.bottleneck, 1e-12)


def greedy_composition(inventory: Dict[str, int], budget: float, graph,
                       *, max_group: int = 2, min_group: int = 1,
                       anneal_iters: int = 300) -> List[GroupTemplate]:
    """Greedy seed: repeatedly add the feasible group template with the
    best modeled capacity-per-dollar until neither budget nor inventory
    admits another group."""
    templates = group_templates(inventory, max_group, min_group)
    if not templates:
        raise ValueError("inventory admits no group template")
    ratio = {t: modeled_capacity(t, graph, anneal_iters) / group_price(t)
             for t in templates}
    order = sorted(templates, key=lambda t: (-ratio[t], t))
    comp: List[GroupTemplate] = []
    progress = True
    while progress:
        progress = False
        for t in order:
            if _fits(comp + [t], inventory, budget):
                comp.append(t)
                progress = True
                break
    if not comp:
        raise ValueError(f"budget ${budget}/hr cannot afford any group "
                         f"(cheapest template costs "
                         f"${min(group_price(t) for t in templates):.2f})")
    return comp


def uniform_composition(inventory: Dict[str, int], budget: float, graph,
                        *, template: Optional[Sequence[str]] = None,
                        max_group: int = 2,
                        anneal_iters: int = 300) -> List[List[str]]:
    """The naive same-budget baseline: fill the budget with copies of
    ONE group template — by default the highest modeled-capacity
    feasible one (the "just buy the big pairs" strategy the paper's
    heterogeneous compositions are measured against)."""
    if template is None:
        templates = group_templates(inventory, max_group)
        afford = [t for t in templates
                  if group_price(t) <= budget + 1e-9]
        if not afford:
            raise ValueError("budget cannot afford any group")
        template = max(afford, key=lambda t: (
            modeled_capacity(t, graph, anneal_iters), t))
    t = tuple(template)
    comp: List[GroupTemplate] = []
    while _fits(comp + [t], inventory, budget):
        comp.append(t)
    if not comp:
        raise ValueError(f"template {t} never fits budget/inventory")
    return [list(g) for g in comp]


# --------------------------------------------------------------------- #
def search_composition(inventory: Dict[str, int], budget: float,
                       trace, graph, *,
                       iters: int = 60, seed: int = 0,
                       max_group: int = 2, min_group: int = 1,
                       temperature: float = 0.08,
                       spec_kwargs: Optional[Dict[str, Any]] = None,
                       subsample: Optional[int] = None,
                       confirm_top: int = 3,
                       reference: bool = False
                       ) -> SizingResult:
    """Search replica-group compositions for ``budget`` $/hr.

    ``inventory`` maps device-catalog names to available counts;
    ``trace`` is the demand (a ``serving.workload`` trace — give it or
    the spec SLOs so goodput is meaningful); ``graph`` the request DDG
    the planner places.  ``spec_kwargs`` are forwarded into every
    candidate :class:`DeploymentSpec` (router, slos, pd, monitor,
    calibration, ...) — the search varies ONLY ``groups``.

    Greedy seed (capacity/$ ordering) + ``iters`` simulated-annealing
    mutations: swap one group for a random feasible template, add a
    template, or drop a group.  Every candidate is scored by a DES
    replay; annealing accepts uphill always and downhill with
    probability ``exp(rel_delta / T)``, T decaying to 0 over the run.
    Deterministic in all arguments.

    Candidate replays share one prepared request list (SLO assignment,
    token scales and KV sizes depend on the spec's graph/slos — never
    on groups) and skip event recording; only the returned incumbent is
    replayed with full logs.  ``subsample`` scores candidates on the
    first N prepared requests only (a deterministic prefix — the demand
    process is unchanged, just truncated) and then confirms the
    ``confirm_top`` best-scoring distinct compositions, plus the
    annealing incumbent, on the full trace; the final incumbent is the
    confirm-tier argmax.  ``reference=True`` restores the
    pre-vectorization search wholesale — reference walk, per-replay
    trace prep, full event logs, no subsampling — the honest "before"
    for benchmarks.
    """
    skw = dict(spec_kwargs or {})
    skw.setdefault("router", "jsed")
    skw["budget"] = budget
    rng = random.Random(f"sizing:{seed}")
    templates = group_templates(inventory, max_group, min_group)

    cur = greedy_composition(inventory, budget, graph,
                             max_group=max_group, min_group=min_group)
    prepared_full = DeploymentSpec(
        groups=[list(t) for t in cur],
        **skw).compile(graph).prepare(trace)
    if subsample is not None and 0 < subsample < len(prepared_full):
        prepared_score = prepared_full[:subsample]
    else:
        prepared_score = prepared_full

    # key -> (subsample score, spec, compiled deployment); keeping the
    # Deployment means a composition is compiled (cluster built, group
    # plans looked up, units assembled) exactly once no matter how many
    # times the annealer, the confirm tier or the final replay visit it
    cache: Dict[Tuple, Tuple[float, DeploymentSpec, Any]] = {}

    def replay(dep, prepared, events: Optional[str]) -> ClusterResult:
        if reference:
            # the historical route end to end: per-replay trace prep
            # and a full event log — exactly what evaluate() cost
            # before the fast core, so benchmarks against it are honest
            return dep.simulate(trace, events="full", reference=True)
        return dep.simulate(events=events, prepared=prepared)

    def evaluate(comp: Sequence[GroupTemplate]):
        key = tuple(sorted(comp))
        if key not in cache:
            spec = DeploymentSpec(groups=[list(t) for t in comp], **skw)
            dep = spec.compile(graph)
            res = replay(dep, prepared_score, None)
            score = res.goodput * 3600.0 / max(spec.price_rate, 1e-12)
            cache[key] = (score, spec, dep)
        return cache[key]

    def mutate(comp: List[GroupTemplate]
               ) -> Optional[List[GroupTemplate]]:
        op = rng.choice(("swap", "swap", "add", "drop"))
        cand = list(comp)
        if op == "swap":
            cand[rng.randrange(len(cand))] = rng.choice(templates)
        elif op == "add":
            cand.append(rng.choice(templates))
        elif len(cand) > 1:
            cand.pop(rng.randrange(len(cand)))
        else:
            return None
        return cand if _fits(cand, inventory, budget) else None

    cur_score, _, _ = evaluate(cur)
    seed_score = cur_score
    best, best_score = list(cur), cur_score
    history: List[Tuple[int, float, float]] = [(0, cur_score, best_score)]
    for it in range(1, iters + 1):
        T = temperature * (1.0 - it / (iters + 1))
        cand = mutate(cur)
        if cand is None:
            # infeasible mutation: nothing was scored, but the row is
            # still recorded (candidate column carries the incumbent,
            # best column carries best_score) so history is always
            # iters + 1 rows and indices align with the iteration count
            history.append((it, cur_score, best_score))
            continue
        s, _, _ = evaluate(cand)
        rel = (s - cur_score) / max(cur_score, 1e-12)
        if s >= cur_score or rng.random() < math.exp(rel / max(T, 1e-9)):
            cur, cur_score = cand, s
        if s > best_score:
            best, best_score = list(cand), s
        history.append((it, s, best_score))
    evals = len(cache)

    confirmed = 0
    if prepared_score is not prepared_full:
        # confirm tier: re-score the subsample's finalists (and the
        # annealing incumbent, in case it slipped out of the top-K) on
        # the FULL trace; ties break on the composition key so the
        # incumbent is deterministic
        finalists = sorted(cache, key=lambda k: (-cache[k][0], k))
        finalists = finalists[:max(1, confirm_top)]
        bkey = tuple(sorted(best))
        if bkey not in finalists:
            finalists.append(bkey)
        full_scores: Dict[Tuple, float] = {}
        for k in finalists:
            spec, dep = cache[k][1], cache[k][2]
            res = replay(dep, prepared_full, None)
            full_scores[k] = (res.goodput * 3600.0
                              / max(spec.price_rate, 1e-12))
        confirmed = len(full_scores)
        best = list(min(full_scores, key=lambda k: (-full_scores[k], k)))

    bkey = tuple(sorted(best))
    spec, dep = cache[bkey][1], cache[bkey][2]
    result = replay(dep, prepared_full, "full")
    score = result.goodput * 3600.0 / max(spec.price_rate, 1e-12)
    return SizingResult(spec=spec, score=score, result=result,
                        seed_score=seed_score, evals=evals,
                        history=history, confirmed=confirmed)
