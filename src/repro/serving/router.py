"""Workload-aware request routing across heterogeneous Tessera replicas.

Scoring policy (join-shortest-expected-delay, JSED)
---------------------------------------------------
Each replica group runs its own Plan over its own device set, so the
*same* request costs a different amount on different replicas — an
H100+RTX pair drains a long-prompt request far faster than an A100+L40s
pair.  The router therefore scores every candidate replica with the
cost model the planner itself optimizes:

    score(replica) = backlog(replica, now) + predicted_service(req)

  * ``backlog`` — seconds until the replica's most-loaded resource
    (compute server or ingress link) frees up: the queueing delay a new
    arrival would actually see,
  * ``predicted_service`` — the unqueued execution latency of *this*
    request on *that* replica, from the per-stage cost model with the
    request's prompt/output lengths scaled in.

and joins the minimum — an expected-completion-time rule.  With
homogeneous replicas and equal-sized requests it degenerates to
join-shortest-queue; with heterogeneous replicas it rate-matches load
to capability (fast groups get proportionally more and bigger
requests), which is what lets the workload-aware router beat
round-robin on heterogeneous mixes (benchmarks/cluster_scaling.py).

Decode-session affinity: multi-turn requests carrying a ``session`` id
re-join the replica that holds their KV/decode state unless its backlog
exceeds the best candidate's by ``affinity_break`` seconds — then the
session migrates (modeling a KV refetch as preferable to queueing).

Admission control: routers accepting an ``slo_shed`` flag return ``-1``
(shed) when every eligible replica's expected completion delay exceeds
the request's per-request SLO — serving it anyway would burn capacity on
a request that is already lost, collapsing goodput under overload.

Phase-split routing (``PDRouter``): classifies replica groups into
prefill-heavy and decode-heavy roles from the cost model's per-group
profile, routes each request's prefill and decode to different groups
(an explicit KV-transfer edge connects them, see
``simulator.simulate_cluster_pd``), and rate-matches the two pools —
prefill admission is throttled by the decode pool's backlog so the
decode side never accumulates an unbounded queue of transferred KV
("Beyond the Buzz", arXiv 2506.05508).

Eligibility: the deployment layer (``serving/spec.py`` +
``simulator.simulate_deployment``) masks groups that are warming up,
draining, or failed by flipping ``ReplicaModel.eligible``; every router
skips ineligible groups and returns ``-1`` when none remain.  With all
groups eligible (the only state the legacy entry points can produce)
every decision is bit-identical to the pre-eligibility routers.

Routers only read replica state; :func:`repro.core.simulator
.simulate_deployment` (or a real dispatch loop) owns the clock.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.simulator import ClusterRequest, ReplicaModel


def eligible_indices(replicas: Sequence[ReplicaModel]) -> List[int]:
    """Groups a router may currently send work to."""
    return [i for i in range(len(replicas))
            if getattr(replicas[i], "eligible", True)]


class Router:
    """Interface: pick a replica index for a request at time ``now``."""

    name = "base"

    def route(self, req: ClusterRequest,
              replicas: Sequence[ReplicaModel], now: float) -> int:
        raise NotImplementedError

    # simulate_cluster duck-types the router as a plain callable
    def __call__(self, req, replicas, now) -> int:
        return self.route(req, replicas, now)


class RoundRobinRouter(Router):
    """Workload-oblivious baseline: equal request counts per replica."""

    name = "round_robin"

    def __init__(self):
        self._next = 0

    def route(self, req, replicas, now) -> int:
        # advance the cursor past masked groups so the cycle covers
        # exactly the eligible set (identical to the legacy cycle when
        # everything is eligible)
        for _ in range(len(replicas)):
            idx = self._next % len(replicas)
            self._next += 1
            if getattr(replicas[idx], "eligible", True):
                return idx
        return -1


class LeastLoadedRouter(Router):
    """Join-shortest-queue on backlog seconds; size- and
    speed-oblivious (does not model the request's own cost)."""

    name = "least_loaded"

    def route(self, req, replicas, now) -> int:
        cand = eligible_indices(replicas)
        if not cand:
            return -1
        # explicit first-minimum loop == min(cand, key=(backlog, i))
        best = cand[0]
        best_b = replicas[best].backlog(now)
        for i in cand[1:]:
            b = replicas[i].backlog(now)
            if b < best_b:
                best, best_b = i, b
        return best


class JSEDRouter(Router):
    """Join-shortest-expected-delay with decode-session affinity and
    optional SLO-based admission control."""

    name = "jsed"

    def __init__(self, affinity_break: float = float("inf"),
                 slo_shed: bool = False,
                 session_affinity: bool = True,
                 kv_penalty: float = 0.0,
                 health=None,
                 brownout_priority: Optional[int] = None):
        # Migrate a session when staying costs this many more seconds
        # of backlog than the best replica; inf = never migrate.
        self.affinity_break = affinity_break
        # Shed a request when even the best replica cannot meet its SLO.
        self.slo_shed = slo_shed
        # False disables the home shortcut entirely — the measured
        # baseline for the affinity-ON-vs-OFF goodput comparison.
        self.session_affinity = session_affinity
        # Seconds of score penalty at 100% KV-block utilization; only
        # felt when the DES runs a KvPoolModel (replicas then carry a
        # kv_util_fn), so 0.0 and kv-less runs stay bit-identical.
        self.kv_penalty = kv_penalty
        # A serving.faults.GroupHealth: groups with an OPEN breaker are
        # skipped (fail-open when every breaker is open), degraded
        # groups pay health.penalty(i, now) seconds of score, and —
        # while ANY breaker is not closed — requests below
        # brownout_priority are shed first (brown-out ordering).
        # None keeps every decision bit-identical to the pre-health
        # router.
        self.health = health
        self.brownout_priority = brownout_priority
        # Bound by simulate_deployment when the spec carries a fabric:
        # a session abandoning its home then charges the QUEUED channel
        # tail of moving its resident state into the shed estimate.
        self._fabric = None
        self._session_home: Dict[int, int] = {}

    def bind_fabric(self, fstate) -> None:
        self._fabric = fstate

    def _queued_tail(self, req, src: Optional[int], dst: int,
                     now: float) -> float:
        """Seconds the session-state move src->dst spends on the shared
        fabric: committed time already ahead on the channel plus the
        transfer at the channel rate.  0.0 without a fabric, without a
        home, or when the move never leaves the island."""
        fs = self._fabric
        if fs is None or src is None or src == dst:
            return 0.0
        ch = fs.channel(src, dst)
        if ch is None:
            return 0.0
        return max(0.0, ch.head() - now) + ch.duration(req.kv_bytes)

    def score(self, req: ClusterRequest, replica: ReplicaModel,
              now: float) -> float:
        return replica.backlog(now) + replica.predicted_service(req)

    def _shed(self, req, replica, now, xfer: float = 0.0) -> bool:
        """Expected delays on the replica the request will ACTUALLY
        join (post-affinity) vs its SLO components.  Colocated expected
        TTFT = queueing + prefill-phase service (decode follows).
        ``xfer`` is the queued fabric tail of a session migration
        landing on this replica (0.0 keeps the math bit-identical to
        the fabric-less router)."""
        if not self.slo_shed:
            return False
        s = self.score(req, replica, now)
        if xfer:
            s += xfer
        if req.slo is not None and s > req.slo:
            return True
        if req.slo_ttft is None:
            return False
        t = (replica.backlog(now)
             + replica.predicted_phase_service(req, "prefill"))
        if xfer:
            t += xfer
        return t > req.slo_ttft

    def route(self, req, replicas, now) -> int:
        cand = eligible_indices(replicas)
        if not cand:
            return -1
        h = self.health
        if h is not None:
            if self.brownout_priority is not None and h.degraded(now) \
                    and getattr(req, "priority", 0) \
                    < self.brownout_priority:
                return -1       # brown-out: low priority sheds first
            ok = [i for i in cand if h.allow(i, now)]
            if ok:              # every breaker open -> fail open
                cand = ok
        # explicit first-minimum loop == min(cand, key=(score, i)):
        # this runs once per candidate group per request, so the
        # lambda/tuple-per-candidate overhead is the router hot path
        rep = replicas[cand[0]]
        best = cand[0]
        best_s = rep.backlog(now) + rep.predicted_service(req)
        if self.kv_penalty:
            kv = getattr(rep, "kv_util_fn", None)
            if kv is not None:
                best_s += self.kv_penalty * kv(now)
        if h is not None:
            best_s += h.penalty(best, now)
        for i in cand[1:]:
            rep = replicas[i]
            s = rep.backlog(now) + rep.predicted_service(req)
            if self.kv_penalty:
                kv = getattr(rep, "kv_util_fn", None)
                if kv is not None:
                    s += self.kv_penalty * kv(now)
            if h is not None:
                s += h.penalty(i, now)
            if s < best_s:
                best, best_s = i, s
        choice = best
        migrate_from: Optional[int] = None
        if self.session_affinity and req.session is not None:
            home = self._session_home.get(req.session)
            if home is not None and h is not None \
                    and not h.allow(home, now):
                # the home group's breaker is open: treat it like a
                # masked group — re-home on whatever JSED picks
                home = None
            if home is not None and not getattr(replicas[home],
                                                "eligible", True):
                # the home group drained or died; its resident state is
                # gone — the session re-homes on whatever JSED picks.
                # The stale entry is only dropped once the request is
                # actually ADMITTED: a shed must leave session state
                # untouched, or one rejected turn silently strips
                # affinity from every later turn of the session.
                home = None
            if home is not None:
                stay_cost = replicas[home].backlog(now)
                move_cost = replicas[best].backlog(now)
                if stay_cost - move_cost <= self.affinity_break:
                    choice = home
                else:
                    # affinity break: the resident state moves across
                    # the fabric before the new home can serve
                    migrate_from = home
        # the SLO check runs against the replica the request will
        # ACTUALLY join — affinity must not smuggle a doomed request
        # past admission control (a queued fabric crossing counts
        # toward the deadline like any other delay)
        xfer = self._queued_tail(req, migrate_from, choice, now)
        if self._shed(req, replicas[choice], now, xfer):
            return -1
        if self.session_affinity and req.session is not None:
            self._session_home[req.session] = choice
        return choice


class PDRouter(Router):
    """Phase-split router: prefill and decode on different groups.

    Role classification — on first routing decision, each group's
    unqueued prefill-phase and decode-phase service times for a
    scale-1 request are computed from its own plan's cost model
    (``ReplicaModel.predicted_phase_service``).  Groups are ranked by
    ``t_prefill / t_decode``: a LOW ratio means the group drains prompt
    FLOPs comparatively faster than bandwidth-bound decode (compute-rich
    hardware) and joins the prefill pool; the rest become the decode
    pool.  ``prefill_frac`` sets how many groups the prefill pool gets;
    explicit ``prefill_pool``/``decode_pool`` index lists override the
    automatic split (the P/D-ratio sweep in benchmarks/pd_split.py).

    Rate matching — before admitting a prefill, the chosen decode
    group's backlog is compared against ``max_kv_lag`` seconds; any
    excess delays the prefill admission by that amount.  The decode pool
    therefore consumes transferred KV at least as fast as prefill
    produces it (bounded resident-KV, ``ClusterResult.peak_kv_bytes``)
    instead of queueing state for requests whose decode is hours away.

    Routing within each pool is JSED restricted to the pool's members;
    with ``slo_shed`` the request is shed when the expected phase-split
    completion delay already exceeds its SLO (the expected TTFT
    includes the KV-transfer tail when an ``interconnect`` is given —
    the full serial transfer, or only the last chunk's tail under
    ``kv_chunks``-way overlapped streaming, matching the earlier
    effective KV-arrival the DES produces).

    Decode-session affinity (``session_affinity``): a follow-up turn
    of a multi-turn session runs BOTH phases on the decode group that
    already holds the session's resident KV/recurrent state — its
    prefill reuses the resident state in place instead of
    re-transferring across the fabric.  Avoided transfers are counted
    in ``transfers_avoided`` (surfaced per run in ``ClusterResult``).
    """

    name = "pd_split"

    def __init__(self, *, prefill_frac: float = 0.5,
                 prefill_pool: Optional[Sequence[int]] = None,
                 decode_pool: Optional[Sequence[int]] = None,
                 max_kv_lag: float = 0.25,
                 slo_shed: bool = False,
                 session_affinity: bool = False,
                 affinity_break: float = float("inf"),
                 interconnect=None,
                 kv_chunks: int = 1,
                 health=None,
                 brownout_priority: Optional[int] = None):
        assert 0.0 < prefill_frac < 1.0 or prefill_pool is not None
        self.prefill_frac = prefill_frac
        # same semantics as JSEDRouter: breaker-open groups drop out of
        # both pools (fail-open per pool), degraded groups pay a score
        # penalty, low-priority requests shed during a brown-out
        self.health = health
        self.brownout_priority = brownout_priority
        self.max_kv_lag = max_kv_lag
        self.slo_shed = slo_shed
        self.session_affinity = session_affinity
        # re-split a follow-up when staying home costs this many more
        # backlog seconds than the best decode candidate (inf = always
        # stay; the JSEDRouter affinity_break semantics)
        self.affinity_break = affinity_break
        self.interconnect = interconnect
        self.kv_chunks = max(int(kv_chunks), 1)
        self.transfers_avoided = 0
        # Bound by simulate_deployment when the spec carries a fabric
        # topology (serving.fabric.FabricState).  None keeps every
        # estimate on the point-to-point interconnect math.
        self._fabric = None
        # Migration handshake with the DES: when an affinity break
        # abandons a decode home, the admitted tuple decision leaves
        # the old home here so the simulator can enqueue the resident
        # state's move as bulk fabric traffic.  Consumed (and cleared)
        # by simulate_deployment's dispatch.
        self.pending_migration: Optional[int] = None
        self._migrate_from: Optional[int] = None
        self._session_decode: Dict[int, int] = {}
        self._pools: Optional[Tuple[List[int], List[int]]] = None
        if prefill_pool is not None or decode_pool is not None:
            assert prefill_pool and decode_pool, \
                "override both pools or neither"
            assert not set(prefill_pool) & set(decode_pool), \
                "pools must be disjoint"
            self._pools = (list(prefill_pool), list(decode_pool))

    # -------------------------------------------------------------- #
    def pools(self, replicas: Sequence[ReplicaModel]
              ) -> Tuple[List[int], List[int]]:
        """(prefill_pool, decode_pool) indices, classifying lazily."""
        if self._pools is None:
            self._pools = self._classify(replicas)
        return self._pools

    def _classify(self, replicas) -> Tuple[List[int], List[int]]:
        if len(replicas) < 2:       # degenerate: colocate on the one
            return [0], [0]
        probe = ClusterRequest(rid=-1, arrival=0.0)
        ratio = []
        for i, rep in enumerate(replicas):
            tp = rep.predicted_phase_service(probe, "prefill")
            td = rep.predicted_phase_service(probe, "decode")
            ratio.append((tp / max(td, 1e-12), i))
        ratio.sort()
        n_pre = min(max(int(round(self.prefill_frac * len(replicas))), 1),
                    len(replicas) - 1)
        pre = sorted(i for _, i in ratio[:n_pre])
        dec = sorted(i for _, i in ratio[n_pre:])
        return pre, dec

    def _best(self, pool: List[int], req, replicas, now,
              phase: str) -> int:
        # explicit first-minimum loop == min(pool, key=(delay, i))
        h = self.health
        rep = replicas[pool[0]]
        best = pool[0]
        best_s = (rep.backlog(now)
                  + rep.predicted_phase_service(req, phase))
        if h is not None:
            best_s += h.penalty(best, now)
        for i in pool[1:]:
            rep = replicas[i]
            s = rep.backlog(now) + rep.predicted_phase_service(req, phase)
            if h is not None:
                s += h.penalty(i, now)
            if s < best_s:
                best, best_s = i, s
        return best

    def bind_fabric(self, fstate) -> None:
        """Called by the DES when the deployment carries a fabric:
        shed estimates then charge the QUEUED channel tail (head-of-
        channel wait + contended transfer) instead of the unloaded
        point-to-point edge."""
        self._fabric = fstate

    def _transfer_tail(self, req, p: int, d: int,
                       now: float = 0.0) -> float:
        """Expected KV-transfer seconds landing in TTFT.  Serial: the
        whole edge.  Overlapped streaming: earlier chunks hide behind
        the remaining prefill compute, so only the last chunk's
        transfer outlives it (the compute-bound best case — the DES
        can only arrive at or before the serial edge, see
        simulator._stream_kv).  With a bound fabric the estimate is
        the QUEUED tail: time already committed ahead on the shared
        channel plus the transfer at the channel's (possibly
        contended) rate — so admission control sees congestion other
        requests and bulk traffic created, not the nameplate edge."""
        fs = self._fabric
        if fs is not None:
            ch = fs.channel(p, d)
            if ch is None:          # same group / same island: free
                return 0.0
            queued = max(0.0, ch.head() - now)
            serial = ch.duration(req.kv_bytes)
            if self.kv_chunks <= 1 or serial <= 0.0:
                return queued + serial
            return queued + min(serial, ch.latency
                                + (req.kv_bytes / self.kv_chunks) / ch.bw)
        ic = self.interconnect
        if ic is None:
            return 0.0
        serial = ic.transfer_time(req.kv_bytes, p, d)
        if self.kv_chunks <= 1 or serial <= 0.0:
            return serial
        return min(serial, ic.base_latency
                   + (req.kv_bytes / self.kv_chunks) / ic.bandwidth(p, d))

    # -------------------------------------------------------------- #
    def route(self, req, replicas, now):
        """Returns (prefill_idx, decode_idx, admit_at) — or -1 (shed),
        or a plain index when the pools degenerate to one group."""
        self.pending_migration = None       # handshake is per-decision
        self._migrate_from = None
        pre_pool, dec_pool = self.pools(replicas)
        # masked groups (warm-up / drain / failure) drop out of their
        # pool; a pool that empties collapses onto the other (the
        # survivors serve both phases colocated) so elasticity cannot
        # strand a phase
        pre_pool = [i for i in pre_pool
                    if getattr(replicas[i], "eligible", True)]
        dec_pool = [i for i in dec_pool
                    if getattr(replicas[i], "eligible", True)]
        if not pre_pool and not dec_pool:
            return -1
        if not pre_pool:
            pre_pool = dec_pool
        if not dec_pool:
            dec_pool = pre_pool
        h = self.health
        if h is not None:
            if self.brownout_priority is not None and h.degraded(now) \
                    and getattr(req, "priority", 0) \
                    < self.brownout_priority:
                return -1       # brown-out: low priority sheds first
            ok_pre = [i for i in pre_pool if h.allow(i, now)]
            ok_dec = [i for i in dec_pool if h.allow(i, now)]
            if ok_pre:          # fail open per pool
                pre_pool = ok_pre
            if ok_dec:
                dec_pool = ok_dec
        # A stale or abandoned home is only dropped once the request is
        # actually ADMITTED — shedding a request must leave session
        # state untouched (same invariant as JSEDRouter.route).
        drop_home = False
        if self.session_affinity and req.session is not None:
            home = self._session_decode.get(req.session)
            if home is not None and h is not None \
                    and not h.allow(home, now):
                # breaker open on the home group: re-split afresh
                home = None
            if home is not None and not getattr(replicas[home],
                                                "eligible", True):
                # resident state left with the group; re-split afresh
                drop_home = True
                home = None
            if home is not None:
                stay = replicas[home].backlog(now)
                best = min(replicas[i].backlog(now) for i in dec_pool)
                if stay - best <= self.affinity_break:
                    # follow-up turn: the decode group already holds
                    # this session's resident state — prefill reuses it
                    # in place, no cross-fabric re-transfer.  Admission
                    # control still applies: a follow-up that cannot
                    # meet its SLO on the home group is shed like any
                    # other request, not smuggled past the check.
                    if self.slo_shed:
                        rep = replicas[home]
                        t_first = (stay + rep.predicted_phase_service(
                            req, "prefill"))
                        total = t_first + rep.predicted_phase_service(
                            req, "decode")
                        if ((req.slo is not None and total > req.slo)
                                or (req.slo_ttft is not None
                                    and t_first > req.slo_ttft)):
                            return -1
                    self.transfers_avoided += 1
                    return home
                drop_home = True                        # migrate
                # the abandoned home still holds the session's resident
                # state; if this decision admits a split, the move
                # ships over the fabric as bulk traffic
                self._migrate_from = home
        p = self._best(pre_pool, req, replicas, now, "prefill")
        d = self._best(dec_pool, req, replicas, now, "decode")
        if p == d:
            if drop_home:
                del self._session_decode[req.session]
            return p
        # rate matching: delay prefill admission by the decode group's
        # backlog beyond the tolerated lag, so prefill production tracks
        # decode-side KV consumption
        lag = max(0.0, replicas[d].backlog(now) - self.max_kv_lag)
        if self.slo_shed:
            expect_ttft = (lag + replicas[p].backlog(now)
                           + replicas[p].predicted_phase_service(
                               req, "prefill")
                           + self._transfer_tail(req, p, d, now))
            expect = expect_ttft + replicas[d].predicted_phase_service(
                req, "decode")
            if req.slo is not None and expect > req.slo:
                return -1
            if req.slo_ttft is not None and expect_ttft > req.slo_ttft:
                return -1
        if self.session_affinity and req.session is not None:
            self._session_decode[req.session] = d
        # only an ADMITTED split migrates state — a shed above leaves
        # the handshake cleared and the session where it was
        self.pending_migration = self._migrate_from
        return p, d, now + lag


ROUTERS: Dict[str, type] = {}


def register_router(cls: type) -> type:
    """Add a Router class to the by-name registry used by
    :func:`make_router` and ``DeploymentSpec`` validation.  Usable as a
    decorator; returns the class.  Registering a duplicate name
    replaces the previous entry (latest wins), so downstream code can
    override a stock policy.
    """
    name = getattr(cls, "name", None)
    if not name or name == Router.name:
        raise ValueError(f"router class {cls!r} needs a distinct "
                         "class-level `name` to be registered")
    ROUTERS[name] = cls
    return cls


for _cls in (RoundRobinRouter, LeastLoadedRouter, JSEDRouter, PDRouter):
    register_router(_cls)


def make_router(name: str, **kw) -> Router:
    """Instantiate a registered router policy by name with kwargs —
    the constructor ``DeploymentSpec.router`` / ``router_kwargs``
    compile down to."""
    try:
        cls = ROUTERS[name]
    except KeyError:
        raise ValueError(f"unknown router {name!r}; "
                         f"pick from {sorted(ROUTERS)}") from None
    return cls(**kw)
