"""Workload-aware request routing across heterogeneous Tessera replicas.

Scoring policy (join-shortest-expected-delay, JSED)
---------------------------------------------------
Each replica group runs its own Plan over its own device set, so the
*same* request costs a different amount on different replicas — an
H100+RTX pair drains a long-prompt request far faster than an A100+L40s
pair.  The router therefore scores every candidate replica with the
cost model the planner itself optimizes:

    score(replica) = backlog(replica, now) + predicted_service(req)

  * ``backlog`` — seconds until the replica's most-loaded resource
    (compute server or ingress link) frees up: the queueing delay a new
    arrival would actually see,
  * ``predicted_service`` — the unqueued execution latency of *this*
    request on *that* replica, from the per-stage cost model with the
    request's prompt/output lengths scaled in.

and joins the minimum — an expected-completion-time rule.  With
homogeneous replicas and equal-sized requests it degenerates to
join-shortest-queue; with heterogeneous replicas it rate-matches load
to capability (fast groups get proportionally more and bigger
requests), which is what lets the workload-aware router beat
round-robin on heterogeneous mixes (benchmarks/cluster_scaling.py).

Decode-session affinity: multi-turn requests carrying a ``session`` id
re-join the replica that holds their KV/decode state unless its backlog
exceeds the best candidate's by ``affinity_break`` seconds — then the
session migrates (modeling a KV refetch as preferable to queueing).

Routers only read replica state; :func:`repro.core.simulator
.simulate_cluster` (or a real dispatch loop) owns the clock.
"""
from __future__ import annotations

from typing import Dict, Sequence

from repro.core.simulator import ClusterRequest, ReplicaModel


class Router:
    """Interface: pick a replica index for a request at time ``now``."""

    name = "base"

    def route(self, req: ClusterRequest,
              replicas: Sequence[ReplicaModel], now: float) -> int:
        raise NotImplementedError

    # simulate_cluster duck-types the router as a plain callable
    def __call__(self, req, replicas, now) -> int:
        return self.route(req, replicas, now)


class RoundRobinRouter(Router):
    """Workload-oblivious baseline: equal request counts per replica."""

    name = "round_robin"

    def __init__(self):
        self._next = 0

    def route(self, req, replicas, now) -> int:
        idx = self._next % len(replicas)
        self._next += 1
        return idx


class LeastLoadedRouter(Router):
    """Join-shortest-queue on backlog seconds; size- and
    speed-oblivious (does not model the request's own cost)."""

    name = "least_loaded"

    def route(self, req, replicas, now) -> int:
        return min(range(len(replicas)),
                   key=lambda i: (replicas[i].backlog(now), i))


class JSEDRouter(Router):
    """Join-shortest-expected-delay with decode-session affinity."""

    name = "jsed"

    def __init__(self, affinity_break: float = float("inf")):
        # Migrate a session when staying costs this many more seconds
        # of backlog than the best replica; inf = never migrate.
        self.affinity_break = affinity_break
        self._session_home: Dict[int, int] = {}

    def score(self, req: ClusterRequest, replica: ReplicaModel,
              now: float) -> float:
        return replica.backlog(now) + replica.predicted_service(req)

    def route(self, req, replicas, now) -> int:
        best = min(range(len(replicas)),
                   key=lambda i: (self.score(req, replicas[i], now), i))
        if req.session is not None:
            home = self._session_home.get(req.session)
            if home is not None:
                stay_cost = replicas[home].backlog(now)
                move_cost = replicas[best].backlog(now)
                if stay_cost - move_cost <= self.affinity_break:
                    return home
            self._session_home[req.session] = best
        return best


ROUTERS = {
    cls.name: cls
    for cls in (RoundRobinRouter, LeastLoadedRouter, JSEDRouter)
}


def make_router(name: str, **kw) -> Router:
    try:
        return ROUTERS[name](**kw)
    except KeyError:
        raise ValueError(f"unknown router {name!r}; "
                         f"pick from {sorted(ROUTERS)}") from None
