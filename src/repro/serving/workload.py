"""Open-loop trace generation for cluster-scale serving experiments.

Real serving traffic is open-loop (arrivals do not wait for service) and
bursty; the cluster benchmarks and tests drive the simulator with traces
from four arrival processes:

  * ``poisson``  — homogeneous Poisson at ``rate`` req/s,
  * ``bursty``   — 2-state MMPP: ON periods at ``burst_factor`` x the
    base rate alternating with quiet OFF periods (same long-run rate),
  * ``diurnal``  — sinusoidally modulated rate (a compressed day/night
    cycle), sampled by thinning against the peak rate,
  * ``chat``     — multi-turn sessions with accumulated context: each
    turn's prompt extends the conversation so far (the workload paged
    KV and session-cache hits are built for).

Request sizes come from a mixture of named request classes (chat,
summarization, generation) with lognormal prompt lengths and geometric
output lengths — heavy-tailed, as production traces are.  Requests can
continue an existing *session* (multi-turn chat): the router uses the
session id for decode/KV affinity.

Everything is driven by ``random.Random(seed)`` — traces are
deterministic and portable across runs and machines.

Generation is vectorized: each generator transplants its
``random.Random`` MT19937 state into a ``numpy.random.RandomState``
(the SAME generator, so the uniform stream is bit-identical) and
applies the arrival/length transforms to whole blocks.  Two numpy
caveats keep the sequences exactly equal to the historical per-request
``random`` calls (regression-tested in tests/test_workload_vec.py):

  * ``np.log``/``np.exp`` take SIMD paths that differ from libm in the
    last ulp on this numpy, so log/exp transforms go through
    ``math.log``/``math.exp`` element-wise (``_log_seq``/``_exp_seq``);
    ``sin``/``cos``/``sqrt``/``cumsum`` are bit-identical and stay
    vectorized,
  * ``random.gauss`` consumes two uniforms on every other call (the
    Box–Muller sine value is cached), so the length sampler indexes the
    uniform block in that 6-per-request-pair pattern.
"""
from __future__ import annotations

import dataclasses
import math
import random
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

_TWOPI = 2.0 * math.pi


class _UniformStream:
    """Bit-exact numpy view of a ``random.Random`` uniform stream.

    Transplants the Mersenne-Twister state, so ``take(n)`` returns
    exactly the floats ``n`` successive ``rng.random()`` calls would
    have produced (both generators derive doubles from the same 624-word
    state with the same 53-bit recipe).
    """

    def __init__(self, rng: random.Random):
        key = rng.getstate()[1]         # 624 words + position
        self._rs = np.random.RandomState()
        self._rs.set_state(("MT19937",
                            np.asarray(key[:-1], dtype=np.uint32),
                            key[-1], 0, 0.0))

    def take(self, n: int) -> np.ndarray:
        return self._rs.random_sample(n)


def _log_seq(x: np.ndarray) -> np.ndarray:
    """Element-wise ``math.log`` (libm, not numpy's SIMD variant)."""
    return np.fromiter(map(math.log, x.tolist()),
                       dtype=np.float64, count=len(x))


def _exp_seq(x: np.ndarray) -> np.ndarray:
    """Element-wise ``math.exp`` (libm, not numpy's SIMD variant)."""
    return np.fromiter(map(math.exp, x.tolist()),
                       dtype=np.float64, count=len(x))


def _exp_gaps(u: np.ndarray, rate: float) -> np.ndarray:
    """``rng.expovariate(rate)`` applied to a uniform block:
    ``-log(1 - u) / rate``, the exact CPython expression."""
    return np.negative(_log_seq(1.0 - u)) / rate


@dataclasses.dataclass(frozen=True)
class WorkloadRequest:
    rid: int
    arrival: float              # seconds since trace start
    prompt_tokens: int
    output_tokens: int
    session: Optional[int] = None   # multi-turn conversation id
    slo: Optional[float] = None     # completion deadline (s of latency)
    slo_ttft: Optional[float] = None    # first-token deadline (s)
    priority: int = 0           # brown-out shedding order (higher
    #                             survives longer; see router health)


@dataclasses.dataclass(frozen=True)
class RequestClass:
    """One mode of the length mixture."""
    name: str
    weight: float
    prompt_median: int          # lognormal median of prompt length
    prompt_sigma: float         # lognormal shape
    output_mean: int            # geometric mean of output length


# Default mixture, loosely shaped like public serving traces: mostly
# chat, some long-prompt summarization, some long-output generation.
DEFAULT_MIX: Tuple[RequestClass, ...] = (
    RequestClass("chat", 0.70, prompt_median=256, prompt_sigma=0.8,
                 output_mean=128),
    RequestClass("summarize", 0.15, prompt_median=2048, prompt_sigma=0.5,
                 output_mean=64),
    RequestClass("generate", 0.15, prompt_median=128, prompt_sigma=0.6,
                 output_mean=512),
)

_MAX_PROMPT = 16384
_MAX_OUTPUT = 4096


def _sample_lengths_block(rng: random.Random, n: int,
                          mix: Sequence[RequestClass]
                          ) -> Tuple[List[int], List[int]]:
    """Vectorized length sampler: (prompts, outputs) for ``n`` requests.

    Reproduces, bit-for-bit, ``n`` sequential draws of the historical
    per-request sampler (class pick, ``gauss`` lognormal prompt,
    geometric output).  ``rng.gauss`` consumes two uniforms on
    even-numbered calls and zero on odd ones (Box–Muller caches the sine
    value), so a request PAIR consumes six uniforms in the fixed order
    [class0, gauss_a, gauss_b, out0, class1, out1].
    """
    if n <= 0:
        return [], []
    pairs_full = n // 2             # pairs with an odd member present
    pairs = (n + 1) // 2            # even members (incl. trailing half)
    u = _UniformStream(rng).take(6 * pairs)
    base = 6 * np.arange(pairs)
    base_full = base[:pairs_full]

    # class pick: first class whose cumulative weight >= r
    acc: List[float] = []
    total = 0.0
    for c in mix:
        total += c.weight
        acc.append(total)
    c_u = np.empty(n)
    c_u[0::2] = u[base]
    c_u[1::2] = u[base_full + 4]
    r = c_u * sum(c.weight for c in mix)
    idx = np.minimum(np.searchsorted(np.asarray(acc), r, side="left"),
                     len(mix) - 1)

    # Box–Muller exactly as random.gauss: cos branch for even calls,
    # cached sin branch for odd calls
    x2pi = u[base + 1] * _TWOPI
    g2rad = np.sqrt(-2.0 * _log_seq(1.0 - u[base + 2]))
    z = np.empty(n)
    z[0::2] = np.cos(x2pi) * g2rad
    z[1::2] = (np.sin(x2pi) * g2rad)[:pairs_full]

    med = np.asarray([float(c.prompt_median) for c in mix])[idx]
    sig = np.asarray([c.prompt_sigma for c in mix])[idx]
    prompt = (med * _exp_seq(0.0 + z * sig)).astype(np.int64)
    np.clip(prompt, 1, _MAX_PROMPT, out=prompt)

    o_u = np.empty(n)
    o_u[0::2] = u[base + 3]
    o_u[1::2] = u[base_full + 5]
    om = np.asarray([float(c.output_mean) for c in mix])[idx]
    output = 1 + (np.negative(om)
                  * _log_seq(np.maximum(o_u, 1e-12))).astype(np.int64)
    np.clip(output, 1, _MAX_OUTPUT, out=output)
    return prompt.tolist(), output.tolist()


def _attach_sessions(rng: random.Random, n: int, follow_prob: float,
                     session_pool: int = 64) -> List[Optional[int]]:
    """With prob ``follow_prob`` a request continues a live session.

    ``session_pool`` bounds the working set of live sessions (the
    population a follow-up draws from); the default 64 preserves the
    historical uniform stream bit-for-bit.
    """
    # Stays scalar: rng.choice draws a data-dependent number of random
    # words (rejection sampling over the live-list length), so the
    # uniform stream cannot be pre-split; bound methods keep it cheap.
    sessions: List[Optional[int]] = []
    append = sessions.append
    rand, choice = rng.random, rng.choice
    live: List[int] = []
    next_sid = 0
    for _ in range(n):
        if live and rand() < follow_prob:
            append(choice(live))
        else:
            append(next_sid)
            live.append(next_sid)
            if len(live) > session_pool:    # bounded working set
                live.pop(0)
            next_sid += 1
    return sessions


def _finish(arrivals: List[float], seed: int,
            mix: Sequence[RequestClass],
            session_follow: float,
            session_pool: int = 64) -> List[WorkloadRequest]:
    rng = random.Random(f"{seed}:lengths")
    sessions = _attach_sessions(random.Random(f"{seed}:sessions"),
                                len(arrivals), session_follow,
                                session_pool)
    prompts, outputs = _sample_lengths_block(rng, len(arrivals), mix)
    return [WorkloadRequest(rid=i, arrival=t, prompt_tokens=p,
                            output_tokens=o, session=s)
            for i, (t, p, o, s) in enumerate(
                zip(sorted(arrivals), prompts, outputs, sessions))]


# --------------------------------------------------------------------- #
def poisson_trace(rate: float, num_requests: int, seed: int = 0,
                  mix: Sequence[RequestClass] = DEFAULT_MIX,
                  session_follow: float = 0.3,
                  session_pool: int = 64) -> List[WorkloadRequest]:
    u = _UniformStream(random.Random(f"{seed}:poisson")).take(num_requests)
    arrivals = np.cumsum(_exp_gaps(u, rate)).tolist()
    return _finish(arrivals, seed, mix, session_follow, session_pool)


def bursty_trace(rate: float, num_requests: int, seed: int = 0,
                 burst_factor: float = 6.0, on_fraction: float = 0.1,
                 period: float = 0.0,
                 mix: Sequence[RequestClass] = DEFAULT_MIX,
                 session_follow: float = 0.3,
                 session_pool: int = 64) -> List[WorkloadRequest]:
    """2-state MMPP with the same long-run rate as ``poisson_trace``.

    ON state: ``burst_factor * rate``; OFF state: the remainder so the
    time-average stays ``rate`` — which requires the ON state to carry
    less than the whole budget: ``burst_factor * on_fraction < 1``.
    Mean cycle length defaults to the time of ~20 requests.
    """
    assert burst_factor * on_fraction < 1.0, \
        "burst_factor * on_fraction must be < 1 to preserve the " \
        "long-run rate (the OFF-state rate would go negative)"
    stream = _UniformStream(random.Random(f"{seed}:bursty"))
    period = period or 20.0 / rate
    on_rate = burst_factor * rate
    off_rate = rate * (1.0 - burst_factor * on_fraction) \
        / (1.0 - on_fraction)
    # The state machine is inherently sequential (state flips depend on
    # prior draws), but the expensive part — libm log per draw — batches:
    # precompute -log(1-u) blocks in draw order; expovariate(lam) is
    # then one divide per draw, matching CPython's -log(1-u)/lam bits.
    block: List[float] = []
    k = 0

    def draw() -> float:
        nonlocal block, k
        if k == len(block):
            block = np.negative(_log_seq(1.0 - stream.take(8192))).tolist()
            k = 0
        e = block[k]
        k += 1
        return e

    # precomputed constants equal the per-iteration 1/mean expressions
    inv_on = 1.0 / (period * on_fraction)
    inv_off = 1.0 / (period * (1 - on_fraction))
    t, arrivals = 0.0, []
    on = True
    state_end = draw() / inv_on
    while len(arrivals) < num_requests:
        dt = draw() / (on_rate if on else off_rate)
        if t + dt >= state_end:         # state flips before next arrival
            t = state_end
            on = not on
            state_end = t + draw() / (inv_on if on else inv_off)
            continue
        t += dt
        arrivals.append(t)
    return _finish(arrivals, seed, mix, session_follow, session_pool)


def diurnal_trace(rate: float, num_requests: int, seed: int = 0,
                  period: float = 0.0, amplitude: float = 0.8,
                  mix: Sequence[RequestClass] = DEFAULT_MIX,
                  session_follow: float = 0.3,
                  session_pool: int = 64) -> List[WorkloadRequest]:
    """Rate ``rate * (1 + amplitude*sin(2 pi t / period))`` by thinning."""
    assert 0.0 <= amplitude < 1.0
    stream = _UniformStream(random.Random(f"{seed}:diurnal"))
    period = period or 50.0 / rate      # a few "days" per trace
    peak = rate * (1.0 + amplitude)
    # Thinning consumes exactly 2 uniforms per candidate (gap, accept),
    # so whole blocks of candidates vectorize; acceptance averages
    # 1/(1+amplitude), so ~1.3x oversampling usually lands in one block.
    chunk = max(1024, min(2 * num_requests, 1 << 20))
    t_prev = 0.0
    arrivals: List[float] = []
    while len(arrivals) < num_requests:
        u = stream.take(2 * chunk)
        gaps = _exp_gaps(u[0::2], peak)
        ts = np.cumsum(np.concatenate(([t_prev], gaps)))[1:]
        lam = rate * (1.0 + amplitude * np.sin(2 * math.pi * ts / period))
        arrivals.extend(ts[u[1::2] < lam / peak].tolist())
        t_prev = float(ts[-1])
    del arrivals[num_requests:]
    return _finish(arrivals, seed, mix, session_follow, session_pool)


def chat_trace(rate: float, num_requests: int, seed: int = 0,
               turns_mean: float = 4.0, think_mean: float = 2.0,
               first_prompt_mean: int = 192, new_tokens_mean: int = 96,
               output_mean: int = 96,
               max_context: int = 4096) -> List[WorkloadRequest]:
    """Chat-heavy multi-turn trace: every request belongs to a session.

    Sessions open as a Poisson process at ``rate / turns_mean``
    sessions/s (so the long-run REQUEST rate is ~``rate``); each runs
    a geometric number of turns (mean ``turns_mean``) separated by
    exponential think gaps.  Turn ``k``'s prompt is the accumulated
    conversation — ``prompt_{k-1} + output_{k-1} + new tokens`` — which
    is precisely the shape paged KV with session residency exploits: a
    follow-up landing on its resident group re-prefills only the NEW
    tokens, so decode-session affinity shows a measured win instead of
    a modeling assumption.  Deterministic in ``seed``.
    """
    assert turns_mean >= 1.0 and think_mean > 0.0
    rng = random.Random(f"{seed}:chat")
    stop = 1.0 / turns_mean             # geometric stop probability
    sess_rate = rate / turns_mean
    rows: List[Tuple[float, int, int, int]] = []
    t0, sid = 0.0, 0
    while len(rows) < num_requests:
        t0 += rng.expovariate(sess_rate)
        t, ctx = t0, 0
        while True:
            new = 1 + int(rng.expovariate(
                1.0 / (first_prompt_mean if ctx == 0
                       else new_tokens_mean)))
            out = 1 + int(rng.expovariate(1.0 / output_mean))
            out = min(out, _MAX_OUTPUT)
            prompt = min(ctx + new, max_context, _MAX_PROMPT)
            rows.append((t, prompt, out, sid))
            ctx = min(prompt + out, max_context)
            if rng.random() < stop or len(rows) >= 2 * num_requests:
                break
            t += rng.expovariate(1.0 / think_mean)
        sid += 1
    rows.sort(key=lambda r: (r[0], r[3]))
    del rows[num_requests:]
    return [WorkloadRequest(rid=i, arrival=t, prompt_tokens=p,
                            output_tokens=o, session=s)
            for i, (t, p, o, s) in enumerate(rows)]


TRACE_KINDS = {
    "poisson": poisson_trace,
    "bursty": bursty_trace,
    "diurnal": diurnal_trace,
    "chat": chat_trace,
}


def make_trace(kind: str, rate: float, num_requests: int, seed: int = 0,
               **kw) -> List[WorkloadRequest]:
    if rate <= 0.0:
        raise ValueError(f"arrival rate must be positive, got {rate}")
    try:
        gen = TRACE_KINDS[kind]
    except KeyError:
        raise ValueError(f"unknown trace kind {kind!r}; "
                         f"pick from {sorted(TRACE_KINDS)}") from None
    return gen(rate, num_requests, seed, **kw)


# --------------------------------------------------------------------- #
def assign_slos(trace: Sequence[WorkloadRequest], *,
                base: float = 0.0,
                per_output_token: float = 0.0,
                ttft: Optional[float] = None
                ) -> List[WorkloadRequest]:
    """Attach per-request SLOs.

    Completion deadline: ``base + per_output_token * output_tokens``
    seconds of end-to-end latency — size-proportional, as production
    SLOs are (a 2k-token generation is allowed more wall time than a
    1-token classification).  ``ttft`` adds a first-token deadline: the
    interactivity SLO that phase-split serving isolates from decode
    head-of-line blocking.  Routers with ``slo_shed`` use the deadlines
    for admission control, and results report goodput (completions
    within BOTH deadlines) next to raw throughput.
    """
    assert base > 0.0 or per_output_token > 0.0 or ttft, \
        "SLO must be positive"
    comp = None if base <= 0.0 and per_output_token <= 0.0 else True
    return [dataclasses.replace(
        r,
        slo=(base + per_output_token * r.output_tokens) if comp else None,
        slo_ttft=ttft)
        for r in trace]


# --------------------------------------------------------------------- #
def trace_stats(trace: Sequence[WorkloadRequest]) -> Dict[str, float]:
    """Summary used by tests and benchmark headers."""
    if not trace:
        return {"n": 0}
    gaps = [b.arrival - a.arrival for a, b in zip(trace, trace[1:])]
    mean_gap = sum(gaps) / max(len(gaps), 1)
    var = sum((g - mean_gap) ** 2 for g in gaps) / max(len(gaps) - 1, 1)
    return {
        "n": len(trace),
        "duration": trace[-1].arrival - trace[0].arrival,
        "rate": (len(trace) - 1) / max(trace[-1].arrival
                                       - trace[0].arrival, 1e-12),
        "cv_interarrival": math.sqrt(var) / max(mean_gap, 1e-12),
        "mean_prompt": sum(r.prompt_tokens for r in trace) / len(trace),
        "mean_output": sum(r.output_tokens for r in trace) / len(trace),
        "sessions": len({r.session for r in trace}),
    }
