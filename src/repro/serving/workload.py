"""Open-loop trace generation for cluster-scale serving experiments.

Real serving traffic is open-loop (arrivals do not wait for service) and
bursty; the cluster benchmarks and tests drive the simulator with traces
from three arrival processes:

  * ``poisson``  — homogeneous Poisson at ``rate`` req/s,
  * ``bursty``   — 2-state MMPP: ON periods at ``burst_factor`` x the
    base rate alternating with quiet OFF periods (same long-run rate),
  * ``diurnal``  — sinusoidally modulated rate (a compressed day/night
    cycle), sampled by thinning against the peak rate.

Request sizes come from a mixture of named request classes (chat,
summarization, generation) with lognormal prompt lengths and geometric
output lengths — heavy-tailed, as production traces are.  Requests can
continue an existing *session* (multi-turn chat): the router uses the
session id for decode/KV affinity.

Everything is driven by ``random.Random(seed)`` — traces are
deterministic and portable across runs and machines.
"""
from __future__ import annotations

import dataclasses
import math
import random
from typing import Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True)
class WorkloadRequest:
    rid: int
    arrival: float              # seconds since trace start
    prompt_tokens: int
    output_tokens: int
    session: Optional[int] = None   # multi-turn conversation id
    slo: Optional[float] = None     # completion deadline (s of latency)
    slo_ttft: Optional[float] = None    # first-token deadline (s)


@dataclasses.dataclass(frozen=True)
class RequestClass:
    """One mode of the length mixture."""
    name: str
    weight: float
    prompt_median: int          # lognormal median of prompt length
    prompt_sigma: float         # lognormal shape
    output_mean: int            # geometric mean of output length


# Default mixture, loosely shaped like public serving traces: mostly
# chat, some long-prompt summarization, some long-output generation.
DEFAULT_MIX: Tuple[RequestClass, ...] = (
    RequestClass("chat", 0.70, prompt_median=256, prompt_sigma=0.8,
                 output_mean=128),
    RequestClass("summarize", 0.15, prompt_median=2048, prompt_sigma=0.5,
                 output_mean=64),
    RequestClass("generate", 0.15, prompt_median=128, prompt_sigma=0.6,
                 output_mean=512),
)

_MAX_PROMPT = 16384
_MAX_OUTPUT = 4096


def _sample_lengths(rng: random.Random,
                    mix: Sequence[RequestClass]) -> Tuple[int, int]:
    r = rng.random() * sum(c.weight for c in mix)
    acc = 0.0
    cls = mix[-1]
    for c in mix:
        acc += c.weight
        if r <= acc:
            cls = c
            break
    prompt = int(cls.prompt_median * math.exp(
        rng.gauss(0.0, cls.prompt_sigma)))
    output = 1 + int(-cls.output_mean * math.log(max(rng.random(), 1e-12)))
    return (max(1, min(prompt, _MAX_PROMPT)),
            max(1, min(output, _MAX_OUTPUT)))


def _attach_sessions(rng: random.Random, n: int,
                     follow_prob: float) -> List[Optional[int]]:
    """With prob ``follow_prob`` a request continues a live session."""
    sessions: List[Optional[int]] = []
    live: List[int] = []
    next_sid = 0
    for _ in range(n):
        if live and rng.random() < follow_prob:
            sessions.append(rng.choice(live))
        else:
            sessions.append(next_sid)
            live.append(next_sid)
            if len(live) > 64:          # bounded working set of sessions
                live.pop(0)
            next_sid += 1
    return sessions


def _finish(arrivals: List[float], seed: int,
            mix: Sequence[RequestClass],
            session_follow: float) -> List[WorkloadRequest]:
    rng = random.Random(f"{seed}:lengths")
    sessions = _attach_sessions(random.Random(f"{seed}:sessions"),
                                len(arrivals), session_follow)
    out = []
    for i, t in enumerate(sorted(arrivals)):
        p, o = _sample_lengths(rng, mix)
        out.append(WorkloadRequest(rid=i, arrival=t, prompt_tokens=p,
                                   output_tokens=o, session=sessions[i]))
    return out


# --------------------------------------------------------------------- #
def poisson_trace(rate: float, num_requests: int, seed: int = 0,
                  mix: Sequence[RequestClass] = DEFAULT_MIX,
                  session_follow: float = 0.3) -> List[WorkloadRequest]:
    rng = random.Random(f"{seed}:poisson")
    t, arrivals = 0.0, []
    for _ in range(num_requests):
        t += rng.expovariate(rate)
        arrivals.append(t)
    return _finish(arrivals, seed, mix, session_follow)


def bursty_trace(rate: float, num_requests: int, seed: int = 0,
                 burst_factor: float = 6.0, on_fraction: float = 0.1,
                 period: float = 0.0,
                 mix: Sequence[RequestClass] = DEFAULT_MIX,
                 session_follow: float = 0.3) -> List[WorkloadRequest]:
    """2-state MMPP with the same long-run rate as ``poisson_trace``.

    ON state: ``burst_factor * rate``; OFF state: the remainder so the
    time-average stays ``rate`` — which requires the ON state to carry
    less than the whole budget: ``burst_factor * on_fraction < 1``.
    Mean cycle length defaults to the time of ~20 requests.
    """
    assert burst_factor * on_fraction < 1.0, \
        "burst_factor * on_fraction must be < 1 to preserve the " \
        "long-run rate (the OFF-state rate would go negative)"
    rng = random.Random(f"{seed}:bursty")
    period = period or 20.0 / rate
    on_rate = burst_factor * rate
    off_rate = rate * (1.0 - burst_factor * on_fraction) \
        / (1.0 - on_fraction)
    t, arrivals = 0.0, []
    on = True
    state_end = rng.expovariate(1.0 / (period * on_fraction))
    while len(arrivals) < num_requests:
        lam = on_rate if on else off_rate
        dt = rng.expovariate(lam)
        if t + dt >= state_end:         # state flips before next arrival
            t = state_end
            on = not on
            mean_len = period * (on_fraction if on else 1 - on_fraction)
            state_end = t + rng.expovariate(1.0 / mean_len)
            continue
        t += dt
        arrivals.append(t)
    return _finish(arrivals, seed, mix, session_follow)


def diurnal_trace(rate: float, num_requests: int, seed: int = 0,
                  period: float = 0.0, amplitude: float = 0.8,
                  mix: Sequence[RequestClass] = DEFAULT_MIX,
                  session_follow: float = 0.3) -> List[WorkloadRequest]:
    """Rate ``rate * (1 + amplitude*sin(2 pi t / period))`` by thinning."""
    assert 0.0 <= amplitude < 1.0
    rng = random.Random(f"{seed}:diurnal")
    period = period or 50.0 / rate      # a few "days" per trace
    peak = rate * (1.0 + amplitude)
    t, arrivals = 0.0, []
    while len(arrivals) < num_requests:
        t += rng.expovariate(peak)
        lam = rate * (1.0 + amplitude * math.sin(2 * math.pi * t / period))
        if rng.random() < lam / peak:
            arrivals.append(t)
    return _finish(arrivals, seed, mix, session_follow)


TRACE_KINDS = {
    "poisson": poisson_trace,
    "bursty": bursty_trace,
    "diurnal": diurnal_trace,
}


def make_trace(kind: str, rate: float, num_requests: int, seed: int = 0,
               **kw) -> List[WorkloadRequest]:
    if rate <= 0.0:
        raise ValueError(f"arrival rate must be positive, got {rate}")
    try:
        gen = TRACE_KINDS[kind]
    except KeyError:
        raise ValueError(f"unknown trace kind {kind!r}; "
                         f"pick from {sorted(TRACE_KINDS)}") from None
    return gen(rate, num_requests, seed, **kw)


# --------------------------------------------------------------------- #
def assign_slos(trace: Sequence[WorkloadRequest], *,
                base: float = 0.0,
                per_output_token: float = 0.0,
                ttft: Optional[float] = None
                ) -> List[WorkloadRequest]:
    """Attach per-request SLOs.

    Completion deadline: ``base + per_output_token * output_tokens``
    seconds of end-to-end latency — size-proportional, as production
    SLOs are (a 2k-token generation is allowed more wall time than a
    1-token classification).  ``ttft`` adds a first-token deadline: the
    interactivity SLO that phase-split serving isolates from decode
    head-of-line blocking.  Routers with ``slo_shed`` use the deadlines
    for admission control, and results report goodput (completions
    within BOTH deadlines) next to raw throughput.
    """
    assert base > 0.0 or per_output_token > 0.0 or ttft, \
        "SLO must be positive"
    comp = None if base <= 0.0 and per_output_token <= 0.0 else True
    return [dataclasses.replace(
        r,
        slo=(base + per_output_token * r.output_tokens) if comp else None,
        slo_ttft=ttft)
        for r in trace]


# --------------------------------------------------------------------- #
def trace_stats(trace: Sequence[WorkloadRequest]) -> Dict[str, float]:
    """Summary used by tests and benchmark headers."""
    if not trace:
        return {"n": 0}
    gaps = [b.arrival - a.arrival for a, b in zip(trace, trace[1:])]
    mean_gap = sum(gaps) / max(len(gaps), 1)
    var = sum((g - mean_gap) ** 2 for g in gaps) / max(len(gaps) - 1, 1)
    return {
        "n": len(trace),
        "duration": trace[-1].arrival - trace[0].arrival,
        "rate": (len(trace) - 1) / max(trace[-1].arrival
                                       - trace[0].arrival, 1e-12),
        "cv_interarrival": math.sqrt(var) / max(mean_gap, 1e-12),
        "mean_prompt": sum(r.prompt_tokens for r in trace) / len(trace),
        "mean_output": sum(r.output_tokens for r in trace) / len(trace),
        "sessions": len({r.session for r in trace}),
    }
