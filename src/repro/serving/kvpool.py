"""Paged KV residency and the unified session-state API.

Two pieces, one file:

* **Paged residency** (`BlockPool`, `PagedKvCache`): a shared
  (layer, block) pool replaces "one session == one max_len slot" as the
  engine's memory architecture.  Every admitted session reserves
  ``ceil(capacity_tokens / block_tokens)`` pool blocks (plus a fixed
  block cost for recurrent / ring-buffer state that cannot be
  token-paged), so admission is gated by free-*block* pressure, not
  free slots.  Sessions time-slice through the small dense decode batch
  ("park" packs a slot's exported state into its pool blocks;
  "activate" gathers it back), priority preemption parks the
  lowest-priority active session, and a hierarchical tier spills idle
  parked sessions HBM -> host with LRU eviction (peer prefetch pulls a
  session straight off another engine).  Park -> activate round-trips
  through ``export_kv``/``import_kv`` with no arithmetic, so resumed
  greedy decode is bit-identical to never having been preempted.

* **Session API** (`KvSlice`, `SessionState`, `SessionManager`): one
  coherent surface over what used to be ten KV/session movers.
  ``engine.sessions`` exposes ``prefill`` / ``stream`` / ``restore`` /
  ``receive`` / ``checkpoint`` / ``migrate`` / ``prefetch``; the legacy
  engine methods (``prefill_handoff{,_stream}``,
  ``admit_handoff{,_stream}``, ``export_sessions`` /
  ``import_session``) remain as thin deprecated shims that delegate
  here and translate to the old wire dicts — bit-identical tokens,
  same error messages, same TTFT accounting.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Any, Dict, Iterator, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.models import layers as LY

__all__ = ["KvSlice", "SessionState", "BlockPool", "PagedKvCache",
           "SessionManager", "ShardChecksumError", "kv_checksum"]


class ShardChecksumError(RuntimeError):
    """A streamed shard arrived corrupted (its ``checksum`` does not
    match its state bytes).  :meth:`SessionManager.receive` raises this
    AFTER rolling the reserved slot/blocks back, so the caller can fall
    back to re-prefilling on the receiving engine."""


def kv_checksum(state: Any) -> int:
    """crc32 over the raw bytes of every leaf of a state pytree (leaf
    order is the pytree's canonical flatten order, so producer and
    consumer agree)."""
    crc = 0
    for leaf in jax.tree_util.tree_leaves(state):
        crc = zlib.crc32(
            np.ascontiguousarray(jax.device_get(leaf)).tobytes(), crc)
    return crc


# ===================================================================== #
# Payload dataclasses: the session-state wire format
# ===================================================================== #
@dataclasses.dataclass(frozen=True)
class KvSlice:
    """One streamed shard of a session's state: a (component, layer,
    token-range) slice of the cache pytree plus its wire size.  The
    unit yielded by :meth:`SessionManager.stream` and consumed by
    :meth:`SessionManager.receive`.  ``checksum`` (opt-in via
    ``stream(checksum=True)``) carries the producer-side
    :func:`kv_checksum`; :meth:`SessionManager.receive` verifies it on
    arrival and rejects the stream on mismatch."""
    rid: int
    component: str                      # "kv" / "rwkv" / "mamba" / ...
    layer: int
    t0: Optional[int] = None            # token window [t0, t1) for "kv"
    t1: Optional[int] = None
    state: Any = None                   # batch-1 layer-1 pytree
    nbytes: int = 0
    checksum: Optional[int] = None      # producer-side kv_checksum
    # fabric priority class (serving.fabric.URGENT / .BULK): decode-
    # blocking handoffs travel URGENT; checkpoint/migration shards
    # travel BULK.  Defaults URGENT so pre-fabric producers and the
    # legacy wire format stay unchanged.
    klass: int = 0

    def verify(self) -> bool:
        """True when no checksum travelled or it matches the state."""
        return (self.checksum is None
                or self.checksum == kv_checksum(self.state))

    def to_legacy(self) -> Dict[str, Any]:
        """The pre-facade stream-shard dict."""
        return {"rid": self.rid, "key": self.component,
                "layer": self.layer, "t0": self.t0, "t1": self.t1,
                "state": self.state, "bytes": self.nbytes}

    @classmethod
    def from_legacy(cls, item: Dict[str, Any]) -> "KvSlice":
        return cls(rid=item["rid"], component=item["key"],
                   layer=item["layer"], t0=item.get("t0"),
                   t1=item.get("t1"), state=item["state"],
                   nbytes=item.get("bytes", 0))


@dataclasses.dataclass
class SessionState:
    """A session's portable decode state: the exported KV / recurrent
    pytree plus the decode cursor.  ``first_token_pending`` encodes the
    one behavioural difference between the old admit paths: True means
    the first token has not streamed to the client yet, so
    :meth:`SessionManager.restore` stamps TTFT on arrival (the old
    ``admit_handoff``); False means the session already streamed
    tokens elsewhere and migration must not touch the client's clock
    (the old ``import_session``)."""
    rid: int
    state: Any                          # cache pytree; None when done
    last_tok: int
    pos: int
    budget: int                         # decode tokens remaining
    nbytes: int                         # wire size of ``state``
    done: bool = False
    first_token_pending: bool = True
    priority: int = 0

    def to_legacy(self, header: bool = False) -> Dict[str, Any]:
        """The pre-facade handoff dict (``header=True`` marks the
        final item of a shard stream)."""
        d = {"rid": self.rid, "state": self.state,
             "last_tok": self.last_tok, "pos": self.pos,
             "budget": self.budget, "kv_bytes": self.nbytes,
             "done": self.done}
        if header:
            d["header"] = True
        return d

    @classmethod
    def from_legacy(cls, h: Dict[str, Any],
                    first_token_pending: bool = True) -> "SessionState":
        return cls(rid=h["rid"], state=h["state"],
                   last_tok=h["last_tok"], pos=h["pos"],
                   budget=h["budget"], nbytes=h["kv_bytes"],
                   done=h["done"],
                   first_token_pending=first_token_pending)


# ===================================================================== #
# Block pool allocator
# ===================================================================== #
class BlockPool:
    """Free-list allocator over a fixed set of pool block ids.

    Invariants (property-tested): a block is never handed out twice,
    and ``free + allocated == n_blocks`` after any interleaving of
    alloc / release."""

    def __init__(self, n_blocks: int):
        assert n_blocks >= 1, "pool needs at least one block"
        self.n_blocks = n_blocks
        self._free: List[int] = list(range(n_blocks - 1, -1, -1))
        self._owner: Dict[int, int] = {}        # block id -> rid

    @property
    def free(self) -> int:
        return len(self._free)

    @property
    def allocated(self) -> int:
        return len(self._owner)

    def alloc(self, rid: int, n: int) -> List[int]:
        if n > len(self._free):
            raise MemoryError(
                f"kv pool exhausted: need {n} blocks, {len(self._free)}"
                f" free of {self.n_blocks}")
        ids = [self._free.pop() for _ in range(n)]
        for b in ids:
            assert b not in self._owner, "double allocation"
            self._owner[b] = rid
        return ids

    def release(self, ids: List[int]) -> None:
        for b in ids:
            assert b in self._owner, "freeing an unowned block"
            del self._owner[b]
            self._free.append(b)

    def check(self) -> bool:
        assert self.free + self.allocated == self.n_blocks, \
            "block accounting broken"
        assert len(set(self._free)) == self.free, "free-list duplicate"
        assert not (set(self._free) & set(self._owner)), \
            "block both free and owned"
        return True


# ===================================================================== #
# Paged residency: block tables, tiers, park/activate
# ===================================================================== #
@dataclasses.dataclass
class _Resident:
    """One session's residency record (active in a slot, parked in
    HBM pool blocks, or spilled to host)."""
    req: Any
    block_ids: List[int]
    capacity: int                       # reserved token capacity
    priority: int = 0
    tier: str = "active"                # "active" | "hbm" | "host"
    payload: Any = None                 # non-token-paged components
    host: Any = None                    # host copy when tier == "host"
    last_tok: int = 0
    pos: int = 0
    budget: int = 0
    seq: int = 0                        # FIFO order for scheduling
    last_use: float = 0.0               # LRU key for spill


class PagedKvCache:
    """The residency layer of a paged engine: a shared (layer, block)
    attention-KV pool plus per-session block tables, with a host spill
    tier below it.

    The dense per-slot cache stays the engine's active-decode working
    set (the jitted hot loop is untouched); this class owns where
    *resident-but-not-decoding* state lives and how many blocks every
    session — active or parked — has reserved.
    """

    def __init__(self, cfg, pool_blocks: int, block_tokens: int,
                 max_len: int):
        assert pool_blocks >= 1 and block_tokens >= 1
        assert max_len % block_tokens == 0, \
            "max_len must be a multiple of kv_block_tokens"
        self.cfg = cfg
        self.block_tokens = block_tokens
        self.max_len = max_len
        self.pool = BlockPool(pool_blocks)
        self.resident: Dict[int, _Resident] = {}
        self._seq = 0
        self.spills = 0
        self.prefetches = 0
        self.preemptions = 0

        probe = M.init_cache(cfg, 1, max_len)
        counts = M.cache_layer_counts(probe)
        # attention KV is token-paged only when the time axis is a real
        # prefix (ring-buffer SWA slot layout depends on absolute
        # positions, so the whole ring travels as fixed payload)
        self.token_paged = ("kv" in counts
                            and cfg.sliding_window is None)
        n_kv = counts.get("kv", cfg.num_layers)
        self.block_bytes = M.kv_block_bytes(cfg, block_tokens,
                                            layers=n_kv)
        if self.token_paged:
            self.arrays = LY.make_kv_block_pool(
                cfg, pool_blocks, block_tokens, layers=n_kv)
            fixed = sum(
                leaf.size * leaf.dtype.itemsize
                for key, val in probe.items() if key != "kv"
                for leaf in jax.tree_util.tree_leaves(val))
        else:
            self.arrays = None
            fixed = sum(leaf.size * leaf.dtype.itemsize
                        for leaf in jax.tree_util.tree_leaves(probe))
        if self.block_bytes == 0:
            # pure-recurrent family (no attention KV anywhere): one
            # session's fixed-size state is the natural block unit
            self.block_bytes = max(fixed, 1)
        # fixed per-session block cost for state that cannot be paged
        self.fixed_blocks = -(-fixed // self.block_bytes) if fixed else 0
        del probe

    # ---------------------------------------------------------------- #
    # Accounting
    # ---------------------------------------------------------------- #
    def blocks_for(self, tokens: int) -> int:
        """Reserved blocks for a session of ``tokens`` capacity."""
        paged = -(-tokens // self.block_tokens) if self.token_paged \
            else 0
        return max(paged + self.fixed_blocks, 1)

    def util(self) -> float:
        return self.pool.allocated / self.pool.n_blocks

    def holds(self, rid: int) -> bool:
        return rid in self.resident

    def parked(self) -> List[int]:
        """Parked session rids in FIFO (park-order) sequence."""
        return sorted((r for r in self.resident
                       if self.resident[r].tier != "active"),
                      key=lambda r: self.resident[r].seq)

    # ---------------------------------------------------------------- #
    # Admission / release
    # ---------------------------------------------------------------- #
    def reserve(self, req: Any, capacity: int, *,
                spill: bool = True) -> bool:
        """Reserve blocks for a session of ``capacity`` tokens,
        spilling idle parked sessions (LRU) to host under pressure.
        Returns False when the pool cannot fit it even after spilling.
        """
        capacity = min(capacity, self.max_len)
        need = self.blocks_for(capacity)
        while self.pool.free < need and spill and self.spill_lru():
            pass
        if self.pool.free < need:
            return False
        ids = self.pool.alloc(req.rid, need)
        self._seq += 1
        self.resident[req.rid] = _Resident(
            req=req, block_ids=ids, capacity=capacity,
            priority=getattr(req, "priority", 0), seq=self._seq)
        return True

    def release(self, rid: int) -> None:
        ent = self.resident.pop(rid, None)
        if ent is not None and ent.block_ids:
            self.pool.release(ent.block_ids)

    # ---------------------------------------------------------------- #
    # Park / activate: the slot <-> pool data path
    # ---------------------------------------------------------------- #
    def park(self, rid: int, state: Any, last_tok: int, pos: int,
             budget: int, now: float = 0.0) -> None:
        """Pack an active session's exported state into its reserved
        pool blocks (one scatter for the paged KV; recurrent / ring
        components ride along as fixed payload)."""
        ent = self.resident[rid]
        assert ent.tier == "active", "parking a non-active session"
        if self.token_paged and "kv" in state:
            nb = -(-max(pos, 1) // self.block_tokens)
            assert nb * self.block_tokens <= \
                len(ent.block_ids) * self.block_tokens
            self.arrays = M.pack_kv_blocks(
                self.arrays, state["kv"], ent.block_ids[:nb])
            ent.payload = {k: v for k, v in state.items() if k != "kv"}
        else:
            ent.payload = state
        ent.last_tok, ent.pos, ent.budget = last_tok, pos, budget
        ent.tier = "hbm"
        ent.last_use = now
        self._seq += 1
        ent.seq = self._seq

    def activate(self, rid: int,
                 now: float = 0.0) -> Tuple[Any, int, int, int]:
        """Reassemble a parked session's state (prefetching from host
        if it was spilled) and mark it active.  Returns
        ``(state, last_tok, pos, budget)`` — exactly the payload
        :func:`repro.models.model.import_kv` installs."""
        ent = self.resident[rid]
        assert ent.tier != "active", "session already active"
        if ent.tier == "host":
            self._prefetch(ent)
        if self.token_paged and self.arrays is not None \
                and ent.payload is not None and ent.pos > 0 \
                and "kv" not in ent.payload:
            nb = -(-ent.pos // self.block_tokens)
            kv = M.gather_kv_blocks(self.arrays, ent.block_ids[:nb],
                                    ent.pos)
            state = dict(ent.payload)
            state["kv"] = kv
        else:
            state = ent.payload
        ent.payload = None
        ent.tier = "active"
        ent.last_use = now
        return state, ent.last_tok, ent.pos, ent.budget

    # ---------------------------------------------------------------- #
    # Hierarchical tier: HBM -> host spill, host -> HBM prefetch
    # ---------------------------------------------------------------- #
    def spill(self, rid: int) -> None:
        """Evict a parked session's blocks to host memory (the full
        assembled state moves; the HBM blocks are freed)."""
        ent = self.resident[rid]
        assert ent.tier == "hbm", "can only spill a parked session"
        if self.token_paged and ent.pos > 0 and ent.payload is not None:
            nb = -(-ent.pos // self.block_tokens)
            state = dict(ent.payload)
            state["kv"] = M.gather_kv_blocks(
                self.arrays, ent.block_ids[:nb], ent.pos)
        else:
            state = ent.payload
        ent.host = jax.device_get(state)
        ent.payload = None
        self.pool.release(ent.block_ids)
        ent.block_ids = []
        ent.tier = "host"
        self.spills += 1

    def _prefetch(self, ent: _Resident) -> None:
        """Bring a host-spilled session back into HBM pool blocks."""
        need = self.blocks_for(ent.capacity)
        while self.pool.free < need and self.spill_lru(
                exclude=ent.req.rid):
            pass
        ent.block_ids = self.pool.alloc(ent.req.rid, need)
        state = jax.tree_util.tree_map(jnp.asarray, ent.host)
        ent.host = None
        if self.token_paged and "kv" in state and ent.pos > 0:
            nb = -(-ent.pos // self.block_tokens)
            self.arrays = M.pack_kv_blocks(
                self.arrays, state["kv"], ent.block_ids[:nb])
            ent.payload = {k: v for k, v in state.items() if k != "kv"}
        else:
            ent.payload = state
        ent.tier = "hbm"
        self.prefetches += 1

    def spill_lru(self, exclude: Optional[int] = None) -> bool:
        """Spill the least-recently-used HBM-parked session.  Returns
        False when nothing is spillable (all sessions active or
        already on host)."""
        cands = [(ent.last_use, ent.seq, rid)
                 for rid, ent in self.resident.items()
                 if ent.tier == "hbm" and rid != exclude]
        if not cands:
            return False
        self.spill(min(cands)[2])
        return True

    # ---------------------------------------------------------------- #
    def assemble(self, rid: int) -> Tuple[Any, int, int, int]:
        """Reassemble a parked session's full state WITHOUT activating
        it (the checkpoint/drain path) and release its blocks."""
        ent = self.resident[rid]
        assert ent.tier != "active", "active sessions export via slots"
        if ent.tier == "host":
            state = jax.tree_util.tree_map(jnp.asarray, ent.host)
        elif self.token_paged and ent.payload is not None \
                and ent.pos > 0 and "kv" not in ent.payload:
            nb = -(-ent.pos // self.block_tokens)
            state = dict(ent.payload)
            state["kv"] = M.gather_kv_blocks(
                self.arrays, ent.block_ids[:nb], ent.pos)
        else:
            state = ent.payload
        out = (state, ent.last_tok, ent.pos, ent.budget)
        self.release(rid)
        return out

    def peek(self, rid: int) -> Tuple[Any, int, int, int]:
        """Reassemble a parked session's state WITHOUT releasing its
        blocks or changing its tier — the non-destructive read the
        periodic checkpoint store polls (see
        :meth:`SessionManager.snapshot`)."""
        ent = self.resident[rid]
        assert ent.tier != "active", "active sessions export via slots"
        if ent.tier == "host":
            state = jax.tree_util.tree_map(jnp.asarray, ent.host)
        elif self.token_paged and ent.payload is not None \
                and ent.pos > 0 and "kv" not in ent.payload:
            nb = -(-ent.pos // self.block_tokens)
            state = dict(ent.payload)
            state["kv"] = M.gather_kv_blocks(
                self.arrays, ent.block_ids[:nb], ent.pos)
        else:
            state = ent.payload
        return state, ent.last_tok, ent.pos, ent.budget


# ===================================================================== #
# SessionManager: the one session-state API
# ===================================================================== #
class SessionManager:
    """``engine.sessions`` — the unified surface over prefill handoff,
    streamed shard handoff, live migration, and peer prefetch.

    The legacy engine methods are thin shims over these six verbs:

    ======================  =========================================
    legacy                  facade
    ======================  =========================================
    prefill_handoff         ``prefill(req).to_legacy()``
    prefill_handoff_stream  ``stream(req)`` (KvSlice / SessionState)
    admit_handoff           ``restore(req, st)`` (first token pending)
    admit_handoff_stream    ``receive(req, slices)``
    export_sessions         ``checkpoint()``
    import_session          ``restore(req, st)`` (token not pending)
    ======================  =========================================

    plus ``migrate(peer)`` (checkpoint -> peer.restore, loss-free),
    ``prefetch(rid, peer)`` (pull ONE session off a peer engine — the
    top of the HBM -> host -> peer cache hierarchy), and the
    fault-tolerance pair ``snapshot()`` (non-destructive periodic
    checkpoint read) / ``crash()`` (lose everything, state
    unexported).
    """

    def __init__(self, engine):
        self.eng = engine

    # ---------------------------------------------------------------- #
    # Producer side: prefill on this engine, state leaves it
    # ---------------------------------------------------------------- #
    def prefill(self, req, now: Optional[float] = None) -> SessionState:
        """Run ``req``'s prompt in a private batch-1 cache (no decode
        slot consumed) and package the resulting state + cursor.  A
        request that finishes AT prefill is finalized here and returns
        ``done=True`` with no state.  TTFT is NOT stamped for live
        sessions — it belongs to the engine that streams the first
        token (``restore`` with ``first_token_pending=True``)."""
        eng = self.eng
        from repro.serving.engine import _PAD_SAFE_FAMILIES
        assert len(req.prompt) < eng.max_len, "prompt exceeds max_len"
        plen = len(req.prompt)
        if eng.cfg.family in _PAD_SAFE_FAMILIES:
            S = min(-(-plen // 8) * 8, eng.max_len - 1)
        else:
            S = plen
        toks = np.zeros((1, S), np.int32)
        toks[0, :plen] = req.prompt
        cache1 = M.init_cache(eng.cfg, 1, eng.max_len)
        if eng._prefill_custom is not None:
            logits, cache1 = eng._prefill_custom(
                eng.params, cache1,
                jnp.asarray(toks[:, :plen], jnp.int32))
        else:
            logits, cache1 = eng._prefill(
                cache1, jnp.asarray(toks, jnp.int32),
                jnp.asarray([plen - 1], jnp.int32))
        jax.block_until_ready(logits)
        t_ready = eng._now(now)
        first = int(eng._sample_host(logits)[0])
        eng.stats.prefill_batches += 1
        req.output.append(first)
        live = req.max_new_tokens > 1 and not (
            eng.eos_id is not None and first == eng.eos_id)
        if not live:        # done at prefill: nothing to hand off
            req.ttft = t_ready
            eng._finalize(req, t_ready)
            return SessionState(
                rid=req.rid, state=None, last_tok=first, pos=plen,
                budget=0, nbytes=0, done=True,
                first_token_pending=False,
                priority=getattr(req, "priority", 0))
        state = M.export_kv(eng.cfg, cache1, 0, plen)
        return SessionState(
            rid=req.rid, state=state, last_tok=first, pos=plen,
            budget=req.max_new_tokens - 1,
            nbytes=M.kv_state_bytes(state), done=False,
            first_token_pending=True,
            priority=getattr(req, "priority", 0))

    def stream(self, req, now: Optional[float] = None,
               chunk_size: Optional[int] = None,
               checksum: bool = False,
               klass: int = 0) -> Iterator[Any]:
        """Pipelined :meth:`prefill`: yield :class:`KvSlice` shards
        the moment each (layer, chunk) is computed, then the
        :class:`SessionState` cursor as the FINAL item (its ``nbytes``
        is the total shard bytes already streamed; ``state`` is None).
        Consuming the generator drives the producer's prefill chunks,
        so a :meth:`receive` on a peer overlaps transfer with the
        remaining prefill compute.  ``checksum=True`` stamps each
        shard with :func:`kv_checksum` (a host-side read per shard —
        off by default; the chaos-injection path turns it on) so the
        receiver can detect in-flight corruption.  ``klass`` stamps
        every shard with a fabric priority class (0 = URGENT decode-
        blocking, 1 = BULK background; see serving.fabric) so a
        transfer scheduler between producer and consumer can order
        competing streams."""
        eng = self.eng
        from repro.serving.engine import _PAD_SAFE_FAMILIES
        assert len(req.prompt) < eng.max_len, "prompt exceeds max_len"
        plen = len(req.prompt)
        C = chunk_size or eng.prefill_chunk or plen
        cache1 = M.init_cache(eng.cfg, 1, eng.max_len)
        sent = 0

        def shard_item(key, layer, t0=None, t1=None):
            shard = M.export_kv_shard(eng.cfg, cache1, 0, key, layer,
                                      t0, t1)
            return KvSlice(rid=req.rid, component=key, layer=layer,
                           t0=t0, t1=t1, state=shard,
                           nbytes=M.kv_state_bytes(shard),
                           checksum=(kv_checksum(shard) if checksum
                                     else None),
                           klass=klass)

        if (eng._prefill_custom is None
                and eng.cfg.sliding_window is None and C < plen):
            toks = np.asarray(req.prompt, np.int32).reshape(1, plen)
            n_kv = M.cache_layer_counts(cache1).get("kv", 0)
            logits = None
            for t0, t1, logits, cache1 in M.iter_prefill_chunks(
                    eng.params, eng.cfg, toks, cache1, chunk_size=C,
                    prefill_call=eng._chunk_call):
                # this chunk's K/V planes are final for every layer
                # the moment the chunk completes: stream them now
                for layer in range(n_kv):
                    item = shard_item("kv", layer, t0, t1)
                    sent += item.nbytes
                    yield item
            stream_kv_tail = False
        else:
            # serial fallback (ring-buffer SWA / injected prefill /
            # single-chunk prompt): same bucketing as prefill()
            if eng.cfg.family in _PAD_SAFE_FAMILIES:
                S = min(-(-plen // 8) * 8, eng.max_len - 1)
            else:
                S = plen
            toks = np.zeros((1, S), np.int32)
            toks[0, :plen] = req.prompt
            if eng._prefill_custom is not None:
                logits, cache1 = eng._prefill_custom(
                    eng.params, cache1,
                    jnp.asarray(toks[:, :plen], jnp.int32))
            else:
                logits, cache1 = eng._prefill(
                    cache1, jnp.asarray(toks, jnp.int32),
                    jnp.asarray([plen - 1], jnp.int32))
            stream_kv_tail = True

        for key, n_layers in M.cache_layer_counts(cache1).items():
            if key == "kv" and not stream_kv_tail:
                continue        # already streamed per chunk above
            for layer in range(n_layers):
                if key == "kv" and eng.cfg.sliding_window is None:
                    item = shard_item(key, layer, 0, plen)
                else:           # recurrent state / whole SWA ring
                    item = shard_item(key, layer)
                sent += item.nbytes
                yield item

        jax.block_until_ready(logits)
        t_ready = eng._now(now)
        first = int(eng._sample_host(logits)[0])
        eng.stats.prefill_batches += 1
        req.output.append(first)
        live = req.max_new_tokens > 1 and not (
            eng.eos_id is not None and first == eng.eos_id)
        if not live:            # done at prefill: producer finalizes
            req.ttft = t_ready
            eng._finalize(req, t_ready)
            yield SessionState(
                rid=req.rid, state=None, last_tok=first, pos=plen,
                budget=0, nbytes=sent, done=True,
                first_token_pending=False,
                priority=getattr(req, "priority", 0))
            return
        yield SessionState(
            rid=req.rid, state=None, last_tok=first, pos=plen,
            budget=req.max_new_tokens - 1, nbytes=sent, done=False,
            first_token_pending=True,
            priority=getattr(req, "priority", 0))

    # ---------------------------------------------------------------- #
    # Consumer side: state lands on this engine, decode continues
    # ---------------------------------------------------------------- #
    def restore(self, req, st: SessionState,
                now: Optional[float] = None) -> bool:
        """Install a session's state and continue decoding here.
        Stamps TTFT iff ``st.first_token_pending`` (handoff admission:
        the first token streams only once the state lands); a migrated
        session keeps its original clock.  Returns False when no slot
        is free or (paged engines) the pool cannot fit the session
        even after spilling — retry after draining."""
        eng = self.eng
        if st.done:
            if st.first_token_pending:
                raise ValueError(
                    f"request {st.rid} finished at prefill; "
                    "there is no decode to admit")
            raise AssertionError("finished session cannot migrate")
        assert st.pos < eng.max_len, \
            "imported state exceeds this engine's max_len"
        eng.sync(now)
        free = [s for s in range(eng.slots) if eng.active[s] is None]
        if not free:
            return False
        slot = free[0]
        if eng._paged is not None and not eng._paged.holds(st.rid):
            cap = min(st.pos + st.budget + 1, eng.max_len)
            if not eng._paged.reserve(req, cap, spill=eng.spill):
                return False
        eng.cache = M.import_kv(eng.cfg, eng.cache, slot, st.state)
        if st.first_token_pending:
            req.ttft = eng._now(now)
        eng.pos = eng.pos.at[slot].set(st.pos)
        eng.last_tok = eng.last_tok.at[slot].set(st.last_tok)
        eng.budget = eng.budget.at[slot].set(st.budget)
        eng.active_mask = eng.active_mask.at[slot].set(True)
        eng.active[slot] = req
        eng._ran[slot] = 0
        eng._recompute_remaining()
        return True

    def receive(self, req, slices,
                now: Optional[float] = None) -> bool:
        """Consume a :meth:`stream` (or legacy shard dicts): reserve a
        slot, install every shard eagerly as it arrives, and start
        decoding the moment the final :class:`SessionState` lands.
        TTFT is stamped at that moment.  Returns False — without
        consuming anything — when no slot (or, paged, no pool room)
        is free.  A checksummed shard that fails :meth:`KvSlice.verify`
        raises :class:`ShardChecksumError` AFTER the reserved
        slot/blocks are rolled back, so the caller can re-prefill
        locally instead."""
        eng = self.eng
        assert len(req.prompt) < eng.max_len, \
            "handoff prompt exceeds this engine's max_len"
        eng.sync(now)
        free = [s for s in range(eng.slots) if eng.active[s] is None]
        if not free:
            return False
        slot = free[0]
        reserved = False
        if eng._paged is not None and not eng._paged.holds(req.rid):
            cap = min(len(req.prompt) + req.max_new_tokens,
                      eng.max_len)
            if not eng._paged.reserve(req, cap, spill=eng.spill):
                return False
            reserved = True
        # host-side reservation only: active_mask stays False, so the
        # decode loop masks the slot until the cursor activates it
        eng.active[slot] = req
        header: Optional[SessionState] = None
        # same-window attention-KV shards coalesce into ONE cache
        # update per chunk; stale leftovers in a released slot are
        # harmless — causal masking hides them and the next admission
        # overwrites them
        pend: List = []
        pend_win = None

        def flush():
            nonlocal pend, pend_win
            if pend:
                eng.cache = M.import_kv_window(
                    eng.cfg, eng.cache, slot, pend[0][0],
                    [s for _, s in pend], pend_win[0])
                pend, pend_win = [], None

        try:
            for raw in slices:
                if isinstance(raw, SessionState):
                    header = raw
                    break
                if isinstance(raw, KvSlice):
                    if not raw.verify():
                        raise ShardChecksumError(
                            f"rid {raw.rid}: shard {raw.component}/"
                            f"{raw.layer} arrived corrupted")
                    item = raw.to_legacy()
                else:
                    item = raw
                if item.get("header"):
                    header = SessionState.from_legacy(item)
                    break
                win = (item.get("t0") or 0, item.get("t1"))
                if (item["key"] == "kv"
                        and eng.cfg.sliding_window is None):
                    if pend and (pend_win != win or
                                 item["layer"] !=
                                 pend[0][0] + len(pend)):
                        flush()
                    pend.append((item["layer"], item["state"]))
                    pend_win = pend_win or win
                    continue
                flush()
                eng.cache = M.import_kv_shard(
                    eng.cfg, eng.cache, slot, item["key"],
                    item["layer"], item["state"], win[0])
            flush()
            assert header is not None, \
                "handoff stream ended without header"
        except BaseException:
            eng.active[slot] = None    # release the reserved slot
            if reserved:
                eng._paged.release(req.rid)
            raise
        if header.done:             # finished at prefill: free the slot
            eng.active[slot] = None
            if reserved:
                eng._paged.release(req.rid)
            return True
        assert header.pos < eng.max_len, \
            "imported state exceeds this engine's max_len"
        req.ttft = eng._now(now)
        eng.pos = eng.pos.at[slot].set(header.pos)
        eng.last_tok = eng.last_tok.at[slot].set(header.last_tok)
        eng.budget = eng.budget.at[slot].set(header.budget)
        eng.active_mask = eng.active_mask.at[slot].set(True)
        eng._ran[slot] = 0
        eng._recompute_remaining()
        return True

    # ---------------------------------------------------------------- #
    # Whole-engine drain / migration / peer prefetch
    # ---------------------------------------------------------------- #
    def checkpoint(self, now: Optional[float] = None
                   ) -> List[Tuple[Any, SessionState]]:
        """Drain this engine loss-free: settle the buffered window,
        package every resident session — active slots AND parked /
        spilled pool residents — as (request, SessionState) with the
        decode cursor, and free all slots and blocks.  Sessions keep
        their clocks (``first_token_pending=False``)."""
        eng = self.eng
        eng.sync(now)
        out: List[Tuple[Any, SessionState]] = []
        if any(r is not None for r in eng.active):
            pos = np.asarray(eng.pos)
            last = np.asarray(eng.last_tok)
            budget = np.asarray(eng.budget)
            for slot in range(eng.slots):
                req = eng.active[slot]
                if req is None:
                    continue
                state = M.export_kv(eng.cfg, eng.cache, slot,
                                    int(pos[slot]))
                out.append((req, SessionState(
                    rid=req.rid, state=state,
                    last_tok=int(last[slot]), pos=int(pos[slot]),
                    budget=int(budget[slot]),
                    nbytes=M.kv_state_bytes(state), done=False,
                    first_token_pending=False,
                    priority=getattr(req, "priority", 0))))
                eng.active[slot] = None
                eng.active_mask = eng.active_mask.at[slot].set(False)
                if eng._paged is not None:
                    eng._paged.release(req.rid)
        if eng._paged is not None:
            for rid in eng._paged.parked():
                preq = eng._paged.resident[rid].req
                state, lt, p, b = eng._paged.assemble(rid)
                out.append((preq, SessionState(
                    rid=rid, state=state, last_tok=lt, pos=p,
                    budget=b, nbytes=M.kv_state_bytes(state),
                    done=False, first_token_pending=False,
                    priority=getattr(preq, "priority", 0))))
        eng._recompute_remaining()
        return out

    def snapshot(self, now: Optional[float] = None
                 ) -> List[Tuple[Any, SessionState]]:
        """Non-destructive :meth:`checkpoint`: settle the buffered
        window and package every resident session — active slots AND
        parked / spilled pool residents — WITHOUT freeing anything;
        decode continues untouched.  The periodic host-side
        ``CheckpointStore`` (serving/faults.py) polls this.  Exported
        states are copies (``export_kv`` / :meth:`PagedKvCache.peek`
        slice fresh arrays), so later decode steps do not mutate a
        taken snapshot."""
        eng = self.eng
        eng.sync(now)
        out: List[Tuple[Any, SessionState]] = []
        if any(r is not None for r in eng.active):
            pos = np.asarray(eng.pos)
            last = np.asarray(eng.last_tok)
            budget = np.asarray(eng.budget)
            for slot in range(eng.slots):
                req = eng.active[slot]
                if req is None:
                    continue
                state = M.export_kv(eng.cfg, eng.cache, slot,
                                    int(pos[slot]))
                out.append((req, SessionState(
                    rid=req.rid, state=state,
                    last_tok=int(last[slot]), pos=int(pos[slot]),
                    budget=int(budget[slot]),
                    nbytes=M.kv_state_bytes(state), done=False,
                    first_token_pending=False,
                    priority=getattr(req, "priority", 0))))
        if eng._paged is not None:
            for rid in eng._paged.parked():
                preq = eng._paged.resident[rid].req
                state, lt, p, b = eng._paged.peek(rid)
                out.append((preq, SessionState(
                    rid=rid, state=state, last_tok=lt, pos=p,
                    budget=b, nbytes=M.kv_state_bytes(state),
                    done=False, first_token_pending=False,
                    priority=getattr(preq, "priority", 0))))
        return out

    def crash(self, now: Optional[float] = None) -> List[Any]:
        """Hard-kill this engine's resident sessions WITHOUT exporting
        state (the fault-injection path): every active slot and every
        pool resident is lost; slots and blocks are freed.  Returns
        the lost requests — the caller decides which can come back
        from a checkpoint store (see ``LaunchedDeployment.inject``)."""
        eng = self.eng
        eng.sync(now)
        lost: List[Any] = []
        for slot in range(eng.slots):
            req = eng.active[slot]
            if req is None:
                continue
            lost.append(req)
            eng.active[slot] = None
            eng.active_mask = eng.active_mask.at[slot].set(False)
        if eng._paged is not None:
            for rid in eng._paged.parked():
                lost.append(eng._paged.resident[rid].req)
            for rid in list(eng._paged.resident):
                eng._paged.release(rid)
        eng._recompute_remaining()
        return lost

    def checkpoint_one(self, rid: int, now: Optional[float] = None
                       ) -> Optional[Tuple[Any, SessionState]]:
        """Checkpoint ONE resident session by rid (active or parked),
        freeing its slot/blocks.  Returns None when this engine does
        not hold it — the probe a peer prefetch uses."""
        eng = self.eng
        eng.sync(now)
        for slot in range(eng.slots):
            req = eng.active[slot]
            if req is None or req.rid != rid:
                continue
            p = int(np.asarray(eng.pos)[slot])
            state = M.export_kv(eng.cfg, eng.cache, slot, p)
            st = SessionState(
                rid=rid, state=state,
                last_tok=int(np.asarray(eng.last_tok)[slot]), pos=p,
                budget=int(np.asarray(eng.budget)[slot]),
                nbytes=M.kv_state_bytes(state), done=False,
                first_token_pending=False,
                priority=getattr(req, "priority", 0))
            eng.active[slot] = None
            eng.active_mask = eng.active_mask.at[slot].set(False)
            if eng._paged is not None:
                eng._paged.release(rid)
            eng._recompute_remaining()
            return req, st
        if eng._paged is not None and eng._paged.holds(rid):
            preq = eng._paged.resident[rid].req
            state, lt, p, b = eng._paged.assemble(rid)
            return preq, SessionState(
                rid=rid, state=state, last_tok=lt, pos=p, budget=b,
                nbytes=M.kv_state_bytes(state), done=False,
                first_token_pending=False,
                priority=getattr(preq, "priority", 0))
        return None

    def migrate(self, peer, now: Optional[float] = None) -> int:
        """Move every resident session to ``peer`` loss-free
        (checkpoint -> peer restore, clocks preserved).  Sessions the
        peer cannot take are re-imported locally; returns the number
        actually moved."""
        dst = peer.sessions if hasattr(peer, "sessions") else peer
        moved = 0
        for req, st in self.checkpoint(now):
            if dst.restore(req, st, now):
                moved += 1
            else:
                ok = self.restore(req, st, now)
                assert ok, "failed to re-import unmigrated session"
        return moved

    def prefetch(self, rid: int, peer,
                 now: Optional[float] = None) -> bool:
        """Pull ONE session off ``peer`` into this engine — the peer
        tier of the HBM -> host -> peer cache hierarchy.  Returns
        False when the peer does not hold it or this engine cannot
        fit it (the session is returned to the peer)."""
        src = peer.sessions if hasattr(peer, "sessions") else peer
        item = src.checkpoint_one(rid, now)
        if item is None:
            return False
        req, st = item
        if self.restore(req, st, now):
            return True
        back = src.restore(req, st, now)
        assert back, "failed to return prefetched session to peer"
        return False
