"""Cluster-scale serving: N heterogeneous Tessera replica groups.

A *replica group* is one disaggregated device set (e.g. an A100+L40s
pair) running the model under its own Plan; a cluster is many such
groups — possibly of different device types and sizes — behind a
router (``repro.serving.router``).  This is the layer the paper's
16-GPU experiments live at: per-replica kernel-granularity
disaggregation (the planner), cross-replica workload-aware routing
(HexGen-2-style rate matching), and per-replica online policy
switching (the monitor).

Per replica group this module precomputes:
  * a Plan per policy ("latency", "throughput") via the planner's
    process-wide plan cache — the same cache elastic re-planning uses,
    so a monitor-triggered policy flip is a cache hit, not a re-solve,
  * the stage-unit timeline for the discrete-event model
    (``repro.core.simulator.ReplicaModel``),
  * an :class:`OnlineMonitor` that flips the replica between policies
    when its queueing ratio crosses beta (with hysteresis).

``simulate`` builds FRESH replica state each call, so one cluster can
be replayed under different routers/traces for apples-to-apples
comparisons; everything downstream is deterministic in (trace, plans,
router).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import planner
from repro.core.costmodel import CATALOG, DeviceSpec
from repro.core.graph import KernelGraph
from repro.core.monitor import MonitorConfig, OnlineMonitor
from repro.core.simulator import (ClusterRequest, ClusterResult,
                                  Interconnect, ReplicaModel, ReplicaUnit,
                                  replica_units, simulate_cluster,
                                  simulate_cluster_pd)
from repro.models.config import ModelConfig
from repro.serving.workload import WorkloadRequest

POLICIES = ("latency", "throughput")


def resolve_devices(group: Sequence) -> List[DeviceSpec]:
    """Accept DeviceSpecs or catalog names."""
    out = []
    for d in group:
        if hasattr(d, "kernel_time"):
            out.append(d)
        elif d in CATALOG:
            out.append(CATALOG[d])
        else:
            raise ValueError(f"unknown device {d!r}; "
                             f"pick from {sorted(CATALOG)}")
    return out


@dataclasses.dataclass
class ReplicaGroup:
    """Static description + per-policy plans of one device group."""

    idx: int
    devices: List[DeviceSpec]
    plans: Dict[str, "planner.Plan"]
    units: Dict[str, List[ReplicaUnit]]

    @property
    def price(self) -> float:
        return sum(d.price for d in self.devices)

    def describe(self) -> str:
        names = "+".join(d.name for d in self.devices)
        obj = {p: f"{pl.objective * 1e3:.2f}ms"
               for p, pl in self.plans.items()}
        return f"replica[{self.idx}] {names} {obj}"


class TesseraCluster:
    """N replica groups serving one model graph.

    ``replica_devices``: one device list (specs or catalog names) per
    replica group, e.g. ``[["a100", "l40s"], ["h100", "rtxpro6000"]]``.
    ``base_prompt``/``base_output`` are the token counts the graph was
    traced with; per-request stage times scale relative to them.
    """

    def __init__(self, graph: KernelGraph,
                 replica_devices: Sequence[Sequence],
                 *,
                 base_prompt: int = 1024,
                 base_output: int = 128,
                 policies: Tuple[str, ...] = POLICIES,
                 monitor_cfg: Optional[MonitorConfig] = MonitorConfig(),
                 initial_policy: str = "latency",
                 bw_override: Optional[float] = None,
                 bw_overrides: Optional[Sequence[Optional[float]]] = None,
                 anneal_iters: int = 1000,
                 model_cfg: Optional[ModelConfig] = None,
                 interconnect: Optional[Interconnect] = None):
        assert replica_devices, "need at least one replica group"
        assert initial_policy in policies
        self.graph = graph
        self.base_prompt = max(base_prompt, 1)
        self.base_output = max(base_output, 1)
        self.monitor_cfg = monitor_cfg
        self.initial_policy = initial_policy
        self.model_cfg = model_cfg
        self.interconnect = interconnect or Interconnect()
        self.policies = tuple(policies)
        self.bw_override = bw_override
        # per-group planner bandwidth (index-aligned with the founding
        # groups; e.g. a fabric topology's contended island bandwidth).
        # Groups past the list — autoscaled additions — fall back to
        # the scalar ``bw_override``.
        self.bw_overrides = (list(bw_overrides)
                             if bw_overrides is not None else None)
        self.anneal_iters = anneal_iters
        self.groups: List[ReplicaGroup] = []
        self.add_groups(replica_devices)

    def add_groups(self, replica_devices: Sequence[Sequence]
                   ) -> List[ReplicaGroup]:
        """Plan and append replica groups (the autoscaling add path —
        a scaled-in group is planned exactly like a founding one).
        Returns the new :class:`ReplicaGroup` records."""
        new: List[ReplicaGroup] = []
        for group in replica_devices:
            devices = resolve_devices(group)
            gi = len(self.groups)
            ov = self.bw_override
            if self.bw_overrides is not None and gi < len(self.bw_overrides):
                if self.bw_overrides[gi] is not None:
                    ov = self.bw_overrides[gi]
            # Identical device sets hit the planner's plan cache, so a
            # 16-device cluster of 8 identical pairs solves each policy
            # once — the same path monitor-triggered re-planning takes.
            plans = {pol: planner.plan(self.graph, devices, policy=pol,
                                       bw_override=ov,
                                       anneal_iters=self.anneal_iters)
                     for pol in self.policies}
            units = {pol: replica_units(self.graph, plan, devices, ov)
                     for pol, plan in plans.items()}
            g = ReplicaGroup(gi, devices, plans, units)
            self.groups.append(g)
            new.append(g)
        return new

    # -------------------------------------------------------------- #
    @property
    def num_devices(self) -> int:
        return sum(len(g.devices) for g in self.groups)

    @property
    def price_rate(self) -> float:
        return sum(g.price for g in self.groups)

    @property
    def capacity(self) -> float:
        """Aggregate steady-state throughput (req/s at scale 1): the sum
        of each group's pipelined ceiling 1 / max_g W_g."""
        return sum(1.0 / g.plans["throughput"].bottleneck
                   for g in self.groups)

    def describe(self) -> str:
        return "\n".join(g.describe() for g in self.groups)

    # -------------------------------------------------------------- #
    def kv_bytes(self, prompt_tokens: int) -> float:
        """Size of the prefill->decode KV-state handoff for one request.

        Attention families carry per-token K and V planes
        (layers x kv_heads x head_dim x 2 x dtype bytes per token);
        recurrent families (ssm) hand off a fixed-size state; hybrids
        both.  Falls back to a per-token heuristic matching the request
        graph's KV-handoff edge when no model config was provided.
        """
        cfg = self.model_cfg
        if cfg is None:
            return float(2 * 2 * 128 * prompt_tokens)   # heuristic
        dt = cfg.jnp_dtype.itemsize
        total = 0.0
        if cfg.family in ("dense", "moe", "vlm", "encdec"):
            # sliding-window caches are ring buffers whose slot layout
            # depends on absolute positions, so the handoff ships the
            # WHOLE ring (export_kv never trims it), not just the
            # filled prefix
            tok = cfg.sliding_window or prompt_tokens
            total += (2 * cfg.num_layers * cfg.num_kv_heads
                      * cfg.head_dim * dt * tok)
        elif cfg.family == "ssm":       # rwkv6: wkv fp32 + 2 shift rows
            total += cfg.num_layers * (
                cfg.rwkv_heads * cfg.rwkv_head_dim ** 2 * 4
                + 2 * cfg.d_model * dt)
        elif cfg.family == "hybrid":    # mamba state + shared-attn KV
            n_attn = (cfg.num_layers + cfg.hybrid_attn_every - 1) \
                // cfg.hybrid_attn_every
            total += cfg.num_layers * (
                cfg.ssm_heads * cfg.ssm_state * cfg.ssm_head_dim * 4
                + (cfg.conv_width - 1)
                * (cfg.d_inner + 2 * cfg.ssm_state) * dt)
            total += (2 * n_attn * cfg.num_kv_heads * cfg.head_dim
                      * dt * prompt_tokens)
        return total

    def to_cluster_request(self, req: WorkloadRequest) -> ClusterRequest:
        return ClusterRequest(
            rid=req.rid, arrival=req.arrival,
            scale_prompt=req.prompt_tokens / self.base_prompt,
            scale_output=req.output_tokens / self.base_output,
            session=req.session,
            kv_bytes=self.kv_bytes(req.prompt_tokens),
            slo=req.slo, slo_ttft=req.slo_ttft,
            priority=getattr(req, "priority", 0))

    def build_replicas(self) -> List[ReplicaModel]:
        """Fresh mutable replica state (queues, monitors, policies)."""
        replicas = []
        for g in self.groups:
            monitor = (OnlineMonitor(self.monitor_cfg,
                                     initial_policy=self.initial_policy)
                       if self.monitor_cfg is not None else None)
            replicas.append(ReplicaModel(
                g.idx, len(g.devices), g.units,
                policy=self.initial_policy, monitor=monitor,
                price=g.price))
        return replicas

    def simulate(self, trace: Sequence[WorkloadRequest],
                 router) -> ClusterResult:
        """Route + replay ``trace``; ``router`` is any callable
        ``(req, replicas, now) -> index`` (see serving/router.py)."""
        creqs = [self.to_cluster_request(r)
                 for r in sorted(trace, key=lambda r: (r.arrival, r.rid))]
        return simulate_cluster(self.build_replicas(), creqs, router)

    def simulate_pd(self, trace: Sequence[WorkloadRequest],
                    router, kv_chunks: int = 1) -> ClusterResult:
        """Phase-split replay: ``router`` may return ``(prefill_idx,
        decode_idx, admit_at)`` (see router.PDRouter); KV-transfer time
        between groups comes from this cluster's ``interconnect``.
        ``kv_chunks > 1`` streams each handoff as that many chunks
        overlapped with the remaining prefill compute (see
        simulator.simulate_cluster_pd)."""
        creqs = [self.to_cluster_request(r)
                 for r in sorted(trace, key=lambda r: (r.arrival, r.rid))]
        return simulate_cluster_pd(self.build_replicas(), creqs, router,
                                   self.interconnect, kv_chunks)
