"""Declarative deployment API: one spec, two backends (DES / engines).

Tessera's headline result is that *deployment shape* — which devices
group into which replica, how phases split across groups, how requests
route — is where heterogeneous wins live.  Before this module the repo
had four parallel entry points for exercising a shape
(``simulate_cluster``, ``simulate_cluster_pd``,
``TesseraCluster.simulate/simulate_pd`` and the hand-wired two-engine
handoff in ``examples/serve_pipeline.py``), which made shape *search*
and runtime *elasticity* impossible to express.  The redesign:

  * :class:`DeploymentSpec` — a serializable, validated description of
    a deployment: device inventory per replica group, inter-group
    fabric, router policy by name + kwargs (``router.ROUTERS``
    registry), phase-split/overlap config (``pd``, ``kv_chunks``,
    affinity via ``router_kwargs``), SLOs, a ``$/hr`` budget and an
    optional measured calibration (``costmodel.calibrate``).
    ``to_json``/``from_json`` round-trip exactly, so a deployment shape
    is a file you can diff, ship and search over.
  * ``spec.compile(graph)`` → :class:`Deployment`, one protocol with
    two backends:
      - :meth:`Deployment.simulate` drives the unified cluster DES
        (``simulator.simulate_deployment``) — subsumes both legacy
        simulate entry points with bit-identical event logs,
      - :meth:`Deployment.launch` instantiates real
        :class:`~repro.serving.engine.ServingEngine` s — a single
        continuous-batching engine, or the prefill+decode pair wired
        through the (optionally streamed) KV handoff.
  * :meth:`Deployment.scale` — runtime autoscaling on the DES backend:
    removed groups drain (the router masks them, resident decode
    sessions finish — loss-free), added groups warm up for a modeled
    delay before becoming routable.
  * ``Deployment.simulate(failures=[(t, group)])`` — replica-level
    fault injection through the same masking machinery: the dead
    group's in-flight requests re-route across the survivors.

``serving/sizing.py`` builds composition search on top of this:
mutate ``spec.groups``, score by simulated goodput/$.
"""
from __future__ import annotations

import dataclasses
import json
import math
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.costmodel import CATALOG, Calibration, calibrate
from repro.core.monitor import MonitorConfig
from repro.core.simulator import (_EVENT_ORDER, ClusterRequest,
                                  ClusterResult, ControlEvent,
                                  Interconnect, KvPoolModel,
                                  simulate_deployment)
from repro.serving.cluster import TesseraCluster
from repro.serving.router import ROUTERS, make_router
from repro.serving.workload import WorkloadRequest, assign_slos

_SLO_KEYS = frozenset({"base", "per_output_token", "ttft"})
_IC_KEYS = frozenset({"default_bw", "base_latency", "bw"})
_ENGINE_KEYS = frozenset({"slots", "max_len", "sync_every",
                          "temperature", "seed", "smoke",
                          "kv_block_tokens", "kv_pool_blocks",
                          "spill", "preempt_priority"})
_POLICIES = ("latency", "throughput")


def _field(default):
    return dataclasses.field(default_factory=default)


@dataclasses.dataclass
class DeploymentSpec:
    """Declarative description of one serving deployment.

    ``groups``: device-catalog names per replica group, e.g.
    ``[["h100", "rtxpro6000"], ["a100", "l40s"]]`` — names only (not
    DeviceSpec objects) so the spec serializes; resolution happens at
    compile.  ``router``/``router_kwargs`` name a registered policy
    (``repro.serving.router.ROUTERS``) — affinity and pool overrides
    live in the kwargs.  ``pd`` selects phase-split simulation and the
    two-engine launch pairing; ``kv_chunks > 1`` streams each KV
    handoff overlapped with the remaining prefill.  ``slos`` (keys
    ``base``/``per_output_token``/``ttft``) are stamped onto every
    simulated trace; ``budget`` is a hard ``$/hr`` ceiling enforced at
    construction; ``calibration`` is a ``CALIBRATION`` payload (see
    ``costmodel.calibrate``) scaling the DES service profiles by
    measured wall/model ratios.  ``engine`` carries launch-time knobs
    (``slots``, ``max_len``, ``sync_every``, ``temperature``,
    ``seed``, ``smoke``) plus the paged-KV knobs
    (``kv_block_tokens``, ``kv_pool_blocks``, ``spill``,
    ``preempt_priority``) — setting ``kv_block_tokens`` turns on
    block-pooled session memory in BOTH backends: real engines page
    their KV, and the DES runs a matching ``KvPoolModel`` (per-group
    occupancy, delayed admission, prefix/session cache hits).

    Validated at construction; every field is JSON-serializable and
    ``from_json(spec.to_json()) == spec``.
    """

    groups: List[List[str]]
    arch: Optional[str] = None          # model architecture (launch +
    #                                     KV-size model)
    base_prompt: int = 1024             # token counts the graph was
    base_output: int = 128              # traced with (per-request scale)
    router: str = "jsed"
    router_kwargs: Dict[str, Any] = _field(dict)
    pd: bool = False                    # phase-split serving
    kv_chunks: int = 1                  # >1 = overlapped KV streaming
    interconnect: Dict[str, Any] = _field(dict)
    slos: Optional[Dict[str, float]] = None
    budget: Optional[float] = None      # $/hr ceiling over all groups
    calibration: Optional[Dict[str, float]] = None
    monitor: Optional[Dict[str, float]] = _field(dict)  # None disables
    initial_policy: str = "latency"
    anneal_iters: int = 1000            # planner effort per group
    bw_override: Optional[float] = None
    engine: Dict[str, Any] = _field(dict)
    # contended-fabric topology (serving.fabric.Topology dict form);
    # None keeps the point-to-point interconnect model bit-identical
    fabric: Optional[Dict[str, Any]] = None

    # ------------------------------------------------------------------ #
    def __post_init__(self):
        if not self.groups or any(not g for g in self.groups):
            raise ValueError("spec needs at least one non-empty "
                             "replica group")
        for g in self.groups:
            for name in g:
                if name not in CATALOG:
                    raise ValueError(
                        f"unknown device {name!r}; "
                        f"pick from {sorted(CATALOG)}")
        if self.router not in ROUTERS:
            raise ValueError(f"unknown router {self.router!r}; "
                             f"pick from {sorted(ROUTERS)}")
        if self.kv_chunks < 1:
            raise ValueError(f"kv_chunks must be >= 1, "
                             f"got {self.kv_chunks}")
        if self.kv_chunks > 1 and not self.pd:
            raise ValueError("kv_chunks > 1 streams the prefill->decode "
                             "KV handoff; it requires pd=True")
        if self.slos is not None:
            bad = set(self.slos) - _SLO_KEYS
            if bad:
                raise ValueError(f"unknown slo keys {sorted(bad)}; "
                                 f"pick from {sorted(_SLO_KEYS)}")
            if not any(v and v > 0 for v in self.slos.values()):
                raise ValueError("slos must set at least one positive "
                                 "deadline")
        bad = set(self.interconnect) - _IC_KEYS
        if bad:
            raise ValueError(f"unknown interconnect keys {sorted(bad)}; "
                             f"pick from {sorted(_IC_KEYS)}")
        for key in self.interconnect.get("bw") or {}:
            src, _, dst = str(key).partition("-")
            if not (src.isdigit() and dst.isdigit()):
                raise ValueError(
                    f"interconnect bw override key {key!r} must be "
                    "'src-dst' group indices, e.g. '0-1'")
        bad = set(self.engine) - _ENGINE_KEYS
        if bad:
            raise ValueError(f"unknown engine keys {sorted(bad)}; "
                             f"pick from {sorted(_ENGINE_KEYS)}")
        bt = self.engine.get("kv_block_tokens")
        pool = self.engine.get("kv_pool_blocks")
        if pool is not None and bt is None:
            raise ValueError("kv_pool_blocks requires kv_block_tokens")
        if bt is not None:
            if int(bt) < 1:
                raise ValueError(f"kv_block_tokens must be >= 1, "
                                 f"got {bt}")
            ml = int(self.engine.get("max_len", 64))
            if ml % int(bt):
                raise ValueError(f"kv_block_tokens={bt} must divide "
                                 f"max_len={ml}")
            if pool is not None and int(pool) < 1:
                raise ValueError(f"kv_pool_blocks must be >= 1, "
                                 f"got {pool}")
        if self.initial_policy not in _POLICIES:
            raise ValueError(f"initial_policy must be one of "
                             f"{_POLICIES}, got {self.initial_policy!r}")
        if self.fabric is not None:
            # full validation (keys, islands, reachability) + every
            # declared group must sit on some island
            topo = self.make_topology()
            for g in range(len(self.groups)):
                topo.island_of(g)
        if self.calibration is not None:
            calibrate(self.calibration)     # raises on a bad payload
        if self.monitor:
            MonitorConfig(**self.monitor)   # raises on unknown fields
        if self.budget is not None and self.price_rate > self.budget + 1e-9:
            raise ValueError(
                f"composition costs ${self.price_rate:.2f}/hr, over the "
                f"${self.budget:.2f}/hr budget")

    # ------------------------------------------------------------------ #
    @property
    def price_rate(self) -> float:
        """$/hr of the declared composition (catalog rental prices)."""
        return sum(CATALOG[n].price for g in self.groups for n in g)

    def make_interconnect(self) -> Interconnect:
        bw = {tuple(int(x) for x in str(k).split("-")): float(v)
              for k, v in (self.interconnect.get("bw") or {}).items()}
        return Interconnect(
            default_bw=float(self.interconnect.get("default_bw", 100e9)),
            base_latency=float(self.interconnect.get("base_latency",
                                                     20e-6)),
            bw=bw)

    def make_topology(self):
        """The contended-fabric :class:`~repro.serving.fabric.Topology`
        (or ``None`` when the spec keeps point-to-point math)."""
        if self.fabric is None:
            return None
        from repro.serving.fabric import Topology
        return Topology.from_dict(self.fabric)

    def calibration_model(self) -> Optional[Calibration]:
        return (calibrate(self.calibration)
                if self.calibration is not None else None)

    def kv_config(self) -> Optional[Dict[str, Any]]:
        """Resolved paged-KV knobs, or ``None`` when the spec doesn't
        page.  The ``slots=`` shim: without an explicit
        ``kv_pool_blocks`` the pool is sized to exactly the fixed-slot
        footprint (``slots * max_len / kv_block_tokens`` blocks), so
        turning paging on changes the memory LAYOUT, not the budget."""
        bt = self.engine.get("kv_block_tokens")
        if bt is None:
            return None
        bt = int(bt)
        slots = int(self.engine.get("slots", 4))
        max_len = int(self.engine.get("max_len", 64))
        pool = self.engine.get("kv_pool_blocks")
        pool = int(pool) if pool is not None else slots * (max_len // bt)
        return {"kv_block_tokens": bt, "kv_pool_blocks": pool,
                "spill": bool(self.engine.get("spill", True)),
                "preempt_priority":
                    bool(self.engine.get("preempt_priority", True))}

    def kv_model(self) -> Optional[KvPoolModel]:
        """DES occupancy model matching the engine knobs (``None``
        when not paging — the DES then runs bit-identically to before
        paging existed)."""
        kvc = self.kv_config()
        if kvc is None:
            return None
        return KvPoolModel(kvc["kv_block_tokens"],
                           kvc["kv_pool_blocks"],
                           base_prompt=self.base_prompt,
                           base_output=self.base_output)

    # ------------------------------------------------------------------ #
    def to_json(self, indent: Optional[int] = 2) -> str:
        return json.dumps(dataclasses.asdict(self), indent=indent)

    @classmethod
    def from_json(cls, s: str) -> "DeploymentSpec":
        obj = json.loads(s)
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(obj) - known
        if unknown:
            raise ValueError(f"unknown DeploymentSpec fields "
                             f"{sorted(unknown)}")
        return cls(**obj)

    def save(self, path) -> None:
        Path(path).write_text(self.to_json() + "\n")

    @classmethod
    def load(cls, path) -> "DeploymentSpec":
        return cls.from_json(Path(path).read_text())

    # ------------------------------------------------------------------ #
    def compile(self, graph=None, model_cfg=None) -> "Deployment":
        """Bind the spec to a kernel graph.  ``graph`` may be omitted
        when only :meth:`Deployment.launch` will be used (engines need
        the model, not the DDG); :meth:`Deployment.simulate` requires
        it.  ``model_cfg`` overrides the KV-size model (defaults to
        the config of ``spec.arch`` when set)."""
        return Deployment(self, graph, model_cfg)


# --------------------------------------------------------------------- #
class Deployment:
    """A compiled :class:`DeploymentSpec`: one protocol, two backends.

    * :meth:`simulate` — the cluster DES, with optional fault
      injection and the elasticity timeline :meth:`scale` builds.
    * :meth:`launch` — real ``ServingEngine`` s in the spec's shape.

    Replica-group planning (the expensive part) happens lazily on
    first ``simulate`` and is shared across repeated simulations;
    every simulate call replays against FRESH replica/router state, so
    one Deployment supports apples-to-apples comparisons.
    """

    def __init__(self, spec: DeploymentSpec, graph=None, model_cfg=None):
        self.spec = spec
        self.graph = graph
        self.model_cfg = model_cfg
        self._cluster: Optional[TesseraCluster] = None
        self._timeline: List[ControlEvent] = []
        self._extra_groups: List[List[str]] = []
        self._reserve: set = set()      # parked group indices (see
        #                                 add_reserve): planned, unbilled,
        #                                 ineligible until a controller
        #                                 activates them

    # ------------------------------------------------------------------ #
    @property
    def num_groups(self) -> int:
        return len(self.spec.groups) + len(self._extra_groups)

    @property
    def price_rate(self) -> float:
        """$/hr including scaled-in groups (drained groups still count:
        the spec does not model partial-hour billing).  Parked reserve
        groups are excluded — they accrue nothing until a controller
        activates them, and elastic runs bill time-weighted through
        ``AutoscalePolicy.billed_dollars``."""
        n0 = len(self.spec.groups)
        return self.spec.price_rate + sum(
            CATALOG[n].price
            for k, g in enumerate(self._extra_groups)
            if n0 + k not in self._reserve for n in g)

    def _resolved(self, group: Sequence[str]):
        cal = self.spec.calibration_model()
        devs = [CATALOG[n] for n in group]
        return cal.apply_all(devs) if cal is not None else devs

    def _model_cfg(self):
        if self.model_cfg is not None:
            return self.model_cfg
        if self.spec.arch:
            import repro.configs as configs
            return configs.get(self.spec.arch)
        return None

    def cluster(self) -> TesseraCluster:
        """The planned cluster behind the DES backend (lazy)."""
        if self._cluster is None:
            if self.graph is None:
                raise ValueError("Deployment.simulate needs "
                                 "spec.compile(graph); this deployment "
                                 "was compiled without one")
            spec = self.spec
            mon = (MonitorConfig(**spec.monitor)
                   if spec.monitor is not None else None)
            all_groups = list(spec.groups) + self._extra_groups
            topo = spec.make_topology()
            bw_overrides = None
            if topo is not None:
                # kernel placement sees the fabric: each group plans
                # against its island bandwidth derated by contention
                # (extra/autoscaled groups fall back to bw_override)
                bw_overrides = [topo.planner_bw(g)
                                for g in range(len(spec.groups))]
            self._cluster = TesseraCluster(
                self.graph,
                [self._resolved(g) for g in all_groups],
                base_prompt=spec.base_prompt,
                base_output=spec.base_output,
                monitor_cfg=mon,
                initial_policy=spec.initial_policy,
                bw_override=spec.bw_override,
                bw_overrides=bw_overrides,
                anneal_iters=spec.anneal_iters,
                model_cfg=self._model_cfg(),
                interconnect=spec.make_interconnect())
        return self._cluster

    def _router(self, health=None):
        kw = dict(self.spec.router_kwargs)
        if health is not None:
            # health-aware degraded routing: the same GroupHealth the
            # DES records errors into is folded into the policy's
            # scores (jsed / pd_split accept it; a policy without a
            # health kwarg fails loudly here)
            kw["health"] = health
        if self.spec.router == "pd_split":
            # the PD router's shed estimate should charge the same
            # transfer tail the DES will produce
            kw.setdefault("interconnect", self.spec.make_interconnect())
            kw.setdefault("kv_chunks", self.spec.kv_chunks)
        # (with spec.fabric set, simulate_deployment later binds the
        # run's FabricState into the router via router.bind_fabric, so
        # shed estimates charge the QUEUED transfer tail)
        return make_router(self.spec.router, **kw)

    # ------------------------------------------------------------------ #
    def scale(self, *, add: Optional[Sequence[Sequence[str]]] = None,
              remove: Optional[Sequence[int]] = None,
              at: float = 0.0, warmup: float = 1.0) -> "Deployment":
        """Schedule runtime autoscaling on the DES backend.

        ``remove``: group indices that begin a graceful drain at
        ``at`` — the router masks them immediately, resident work
        (decode sessions included) finishes normally, and no accepted
        request is dropped as long as another group stays eligible.
        ``add``: device-name lists planned now but routable only from
        ``at + warmup`` (modeled weight-load + compile delay).  The
        timeline composes: call ``scale`` repeatedly to script a whole
        capacity schedule, then :meth:`simulate`.  Returns ``self``.

        Note: ``scale`` deliberately does NOT re-check ``spec.budget``
        — emergency capacity beyond the provisioning budget is an
        operator decision the spec cannot veto; :attr:`price_rate`
        reports the honest post-scale rate.

        Draining the ONLY eligible group is rejected: the scheduled
        timeline is replayed to ``at`` and the call raises when the
        removals would leave nothing routable (adds in the same call
        count if their warm-up completes by ``at``) — every arrival
        after such a drain would be shed, which is never what an
        autoscaler meant.  Schedule the replacement first, or later.
        """
        removals = []
        for g in (remove or []):
            g = int(g)
            if not 0 <= g < self.num_groups:
                raise ValueError(f"cannot remove group {g}; deployment "
                                 f"has {self.num_groups}")
            removals.append(g)
        for group in (add or []):
            for name in group:
                if name not in CATALOG:
                    raise ValueError(f"unknown device {name!r}; "
                                     f"pick from {sorted(CATALOG)}")
            idx = self.num_groups
            self._extra_groups.append(list(group))
            if self._cluster is not None:
                self._cluster.add_groups([self._resolved(group)])
            self._timeline.append(
                ControlEvent(float(at) + float(warmup), "up", idx))
        if removals:
            left = self._eligible_at(float(at)) - set(removals)
            if not left:
                raise ValueError(
                    f"scale(remove={removals}) would leave no eligible "
                    f"group at t={float(at)}; scale up first (or give "
                    f"the drain a later `at`)")
            for g in removals:
                self._timeline.append(ControlEvent(float(at), "down", g))
        return self

    def _eligible_at(self, t: float) -> set:
        """Which groups the scheduled timeline leaves routable at
        ``t``: reserve groups park, groups with a pending "up" start
        masked, then events at or before ``t`` replay in DES order."""
        pend_up = {e.group for e in self._timeline if e.kind == "up"}
        elig = {g for g in range(self.num_groups)
                if g not in self._reserve and g not in pend_up}
        for e in sorted(self._timeline, key=lambda e:
                        (e.time, _EVENT_ORDER[e.kind], e.group)):
            if e.time > t:
                break
            if e.kind == "up":
                elig.add(e.group)
            else:
                elig.discard(e.group)
        return elig

    # ------------------------------------------------------------------ #
    def add_reserve(self, groups: Sequence[Sequence[str]]) -> List[int]:
        """Provision parked reserve groups for a controller.

        Each group is planned immediately (plan-cache backed) but
        starts ineligible with no scheduled warm-up and accrues no
        cost: only a controller activation (an "up" control event)
        makes it routable and starts its billing clock.  Returns the
        new group indices.
        """
        idxs: List[int] = []
        for group in (groups or []):
            for name in group:
                if name not in CATALOG:
                    raise ValueError(f"unknown device {name!r}; "
                                     f"pick from {sorted(CATALOG)}")
            idx = self.num_groups
            self._extra_groups.append(list(group))
            self._reserve.add(idx)
            if self._cluster is not None:
                self._cluster.add_groups([self._resolved(group)])
            idxs.append(idx)
        return idxs

    # ------------------------------------------------------------------ #
    def prepare(self, trace: Sequence[WorkloadRequest]
                ) -> List[ClusterRequest]:
        """Preprocess a workload trace ONCE for repeated replays.

        Produces exactly the sorted ``ClusterRequest`` list
        :meth:`simulate` builds internally — spec SLOs stamped
        (overriding any the trace carried), arrival-sorted, per-request
        scales and KV sizes resolved — so callers replaying the same
        trace against many candidates (sizing search, controller
        sweeps) pay the conversion once and pass ``prepared=``.
        KV sizes are memoized by prompt length (the KV model is a pure
        function of it), which is most of the historical per-call cost.
        """
        cluster = self.cluster()
        slos = self.spec.slos
        if slos:
            slo_base = slos.get("base", 0.0) or 0.0
            slo_tok = slos.get("per_output_token", 0.0) or 0.0
            slo_ttft = slos.get("ttft")
            slo_comp = not (slo_base <= 0.0 and slo_tok <= 0.0)
        kv_memo: Dict[int, float] = {}
        kv_bytes = cluster.kv_bytes
        bp = cluster.base_prompt
        bo = cluster.base_output
        out: List[ClusterRequest] = []
        for r in sorted(trace, key=lambda r: (r.arrival, r.rid)):
            p = r.prompt_tokens
            kv = kv_memo.get(p)
            if kv is None:
                kv = kv_memo[p] = kv_bytes(p)
            if slos:
                slo = (slo_base + slo_tok * r.output_tokens
                       if slo_comp else None)
                ttft = slo_ttft
            else:
                slo, ttft = r.slo, r.slo_ttft
            out.append(ClusterRequest(
                rid=r.rid, arrival=r.arrival,
                scale_prompt=p / bp,
                scale_output=r.output_tokens / bo,
                session=r.session, kv_bytes=kv,
                slo=slo, slo_ttft=ttft,
                priority=getattr(r, "priority", 0)))
        return out

    def simulate(self, trace: Optional[Sequence[WorkloadRequest]] = None,
                 *,
                 failures: Optional[Sequence[Tuple[float, int]]] = None,
                 faults=None, recovery=None, health=None,
                 router=None, controller=None,
                 events: Optional[str] = "full",
                 reference: bool = False,
                 prepared: Optional[Sequence[ClusterRequest]] = None
                 ) -> ClusterResult:
        """Replay an open-loop trace on the DES backend.

        ``failures=[(t, group_idx), ...]`` hard-kills groups mid-trace
        (in-flight requests re-route, see
        ``simulator.simulate_deployment``); the autoscaling timeline
        from :meth:`scale` is applied on every call.  ``router``
        overrides the spec's policy with a caller-built instance
        (apples-to-apples replays); by default a FRESH router is built
        per call so no routing state leaks between replays.  When the
        spec declares ``slos`` they are stamped onto the trace
        (overriding any the trace already carried).

        ``faults`` (a ``serving.faults.FaultPlan``) is the typed
        superset of ``failures``: crashes with scheduled recovery,
        straggle windows, and flaky KV links, all seeded.  ``recovery``
        (a ``RecoveryConfig``) turns on checkpoint-based session
        recovery — crash victims restore from their last periodic
        checkpoint and replay the lost decode suffix on a survivor
        instead of landing in ``dropped``.  ``health`` (a
        ``GroupHealth``) is shared between the DES (which records
        transfer errors and crash/recover flips into it) and the
        per-call router (which folds its breaker state and penalties
        into scoring); ``recovery`` rides along only with ``faults``,
        while ``health`` may also come alone (a straggle detector
        tripping breakers with no injected fault) — a health-only run
        binds an empty fault plan, leaving the schedule bit-identical
        to a plain run.  The
        contradictory-timeline validation
        (``simulator.validate_timeline``) covers the merged
        ``scale`` + ``failures`` + ``faults`` schedule.

        ``controller`` (a ``serving.controller.AutoscalePolicy``)
        closes the loop: it is bound to this deployment (provisioning
        its parked reserve pool on first use), observes windowed DES
        signals every ``controller.interval`` simulated seconds, and
        injects scale up/down events into the live timeline.

        ``events`` selects event recording (``"full"`` | ``"agg"`` |
        ``None`` — see ``simulator.simulate_deployment``);
        ``reference=True`` replays on the historical per-unit walk
        (the parity oracle / benchmark baseline); ``prepared`` replaces
        ``trace`` with a :meth:`prepare` result so repeated replays
        skip the per-call trace preprocessing.
        """
        cluster = self.cluster()
        if controller is not None:
            controller.bind(self)
        if prepared is not None:
            creqs: Sequence[ClusterRequest] = prepared
        elif trace is None:
            raise ValueError("simulate needs a trace (or a "
                             "prepare()d one via prepared=)")
        elif reference:
            # historical prep path (per-replay SLO stamping + scalar
            # per-request conversion) so reference mode is an honest
            # end-to-end baseline, not just the reference walk
            if self.spec.slos:
                trace = assign_slos(trace, **self.spec.slos)
            creqs = [cluster.to_cluster_request(r)
                     for r in sorted(trace,
                                     key=lambda r: (r.arrival, r.rid))]
        else:
            creqs = self.prepare(trace)
        timeline = list(self._timeline)
        for (t, g) in (failures or []):
            g = int(g)
            if not 0 <= g < self.num_groups:
                raise ValueError(f"cannot fail group {g}; deployment "
                                 f"has {self.num_groups}")
            timeline.append(ControlEvent(float(t), "fail", g))
        fstate = None
        if faults is not None:
            from repro.serving.faults import FaultPlan
            fstate = (faults.bind(self.num_groups, recovery=recovery,
                                  health=health)
                      if isinstance(faults, FaultPlan) else faults)
            timeline.extend(fstate.control_events())
        elif health is not None:
            # health-ALONE runs (e.g. a straggle detector tripping
            # breakers with no injected fault) bind an empty plan: its
            # link() is always None, so the schedule is bit-identical
            # to a plain run — only the breaker state is live.
            from repro.serving.faults import FaultPlan
            fstate = FaultPlan().bind(self.num_groups, recovery=recovery,
                                      health=health)
        elif recovery is not None:
            raise ValueError("recovery= rides along with a faults= "
                             "plan; pass one")
        replicas = cluster.build_replicas()
        if reference:
            for rep in replicas:
                rep.reference = True
        return simulate_deployment(
            replicas, creqs, router or self._router(health=health),
            interconnect=cluster.interconnect,
            kv_chunks=self.spec.kv_chunks,
            timeline=timeline,
            controller=controller,
            start_ineligible=sorted(self._reserve),
            events=events,
            kv=self.spec.kv_model(),
            faults=fstate,
            fabric=self.spec.make_topology())

    # ------------------------------------------------------------------ #
    def launch(self, cfg=None, params=None) -> "LaunchedDeployment":
        """Instantiate the spec's shape with REAL engines.

        ``cfg``/``params`` default from ``spec.arch`` (smoke-sized
        unless ``spec.engine["smoke"]`` is false — full configs do not
        fit a CPU host).  The launch backend realizes the phase
        topology (single engine, or prefill+decode pair with serial or
        streamed KV handoff); it does not model the DES's queueing
        knobs (router, SLOs), which have no meaning for two local
        engines.
        """
        spec = self.spec
        if cfg is None:
            if not spec.arch:
                raise ValueError("launch needs spec.arch or an "
                                 "explicit cfg")
            import repro.configs as configs
            cfg = (configs.get_smoke(spec.arch)
                   if spec.engine.get("smoke", True)
                   else configs.get(spec.arch))
        if params is None:
            from repro.models import model as M
            params = M.init_params(cfg)
        return LaunchedDeployment(spec, cfg, params)


# --------------------------------------------------------------------- #
class LaunchedDeployment:
    """Real-engine backend of a :class:`DeploymentSpec`.

    ``pd=False``: one continuous-batching :class:`ServingEngine`.
    ``pd=True``: a prefill engine + decode engine wired through the KV
    handoff — serial ``prefill_handoff``/``admit_handoff`` export/
    import, or streamed (layer, chunk) shards overlapping the
    remaining prefill when ``kv_chunks > 1`` — the two-engine flow
    that previously existed only as example code.  Greedy decode is
    bit-identical to a single engine either way (asserted in
    tests/test_deployment.py and examples/serve_pipeline.py).
    """

    def __init__(self, spec: DeploymentSpec, cfg, params):
        from repro.serving.engine import ServingEngine
        self.spec = spec
        self.cfg = cfg
        self.params = params
        self.wire_bytes = 0
        self.shards = 0
        self.migrations = 0
        ekw = spec.engine
        self.max_len = int(ekw.get("max_len", 64))
        common = dict(slots=int(ekw.get("slots", 4)),
                      max_len=self.max_len,
                      temperature=float(ekw.get("temperature", 0.0)),
                      seed=int(ekw.get("seed", 0)))
        kvc = spec.kv_config()
        if kvc is not None:
            common.update(kv_block_tokens=kvc["kv_block_tokens"],
                          kv_pool_blocks=kvc["kv_pool_blocks"],
                          spill=kvc["spill"],
                          preempt_priority=kvc["preempt_priority"])
        sync_every = int(ekw.get("sync_every", 4))
        self._engine_kw = dict(common, sync_every=sync_every)
        self._actions: List[Dict[str, Any]] = []
        self._chaos = None              # bound FaultState (see inject)
        self._store = None              # CheckpointStore under recovery
        # live accounting twin of the DES fabric: counts real bytes per
        # channel and priority class (None without spec.fabric)
        self._fabric = None
        topo = spec.make_topology()
        if topo is not None:
            from repro.serving.fabric import LiveFabric
            self._fabric = LiveFabric(topo, len(spec.groups))
        self.kv_retries = 0             # transparent shard retransmits
        self.kv_corrupted = 0           # shards delivered corrupted
        self.reprefills = 0             # handoffs re-prefilled on decode
        self.lost_sessions = 0          # sessions lost to crashes
        self.recovered_sessions = 0     # ... restored from checkpoints
        if spec.pd:
            chunk = (max(1, math.ceil(self.max_len / spec.kv_chunks))
                     if spec.kv_chunks > 1 else None)
            self.prefill_engine = ServingEngine(cfg, params,
                                                prefill_chunk=chunk,
                                                **common)
            self.decode_engine = ServingEngine(cfg, params,
                                               sync_every=sync_every,
                                               **common)
            self.engines = [self.prefill_engine, self.decode_engine]
        else:
            # one colocated engine per replica group: the pool the
            # live scale() drains / grows
            self.engines = [ServingEngine(cfg, params, **self._engine_kw)
                            for _ in spec.groups]
            self.engine = self.engines[0]
            self._routable = [True] * len(self.engines)

    # ------------------------------------------------------------------ #
    def inject(self, plan, recovery=None) -> "LaunchedDeployment":
        """Arm a ``serving.faults.FaultPlan`` against the REAL engines
        for the next :meth:`run` — the live counterpart of
        ``Deployment.simulate(faults=...)``.

        On the colocated pool (``pd=False``): ``crash`` hard-kills an
        engine's resident sessions mid-run (``sessions.crash`` — state
        unexported) and un-routes it until its ``recover_at``;
        ``straggle`` paces the engine's decode loop down by ``factor``
        over its window.  With ``recovery`` (a ``RecoveryConfig``) a
        ``CheckpointStore`` polls non-destructive session snapshots
        every ``interval`` seconds and crash victims restore on a
        survivor from their last checkpoint — the truncated suffix
        re-decodes bit-identically (greedy), so no accepted session is
        lost; without it victims restart from scratch.

        On the PD pair (``pd=True``, ``kv_chunks > 1``): ``flaky_link``
        wraps the streamed KV handoff in a :class:`ChaosLink` —
        per-shard failures retransmit up to the link's retry budget;
        a shard that exhausts it arrives corrupted, the receiver's
        checksum (``stream(checksum=True)``) catches it, and the
        request falls back to re-prefilling on the decode engine with
        bit-identical greedy tokens.  Crash/straggle chaos is rejected
        there: the pair is a fixed topology.
        """
        from repro.serving.faults import CheckpointStore, GroupHealth
        if self.spec.pd and (plan.crashes or plan.straggles):
            raise ValueError("the pd pair is a fixed topology — only "
                             "flaky_link chaos applies; run crash/"
                             "straggle plans on the colocated pool "
                             "(pd=False)")
        n = len(self.engines)
        self._chaos = plan.bind(n, recovery=recovery,
                                health=GroupHealth(n))
        self._store = (CheckpointStore(recovery)
                       if recovery is not None else None)
        return self

    def scale(self, *, add: Optional[Sequence[Sequence[str]]] = None,
              remove: Optional[Sequence[int]] = None,
              at: float = 0.0, warmup: float = 0.0
              ) -> "LaunchedDeployment":
        """Schedule runtime autoscaling on the REAL engine pool — the
        same decision surface as ``Deployment.scale``, executed during
        the next :meth:`run`.

        ``remove``: engine indices that drain at ``at`` seconds into
        the run — the engine stops taking admissions, every resident
        mid-decode session is exported (``export_sessions``) and
        re-imported into a surviving engine (``import_session``), so
        no accepted request is dropped and greedy tokens are
        bit-identical to never having moved.  ``add``: device-name
        lists (cosmetic here — every launch engine runs the same local
        model) whose engines are built at ``at + warmup`` and
        jit-primed (``ServingEngine.warmup``) BEFORE they become
        routable; warm-up is real compile work on this backend, so
        ``warmup`` only delays when it starts.  Only the colocated
        pool scales (``pd=False``); the PD pair is a fixed topology.

        Removing every routable engine is rejected up front: the
        scheduled actions are replayed (adds count at their start
        time) and the call raises if any drain would leave nothing
        routable — schedule the replacement at or before the drain.
        """
        if self.spec.pd:
            raise ValueError("live scale() drives the colocated engine "
                             "pool; the pd=True prefill/decode pair is "
                             "a fixed topology")
        adds = [list(g) for g in (add or [])]
        for group in adds:
            for name in group:
                if name not in CATALOG:
                    raise ValueError(f"unknown device {name!r}; "
                                     f"pick from {sorted(CATALOG)}")
        n_total = len(self.engines) + sum(
            1 for a in self._actions if a["kind"] == "add") + len(adds)
        removals = []
        for g in (remove or []):
            g = int(g)
            if not 0 <= g < n_total or g in removals:
                raise ValueError(f"cannot remove engine {g}; pool has "
                                 f"{n_total} (scheduled adds included) "
                                 f"and repeats are not allowed")
            removals.append(g)
        planned = sorted(
            self._actions
            + [{"at": float(at) + float(warmup), "kind": "add"}
               for _ in adds]
            + [{"at": float(at), "kind": "remove", "group": g}
               for g in removals],
            key=lambda a: (a["at"], 0 if a["kind"] == "add" else 1))
        routable = sum(self._routable)
        for a in planned:
            routable += 1 if a["kind"] == "add" else -1
            if routable < 1:
                raise ValueError(
                    f"scale(remove={removals}) would drain the last "
                    f"routable engine at t={float(at)}; scale up first "
                    f"(or give the drain a later `at`)")
        self._actions = planned
        return self

    def _live_chan(self, src: int, dst: int):
        """The live fabric channel between two ENGINE indices, or None
        — without a fabric, for same-group/same-island hops, and for
        autoscaled engines past the founding groups (the topology only
        maps the groups the spec declared)."""
        fab = self._fabric
        if fab is None:
            return None
        n = len(self.spec.groups)
        if not (0 <= src < n and 0 <= dst < n):
            return None
        return fab.channel(src, dst)

    def _account_ckpt(self, gi: int, nbytes: int) -> None:
        """CheckpointStore ``on_store`` hook: snapshot bytes ride the
        fabric to the host as bulk traffic (skipped for autoscaled
        engines and host-less topologies — accounting must never make
        a checkpoint fail)."""
        fab = self._fabric
        if fab is None or not (0 <= gi < len(self.spec.groups)):
            return
        if fab.topo.host_island is None:
            return
        fab.account_ckpt(gi, int(nbytes))

    def _pick_engine(self):
        """The routable engine with the most free slots (host view;
        conservative between syncs), or None when every one is full."""
        best, best_free = None, 0
        for j, eng in enumerate(self.engines):
            if not self._routable[j]:
                continue
            free = eng.active.count(None)
            if free > best_free:
                best, best_free = eng, free
        return best

    def _apply_action(self, act: Dict[str, Any], clk) -> None:
        if act["kind"] == "add":
            from repro.serving.engine import ServingEngine
            eng = ServingEngine(self.cfg, self.params, **self._engine_kw)
            eng.warmup()        # compiles primed BEFORE routable flips
            self.engines.append(eng)
            self._routable.append(True)
            return
        g = act["group"]
        self._routable[g] = False     # no new admissions from here on
        for req, h in self.engines[g].export_sessions(clk()):
            self.wire_bytes += h["kv_bytes"]
            self.migrations += 1
            while True:
                tgt = self._pick_engine()
                if tgt is not None and tgt.import_session(req, h, clk()):
                    ch = self._live_chan(g, self.engines.index(tgt))
                    if ch is not None:
                        from repro.serving.fabric import BULK
                        ch.account(int(h["kv_bytes"]), BULK)
                    break
                # every routable engine full: drain one decode step
                # everywhere and retry — a slot frees in finitely many
                # steps because resident budgets are finite
                for eng in self.engines:
                    eng.step(clk())

    def _run_pool(self, requests: Sequence) -> Dict[str, Any]:
        """Elastic multi-engine run: admit due arrivals to the
        least-loaded routable engine, apply due scale actions (AFTER
        admission, so an ``at=0`` drain deterministically exercises
        in-flight migration), step every engine with resident work."""
        t0 = time.perf_counter()

        def clk() -> float:
            return time.perf_counter() - t0

        pending = sorted(requests, key=lambda r: (r.arrival, r.rid))
        acts = list(self._actions)
        self._actions = []
        chaos = self._chaos
        chaos_evs: List[Tuple[float, str, int]] = []
        if chaos is not None:
            for c in chaos.plan.crashes:
                chaos_evs.append((c.t, "crash", c.group))
                if c.recover_at is not None:
                    chaos_evs.append((c.recover_at, "revive", c.group))
            chaos_evs.sort()
        waiting: List[Any] = []     # checkpointed crash victims, until
        #                             a survivor has room to restore
        ticks = 0
        while pending or acts or chaos_evs or waiting \
                or any(e._any_active() for e in self.engines):
            now = clk()
            ticks += 1
            if self._store is not None:
                self._store.poll(self.engines, now,
                                 on_store=(self._account_ckpt
                                           if self._fabric is not None
                                           else None))
            while pending and pending[0].arrival <= now:
                eng = self._pick_engine()
                if eng is None:
                    break               # pool full; step below drains
                eng.admit_batch([pending.pop(0)], clk())
            while acts and acts[0]["at"] <= now:
                self._apply_action(acts.pop(0), clk)
            while chaos_evs and chaos_evs[0][0] <= now:
                _, kind, g = chaos_evs.pop(0)
                if kind == "crash":
                    self._routable[g] = False
                    chaos.health.trip(g, now)
                    for req in self.engines[g].sessions.crash(clk()):
                        self.lost_sessions += 1
                        if self._store is not None \
                                and req.rid in self._store:
                            waiting.append(req)
                        else:           # no checkpoint: from scratch
                            del req.output[:]
                            pending.append(req)
                    pending.sort(key=lambda r: (r.arrival, r.rid))
                else:                   # revive
                    self._routable[g] = True
                    chaos.health.reset(g, now)
            if waiting:
                still = []
                for req in waiting:
                    tgt = self._pick_engine()
                    if tgt is not None and \
                            self._store.restore(req, tgt, clk()):
                        self.recovered_sessions += 1
                    else:               # no room yet: retry next tick
                        still.append(req)
                waiting = still
            stepped = False
            for g, eng in enumerate(self.engines):
                if chaos is not None:
                    f = chaos.straggle_factor(g, now)
                    if f > 1.0 and ticks % max(int(round(f)), 1):
                        continue        # straggler: skip this tick
                if eng._any_active():
                    eng.step(clk())
                    stepped = True
            if not stepped:
                due = [r.arrival for r in pending[:1]] \
                    + [a["at"] for a in acts[:1]] \
                    + [e[0] for e in chaos_evs[:1]]
                if due:
                    time.sleep(min(0.05, max(0.0, min(due) - clk())))
        for eng in self.engines:
            eng.sync(clk())
        summaries = [e.stats.summary() for e in self.engines]
        agg = {k: sum(s[k] for s in summaries)
               for k in ("completed", "decode_steps", "host_syncs",
                         "prefill_batches")}
        done = sum(s["completed"] for s in summaries)
        for k in ("mean_ttft", "mean_tpot", "mean_norm_latency"):
            agg[k] = (sum(s[k] * s["completed"] for s in summaries)
                      / done if done else 0.0)
        out = {"engine": agg, "engines": summaries,
               "wire_bytes": self.wire_bytes, "shards": self.shards,
               "migrations": self.migrations,
               "routable": list(self._routable)}
        if chaos is not None:
            out.update(
                lost_sessions=self.lost_sessions,
                recovered_sessions=self.recovered_sessions,
                checkpoints=(self._store.checkpoints
                             if self._store is not None else 0))
        if self._fabric is not None:
            out["fabric"] = self._fabric.stats()
        return out

    # ------------------------------------------------------------------ #
    def _counted(self, gen):
        for item in gen:
            if not item.get("header"):
                self.shards += 1
                self.wire_bytes += item["bytes"]
            yield item

    def _counted_native(self, gen):
        """Shard accounting for a native ``sessions.stream`` (KvSlice /
        SessionState items — the chaos-injected path keeps the typed
        shards so checksums survive to the receiver)."""
        from repro.serving.kvpool import SessionState
        for item in gen:
            if not isinstance(item, SessionState):
                self.shards += 1
                self.wire_bytes += item.nbytes
            yield item

    def run(self, requests: Sequence) -> Dict[str, Any]:
        """Serve ``requests`` (engine ``Request`` objects, mutated in
        place with outputs/timestamps) to completion.  Returns a stats
        dict; for a PD pair the decode engine's stats are the
        user-visible ones (it streams every token)."""
        if not self.spec.pd:
            if len(self.engines) == 1 and not self._actions \
                    and self._chaos is None:
                # solo fast path: identical to the pre-elastic backend
                stats = self.engine.run(list(requests))
                return {"engine": stats.summary(), "wire_bytes": 0,
                        "shards": 0}
            return self._run_pool(requests)
        t0 = time.perf_counter()
        pre, dec = self.prefill_engine, self.decode_engine

        def clk() -> float:
            return time.perf_counter() - t0

        ordered = sorted(requests, key=lambda r: (r.arrival, r.rid))
        if self.spec.kv_chunks > 1:
            link = (self._chaos.live_link(0, 1)
                    if self._chaos is not None else None)
            # live fabric channel prefill -> decode: the handoff's
            # shards are counted as URGENT (decode-blocking) traffic.
            # The channel wraps OUTSIDE the chaos link, so it counts
            # each shard as delivered once (retransmits inside the
            # link are the link's own accounting, link.retries).
            fabch = self._live_chan(0, 1)
            for req in ordered:
                if link is None and fabch is None:
                    gen = self._counted(
                        pre.prefill_handoff_stream(req, clk()))
                    while not dec.admit_handoff_stream(req, gen, clk()):
                        dec.step(clk())     # drain a slot, retry
                    continue
                # chaos-injected and/or fabric-accounted handoff:
                # typed shards (checksummed only under chaos) through
                # the flaky channel.  Transient failures retransmit
                # inside the link; a shard that exhausts its retries
                # arrives corrupted and the receiver's checksum trips.
                from repro.serving.fabric import URGENT
                from repro.serving.kvpool import ShardChecksumError
                shards = self._counted_native(
                    pre.sessions.stream(req, clk(),
                                        checksum=link is not None,
                                        klass=URGENT))
                if link is not None:
                    shards = link.wrap(shards)
                if fabch is not None:
                    shards = fabch.wrap(shards)
                try:
                    while not dec.sessions.receive(req, shards, clk()):
                        dec.step(clk())     # drain a slot, retry
                except ShardChecksumError:
                    # poisoned transfer: run BOTH phases on the decode
                    # engine instead.  The aborted stream never touched
                    # req.output, so the greedy tokens of the re-prefill
                    # are bit-identical to the fault-free run.
                    self.reprefills += 1
                    del req.output[:]
                    while not dec.admit_batch([req], clk()):
                        dec.step(clk())
            if link is not None:
                self.kv_retries = link.retries
                self.kv_corrupted = link.corrupted
        else:
            fabch = self._live_chan(0, 1)
            handoffs: List[Tuple[Any, Dict]] = []
            for req in ordered:
                h = pre.prefill_handoff(req, clk())
                if not h["done"]:
                    self.wire_bytes += h["kv_bytes"]
                    if fabch is not None:
                        from repro.serving.fabric import URGENT
                        fabch.account(int(h["kv_bytes"]), URGENT)
                    handoffs.append((req, h))
            while handoffs:
                while handoffs and dec.admit_handoff(
                        handoffs[0][0], handoffs[0][1], clk()):
                    handoffs.pop(0)
                if handoffs:
                    dec.step(clk())
        while dec._any_active():
            dec.step(clk())
        dec.sync(clk())
        out = {"engine": dec.stats.summary(),
               "prefill": pre.stats.summary(),
               "wire_bytes": self.wire_bytes, "shards": self.shards}
        if self._chaos is not None:
            out.update(kv_retries=self.kv_retries,
                       kv_corrupted=self.kv_corrupted,
                       reprefills=self.reprefills)
        if self._fabric is not None:
            out["fabric"] = self._fabric.stats()
        return out
