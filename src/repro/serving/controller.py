"""Closed-loop autoscaling: the controller that *decides*.

PR 5 shipped every elasticity mechanism — ``Deployment.scale()``, the
``ControlEvent`` timeline, loss-free drain, warm-up gating — but the
composition stayed frozen at construction while the workload generators
produce diurnal/bursty traces whose demand swings several-fold.  This
module closes the loop: :class:`AutoscalePolicy` watches the DES's
windowed signals (shed rate, SLO misses, queue depth, per-group
utilization — the ``ClusterResult``/``OnlineMonitor`` vocabulary) and
issues the same up/down control events ``Deployment.scale`` builds,
against the spec's ``$/hr`` budget:

  * **scale up** from a parked reserve pool when the windowed shed
    rate or queue depth breach their thresholds — the best
    capacity-per-dollar reserve group that still fits the budget is
    activated behind a modeled warm-up delay;
  * **scale down** toward the cheapest composition that still clears
    the observed demand when the window is clean (no sheds, low
    backlog, low utilization) — the priciest group whose removal keeps
    ``capacity >= headroom * demand`` drains gracefully;
  * **hysteresis + cooldown** so the controller does not flap: up
    thresholds are inflated by ``(1 + hysteresis)`` and down
    thresholds deflated by ``(1 - hysteresis)`` (the
    ``MonitorConfig`` band idiom), and at most one action fires per
    ``cooldown`` seconds.

The controller plugs into ``Deployment.simulate(trace,
controller=...)``; the DES hands it a
:class:`~repro.core.simulator.ControlSignals` snapshot every
``interval`` seconds of *simulated* time and merges the returned
events into the live timeline (``simulator.simulate_deployment``).

Billing is time-weighted: every group accrues ``$/hr`` only while
provisioned — founding groups from the trace start until drained,
reserve groups from the activation *decision* (warm-up time is paid
for, as on real clouds) until drained.  :meth:`AutoscalePolicy
.billed_dollars` and :func:`goodput_per_dollar` make the elastic run
comparable with static compositions, whose bill is simply
``price_rate * makespan``.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

from repro.core.simulator import (ClusterResult, ControlEvent,
                                  ControlSignals)


@dataclasses.dataclass(frozen=True)
class AutoscaleConfig:
    """Thresholds and pacing for :class:`AutoscalePolicy`.

    Signals are aggregated over a sliding ``window`` of decision
    epochs (``interval`` seconds each).  ``shed_hi`` and ``queue_hi``
    trigger scale-up; a window below ``queue_lo`` mean backlog *and*
    ``util_lo`` utilization with zero sheds allows scale-down.
    ``hysteresis`` widens the dead band between the two regimes
    (MonitorConfig idiom: up thresholds ``*(1+h)``, down ``*(1-h)``);
    ``cooldown`` spaces actions; ``warmup`` is the modeled delay
    before an activated group becomes routable; ``headroom`` keeps
    modeled capacity at ``headroom * observed demand`` after any
    scale-down.
    """
    interval: float = 1.0        # decision-epoch seconds (DES time)
    window: float = 4.0          # sliding-window span in seconds
    shed_hi: float = 0.0         # windowed shed fraction above -> up
    queue_hi: float = 1.0        # mean eligible-group backlog (s) -> up
    queue_lo: float = 0.25       # mean backlog below -> down allowed
    util_lo: float = 0.5         # mean eligible utilization below -> down
    hysteresis: float = 0.1
    cooldown: float = 2.0        # min seconds between actions
    warmup: float = 1.0          # modeled warm-up of an activated group
    headroom: float = 1.3        # capacity >= headroom * demand after down
    # KV-memory pressure: scale up when the windowed max per-group
    # KV-block utilization exceeds this.  None (default) ignores the
    # signal entirely — existing controller runs are bit-identical.
    # Only meaningful when the DES runs a KvPoolModel (otherwise
    # ControlSignals.kv_util is empty and the trigger never fires).
    kv_hi: Optional[float] = None

    def __post_init__(self):
        if self.interval <= 0.0:
            raise ValueError("interval must be > 0")
        if self.window < self.interval:
            raise ValueError("window must cover at least one interval")


@dataclasses.dataclass(frozen=True)
class ScaleDecision:
    """One controller action, for the audit log / benchmark table."""
    time: float
    action: str                  # "up" | "down"
    group: int
    reason: str
    price_rate: float            # active $/hr AFTER the action


class AutoscalePolicy:
    """Budget-aware up/down decisions from windowed DES signals.

    ``inventory`` lists reserve group templates (device-name lists,
    e.g. ``[["a100", "l40s"], ["l40s"]]``) that are *planned* up front
    but start parked (ineligible, unbilled).  The controller activates
    them under pressure and parks active groups when the window is
    clean — founding and reserve groups are treated uniformly once
    running, so the composition can shrink below the founding shape in
    a trough and regrow later.

    Protocol consumed by ``simulator.simulate_deployment``: attributes
    ``interval``/``reserve``; methods ``begin(t0)``,
    ``decide(signals)``, ``finish(t_end)``.  ``Deployment.simulate``
    calls :meth:`bind` first.  All state is reset per run, so one
    policy instance supports repeated apples-to-apples replays.
    """

    def __init__(self, config: AutoscaleConfig = AutoscaleConfig(),
                 inventory: Optional[Sequence[Sequence[str]]] = None):
        self.cfg = config
        self.inventory = [list(g) for g in (inventory or [])]
        self._dep = None
        self.reserve: List[int] = []     # parked group indices (live)
        self._price: Dict[int, float] = {}
        self._capacity: Dict[int, float] = {}
        self._budget: Optional[float] = None
        self._reset(0.0)

    # -------------------------------------------------------------- #
    @property
    def interval(self) -> float:
        return self.cfg.interval

    def bind(self, deployment) -> None:
        """Attach to a compiled Deployment (idempotent): provision the
        reserve pool and cache per-group price / modeled capacity."""
        if self._dep is deployment:
            return
        if self._dep is not None:
            raise ValueError("AutoscalePolicy is already bound to a "
                             "different deployment")
        self._dep = deployment
        self._initial_reserve = list(
            deployment.add_reserve(self.inventory))
        self._budget = deployment.spec.budget
        cluster = deployment.cluster()
        for i, g in enumerate(cluster.groups):
            self._price[i] = g.price
            self._capacity[i] = 1.0 / g.plans["throughput"].bottleneck
        # founding groups = everything NOT parked on the deployment;
        # reserves parked by a previously bound controller stay parked
        # (and unbilled) rather than masquerading as founders
        self._founders = [i for i in range(len(cluster.groups))
                          if i not in deployment._reserve]

    # -------------------------------------------------------------- #
    def _reset(self, t0: float) -> None:
        # O(1) window fold: arrivals/shed are integer counters, so the
        # running totals stay exact as epochs enter and leave the
        # deque; only the per-group pressure rows (which depend on the
        # CURRENT active set applied to each snapshot) still walk the
        # bounded window
        keep = max(1, int(round(self.cfg.window / self.cfg.interval)))
        self._win: Deque[ControlSignals] = deque(maxlen=keep)
        self._arr_sum = 0
        self._shed_sum = 0
        self._last_action = t0 - self.cfg.cooldown
        self._warm_at: Dict[int, float] = {}
        self.active: Dict[int, float] = {}   # group -> billing start
        self.decisions: List[ScaleDecision] = []
        self._bill: List[Tuple[int, float, Optional[float]]] = []
        self._horizon: Optional[float] = None

    def begin(self, t0: float) -> None:
        """Run start (called by the DES): founding groups go active
        and billed from ``t0``; the reserve pool parks."""
        if self._dep is None:
            raise ValueError("call bind(deployment) before a run — "
                             "Deployment.simulate(controller=...) does")
        self._reset(t0)
        self.reserve = list(self._initial_reserve)
        for i in self._founders:
            self.active[i] = t0
            self._warm_at[i] = t0

    def finish(self, t_end: float) -> None:
        """Run end: close every open billing interval at ``t_end``."""
        self._horizon = t_end
        for g, on in self.active.items():
            self._bill.append((g, on, None))

    # -------------------------------------------------------------- #
    @property
    def active_price_rate(self) -> float:
        return sum(self._price[g] for g in self.active)

    def billed_dollars(self, horizon: Optional[float] = None) -> float:
        """Time-weighted rental: each group accrues its $/hr only
        while provisioned (activation decision -> drain), warm-up
        included."""
        h = self._horizon if horizon is None else horizon
        if h is None:
            raise ValueError("run not finished; pass an explicit "
                             "horizon")
        closed = [(g, on, off if off is not None else h)
                  for g, on, off in self._bill]
        return sum(self._price[g] * max(0.0, min(off, h) - on) / 3600.0
                   for g, on, off in closed)

    # -------------------------------------------------------------- #
    def _windowed(self):
        win = self._win
        arr = self._arr_sum
        shed = self._shed_sum
        span = len(win) * self.cfg.interval
        demand = arr / max(span, 1e-12)
        shed_rate = shed / max(arr, 1)
        # queue/util over ACTIVE, WARM groups only: parked or warming
        # groups idle at zero and would dilute the pressure signal
        rows = []
        for s in win:
            idx = [g for g in self.active
                   if s.eligible[g] and self._warm_at[g] <= s.now]
            if idx:
                rows.append((sum(s.backlog[g] for g in idx) / len(idx),
                             sum(s.util[g] for g in idx) / len(idx)))
        backlog = sum(r[0] for r in rows) / len(rows) if rows else 0.0
        util = sum(r[1] for r in rows) / len(rows) if rows else 0.0
        return demand, shed_rate, backlog, util

    def _scale_up(self, now: float, reason: str) -> List[ControlEvent]:
        """Activate the best capacity-per-dollar reserve group that
        still fits the budget."""
        afford = [g for g in self.reserve
                  if self._budget is None
                  or self.active_price_rate + self._price[g]
                  <= self._budget + 1e-9]
        if not afford:
            return []
        g = max(afford, key=lambda i: (
            self._capacity[i] / max(self._price[i], 1e-12), -i))
        self.reserve.remove(g)
        self.active[g] = now                 # billed from the decision
        self._warm_at[g] = now + self.cfg.warmup
        self._last_action = now
        self.decisions.append(ScaleDecision(
            now, "up", g, reason, self.active_price_rate))
        return [ControlEvent(now + self.cfg.warmup, "up", g)]

    def _scale_down(self, now: float, demand: float,
                    reason: str) -> List[ControlEvent]:
        """Park the priciest warm group whose removal still leaves
        ``headroom * demand`` of modeled capacity."""
        warm = [g for g in self.active if self._warm_at[g] <= now]
        if len(warm) <= 1:
            return []                        # never drain the last group
        total_cap = sum(self._capacity[g] for g in self.active)
        need = self.cfg.headroom * demand
        drop = [g for g in warm if total_cap - self._capacity[g] >= need]
        if not drop:
            return []
        g = max(drop, key=lambda i: (self._price[i], i))
        self._bill.append((g, self.active.pop(g), now))
        self.reserve.append(g)
        self._last_action = now
        self.decisions.append(ScaleDecision(
            now, "down", g, reason, self.active_price_rate))
        return [ControlEvent(now, "down", g)]

    def decide(self, sig: ControlSignals) -> List[ControlEvent]:
        """One decision epoch: fold the new snapshot into the sliding
        window, then at most one action (after the cooldown)."""
        if len(self._win) == self._win.maxlen:
            old = self._win[0]
            self._arr_sum -= old.arrivals
            self._shed_sum -= old.shed
        self._win.append(sig)
        self._arr_sum += sig.arrivals
        self._shed_sum += sig.shed
        if sig.now - self._last_action < self.cfg.cooldown:
            return []
        demand, shed_rate, backlog, util = self._windowed()
        h = self.cfg.hysteresis
        if shed_rate > self.cfg.shed_hi * (1.0 + h) + 1e-12:
            return self._scale_up(
                sig.now, f"shed_rate={shed_rate:.3f}")
        if backlog > self.cfg.queue_hi * (1.0 + h):
            return self._scale_up(
                sig.now, f"backlog={backlog:.3f}s")
        if self.cfg.kv_hi is not None:
            # windowed MAX (not mean) per-group KV-block utilization:
            # one full pool delays admissions even while others idle
            kv = 0.0
            for s in self._win:
                if not s.kv_util:
                    continue
                for g in self.active:
                    if (g < len(s.kv_util) and s.eligible[g]
                            and self._warm_at[g] <= s.now):
                        kv = max(kv, s.kv_util[g])
            if kv > self.cfg.kv_hi * (1.0 + h):
                return self._scale_up(sig.now, f"kv_util={kv:.2f}")
        if (shed_rate == 0.0
                and backlog < self.cfg.queue_lo * (1.0 - h)
                and util < self.cfg.util_lo * (1.0 - h)):
            return self._scale_down(
                sig.now, demand,
                f"idle util={util:.2f} backlog={backlog:.3f}s")
        return []


def goodput_per_dollar(result: ClusterResult,
                       billed: Optional[float] = None) -> float:
    """Requests served within SLO per rental dollar.

    For a static composition (``billed=None``) the bill is
    ``price_rate * makespan``; an elastic run passes
    ``AutoscalePolicy.billed_dollars()``.  Reduces to the sizing
    objective ``goodput * 3600 / price_rate`` in the static case.
    """
    if billed is None:
        billed = result.price_rate * result.makespan / 3600.0
    return result.slo_ok / max(billed, 1e-12)
