"""Contended fabric: topology-aware shared channels + a KV-transfer
scheduler.

The point-to-point :class:`~repro.core.simulator.Interconnect` prices a
transfer as if every request owned a private pipe.  Real clusters do
not work that way: groups inside an NVLink island share the island's
switch fabric, islands talk over a handful of PCIe/IB crossings, and a
checkpoint ship to host contends with the KV shard a decode replica is
blocked on.  This module models exactly that:

* :class:`Island` / :class:`Crossing` / :class:`Topology` — a named
  description of the fabric (which groups share an NVLink island, which
  island pairs are bridged, at what bandwidth/latency, full- or
  half-duplex) with a dict/JSON round trip so it rides inside
  ``DeploymentSpec``.
* :class:`ChannelState` — one shared directed channel with
  cross-request queueing.  Two disciplines:

  - ``"fifo"``: a single busy-until timeline; urgent and bulk traffic
    book in dispatch order.
  - ``"priority"`` (the :class:`TransferScheduler` policy):
    decode-blocking KV shards book immediately against the urgent
    timeline, while bulk traffic (checkpoint shipping, session
    migration, spill/prefetch) is *preemptible*: it drains lazily into
    the gaps urgent traffic leaves behind, sliced into as many spans as
    preemption requires.

* :class:`FabricState` — per-run mutable state: lowers ``src -> dst``
  group transfers onto channels, accepts bulk enqueues, materializes
  bulk schedules up to a watermark, and cancels pending bulk whose
  source died.
* :class:`LiveFabric` — accounting twin for launched engines: counts
  real streamed bytes per channel per class and reports the modeled
  channel seconds they would occupy.

Determinism contract: the committed-schedule DES resolves everything in
global dispatch order, so every urgent booking made *after* time ``w``
has ``ready >= w``.  That makes ``w`` a safe bulk watermark — any idle
interval the urgent timeline has left below ``w`` is permanently free,
and bulk can be materialized into it without ever needing to rewrite an
already-emitted event.  (This is the same trick the fast DES core uses:
commit early, never revisit.)

What the model abstracts away (honest caveats, also in the README):
one bottleneck channel per (src, dst) pair — no store-and-forward
multi-hop, no per-link routing inside an island; bulk slices restart
for free after preemption (no re-transmission penalty); FIFO bulk books
at enqueue time and cannot be cancelled.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Callable, Dict, Iterable, Iterator, List, Optional, Tuple

__all__ = [
    "URGENT", "BULK", "HOST",
    "Island", "Crossing", "Topology", "TransferScheduler",
    "ChannelState", "FabricState", "LiveChannel", "LiveFabric",
]

# Priority classes.  URGENT is decode-blocking KV movement; BULK is
# everything that can wait (checkpoint shipping, session migration,
# spill/prefetch).
URGENT = 0
BULK = 1

# Pseudo group index for the host-side checkpoint store.
HOST = -1

_EPS = 1e-12

_ISLAND_KEYS = frozenset({"name", "groups", "bw", "latency"})
_CROSSING_KEYS = frozenset({"src", "dst", "bw", "latency", "duplex"})
_TOPOLOGY_KEYS = frozenset(
    {"islands", "crossings", "host_island", "scheduler"})


# ===================================================================== #
# Topology description
# ===================================================================== #
@dataclasses.dataclass(frozen=True)
class Island:
    """A set of replica groups behind one shared switch fabric (an
    NVLink island).  Intra-island transfers between *different* groups
    all ride one shared channel with ``bw`` bytes/s and ``latency``
    seconds of per-transfer setup."""
    name: str
    groups: Tuple[int, ...] = ()
    bw: float = 600e9
    latency: float = 5e-6

    def __post_init__(self) -> None:
        if not self.name:
            raise ValueError("island needs a non-empty name")
        if self.bw <= 0 or self.latency < 0:
            raise ValueError(f"island {self.name!r}: bw must be > 0 and "
                             f"latency >= 0")
        gs = tuple(int(g) for g in self.groups)
        if any(g < 0 for g in gs):
            raise ValueError(f"island {self.name!r}: negative group index")
        if len(set(gs)) != len(gs):
            raise ValueError(f"island {self.name!r}: duplicate group")
        object.__setattr__(self, "groups", gs)


@dataclasses.dataclass(frozen=True)
class Crossing:
    """A bridge between two islands (PCIe switch, IB link, host NIC).
    ``duplex="full"`` gives each direction its own channel; ``"half"``
    makes both directions share ONE channel — the congestion mechanism
    a checkpoint ship exploits when it fights a KV shard headed the
    other way."""
    src: str
    dst: str
    bw: float = 25e9
    latency: float = 50e-6
    duplex: str = "full"

    def __post_init__(self) -> None:
        if self.src == self.dst:
            raise ValueError(f"crossing {self.src!r}->{self.dst!r}: "
                             f"endpoints must differ")
        if self.bw <= 0 or self.latency < 0:
            raise ValueError(f"crossing {self.src!r}->{self.dst!r}: bw must "
                             f"be > 0 and latency >= 0")
        if self.duplex not in ("full", "half"):
            raise ValueError(f"crossing {self.src!r}->{self.dst!r}: duplex "
                             f"must be 'full' or 'half', got {self.duplex!r}")


@dataclasses.dataclass(frozen=True)
class Topology:
    """The fabric: islands, crossings between them, where the host-side
    checkpoint store hangs (``host_island``), and which scheduling
    discipline channels run (``scheduler``: ``"priority"`` or
    ``"fifo"``).

    Lowers a ``src -> dst`` group pair onto a channel key:

    * same island                  -> the island's shared channel
    * islands bridged ``src->dst`` -> that crossing's channel
    * only the reverse crossing exists and it is half-duplex
                                   -> the SAME channel as the reverse
    * anything else                -> ``ValueError``

    Group index ``HOST`` (-1) denotes the host checkpoint store and
    resolves to ``host_island``.
    """
    islands: Tuple[Island, ...]
    crossings: Tuple[Crossing, ...] = ()
    host_island: Optional[str] = None
    scheduler: str = "priority"

    def __post_init__(self) -> None:
        object.__setattr__(self, "islands", tuple(self.islands))
        object.__setattr__(self, "crossings", tuple(self.crossings))
        if not self.islands:
            raise ValueError("topology needs at least one island")
        if self.scheduler not in ("priority", "fifo"):
            raise ValueError(f"scheduler must be 'priority' or 'fifo', "
                             f"got {self.scheduler!r}")
        names = [i.name for i in self.islands]
        if len(set(names)) != len(names):
            raise ValueError("duplicate island name")
        seen: Dict[int, str] = {}
        for isl in self.islands:
            for g in isl.groups:
                if g in seen:
                    raise ValueError(f"group {g} in both {seen[g]!r} and "
                                     f"{isl.name!r}")
                seen[g] = isl.name
        if self.host_island is not None and self.host_island not in names:
            raise ValueError(f"host_island {self.host_island!r} is not a "
                             f"declared island")
        xs = set()
        for x in self.crossings:
            if x.src not in names or x.dst not in names:
                raise ValueError(f"crossing {x.src!r}->{x.dst!r} references "
                                 f"an undeclared island")
            if (x.src, x.dst) in xs:
                raise ValueError(f"duplicate crossing {x.src!r}->{x.dst!r}")
            xs.add((x.src, x.dst))
        for x in self.crossings:
            if (x.duplex == "half" and (x.dst, x.src) in xs):
                raise ValueError(f"half-duplex crossing {x.src!r}->{x.dst!r} "
                                 f"conflicts with a declared reverse crossing")
        # Eager reachability: every ordered island pair that can source
        # a transfer (has groups, or is the host island) must resolve.
        ends = [i.name for i in self.islands
                if i.groups or i.name == self.host_island]
        for a in ends:
            for b in ends:
                if a != b:
                    self._crossing_of(a, b)  # raises if unreachable

    # -- lowering ----------------------------------------------------- #
    def island_of(self, group: int) -> Island:
        if group == HOST:
            if self.host_island is None:
                raise ValueError("transfer touches the host but the "
                                 "topology declares no host_island")
            for isl in self.islands:
                if isl.name == self.host_island:
                    return isl
        for isl in self.islands:
            if group in isl.groups:
                return isl
        raise ValueError(f"group {group} is not on any island")

    def _crossing_of(self, a: str, b: str) -> Tuple[Crossing, Tuple]:
        for x in self.crossings:
            if x.src == a and x.dst == b:
                return x, ("x", a, b)
        for x in self.crossings:
            if x.src == b and x.dst == a and x.duplex == "half":
                return x, ("x", b, a)          # shared with the reverse
        raise ValueError(f"no crossing routes {a!r} -> {b!r}")

    def channel_key(self, src: int, dst: int) -> Optional[Tuple]:
        """Channel key for a src->dst group transfer, or ``None`` when
        no fabric hop is involved (same group)."""
        if src == dst:
            return None
        a, b = self.island_of(src), self.island_of(dst)
        if a.name == b.name:
            return ("isl", a.name)
        _, key = self._crossing_of(a.name, b.name)
        return key

    def channel_params(self, key: Tuple) -> Tuple[float, float]:
        """(bw, latency) of a channel key."""
        if key[0] == "isl":
            for isl in self.islands:
                if isl.name == key[1]:
                    return isl.bw, isl.latency
            raise KeyError(key)
        for x in self.crossings:
            if x.src == key[1] and x.dst == key[2]:
                return x.bw, x.latency
        raise KeyError(key)

    def planner_bw(self, group: int) -> float:
        """Effective KV/activation bandwidth the *planner* should
        assume for intra-group kernel placement: the group's island
        fabric, derated by how many co-resident groups share it (see
        :func:`repro.core.planner.contended_bw`)."""
        from repro.core.planner import contended_bw
        isl = self.island_of(group)
        return contended_bw(isl.bw, len(isl.groups))

    # -- dict / JSON round trip --------------------------------------- #
    def to_dict(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {
            "islands": [{"name": i.name, "groups": list(i.groups),
                         "bw": i.bw, "latency": i.latency}
                        for i in self.islands],
        }
        if self.crossings:
            d["crossings"] = [{"src": x.src, "dst": x.dst, "bw": x.bw,
                               "latency": x.latency, "duplex": x.duplex}
                              for x in self.crossings]
        if self.host_island is not None:
            d["host_island"] = self.host_island
        if self.scheduler != "priority":
            d["scheduler"] = self.scheduler
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Topology":
        if not isinstance(d, dict):
            raise ValueError(f"fabric must be a dict, got {type(d).__name__}")
        extra = set(d) - _TOPOLOGY_KEYS
        if extra:
            raise ValueError(f"unknown fabric key(s): {sorted(extra)}")
        islands = []
        for idat in d.get("islands", ()):
            extra = set(idat) - _ISLAND_KEYS
            if extra:
                raise ValueError(f"unknown island key(s): {sorted(extra)}")
            islands.append(Island(name=idat.get("name", ""),
                                  groups=tuple(idat.get("groups", ())),
                                  bw=float(idat.get("bw", 600e9)),
                                  latency=float(idat.get("latency", 5e-6))))
        crossings = []
        for xdat in d.get("crossings", ()):
            extra = set(xdat) - _CROSSING_KEYS
            if extra:
                raise ValueError(f"unknown crossing key(s): {sorted(extra)}")
            crossings.append(Crossing(src=xdat.get("src", ""),
                                      dst=xdat.get("dst", ""),
                                      bw=float(xdat.get("bw", 25e9)),
                                      latency=float(xdat.get("latency",
                                                             50e-6)),
                                      duplex=xdat.get("duplex", "full")))
        return cls(islands=tuple(islands), crossings=tuple(crossings),
                   host_island=d.get("host_island"),
                   scheduler=d.get("scheduler", "priority"))

    def bind(self, n_groups: int) -> "FabricState":
        """Per-run mutable state.  Validates that every group the run
        will place work on is on some island."""
        for g in range(n_groups):
            self.island_of(g)
        return FabricState(self, n_groups)


# The scheduler is the policy half of the channel: it decides how the
# two classes share a timeline.  Kept as an explicit object so the
# discipline is nameable/configurable ("priority" vs "fifo") rather
# than baked into ChannelState.
@dataclasses.dataclass(frozen=True)
class TransferScheduler:
    """Channel discipline.  ``"priority"``: urgent (decode-blocking KV)
    books immediately and preempts; bulk drains into the gaps, sliced
    as needed.  ``"fifo"``: one timeline, both classes book in dispatch
    order."""
    policy: str = "priority"

    def __post_init__(self) -> None:
        if self.policy not in ("priority", "fifo"):
            raise ValueError(f"unknown scheduler policy {self.policy!r}")

    def make_channel(self, key: Tuple, bw: float, latency: float
                     ) -> "ChannelState":
        return ChannelState(key, bw, latency, self.policy)


# ===================================================================== #
# One shared channel
# ===================================================================== #
class ChannelState:
    """Mutable schedule of one shared directed channel.

    Priority discipline invariants (the tests enforce these):

    * ``urgent_free`` only advances, and every urgent booking starts at
      ``max(ready, urgent_free)`` — urgent traffic NEVER waits for bulk.
    * Idle intervals below ``urgent_free`` are recorded in ``_gaps``;
      once recorded they are permanently free (any future urgent
      booking has ``ready >=`` the current watermark), so bulk can fill
      them without rewriting history.
    * Bulk is served strictly in ``(ready, seq)`` order, one segment at
      a time (``_cur`` holds the partially-served head), so completion
      order within the bulk class never inverts.
    * Byte conservation: the sum of emitted slice durations for a bulk
      tag equals its full ``latency + nbytes/bw`` duration.
    """

    __slots__ = ("key", "bw", "latency", "policy",
                 "urgent_free", "free", "busy", "wait", "nbytes",
                 "bulk_busy", "bulk_bytes", "bulk_ptr",
                 "_gaps", "_pend", "_cur", "_seq", "_done", "_order",
                 "_cancel")

    def __init__(self, key: Tuple, bw: float, latency: float,
                 policy: str = "priority") -> None:
        self.key = key
        self.bw = float(bw)
        self.latency = float(latency)
        self.policy = policy
        self.urgent_free = 0.0      # priority: urgent timeline head
        self.free = 0.0             # fifo: single timeline head
        self.busy = 0.0             # urgent seconds booked
        self.wait = 0.0             # urgent queueing delay (start-ready)
        self.nbytes = 0.0           # urgent bytes moved
        self.bulk_busy = 0.0        # bulk seconds emitted
        self.bulk_bytes = 0.0       # bulk bytes completed
        self.bulk_ptr = 0.0         # furthest bulk emission past urgent_free
        self._gaps: List[List[float]] = []   # settled idle [s, e) slots
        self._pend: List[Tuple] = []         # heap: (ready, seq, dur, nbytes, tag, src, dst, rid)
        self._cur: Optional[List] = None     # [resume_at, dur_left, nbytes, tag, src, dst, rid]
        self._seq = 0
        self._done: Dict[Any, float] = {}    # bulk tag -> completion time
        self._order: List[Any] = []          # bulk tags in service order
        self._cancel: set = set()

    # -- urgent class -------------------------------------------------- #
    def head(self) -> float:
        """Time the next urgent byte could start moving."""
        return self.urgent_free if self.policy == "priority" else self.free

    def duration(self, nbytes: float) -> float:
        return self.latency + nbytes / self.bw

    def commit_urgent(self, spans: Iterable[Tuple[float, float]],
                      ready: float, nbytes: float) -> None:
        """Book an urgent transfer whose spans were computed against
        ``head()``.  Under priority, idle intervals skipped over become
        permanent bulk gaps."""
        spans = list(spans)
        if not spans:
            return
        self.wait += max(0.0, spans[0][0] - ready)
        self.nbytes += nbytes
        for s, e in spans:
            self.busy += e - s
            if self.policy == "priority":
                a0 = max(self.urgent_free, self._open_ptr())
                if s > a0 + _EPS:
                    self._gaps.append([a0, s])
                if e > self.urgent_free:
                    self.urgent_free = e
            else:
                if e > self.free:
                    self.free = e

    def _open_ptr(self) -> float:
        # How far the open region past the last urgent booking has
        # already been consumed by bulk.
        return max(self.urgent_free, self.bulk_ptr)

    # -- bulk class ---------------------------------------------------- #
    def enqueue_bulk(self, ready: float, nbytes: float, tag: Any,
                     src: int, dst: int, rid: int,
                     sink: Optional[Callable] = None) -> None:
        if nbytes <= 0:
            self._done[tag] = ready
            self._order.append(tag)
            return
        dur = self.duration(nbytes)
        if self.policy == "fifo":
            # FIFO books at enqueue, in dispatch order, one timeline.
            s = max(ready, self.free)
            e = s + dur
            self.free = e
            self.bulk_busy += dur
            self.bulk_bytes += nbytes
            self._done[tag] = e
            self._order.append(tag)
            if sink is not None:
                sink(src, dst, rid, s, e)
            return
        heapq.heappush(self._pend,
                       (ready, self._seq, dur, nbytes, tag, src, dst, rid))
        self._seq += 1

    def materialize(self, w: float, sink: Optional[Callable] = None) -> None:
        """Serve pending bulk into settled capacity strictly below the
        watermark ``w``.  Safe whenever every future urgent booking is
        guaranteed ``ready >= w`` (true in dispatch order)."""
        if self.policy == "fifo":
            return
        while True:
            if self._cur is None:
                nxt = None
                while self._pend:
                    cand = self._pend[0]
                    if cand[4] in self._cancel:
                        heapq.heappop(self._pend)
                        self._cancel.discard(cand[4])
                        continue
                    nxt = cand
                    break
                if nxt is None or nxt[0] >= w:
                    return
                heapq.heappop(self._pend)
                ready, _, dur, nbytes, tag, src, dst, rid = nxt
                self._cur = [ready, dur, nbytes, tag, src, dst, rid]
                self._order.append(tag)
            cur = self._cur
            if cur[3] in self._cancel:
                self._cancel.discard(cur[3])
                self._cur = None
                continue
            s, cap, gi = self._slot(cur[0], w)
            if s is None:
                return
            take = min(cur[1], cap - s)
            e = s + take
            if sink is not None:
                sink(cur[4], cur[5], cur[6], s, e)
            self._consume(gi, s, e)
            self.bulk_busy += take
            cur[1] -= take
            if cur[1] <= _EPS:
                self._done[cur[3]] = e
                self.bulk_bytes += cur[2]
                self._cur = None
            else:
                cur[0] = e

    def _slot(self, r: float, w: float):
        """Earliest idle [s, cap) at or after ``r`` and strictly below
        ``w``: first a settled gap, else the open region past the
        urgent timeline.  Returns (start, cap, gap_index|None)."""
        for gi, (g0, g1) in enumerate(self._gaps):
            if g1 <= r + _EPS or g0 >= w:
                continue
            s = max(g0, r)
            if s < min(g1, w) - _EPS:
                return s, min(g1, w), gi
        s = max(self._open_ptr(), r)
        if s < w - _EPS:
            return s, w, None
        return None, None, None

    def _consume(self, gi: Optional[int], s: float, e: float) -> None:
        if gi is None:
            # Open region: remember progress so the next urgent commit
            # does not re-record [urgent_free, e) as a free gap.
            if e > self.bulk_ptr:
                self.bulk_ptr = e
            return
        g0, g1 = self._gaps[gi]
        frags = []
        if g0 < s - _EPS:
            frags.append([g0, s])
        if e < g1 - _EPS:
            frags.append([e, g1])
        self._gaps[gi:gi + 1] = frags

    def cancel_bulk(self, pred: Callable[[Any, int], bool]) -> int:
        """Cancel pending (un-started remainder of) bulk segments whose
        ``pred(tag, src)`` holds.  Already-emitted slices stay — that
        bandwidth was genuinely spent."""
        n = 0
        for item in self._pend:
            if item[4] not in self._cancel and pred(item[4], item[5]):
                self._cancel.add(item[4])
                n += 1
        cur = self._cur
        if (cur is not None and cur[3] not in self._cancel
                and pred(cur[3], cur[4])):
            self._cancel.add(cur[3])
            n += 1
        return n

    def done_at(self, tag: Any) -> Optional[float]:
        return self._done.get(tag)

    def completions(self) -> List[Tuple[Any, float]]:
        """(tag, completion) in bulk service order (completed only)."""
        return [(t, self._done[t]) for t in self._order if t in self._done]


# ===================================================================== #
# Per-run fabric state
# ===================================================================== #
class FabricState:
    """Channels + lowering for one simulation/launch run.  The
    simulator points ``sink`` at its event log so bulk slices emit
    ``FABRIC_BULK`` events when they materialize."""

    def __init__(self, topo: Topology, n_groups: int) -> None:
        self.topo = topo
        self.n_groups = n_groups
        self.scheduler = TransferScheduler(topo.scheduler)
        self._channels: Dict[Tuple, ChannelState] = {}
        self.sink: Optional[Callable[[int, int, int, float, float], None]] \
            = None
        self.bulk_enqueued = 0

    def channel(self, src: int, dst: int) -> Optional[ChannelState]:
        key = self.topo.channel_key(src, dst)
        if key is None:
            return None
        ch = self._channels.get(key)
        if ch is None:
            bw, lat = self.topo.channel_params(key)
            ch = self.scheduler.make_channel(key, bw, lat)
            self._channels[key] = ch
        return ch

    def channels(self) -> List[ChannelState]:
        return list(self._channels.values())

    # -- bulk traffic -------------------------------------------------- #
    def enqueue_bulk(self, src: int, dst: int, rid: int, nbytes: float,
                     ready: float, tag: Any) -> None:
        ch = self.channel(src, dst)
        if ch is None:
            return
        self.bulk_enqueued += 1
        ch.enqueue_bulk(ready, nbytes, tag, src, dst, rid, self.sink)

    def materialize(self, w: float) -> None:
        for ch in self._channels.values():
            ch.materialize(w, self.sink)

    def flush(self) -> None:
        self.materialize(float("inf"))

    def cancel_src(self, group: int, now: float) -> int:
        """A group died at ``now``: finish accounting up to ``now``,
        then cancel every not-yet-started bulk remainder sourced from
        it (its memory is gone; there is nothing left to ship)."""
        self.materialize(now)
        n = 0
        for ch in self._channels.values():
            n += ch.cancel_bulk(lambda tag, src: src == group)
        return n

    def ships_done(self, ship: Optional[Tuple[int, int, int]],
                   t: float) -> int:
        """How many checkpoint ships of record ``ship = (group, seq,
        K)`` completed by time ``t``.  Materializes to ``t`` first —
        safe because ``t`` is the fault time currently being applied in
        dispatch order."""
        if ship is None:
            return 0
        g, seq, total = ship
        ch = self.channel(g, HOST)
        if ch is None:
            return 0
        ch.materialize(t, self.sink)
        k = 0
        for j in range(1, total + 1):
            at = ch.done_at(("ckpt", seq, j))
            if at is not None and at <= t:
                k += 1
        return k

    # -- run-level accounting ------------------------------------------ #
    def stats(self) -> Dict[str, float]:
        chs = self._channels.values()
        return {
            "wait_seconds": sum(c.wait for c in chs),
            "urgent_seconds": sum(c.busy for c in chs),
            "urgent_bytes": sum(c.nbytes for c in chs),
            "bulk_seconds": sum(c.bulk_busy for c in chs),
            "bulk_bytes": sum(c.bulk_bytes for c in chs),
        }

    def ckpt_completed(self) -> int:
        n = 0
        for ch in self._channels.values():
            for tag in ch._done:
                if isinstance(tag, tuple) and tag and tag[0] == "ckpt":
                    n += 1
        return n


# ===================================================================== #
# Live accounting twin
# ===================================================================== #
class LiveChannel:
    """Accounting-only channel for launched engines: counts real bytes
    streamed per class and reports the modeled seconds they occupy."""

    __slots__ = ("key", "bw", "latency", "bytes_by_class",
                 "transfers_by_class")

    def __init__(self, key: Tuple, bw: float, latency: float) -> None:
        self.key = key
        self.bw = float(bw)
        self.latency = float(latency)
        self.bytes_by_class = {URGENT: 0, BULK: 0}
        self.transfers_by_class = {URGENT: 0, BULK: 0}

    def account(self, nbytes: int, klass: int = URGENT) -> None:
        self.bytes_by_class[klass] += int(nbytes)
        self.transfers_by_class[klass] += 1

    def wrap(self, shards: Iterable[Any], klass: int = URGENT
             ) -> Iterator[Any]:
        """Pass shards through, counting each stamped shard's
        ``nbytes``.  Only items carrying a ``klass`` attribute (typed
        :class:`~repro.serving.kvpool.KvSlice` shards) are accounted —
        the terminal ``SessionState`` cursor's ``nbytes`` is the TOTAL
        of the shards already counted, so it must not be re-charged.
        A shard's own ``klass`` stamp overrides the stream default."""
        for item in shards:
            k = getattr(item, "klass", None)
            if k is not None:
                nb = getattr(item, "nbytes", 0) or 0
                if nb:
                    self.account(nb, k)
            yield item

    def modeled_seconds(self, klass: int) -> float:
        n = self.transfers_by_class[klass]
        return n * self.latency + self.bytes_by_class[klass] / self.bw


class LiveFabric:
    """Per-launch accounting: one :class:`LiveChannel` per fabric
    channel, same lowering as the DES."""

    def __init__(self, topo: Topology, n_groups: int) -> None:
        for g in range(n_groups):
            topo.island_of(g)
        self.topo = topo
        self._channels: Dict[Tuple, LiveChannel] = {}

    def channel(self, src: int, dst: int) -> Optional[LiveChannel]:
        key = self.topo.channel_key(src, dst)
        if key is None:
            return None
        ch = self._channels.get(key)
        if ch is None:
            bw, lat = self.topo.channel_params(key)
            ch = LiveChannel(key, bw, lat)
            self._channels[key] = ch
        return ch

    def account_ckpt(self, src: int, nbytes: int) -> None:
        """Checkpoint bytes shipped src -> host as bulk traffic."""
        ch = self.channel(src, HOST)
        if ch is not None and nbytes > 0:
            ch.account(nbytes, BULK)

    def stats(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {"urgent_bytes": 0, "bulk_bytes": 0,
                               "urgent_seconds": 0.0, "bulk_seconds": 0.0}
        for ch in self._channels.values():
            out["urgent_bytes"] += ch.bytes_by_class[URGENT]
            out["bulk_bytes"] += ch.bytes_by_class[BULK]
            out["urgent_seconds"] += ch.modeled_seconds(URGENT)
            out["bulk_seconds"] += ch.modeled_seconds(BULK)
        return out
