"""State-space and RNN blocks: Mamba2 (SSD) and RWKV6 (Finch).

Mamba2 uses the chunked SSD formulation (intra-chunk masked attention-like
matmuls + inter-chunk state carry) so training/prefill is matmul-dominated
— the form the Pallas kernel accelerates on TPU.  Decode is the O(1)
recurrent step.

RWKV6 implements data-dependent per-channel decay (the Finch contribution)
with token-shift time mixing and relu^2 channel mixing.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

Params = Dict[str, Any]


# ===================================================================== #
# Mamba2 (SSD)
# ===================================================================== #
def init_mamba2(cfg: ModelConfig, key=None) -> Params:
    d = cfg.d_model
    di = cfg.d_inner
    H = cfg.ssm_heads
    N = cfg.ssm_state
    dt = cfg.jnp_dtype
    if key is None:
        key = jax.random.PRNGKey(4)
    ks = jax.random.split(key, 6)
    s = 1.0 / math.sqrt(d)
    return {
        # projections: z (gate), x, B, C, dt
        "in_proj": (jax.random.normal(ks[0], (d, 2 * di + 2 * N + H))
                    * s).astype(dt),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_width, di + 2 * N))
                   * 0.1).astype(dt),
        "conv_b": jnp.zeros((di + 2 * N,), dtype=dt),
        "A_log": jnp.zeros((H,), dtype=jnp.float32),
        "D": jnp.ones((H,), dtype=jnp.float32),
        "dt_bias": jnp.zeros((H,), dtype=jnp.float32),
        "out_norm": jnp.ones((di,), dtype=dt),
        "out_proj": (jax.random.normal(ks[2], (di, d))
                     / math.sqrt(di)).astype(dt),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv1d.  x: (B, S, C); w: (K, C).
    state: (B, K-1, C) carry of previous tokens for decode."""
    B, S, C = x.shape
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((B, K - 1, C), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)          # (B, S+K-1, C)
    out = jnp.zeros((B, S, C), jnp.float32)
    for i in range(K):
        out = out + xp[:, i:i + S].astype(jnp.float32) * \
            w[i].astype(jnp.float32)
    new_state = xp[:, S:]                              # last K-1 tokens
    return (out + b.astype(jnp.float32)).astype(x.dtype), new_state


def _ssd_chunk_scan(xh, B_, C_, a_log, chunk: int, h0=None):
    """Chunked SSD.  xh: (B, S, H, P) dt-scaled inputs; B_/C_: (B, S, N);
    a_log: (B, S, H) log decay (negative); h0: (B, H, N, P) incoming
    state (zeros when starting fresh).  Returns (y, final_state)."""
    Bb, S, H, P = xh.shape
    N = B_.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nC = S // chunk
    xh = xh.reshape(Bb, nC, chunk, H, P)
    Bc = B_.reshape(Bb, nC, chunk, N)
    Cc = C_.reshape(Bb, nC, chunk, N)
    al = a_log.reshape(Bb, nC, chunk, H)
    cum = jnp.cumsum(al, axis=2)                       # (B, nC, Q, H)

    # intra-chunk: L[t, s] = exp(cum_t - cum_s) for s <= t
    def intra(args):
        xc, bc, cc, cm = args                          # per-chunk slices
        # cm: (B, Q, H)
        diff = cm[:, :, None, :] - cm[:, None, :, :]   # (B, Q, Q, H)
        Q = cm.shape[1]
        mask = jnp.tril(jnp.ones((Q, Q), bool))
        L = jnp.where(mask[None, :, :, None], jnp.exp(diff), 0.0)
        cb = jnp.einsum("bqn,bsn->bqs", cc, bc)        # (B, Q, Q)
        W = cb[:, :, :, None] * L                      # (B, Q, Q, H)
        y = jnp.einsum("bqsh,bshp->bqhp", W, xc.astype(jnp.float32))
        # state contribution of this chunk
        dec = jnp.exp(cm[:, -1, None, :] - cm)         # (B, Q, H)
        st = jnp.einsum("bsh,bsn,bshp->bhnp", dec, bc,
                        xc.astype(jnp.float32))        # (B, H, N, P)
        return y, st

    # scan over chunks carrying the running state (B, H, N, P)
    def body(h, idx):
        xc = xh[:, idx]
        bc = Bc[:, idx].astype(jnp.float32)
        cc = Cc[:, idx].astype(jnp.float32)
        cm = cum[:, idx]
        y_intra, st = intra((xc, bc, cc, cm))
        # contribution of incoming state: y_state[t] = C_t . (exp(cum_t) h)
        decay_in = jnp.exp(cm)                         # (B, Q, H)
        y_state = jnp.einsum("bqn,bqh,bhnp->bqhp", cc, decay_in, h)
        h_new = jnp.exp(cm[:, -1])[:, :, None, None] * h + st
        return h_new, y_intra + y_state

    h0 = (jnp.zeros((Bb, H, N, P), jnp.float32) if h0 is None
          else h0.astype(jnp.float32))
    hT, ys = jax.lax.scan(body, h0, jnp.arange(nC))
    y = jnp.moveaxis(ys, 0, 1).reshape(Bb, S, H, P)
    return y, hT


def mamba2(p: Params, x: jnp.ndarray, cfg: ModelConfig, *,
           state: Optional[Dict[str, jnp.ndarray]] = None,
           chunk: int = 128
           ) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """Mamba2 mixer.  state = {"ssm": (B,H,N,P), "conv": (B,K-1,C)} for
    decode; None for train/prefill (returns final state when given)."""
    B, S, d = x.shape
    di, H, N = cfg.d_inner, cfg.ssm_heads, cfg.ssm_state
    P = cfg.ssm_head_dim
    proj = x @ p["in_proj"]                            # (B,S,2di+2N+H)
    z, xs, Bv, Cv, dt_raw = jnp.split(
        proj, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], axis=-1)
    conv_in = jnp.concatenate([xs, Bv, Cv], axis=-1)
    conv_state = state["conv"] if state is not None else None
    conv_out, new_conv = _causal_conv(conv_in, p["conv_w"], p["conv_b"],
                                      conv_state)
    conv_out = jax.nn.silu(conv_out)
    xs, Bv, Cv = jnp.split(conv_out, [di, di + N], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])                           # (H,) negative
    a_log = dt * A                                     # (B,S,H) log decay
    xh = xs.reshape(B, S, H, P)
    xh_dt = xh.astype(jnp.float32) * dt[..., None]

    if S == 1 and state is not None:
        # recurrent decode step
        h = state["ssm"]                               # (B,H,N,P)
        a = jnp.exp(a_log[:, 0])                       # (B,H)
        h = h * a[:, :, None, None] + jnp.einsum(
            "bn,bhp->bhnp", Bv[:, 0].astype(jnp.float32), xh_dt[:, 0])
        y = jnp.einsum("bn,bhnp->bhp", Cv[:, 0].astype(jnp.float32), h)
        y = y[:, None]                                 # (B,1,H,P)
        new_state = {"ssm": h, "conv": new_conv}
    else:
        pad = (-S) % chunk
        if pad:
            xh_dt = jnp.pad(xh_dt, ((0, 0), (0, pad), (0, 0), (0, 0)))
            Bv = jnp.pad(Bv, ((0, 0), (0, pad), (0, 0)))
            Cv = jnp.pad(Cv, ((0, 0), (0, pad), (0, 0)))
            a_log = jnp.pad(a_log, ((0, 0), (0, pad), (0, 0)))
        # carry the incoming SSM state (chunked prefill continues an
        # earlier chunk's state; a fresh prefill passes zeros)
        y, hT = _ssd_chunk_scan(xh_dt, Bv, Cv, a_log, chunk,
                                h0=None if state is None
                                else state["ssm"])
        y = y[:, :S]
        new_state = ({"ssm": hT, "conv": new_conv}
                     if state is not None else None)

    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, S, di).astype(x.dtype)
    # gated RMS norm (mamba2 style)
    y32 = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(y32), axis=-1, keepdims=True)
    y = (y32 * jax.lax.rsqrt(var + cfg.norm_eps)
         * p["out_norm"].astype(jnp.float32)).astype(x.dtype)
    return y @ p["out_proj"], new_state


def make_mamba2_state(cfg: ModelConfig, batch: int,
                      layers: Optional[int] = None) -> Dict[str, Any]:
    L = layers if layers is not None else cfg.num_layers
    H, N, P = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim
    C = cfg.d_inner + 2 * cfg.ssm_state
    return {
        "ssm": jnp.zeros((L, batch, H, N, P), jnp.float32),
        "conv": jnp.zeros((L, batch, cfg.conv_width - 1, C),
                          cfg.jnp_dtype),
    }


# ===================================================================== #
# RWKV6 (Finch)
# ===================================================================== #
def init_rwkv6(cfg: ModelConfig, key=None) -> Params:
    d = cfg.d_model
    P = cfg.rwkv_head_dim
    H = cfg.rwkv_heads
    f = cfg.d_ff
    dt = cfg.jnp_dtype
    if key is None:
        key = jax.random.PRNGKey(5)
    ks = jax.random.split(key, 10)
    s = 1.0 / math.sqrt(d)
    lora = max(32, d // 64)
    return {
        # time-mix
        "mu": (jax.random.uniform(ks[0], (5, d))).astype(dt),  # r,k,v,w,g
        "w_r": (jax.random.normal(ks[1], (d, d)) * s).astype(dt),
        "w_k": (jax.random.normal(ks[2], (d, d)) * s).astype(dt),
        "w_v": (jax.random.normal(ks[3], (d, d)) * s).astype(dt),
        "w_g": (jax.random.normal(ks[4], (d, d)) * s).astype(dt),
        "w_o": (jax.random.normal(ks[5], (d, d)) * s).astype(dt),
        # data-dependent decay LoRA: w_t = exp(-exp(w0 + tanh(x A) B))
        "w0": jnp.full((d,), -2.0, dtype=jnp.float32),
        "w_lora_a": (jax.random.normal(ks[6], (d, lora)) * s).astype(dt),
        "w_lora_b": (jax.random.normal(ks[7], (lora, d)) * 0.01).astype(dt),
        "u": jnp.zeros((H, P), dtype=jnp.float32),      # bonus
        "ln_x": jnp.ones((d,), dtype=dt),               # per-head norm
        # channel-mix
        "mu_c": (jax.random.uniform(ks[8], (2, d))).astype(dt),
        "ck": (jax.random.normal(ks[9], (d, f)) * s).astype(dt),
        "cv": (jax.random.normal(ks[0], (f, d)) / math.sqrt(f)).astype(dt),
        "cr": (jax.random.normal(ks[1], (d, d)) * s).astype(dt),
    }


def _token_shift(x, last):
    """shifted[t] = x[t-1]; shifted[0] = last (carried across steps)."""
    B, S, d = x.shape
    if S == 1:
        return last[:, None]
    prev = jnp.concatenate([last[:, None], x[:, :-1]], axis=1)
    return prev


def _wkv_scan(r, k, v, w, u, state):
    """RWKV6 linear attention.  r,k,v: (B,S,H,P); w: (B,S,H,P) decay in
    (0,1); u: (H,P) bonus; state: (B,H,P,P) [key x value].
    y_t = r_t . (S_{t-1} + diag(u) k_t v_t^T);  S_t = diag(w_t) S_{t-1}
          + k_t v_t^T.
    """
    B, S, H, P = r.shape

    def step(s, t):
        rt = r[:, t].astype(jnp.float32)
        kt = k[:, t].astype(jnp.float32)
        vt = v[:, t].astype(jnp.float32)
        wt = w[:, t]
        kv = kt[..., :, None] * vt[..., None, :]       # (B,H,P,P)
        y = jnp.einsum("bhp,bhpq->bhq", rt, s + u[None, :, :, None] * kv)
        s = wt[..., :, None] * s + kv
        return s, y

    sT, ys = jax.lax.scan(step, state, jnp.arange(S))
    return jnp.moveaxis(ys, 0, 1), sT                  # (B,S,H,P), state


def rwkv6_time_mix(p: Params, x: jnp.ndarray, cfg: ModelConfig, *,
                   state: Optional[Dict[str, jnp.ndarray]] = None
                   ) -> Tuple[jnp.ndarray, Optional[Dict[str, Any]]]:
    B, S, d = x.shape
    H, P = cfg.rwkv_heads, cfg.rwkv_head_dim
    last = state["tm_x"] if state is not None else jnp.zeros((B, d), x.dtype)
    prev = _token_shift(x, last)
    mu = p["mu"]
    xr = x + (prev - x) * mu[0]
    xk = x + (prev - x) * mu[1]
    xv = x + (prev - x) * mu[2]
    xw = x + (prev - x) * mu[3]
    xg = x + (prev - x) * mu[4]
    r = (xr @ p["w_r"]).reshape(B, S, H, P)
    k = (xk @ p["w_k"]).reshape(B, S, H, P)
    v = (xv @ p["w_v"]).reshape(B, S, H, P)
    g = jax.nn.silu(xg @ p["w_g"])
    # data-dependent decay (the RWKV6 contribution)
    ww = p["w0"] + (jnp.tanh(xw @ p["w_lora_a"]) @
                    p["w_lora_b"]).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(ww)).reshape(B, S, H, P)      # in (0,1)
    s0 = (state["wkv"] if state is not None
          else jnp.zeros((B, H, P, P), jnp.float32))
    y, sT = _wkv_scan(r, k, v, w, p["u"], s0)
    y = y.reshape(B, S, d)
    # group norm per head
    yh = y.reshape(B, S, H, P)
    mean = yh.mean(axis=-1, keepdims=True)
    var = yh.var(axis=-1, keepdims=True)
    yh = (yh - mean) * jax.lax.rsqrt(var + 64e-5)
    y = yh.reshape(B, S, d) * p["ln_x"].astype(jnp.float32)
    out = (y.astype(x.dtype) * g) @ p["w_o"]
    new_state = None
    if state is not None:
        new_state = {"tm_x": x[:, -1], "wkv": sT}
    return out, new_state


def rwkv6_channel_mix(p: Params, x: jnp.ndarray, cfg: ModelConfig, *,
                      state: Optional[jnp.ndarray] = None
                      ) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    B, S, d = x.shape
    last = state if state is not None else jnp.zeros((B, d), x.dtype)
    prev = _token_shift(x, last)
    xk = x + (prev - x) * p["mu_c"][0]
    xr = x + (prev - x) * p["mu_c"][1]
    k = jnp.square(jax.nn.relu(xk @ p["ck"]))
    kv = k @ p["cv"]
    out = jax.nn.sigmoid(xr @ p["cr"]) * kv
    new_state = x[:, -1] if state is not None else None
    return out, new_state


def make_rwkv6_state(cfg: ModelConfig, batch: int,
                     layers: Optional[int] = None) -> Dict[str, Any]:
    L = layers if layers is not None else cfg.num_layers
    H, P, d = cfg.rwkv_heads, cfg.rwkv_head_dim, cfg.d_model
    return {
        "tm_x": jnp.zeros((L, batch, d), cfg.jnp_dtype),
        "wkv": jnp.zeros((L, batch, H, P, P), jnp.float32),
        "cm_x": jnp.zeros((L, batch, d), cfg.jnp_dtype),
    }
