"""Model assembly: one functional decoder stack covering every family.

Layer stacks are ``jax.lax.scan``-based over *stacked* per-layer parameter
leaves (leading dim = num_layers) so XLA compiles a single layer body —
essential for 40-cell dry-run compile times.  ``scan_layers=False``
unrolls the stack with per-layer region markers, the form the Tessera
analyzer consumes (one DDG node per kernel per layer).

Entry points (all pure):
  init_params / init_cache
  forward_logits(params, cfg, tokens, ...)      full-sequence logits
  loss_fn(params, cfg, tokens, targets)         train loss
  prefill(params, cfg, tokens, cache)           fill caches, last logits
  decode_step(params, cfg, tokens, cache, pos)  one token, (B,) positions
  export_kv / import_kv / kv_state_bytes        per-request state handoff
                                                (prefill/decode split)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import marker
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.config import ModelConfig

Params = Dict[str, Any]


# ===================================================================== #
# Parameter construction
# ===================================================================== #
def _stack_init(fn, num: int, key):
    keys = jax.random.split(key, num)
    return jax.vmap(fn)(keys)


def init_params(cfg: ModelConfig, key=None) -> Params:
    if key is None:
        key = jax.random.PRNGKey(0)
    k_emb, k_layers, k_extra = jax.random.split(key, 3)
    p: Params = {"embed": L.init_embed(cfg, k_emb),
                 "final_norm": L.init_rmsnorm(cfg.d_model, cfg.jnp_dtype)}

    if cfg.family in ("dense", "moe", "vlm"):
        def one(k):
            k1, k2 = jax.random.split(k)
            lp = {"ln1": L.init_rmsnorm(cfg.d_model, cfg.jnp_dtype),
                  "attn": L.init_attention(cfg, key=k1),
                  "ln2": L.init_rmsnorm(cfg.d_model, cfg.jnp_dtype)}
            if cfg.family == "moe":
                lp["moe"] = L.init_moe(cfg, k2)
            else:
                lp["mlp"] = L.init_mlp(cfg, key=k2)
            return lp
        p["layers"] = _stack_init(one, cfg.num_layers, k_layers)

    elif cfg.family == "ssm":           # rwkv6
        def one(k):
            return {"ln1": L.init_rmsnorm(cfg.d_model, cfg.jnp_dtype),
                    "tm": S.init_rwkv6(cfg, k),
                    "ln2": L.init_rmsnorm(cfg.d_model, cfg.jnp_dtype)}
        p["layers"] = _stack_init(one, cfg.num_layers, k_layers)

    elif cfg.family == "hybrid":        # zamba2
        def one(k):
            return {"ln": L.init_rmsnorm(cfg.d_model, cfg.jnp_dtype),
                    "mamba": S.init_mamba2(cfg, k)}
        p["layers"] = _stack_init(one, cfg.num_layers, k_layers)
        k1, k2 = jax.random.split(k_extra)
        p["shared_attn"] = {
            "ln1": L.init_rmsnorm(cfg.d_model, cfg.jnp_dtype),
            "attn": L.init_attention(cfg, key=k1),
            "ln2": L.init_rmsnorm(cfg.d_model, cfg.jnp_dtype),
            "mlp": L.init_mlp(cfg, key=k2),
        }

    elif cfg.family == "encdec":        # seamless backbone
        def enc_one(k):
            k1, k2 = jax.random.split(k)
            return {"ln1": L.init_rmsnorm(cfg.d_model, cfg.jnp_dtype),
                    "attn": L.init_attention(cfg, key=k1),
                    "ln2": L.init_rmsnorm(cfg.d_model, cfg.jnp_dtype),
                    "mlp": L.init_mlp(cfg, key=k2)}

        def dec_one(k):
            k1, k2, k3 = jax.random.split(k, 3)
            return {"ln1": L.init_rmsnorm(cfg.d_model, cfg.jnp_dtype),
                    "attn": L.init_attention(cfg, key=k1),
                    "ln_x": L.init_rmsnorm(cfg.d_model, cfg.jnp_dtype),
                    "xattn": L.init_attention(cfg, key=k2),
                    "ln2": L.init_rmsnorm(cfg.d_model, cfg.jnp_dtype),
                    "mlp": L.init_mlp(cfg, key=k3)}
        ke, kd = jax.random.split(k_layers)
        p["encoder"] = _stack_init(enc_one, cfg.encoder_layers, ke)
        p["layers"] = _stack_init(dec_one, cfg.num_layers, kd)
        p["enc_norm"] = L.init_rmsnorm(cfg.d_model, cfg.jnp_dtype)
    else:
        raise ValueError(cfg.family)
    return p


# ===================================================================== #
# Layer bodies (shared by scan and unrolled paths)
# ===================================================================== #
def _dense_block(lp, x, cfg, *, positions, cache=None, cache_pos=None,
                 positions3=None, layer_idx=-1, tagged=False):
    # Region markers open BEFORE the computation so the analyzer tags
    # every kernel traced inside the block (begin ... end brackets the
    # equation stream).
    xin = L.rms_norm(lp["ln1"], x, cfg.norm_eps)
    if tagged:
        xin, close = marker.tag(xin, block="attention", layer=layer_idx)
    h, new_cache = L.attention(
        lp["attn"], xin, cfg, positions=positions, kv_cache=cache,
        cache_pos=cache_pos, positions3=positions3)
    if tagged:
        h = close(h)
    x = x + h
    y_in = L.rms_norm(lp["ln2"], x, cfg.norm_eps)
    blk = "moe" if cfg.family == "moe" else "ffn"
    if tagged:
        y_in, close2 = marker.tag(y_in, block=blk, layer=layer_idx)
    if cfg.family == "moe":
        y = L.moe(lp["moe"], y_in, cfg)
    else:
        y = L.mlp(lp["mlp"], y_in, cfg)
    if tagged:
        y = close2(y)
    return x + y, new_cache


def _rwkv_block(lp, x, cfg, *, state=None, layer_idx=-1, tagged=False):
    st_tm = None if state is None else \
        {"tm_x": state["tm_x"], "wkv": state["wkv"]}
    xin = L.rms_norm(lp["ln1"], x, cfg.norm_eps)
    if tagged:
        xin, close = marker.tag(xin, block="ssm", layer=layer_idx)
    h, new_tm = S.rwkv6_time_mix(lp["tm"], xin, cfg, state=st_tm)
    if tagged:
        h = close(h)
    x = x + h
    st_cm = None if state is None else state["cm_x"]
    # channel-mix params live inside the "tm" dict (see ssm.init_rwkv6)
    yin = L.rms_norm(lp["ln2"], x, cfg.norm_eps)
    if tagged:
        yin, close2 = marker.tag(yin, block="ffn", layer=layer_idx)
    y, new_cm = S.rwkv6_channel_mix(lp["tm"], yin, cfg, state=st_cm)
    if tagged:
        y = close2(y)
    new_state = None
    if state is not None:
        new_state = {"tm_x": new_tm["tm_x"], "wkv": new_tm["wkv"],
                     "cm_x": new_cm}
    return x + y, new_state


def _mamba_block(lp, x, cfg, *, state=None, layer_idx=-1, tagged=False):
    st = None if state is None else \
        {"ssm": state["ssm"], "conv": state["conv"]}
    xin = L.rms_norm(lp["ln"], x, cfg.norm_eps)
    if tagged:
        xin, close = marker.tag(xin, block="ssm", layer=layer_idx)
    h, new_st = S.mamba2(lp["mamba"], xin, cfg, state=st)
    if tagged:
        h = close(h)
    return x + h, new_st


def _shared_attn_block(sp, x, cfg, *, positions, cache=None,
                       cache_pos=None, tagged=False, layer_idx=-1):
    xin = L.rms_norm(sp["ln1"], x, cfg.norm_eps)
    if tagged:
        xin, close = marker.tag(xin, block="attention", layer=layer_idx)
    h, new_cache = L.attention(sp["attn"], xin, cfg, positions=positions,
                               kv_cache=cache, cache_pos=cache_pos)
    if tagged:
        h = close(h)
    x = x + h
    yin = L.rms_norm(sp["ln2"], x, cfg.norm_eps)
    if tagged:
        yin, close2 = marker.tag(yin, block="ffn", layer=layer_idx)
    y = L.mlp(sp["mlp"], yin, cfg)
    if tagged:
        y = close2(y)
    return x + y, new_cache


# ===================================================================== #
# Layer-stack driver: scan (compile-once) or unrolled (analysis/roofline)
# ===================================================================== #
def _run_stack(body, x, xs_tree, scan: bool):
    """Exactly jax.lax.scan(body, x, xs_tree) semantics; ``scan=False``
    unrolls the loop in Python (used by the Tessera analyzer and by the
    roofline L1/L2 extrapolation compiles)."""
    if scan:
        return jax.lax.scan(body, x, xs_tree)
    leaves = jax.tree_util.tree_leaves(xs_tree)
    L = leaves[0].shape[0]
    ys = []
    for i in range(L):
        x, y = body(x, jax.tree_util.tree_map(lambda a: a[i], xs_tree))
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return x, ys


# ===================================================================== #
# Caches
# ===================================================================== #
def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               enc_len: Optional[int] = None) -> Params:
    if cfg.family in ("dense", "moe", "vlm"):
        return {"kv": L.make_kv_cache(cfg, batch, max_len)}
    if cfg.family == "ssm":
        return {"rwkv": S.make_rwkv6_state(cfg, batch)}
    if cfg.family == "hybrid":
        n_attn = (cfg.num_layers + cfg.hybrid_attn_every - 1) \
            // cfg.hybrid_attn_every
        return {"mamba": S.make_mamba2_state(cfg, batch),
                "kv": L.make_kv_cache(cfg, batch, max_len, layers=n_attn)}
    if cfg.family == "encdec":
        enc_len = enc_len or max_len
        return {"kv": L.make_kv_cache(cfg, batch, max_len),
                "cross_k": jnp.zeros(
                    (cfg.num_layers, batch, enc_len, cfg.num_kv_heads,
                     cfg.head_dim), cfg.jnp_dtype),
                "cross_v": jnp.zeros(
                    (cfg.num_layers, batch, enc_len, cfg.num_kv_heads,
                     cfg.head_dim), cfg.jnp_dtype)}
    raise ValueError(cfg.family)


# ===================================================================== #
# Forward paths
# ===================================================================== #
def _maybe_remat(fn, remat: bool):
    return jax.checkpoint(fn) if remat else fn


def _embed_inputs(params, cfg, tokens, patch_embeds):
    x = L.embed(params["embed"], tokens).astype(cfg.jnp_dtype)
    if cfg.family == "vlm" and patch_embeds is not None:
        npat = patch_embeds.shape[1]
        x = jax.lax.dynamic_update_slice_in_dim(
            x, patch_embeds.astype(x.dtype), 0, axis=1)
    return x


def _encoder_forward(params, cfg, enc_embeds, *, scan_layers=True,
                     remat=False):
    """Bidirectional encoder over precomputed frame embeddings (B,S,d)."""
    x = enc_embeds.astype(cfg.jnp_dtype)
    B, Se, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(Se)[None], (B, Se))

    def body(x, lp):
        h, _ = L.attention(lp["attn"],
                           L.rms_norm(lp["ln1"], x, cfg.norm_eps), cfg,
                           positions=positions, causal=False)
        x = x + h
        y = L.mlp(lp["mlp"], L.rms_norm(lp["ln2"], x, cfg.norm_eps), cfg)
        return x + y, None

    if scan_layers:
        x, _ = jax.lax.scan(_maybe_remat(body, remat), x,
                            params["encoder"])
    else:
        for i in range(cfg.encoder_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i], params["encoder"])
            x, _ = body(x, lp)
    return L.rms_norm(params["enc_norm"], x, cfg.norm_eps)


def forward_logits(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
                   *, patch_embeds=None, positions3=None, enc_embeds=None,
                   scan_layers: bool = True, remat: bool = False,
                   q_chunk: int = 512) -> jnp.ndarray:
    """Full-sequence logits (teacher forcing / training / prefill-style)."""
    B, Sq = tokens.shape
    x = _embed_inputs(params, cfg, tokens, patch_embeds)
    positions = jnp.broadcast_to(jnp.arange(Sq)[None], (B, Sq))

    enc_out = None
    if cfg.family == "encdec":
        assert enc_embeds is not None, "encdec requires encoder inputs"
        enc_out = _encoder_forward(params, cfg, enc_embeds,
                                   scan_layers=scan_layers, remat=remat)

    if cfg.family in ("dense", "moe", "vlm"):
        def body(x, lp):
            y, _ = _dense_block(lp, x, cfg, positions=positions,
                                positions3=positions3)
            return y, None
        if scan_layers:
            x, _ = jax.lax.scan(_maybe_remat(body, remat), x,
                                params["layers"])
        else:
            for i in range(cfg.num_layers):
                lp = jax.tree_util.tree_map(lambda a: a[i],
                                            params["layers"])
                x, _ = _dense_block(lp, x, cfg, positions=positions,
                                    positions3=positions3, layer_idx=i,
                                    tagged=True)

    elif cfg.family == "ssm":
        def body(x, lp):
            y, _ = _rwkv_block(lp, x, cfg)
            return y, None
        if scan_layers:
            x, _ = jax.lax.scan(_maybe_remat(body, remat), x,
                                params["layers"])
        else:
            for i in range(cfg.num_layers):
                lp = jax.tree_util.tree_map(lambda a: a[i],
                                            params["layers"])
                x, _ = _rwkv_block(lp, x, cfg, layer_idx=i, tagged=True)

    elif cfg.family == "hybrid":
        k = cfg.hybrid_attn_every
        nSB = cfg.num_layers // k
        sp = params["shared_attn"]
        stacked = jax.tree_util.tree_map(
            lambda a: a.reshape((nSB, k) + a.shape[1:]), params["layers"])

        def body(x, lp_group):
            x, _ = _shared_attn_block(sp, x, cfg, positions=positions)
            for j in range(k):
                lp = jax.tree_util.tree_map(lambda a: a[j], lp_group)
                x, _ = _mamba_block(lp, x, cfg)
            return x, None
        if scan_layers:
            x, _ = jax.lax.scan(_maybe_remat(body, remat), x, stacked)
        else:
            for i in range(nSB):
                lp_group = jax.tree_util.tree_map(lambda a: a[i], stacked)
                x, _ = body(x, lp_group)

    elif cfg.family == "encdec":
        def body(x, lp):
            h, _ = L.attention(lp["attn"],
                               L.rms_norm(lp["ln1"], x, cfg.norm_eps),
                               cfg, positions=positions)
            x = x + h
            ck = jnp.einsum("bsd,dhk->bshk", enc_out, lp["xattn"]["wk"])
            cv = jnp.einsum("bsd,dhk->bshk", enc_out, lp["xattn"]["wv"])
            h, _ = L.attention(lp["xattn"],
                               L.rms_norm(lp["ln_x"], x, cfg.norm_eps),
                               cfg, positions=positions,
                               cross_kv=(ck, cv), causal=False)
            x = x + h
            y = L.mlp(lp["mlp"], L.rms_norm(lp["ln2"], x, cfg.norm_eps),
                      cfg)
            return x + y, None
        if scan_layers:
            x, _ = jax.lax.scan(_maybe_remat(body, remat), x,
                                params["layers"])
        else:
            for i in range(cfg.num_layers):
                lp = jax.tree_util.tree_map(lambda a: a[i],
                                            params["layers"])
                x, _ = body(x, lp)
    else:
        raise ValueError(cfg.family)

    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    return L.unembed(params["embed"], x, cfg)


def loss_fn(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
            targets: jnp.ndarray, **kw) -> jnp.ndarray:
    logits = forward_logits(params, cfg, tokens, **kw)
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None],
                               axis=-1)[..., 0]
    return jnp.mean(logz - gold)


# --------------------------------------------------------------------- #
# Serving paths
# --------------------------------------------------------------------- #
def prefill(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
            cache: Params, *, patch_embeds=None, positions3=None,
            enc_embeds=None, scan_layers: bool = True,
            q_chunk: int = 512,
            last_pos: Optional[jnp.ndarray] = None,
            offset: Optional[jnp.ndarray] = None
            ) -> Tuple[jnp.ndarray, Params]:
    """Process the prompt, fill caches, return last-position logits.

    ``last_pos`` (B,) selects a per-row "last" position instead of the
    literal final column — used by continuous-batching engines that
    right-pad a multi-request admission batch to a common length (each
    row's true prompt ends at its own index).

    ``offset`` switches to CHUNK mode: ``tokens`` are the prompt slice
    at absolute positions [offset, offset+Sq) and the cache already
    holds the state of the preceding chunks.  Attention K/V are written
    at the offset and queries attend over the filled prefix + this
    chunk (exact under causal masking); recurrent state threads through
    the cache by construction.  Driving successive chunks through this
    path is exactly :func:`prefill_chunked`.  ``last_pos`` stays
    chunk-relative in this mode.  Ring-buffer (sliding-window) caches
    and encdec are not chunkable (wrap-around slot layout / encoder
    coupling).
    """
    B, Sq = tokens.shape
    x = _embed_inputs(params, cfg, tokens, patch_embeds)
    positions = jnp.broadcast_to(jnp.arange(Sq)[None], (B, Sq))
    if offset is not None:
        assert cfg.family in ("dense", "moe", "ssm", "hybrid"), \
            "chunked prefill serves decoder-only families"
        assert cfg.sliding_window is None, \
            "chunked prefill is undefined for ring-buffer SWA caches"
        positions = positions + jnp.asarray(offset, jnp.int32)
    new_cache = dict(cache)

    if cfg.family in ("dense", "moe", "vlm"):
        def body(x, xs):
            lp, ck, cv = xs
            y, nc = _dense_block(lp, x, cfg, positions=positions,
                                 cache={"k": ck, "v": cv},
                                 cache_pos=offset,
                                 positions3=positions3,
                                 tagged=not scan_layers)
            return y, (nc["k"], nc["v"])
        x, (nk, nv) = _run_stack(
            body, x, (params["layers"], cache["kv"]["k"],
                      cache["kv"]["v"]), scan_layers)
        new_cache["kv"] = {"k": nk, "v": nv}

    elif cfg.family == "ssm":
        st = cache["rwkv"]

        def body(x, xs):
            lp, tm_x, wkv, cm_x = xs
            y, ns = _rwkv_block(lp, x, cfg,
                                state={"tm_x": tm_x, "wkv": wkv,
                                       "cm_x": cm_x},
                                tagged=not scan_layers)
            return y, (ns["tm_x"], ns["wkv"], ns["cm_x"])
        x, (tm, wkv, cm) = _run_stack(
            body, x, (params["layers"], st["tm_x"], st["wkv"],
                      st["cm_x"]), scan_layers)
        new_cache["rwkv"] = {"tm_x": tm, "wkv": wkv, "cm_x": cm}

    elif cfg.family == "hybrid":
        k = cfg.hybrid_attn_every
        nSB = cfg.num_layers // k
        sp = params["shared_attn"]
        stacked = jax.tree_util.tree_map(
            lambda a: a.reshape((nSB, k) + a.shape[1:]), params["layers"])
        mst = jax.tree_util.tree_map(
            lambda a: a.reshape((nSB, k) + a.shape[1:]), cache["mamba"])

        def body(x, xs):
            lp_group, mamba_g, ck, cv = xs
            x, nc = _shared_attn_block(sp, x, cfg, positions=positions,
                                       cache={"k": ck, "v": cv},
                                       cache_pos=offset,
                                       tagged=not scan_layers)
            new_m = []
            for j in range(k):
                lp = jax.tree_util.tree_map(lambda a: a[j], lp_group)
                stj = jax.tree_util.tree_map(lambda a: a[j], mamba_g)
                x, ns = _mamba_block(lp, x, cfg, state=stj,
                                     tagged=not scan_layers)
                new_m.append(ns)
            new_m = jax.tree_util.tree_map(lambda *a: jnp.stack(a),
                                           *new_m)
            return x, (new_m, nc["k"], nc["v"])
        x, (nm, nk, nv) = _run_stack(
            body, x, (stacked, mst, cache["kv"]["k"], cache["kv"]["v"]),
            scan_layers)
        new_cache["mamba"] = jax.tree_util.tree_map(
            lambda a: a.reshape((-1,) + a.shape[2:]), nm)
        new_cache["kv"] = {"k": nk, "v": nv}

    elif cfg.family == "encdec":
        assert enc_embeds is not None
        enc_out = _encoder_forward(params, cfg, enc_embeds,
                                   scan_layers=scan_layers)

        def body(x, xs):
            lp, ck, cv = xs
            h, nc = L.attention(lp["attn"],
                                L.rms_norm(lp["ln1"], x, cfg.norm_eps),
                                cfg, positions=positions,
                                kv_cache={"k": ck, "v": cv})
            x = x + h
            xk = jnp.einsum("bsd,dhk->bshk", enc_out, lp["xattn"]["wk"])
            xv = jnp.einsum("bsd,dhk->bshk", enc_out, lp["xattn"]["wv"])
            h, _ = L.attention(lp["xattn"],
                               L.rms_norm(lp["ln_x"], x, cfg.norm_eps),
                               cfg, positions=positions,
                               cross_kv=(xk, xv), causal=False)
            x = x + h
            y = L.mlp(lp["mlp"], L.rms_norm(lp["ln2"], x, cfg.norm_eps),
                      cfg)
            return x + y, (nc["k"], nc["v"], xk, xv)
        x, (nk, nv, xk, xv) = _run_stack(
            body, x, (params["layers"], cache["kv"]["k"],
                      cache["kv"]["v"]), scan_layers)
        new_cache["kv"] = {"k": nk, "v": nv}
        assert xk.shape[2] == cache["cross_k"].shape[2], \
            "cross-KV cache must be allocated with enc_len"
        new_cache["cross_k"] = xk
        new_cache["cross_v"] = xv
    else:
        raise ValueError(cfg.family)

    if last_pos is None:
        xl = x[:, -1:]
    else:
        idx = jnp.asarray(last_pos, jnp.int32)[:, None, None]
        xl = jnp.take_along_axis(
            x, jnp.broadcast_to(idx, (x.shape[0], 1, x.shape[2])), axis=1)
    xl = L.rms_norm(params["final_norm"], xl, cfg.norm_eps)
    return L.unembed(params["embed"], xl, cfg)[:, 0], new_cache


def iter_prefill_chunks(params: Params, cfg: ModelConfig,
                        tokens, cache: Params, *, chunk_size: int,
                        last_pos: Optional[jnp.ndarray] = None,
                        scan_layers: bool = True, prefill_call=None):
    """Drive ``prefill(offset=)`` over fixed-size prompt chunks,
    yielding ``(t0, t1, logits, cache)`` after each — ``logits`` is
    the per-row last-position selection over all chunks SO FAR, so the
    final yield carries exactly :func:`prefill`'s result.

    The single source of the chunk-walk logic (range/clip/row-select):
    :func:`prefill_chunked` drains it, the serving engine interleaves
    decode steps between its yields, and the streamed handoff exports
    (layer, chunk) KV shards at each yield.

    ``prefill_call(cache, tokens_chunk, offset, rel_last) -> (logits,
    cache)`` lets callers inject a jitted chunk step; defaults to the
    plain :func:`prefill`.
    """
    B, S = tokens.shape
    assert chunk_size >= 1
    assert cfg.sliding_window is None, \
        "chunked prefill is undefined for ring-buffer SWA caches"
    if prefill_call is None:
        def prefill_call(c, t, off, lp):
            return prefill(params, cfg, jnp.asarray(t, jnp.int32), c,
                           offset=off, last_pos=lp,
                           scan_layers=scan_layers)
    last = (jnp.full((B,), S - 1, jnp.int32) if last_pos is None
            else jnp.asarray(last_pos, jnp.int32))
    logits = None
    for t0 in range(0, S, chunk_size):
        t1 = min(t0 + chunk_size, S)
        rel = jnp.clip(last - t0, 0, t1 - t0 - 1)
        lg, cache = prefill_call(cache, tokens[:, t0:t1],
                                 jnp.asarray(t0, jnp.int32), rel)
        # keep each row's logits from the chunk containing its last
        # position (rows whose prompt ended earlier ignore later chunks)
        sel = (last >= t0) & (last < t1)
        logits = lg if logits is None else \
            jnp.where(sel[:, None], lg, logits)
        yield t0, t1, logits, cache


def prefill_chunked(params: Params, cfg: ModelConfig,
                    tokens: jnp.ndarray, cache: Params, *,
                    chunk_size: int,
                    last_pos: Optional[jnp.ndarray] = None,
                    scan_layers: bool = True,
                    prefill_call=None) -> Tuple[jnp.ndarray, Params]:
    """Whole-prompt prefill as a sequence of fixed-size chunks.

    Equivalent to :func:`prefill` (same final logits and cache): each
    chunk updates the cache incrementally through ``prefill(offset=)``,
    which is exact for recurrent families by construction and
    causal-mask-safe for attention families.  This is what lets a
    serving engine (a) interleave decode steps of live slots between
    the chunks of a long admitted prompt and (b) stream completed
    (layer, chunk) KV shards to a decode engine while later chunks
    still compute.  Ring-buffer SWA caches fall back to one
    whole-prompt prefill (wrap-around slot layout is not chunkable).
    """
    S = tokens.shape[1]
    if cfg.sliding_window is not None or chunk_size >= S:
        return prefill(params, cfg, tokens, cache, last_pos=last_pos,
                       scan_layers=scan_layers)
    logits = None
    for _, _, logits, cache in iter_prefill_chunks(
            params, cfg, tokens, cache, chunk_size=chunk_size,
            last_pos=last_pos, scan_layers=scan_layers,
            prefill_call=prefill_call):
        pass
    return logits, cache


# --------------------------------------------------------------------- #
# Per-request state handoff (prefill/decode disaggregation)
# --------------------------------------------------------------------- #
def export_kv(cfg: ModelConfig, cache: Params, slot: int,
              length: Optional[int] = None) -> Params:
    """Extract one sequence's KV / recurrent state from a batched cache.

    Returns a batch-1 pytree mirroring the cache structure — the payload
    a prefill engine ships to a decode-only engine.  Every cache leaf is
    (L, B, ...); the batch axis is sliced at ``slot``.  For attention KV
    the time axis is additionally trimmed to ``length`` (only the filled
    prefix transfers — the size the cost model charges the interconnect
    for); recurrent state (ssm / hybrid mamba) is fixed-size and ships
    whole.  Ring-buffer (sliding-window) KV is never trimmed: slot
    layout depends on absolute positions.
    """
    out: Params = {}
    for key, val in cache.items():
        sub = jax.tree_util.tree_map(lambda a: a[:, slot:slot + 1], val)
        if key == "kv" and length is not None \
                and cfg.sliding_window is None:
            sub = {"k": sub["k"][:, :, :length],
                   "v": sub["v"][:, :, :length]}
        out[key] = sub
    return out


def import_kv(cfg: ModelConfig, cache: Params, slot: int,
              state: Params) -> Params:
    """Write an exported per-request state into ``slot`` of a batched
    cache (the decode_only admission path).  Inverse of
    :func:`export_kv`: a round trip through export/import must leave
    decode bit-identical to never having left the original engine.
    """
    new = dict(cache)
    for key, val in state.items():
        if key == "kv":
            T = val["k"].shape[2]
            new["kv"] = {
                "k": cache["kv"]["k"].at[:, slot:slot + 1, :T].set(
                    val["k"].astype(cache["kv"]["k"].dtype)),
                "v": cache["kv"]["v"].at[:, slot:slot + 1, :T].set(
                    val["v"].astype(cache["kv"]["v"].dtype)),
            }
        else:
            new[key] = jax.tree_util.tree_map(
                lambda full, s: full.at[:, slot:slot + 1].set(
                    s.astype(full.dtype)), cache[key], val)
    return new


def kv_state_bytes(state: Params) -> int:
    """Wire size of an exported state (what the interconnect carries)."""
    return sum(leaf.size * leaf.dtype.itemsize
               for leaf in jax.tree_util.tree_leaves(state))


# --------------------------------------------------------------------- #
# Layer-granular shards: the streaming unit of a pipelined handoff.
# export_kv emits one monolithic payload only after the whole prompt
# finishes; these shards let a prefill engine ship each (component,
# layer[, token-range]) slice as soon as it is computed, overlapping
# the fabric transfer with the remaining prefill compute.  Installing
# every shard of a request == import_kv of its whole export.
# --------------------------------------------------------------------- #
def cache_layer_counts(cache: Params) -> Dict[str, int]:
    """Leading (layer) dimension per cache component — components can
    disagree (a hybrid's shared-attn KV has fewer layers than its
    mamba state)."""
    return {key: jax.tree_util.tree_leaves(val)[0].shape[0]
            for key, val in cache.items()}


def export_kv_shard(cfg: ModelConfig, cache: Params, slot: int,
                    key: str, layer: int,
                    t0: Optional[int] = None,
                    t1: Optional[int] = None) -> Params:
    """One layer's slice of one sequence's state.

    For attention KV (``key == "kv"``) an optional token range
    ``[t0, t1)`` selects a chunk of the time axis — the (layer, chunk)
    granularity of a streamed handoff.  Ring-buffer (sliding-window) KV
    and recurrent state ignore the range and ship the whole layer
    (ring slot layout depends on absolute positions; recurrent state is
    fixed-size and only its final value matters).
    """
    sub = jax.tree_util.tree_map(
        lambda a: a[layer:layer + 1, slot:slot + 1], cache[key])
    if key == "kv" and t0 is not None and cfg.sliding_window is None:
        sub = {"k": sub["k"][:, :, t0:t1], "v": sub["v"][:, :, t0:t1]}
    return sub


def import_kv_window(cfg: ModelConfig, cache: Params, slot: int,
                     layer0: int, shards, t0: int = 0) -> Params:
    """Install a contiguous ascending run of attention-KV layer shards
    (layers ``layer0, layer0+1, ...``, all covering the same token
    window starting at ``t0``) in ONE cache update.

    A streamed admission receives one shard per layer per chunk;
    installing each individually rebuilds the whole batched cache
    O(layers x chunks) times, so the consumer buffers a window's run
    and flushes it here — one functional update per chunk instead of
    one per (layer, chunk).
    """
    ks = jnp.concatenate([s["k"] for s in shards], axis=0)
    vs = jnp.concatenate([s["v"] for s in shards], axis=0)
    L, T = ks.shape[0], ks.shape[2]
    new = dict(cache)
    new["kv"] = {
        "k": cache["kv"]["k"].at[
            layer0:layer0 + L, slot:slot + 1, t0:t0 + T].set(
            ks.astype(cache["kv"]["k"].dtype)),
        "v": cache["kv"]["v"].at[
            layer0:layer0 + L, slot:slot + 1, t0:t0 + T].set(
            vs.astype(cache["kv"]["v"].dtype)),
    }
    return new


def import_kv_shard(cfg: ModelConfig, cache: Params, slot: int,
                    key: str, layer: int, shard: Params,
                    t0: int = 0) -> Params:
    """Install one exported layer shard into ``slot`` of a batched
    cache.  Inverse of :func:`export_kv_shard`; installing all shards
    of a request reproduces :func:`import_kv` of its whole export."""
    new = dict(cache)
    if key == "kv" and cfg.sliding_window is None:
        T = shard["k"].shape[2]
        new["kv"] = {
            "k": cache["kv"]["k"].at[
                layer:layer + 1, slot:slot + 1, t0:t0 + T].set(
                shard["k"].astype(cache["kv"]["k"].dtype)),
            "v": cache["kv"]["v"].at[
                layer:layer + 1, slot:slot + 1, t0:t0 + T].set(
                shard["v"].astype(cache["kv"]["v"].dtype)),
        }
    else:
        new[key] = jax.tree_util.tree_map(
            lambda full, s: full.at[layer:layer + 1, slot:slot + 1].set(
                s.astype(full.dtype)), cache[key], shard)
    return new


# --------------------------------------------------------------------- #
# Paged KV blocks: block-granular views over a shared (layer, block)
# pool.  A session's attention KV is stored as ceil(T / block_tokens)
# pool blocks named by its block table; one block id spans all layers.
# pack/gather round-trip through the pool is exact (same dtype, no
# arithmetic), so parking a session and re-activating it later leaves
# greedy decode bit-identical to never having left the dense cache.
# --------------------------------------------------------------------- #
def kv_block_bytes(cfg: ModelConfig, block_tokens: int,
                   layers: Optional[int] = None) -> int:
    """Bytes one pool block holds across all layers (K and V)."""
    L = layers if layers is not None else cfg.num_layers
    itemsize = jnp.zeros((), cfg.jnp_dtype).dtype.itemsize
    return 2 * L * block_tokens * cfg.num_kv_heads * cfg.head_dim \
        * itemsize


def pack_kv_blocks(pool: Params, state: Params, block_ids) -> Params:
    """Scatter a batch-1 exported attention-KV state (L, 1, T, Hkv, D)
    into pool blocks ``block_ids`` — one functional update per
    component.  T is zero-padded up to ``len(block_ids) * block_tokens``
    (the tail of the last block is unused capacity)."""
    ids = jnp.asarray(block_ids, jnp.int32)
    bt = pool["k"].shape[2]
    new = {}
    for c in ("k", "v"):
        val = state[c][:, 0]                       # (L, T, Hkv, D)
        Lc, T = val.shape[0], val.shape[1]
        need = int(ids.shape[0]) * bt
        if T < need:
            val = jnp.pad(val, ((0, 0), (0, need - T), (0, 0), (0, 0)))
        blocks = val[:, :need].reshape(
            Lc, int(ids.shape[0]), bt, *val.shape[2:])
        new[c] = pool[c].at[:, ids].set(blocks.astype(pool[c].dtype))
    return new


def gather_kv_blocks(pool: Params, block_ids, length: int) -> Params:
    """Inverse of :func:`pack_kv_blocks`: gather ``block_ids`` from the
    pool and return a batch-1 state (L, 1, length, Hkv, D) — the exact
    payload :func:`import_kv` installs into a dense cache slot."""
    ids = jnp.asarray(block_ids, jnp.int32)
    out = {}
    for c in ("k", "v"):
        blocks = pool[c][:, ids]                   # (L, nb, bt, Hkv, D)
        Lc, nb, bt = blocks.shape[:3]
        flat = blocks.reshape(Lc, nb * bt, *blocks.shape[3:])
        out[c] = flat[:, :length][:, None]         # (L, 1, T, Hkv, D)
    return out


def decode_step(params: Params, cfg: ModelConfig, tokens: jnp.ndarray,
                cache: Params, pos: jnp.ndarray, *, positions3=None,
                scan_layers: bool = True) -> Tuple[jnp.ndarray, Params]:
    """One decode step.  tokens: (B, 1) int32; pos: (B,) absolute
    positions.  Returns (logits (B, V), updated cache)."""
    B = tokens.shape[0]
    x = L.embed(params["embed"], tokens).astype(cfg.jnp_dtype)
    positions = pos[:, None]                          # (B, 1)
    new_cache = dict(cache)

    if cfg.family in ("dense", "moe", "vlm"):
        cap = cache["kv"]["k"].shape[2]
        slot = pos % cap if cfg.sliding_window is not None else pos

        def body(x, xs):
            lp, ck, cv = xs
            y, nc = _dense_block(lp, x, cfg, positions=positions,
                                 cache={"k": ck, "v": cv},
                                 cache_pos=slot, positions3=positions3,
                                 tagged=not scan_layers)
            return y, (nc["k"], nc["v"])
        x, (nk, nv) = _run_stack(
            body, x, (params["layers"], cache["kv"]["k"],
                      cache["kv"]["v"]), scan_layers)
        new_cache["kv"] = {"k": nk, "v": nv}

    elif cfg.family == "ssm":
        st = cache["rwkv"]

        def body(x, xs):
            lp, tm_x, wkv, cm_x = xs
            y, ns = _rwkv_block(lp, x, cfg,
                                state={"tm_x": tm_x, "wkv": wkv,
                                       "cm_x": cm_x},
                                tagged=not scan_layers)
            return y, (ns["tm_x"], ns["wkv"], ns["cm_x"])
        x, (tm, wkv, cm) = _run_stack(
            body, x, (params["layers"], st["tm_x"], st["wkv"],
                      st["cm_x"]), scan_layers)
        new_cache["rwkv"] = {"tm_x": tm, "wkv": wkv, "cm_x": cm}

    elif cfg.family == "hybrid":
        k = cfg.hybrid_attn_every
        nSB = cfg.num_layers // k
        sp = params["shared_attn"]
        stacked = jax.tree_util.tree_map(
            lambda a: a.reshape((nSB, k) + a.shape[1:]), params["layers"])
        mst = jax.tree_util.tree_map(
            lambda a: a.reshape((nSB, k) + a.shape[1:]), cache["mamba"])

        def body(x, xs):
            lp_group, mamba_g, ck, cv = xs
            x, nc = _shared_attn_block(sp, x, cfg, positions=positions,
                                       cache={"k": ck, "v": cv},
                                       cache_pos=pos,
                                       tagged=not scan_layers)
            new_m = []
            for j in range(k):
                lp = jax.tree_util.tree_map(lambda a: a[j], lp_group)
                stj = jax.tree_util.tree_map(lambda a: a[j], mamba_g)
                x, ns = _mamba_block(lp, x, cfg, state=stj,
                                     tagged=not scan_layers)
                new_m.append(ns)
            new_m = jax.tree_util.tree_map(lambda *a: jnp.stack(a),
                                           *new_m)
            return x, (new_m, nc["k"], nc["v"])
        x, (nm, nk, nv) = _run_stack(
            body, x, (stacked, mst, cache["kv"]["k"], cache["kv"]["v"]),
            scan_layers)
        new_cache["mamba"] = jax.tree_util.tree_map(
            lambda a: a.reshape((-1,) + a.shape[2:]), nm)
        new_cache["kv"] = {"k": nk, "v": nv}

    elif cfg.family == "encdec":
        def body(x, xs):
            lp, ck, cv, xk, xv = xs
            h, nc = L.attention(lp["attn"],
                                L.rms_norm(lp["ln1"], x, cfg.norm_eps),
                                cfg, positions=positions,
                                kv_cache={"k": ck, "v": cv},
                                cache_pos=pos)
            x = x + h
            h, _ = L.attention(lp["xattn"],
                               L.rms_norm(lp["ln_x"], x, cfg.norm_eps),
                               cfg, positions=positions,
                               cross_kv=(xk, xv), causal=False)
            x = x + h
            y = L.mlp(lp["mlp"], L.rms_norm(lp["ln2"], x, cfg.norm_eps),
                      cfg)
            return x + y, (nc["k"], nc["v"])
        x, (nk, nv) = _run_stack(
            body, x, (params["layers"], cache["kv"]["k"],
                      cache["kv"]["v"], cache["cross_k"],
                      cache["cross_v"]), scan_layers)
        new_cache["kv"] = {"k": nk, "v": nv}
    else:
        raise ValueError(cfg.family)

    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    return L.unembed(params["embed"], x, cfg)[:, 0], new_cache
