"""Neural net layers shared by every architecture family.

Functional style: ``init_*`` build parameter pytrees, ``apply``-style
functions are pure.  Attention uses *query chunking* with windowed KV
slicing so that 32k-token prefill and 500k-token SWA never materialize an
O(S^2) logits tensor — this is what makes the big dry-run cells fit in
HBM without depending on the Pallas kernels (which target real TPUs and
are validated separately in interpret mode).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

Params = Dict[str, Any]


# --------------------------------------------------------------------- #
# Norms
# --------------------------------------------------------------------- #
def init_rmsnorm(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rms_norm(p: Params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


# --------------------------------------------------------------------- #
# RoPE (+ M-RoPE for qwen2-vl)
# --------------------------------------------------------------------- #
def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: (B, S, H, D); positions: (B, S) int32."""
    freqs = rope_frequencies(x.shape[-1], theta)           # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions3: jnp.ndarray,
                theta: float, sections: Tuple[int, ...]) -> jnp.ndarray:
    """Multimodal RoPE: positions3 (3, B, S) = (t, h, w) position ids;
    frequency channels are split across the three axes (qwen2-vl)."""
    D = x.shape[-1]
    freqs = rope_frequencies(D, theta)                      # (D/2,)
    # build per-channel position by section
    sec = jnp.concatenate([
        jnp.full((s,), i, dtype=jnp.int32)
        for i, s in enumerate(sections)])                   # (D/2,)
    # positions3: (3, B, S) -> (B, S, D/2) selecting axis sec[c] per channel
    p3 = jnp.moveaxis(positions3, 0, -1)                    # (B, S, 3)
    chan_pos = jnp.take(p3, sec, axis=-1).astype(jnp.float32)  # (B,S,D/2)
    ang = chan_pos * freqs                                   # (B, S, D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------- #
# Attention (GQA / MQA / SWA / cross) with query chunking
# --------------------------------------------------------------------- #
def init_attention(cfg: ModelConfig, d_model: Optional[int] = None,
                   key=None) -> Params:
    d = d_model or cfg.d_model
    hd, H, Hkv = cfg.head_dim, cfg.padded_heads, cfg.num_kv_heads
    dt = cfg.jnp_dtype
    if key is None:
        key = jax.random.PRNGKey(0)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    wq = jax.random.normal(k1, (d, H, hd)) * s
    wo = jax.random.normal(k4, (H, hd, d)) * s
    if H != cfg.num_heads:
        # Megatron-style head padding: zero q/o slices for TP
        # divisibility — output is exactly the unpadded model's.
        mask = (jnp.arange(H) < cfg.num_heads)
        wq = wq * mask[None, :, None]
        wo = wo * mask[:, None, None]
    p = {
        "wq": wq.astype(dt),
        "wk": (jax.random.normal(k2, (d, Hkv, hd)) * s).astype(dt),
        "wv": (jax.random.normal(k3, (d, Hkv, hd)) * s).astype(dt),
        "wo": wo.astype(dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), dtype=dt)
        p["bk"] = jnp.zeros((Hkv, hd), dtype=dt)
        p["bv"] = jnp.zeros((Hkv, hd), dtype=dt)
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(hd, dt)
        p["k_norm"] = init_rmsnorm(hd, dt)
    return p


def _sdpa_chunked(q, k, v, *, causal: bool, q_offset,
                  window: Optional[int], chunk: int,
                  softcap: float = 0.0, kv_valid_len=None) -> jnp.ndarray:
    """Scaled dot-product attention, chunked over the query axis.

    q: (B, Sq, H, D);  k/v: (B, Skv, Hkv, D).  ``q_offset`` is the
    absolute position of q[0] relative to k[0] (decode offset).  With a
    sliding ``window`` only the last ``window + chunk`` keys are sliced
    per chunk, keeping FLOPs O(Sq * window).
    """
    B, Sq, H, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    rep = H // Hkv
    scale = 1.0 / math.sqrt(D)
    q = q * scale

    # per-batch query offset (continuous batching: each request sits at a
    # different absolute position)
    q_off = jnp.asarray(q_offset)
    if q_off.ndim == 0:
        q_off = jnp.broadcast_to(q_off, (B,))

    def attend(qc, kc, vc, qpos, kpos):
        # qc: (B, C, H, D); kc/vc: (B, Kc, Hkv, D); qpos: (B, C);
        # kpos: (Kc,) or (B, Kc)
        qg = qc.reshape(B, qc.shape[1], Hkv, rep, D)
        # bf16 x bf16 -> f32 on the MXU: accumulate in fp32 WITHOUT
        # materializing fp32 copies of Q/K in HBM (hillclimb §Perf).
        logits = jnp.einsum("bqhrd,bkhd->bhrqk", qg, kc,
                            preferred_element_type=jnp.float32)
        if softcap > 0.0:
            logits = jnp.tanh(logits / softcap) * softcap
        kp = kpos if kpos.ndim == 2 else kpos[None, :]       # (B|1, Kc)
        mask = jnp.ones((B, qc.shape[1], kc.shape[1]), dtype=bool)
        if causal:
            mask &= kp[:, None, :] <= qpos[:, :, None]
        if window is not None:
            mask &= kp[:, None, :] > qpos[:, :, None] - window
        mask &= kp[:, None, :] >= 0                 # padded window slots
        if kv_valid_len is not None:                # ring-buffer warmup
            vl = jnp.asarray(kv_valid_len)
            vl = vl[:, None, None] if vl.ndim else vl
            mask &= kp[:, None, :] < vl
        logits = jnp.where(mask[:, None, None], logits, -1e30)
        w = jax.nn.softmax(logits, axis=-1)
        # PV product in the value dtype with fp32 accumulation (flash
        # kernels do exactly this); avoids an fp32 copy of V.
        o = jnp.einsum("bhrqk,bkhd->bqhrd", w.astype(v.dtype), vc,
                       preferred_element_type=jnp.float32)
        return o.reshape(B, qc.shape[1], H, D).astype(v.dtype)

    if Sq <= chunk:
        qpos = q_off[:, None] + jnp.arange(Sq)[None, :]
        kpos = jnp.arange(Skv)
        return attend(q, k, v, qpos, kpos)

    assert Sq % chunk == 0, (Sq, chunk)
    n_chunks = Sq // chunk
    qs = q.reshape(B, n_chunks, chunk, H, D)

    if window is not None and Skv > window + chunk:
        kv_span = window + chunk

        def body(i):
            qc = qs[:, i]
            qpos = q_off[:, None] + i * chunk + jnp.arange(chunk)[None]
            start = i * chunk + chunk - kv_span     # may be negative
            start_c = jnp.clip(start, 0, Skv - kv_span)
            kc = jax.lax.dynamic_slice_in_dim(k, start_c, kv_span, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(v, start_c, kv_span, axis=1)
            kpos = start_c + jnp.arange(kv_span)
            return attend(qc, kc, vc, qpos, kpos)
    else:
        kpos = jnp.arange(Skv)

        def body(i):
            qc = qs[:, i]
            qpos = q_off[:, None] + i * chunk + jnp.arange(chunk)[None]
            return attend(qc, k, v, qpos, kpos)

    out = jax.lax.map(body, jnp.arange(n_chunks))   # (n, B, C, H, D)
    return jnp.moveaxis(out, 0, 1).reshape(B, Sq, H, D)


def attention(p: Params, x: jnp.ndarray, cfg: ModelConfig, *,
              positions: jnp.ndarray,
              kv_cache: Optional[Dict[str, jnp.ndarray]] = None,
              cache_pos: Optional[jnp.ndarray] = None,
              cross_kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
              causal: bool = True,
              q_chunk: int = 512,
              positions3: Optional[jnp.ndarray] = None,
              ) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """GQA attention.

    Modes:
      * kv_cache=None, cross_kv=None: full self-attention (train/encoder).
      * kv_cache given + x.shape[1] == cache capacity write: prefill fill.
      * kv_cache given + multi-token x + scalar ``cache_pos``: CHUNKED
        prefill — this chunk's K/V are written at offset ``cache_pos``
        and queries attend over the whole cache (the already-filled
        prefix plus this chunk; unfilled higher slots are excluded by
        the causal mask, so the result equals whole-prompt prefill).
      * kv_cache given + single-token x: decode step, in-place cache
        update at ``cache_pos`` (ring-buffer position for SWA).
      * cross_kv given: cross-attention over precomputed encoder K/V.
    Returns (output, updated kv_cache or None).
    """
    B, S, _ = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if "bq" in p:
        q = q + p["bq"]
    if cross_kv is None:
        k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
        if "bk" in p:
            k = k + p["bk"]
            v = v + p["bv"]
    else:
        k, v = cross_kv
    if cfg.qk_norm:
        q = rms_norm(p["q_norm"], q, cfg.norm_eps)
        if cross_kv is None:
            k = rms_norm(p["k_norm"], k, cfg.norm_eps)
    if cross_kv is None:        # no RoPE on cross-attention
        if cfg.mrope and positions3 is not None:
            q = apply_mrope(q, positions3, cfg.rope_theta,
                            cfg.mrope_sections)
            k = apply_mrope(k, positions3, cfg.rope_theta,
                            cfg.mrope_sections)
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    q_offset = 0
    window = cfg.sliding_window
    if kv_cache is not None and cross_kv is None:
        if S == 1:
            # decode: write this token's K/V at cache_pos, read whole cache
            new_cache = {"k": _dyn_update(kv_cache["k"], k, cache_pos),
                         "v": _dyn_update(kv_cache["v"], v, cache_pos)}
            k, v = new_cache["k"], new_cache["v"]
            cap = k.shape[1]
            if window is not None and cap <= window:
                # Ring buffer: every written slot is within the window;
                # slot order is irrelevant (RoPE applied before caching).
                # Mask only unwritten slots during warmup (per batch).
                valid = jnp.minimum(positions[:, 0] + 1, cap)   # (B,)
                out = _sdpa_chunked(
                    q, k, v, causal=False, q_offset=0, window=None,
                    chunk=q_chunk, softcap=cfg.attn_logit_softcap,
                    kv_valid_len=valid)
                y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
                return y, new_cache
            q_offset = positions[:, 0]                          # (B,)
        elif cache_pos is not None:
            # chunked prefill: write this chunk's K/V at the scalar
            # offset and attend over the full cache.  Slots below the
            # offset hold earlier chunks; slots at or above the chunk
            # end are zero-filled but carry kpos > qpos, so the causal
            # mask excludes them — exactness needs no valid-length
            # bookkeeping.  (Ring-buffer SWA caches never take this
            # path: their slot layout wraps at the window.)
            assert window is None, \
                "chunked prefill is undefined for ring-buffer SWA caches"
            off = jnp.asarray(cache_pos, jnp.int32)
            new_cache = {
                "k": jax.lax.dynamic_update_slice_in_dim(
                    kv_cache["k"], k.astype(kv_cache["k"].dtype), off,
                    axis=1),
                "v": jax.lax.dynamic_update_slice_in_dim(
                    kv_cache["v"], v.astype(kv_cache["v"].dtype), off,
                    axis=1),
            }
            k, v = new_cache["k"], new_cache["v"]
            q_offset = off
        else:
            # prefill: fill cache[0:S]
            new_cache = {
                "k": _fill(kv_cache["k"], k),
                "v": _fill(kv_cache["v"], v),
            }
    out = _sdpa_chunked(q, k, v, causal=causal, q_offset=q_offset,
                        window=window, chunk=q_chunk,
                        softcap=cfg.attn_logit_softcap)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, new_cache


def _dyn_update(cache, val, pos):
    """cache (B, T, H, D) <- val (B, 1, H, D) at per-batch positions.

    ``pos`` may be a python int, a scalar array, or a (B,) vector (each
    request in a continuous batch sits at its own position)."""
    val = val.astype(cache.dtype)
    pos = jnp.asarray(pos)
    if pos.ndim == 0:
        return jax.lax.dynamic_update_slice_in_dim(cache, val, pos, axis=1)
    return jax.vmap(
        lambda c, u, s: jax.lax.dynamic_update_slice_in_dim(
            c, u, s, axis=0))(cache, val, pos)


def _fill(cache, val):
    cap, S = cache.shape[1], val.shape[1]
    if cap == S:
        return val.astype(cache.dtype)
    if S < cap:
        return jax.lax.dynamic_update_slice_in_dim(
            cache, val.astype(cache.dtype), 0, axis=1)
    # SWA ring buffer smaller than the prefill: scatter the last ``cap``
    # tokens at their ring slots (abs_pos % cap) so subsequent decode
    # writes at (pos % cap) line up.
    tail = val[:, S - cap:].astype(cache.dtype)
    slots = (jnp.arange(S - cap, S) % cap)
    return cache.at[:, slots].set(tail)


def make_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  layers: Optional[int] = None) -> Dict[str, Any]:
    """Zero-initialized stacked KV cache (L, B, T, Hkv, D).  SWA models
    allocate only the window (ring buffer)."""
    L = layers if layers is not None else cfg.num_layers
    T = max_len
    if cfg.sliding_window is not None:
        T = min(T, cfg.sliding_window)
    shape = (L, batch, T, cfg.num_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, cfg.jnp_dtype),
            "v": jnp.zeros(shape, cfg.jnp_dtype)}


def make_kv_block_pool(cfg: ModelConfig, pool_blocks: int,
                       block_tokens: int,
                       layers: Optional[int] = None) -> Dict[str, Any]:
    """Zero-initialized paged KV pool (L, P, bt, Hkv, D).

    The pool replaces the per-slot batch axis with a flat block axis: a
    session's KV lives in ``ceil(tokens / block_tokens)`` pool blocks
    named by its block table, so resident capacity is bounded by tokens
    actually held rather than by ``slots * max_len``.
    """
    L = layers if layers is not None else cfg.num_layers
    shape = (L, pool_blocks, block_tokens, cfg.num_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, cfg.jnp_dtype),
            "v": jnp.zeros(shape, cfg.jnp_dtype)}


# --------------------------------------------------------------------- #
# MLP: SwiGLU / GeGLU
# --------------------------------------------------------------------- #
def init_mlp(cfg: ModelConfig, d_model: Optional[int] = None,
             d_ff: Optional[int] = None, key=None) -> Params:
    d = d_model or cfg.d_model
    f = d_ff or cfg.d_ff
    dt = cfg.jnp_dtype
    if key is None:
        key = jax.random.PRNGKey(1)
    k1, k2, k3 = jax.random.split(key, 3)
    s = 1.0 / math.sqrt(d)
    return {
        "w_gate": (jax.random.normal(k1, (d, f)) * s).astype(dt),
        "w_up": (jax.random.normal(k2, (d, f)) * s).astype(dt),
        "w_down": (jax.random.normal(k3, (f, d)) / math.sqrt(f)).astype(dt),
    }


def mlp(p: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    g = x @ p["w_gate"]
    u = x @ p["w_up"]
    act = jax.nn.gelu(g) if cfg.activation == "geglu" else jax.nn.silu(g)
    return (act * u) @ p["w_down"]


# --------------------------------------------------------------------- #
# Mixture of Experts: top-k router + sort-based ragged dispatch
# --------------------------------------------------------------------- #
def init_moe(cfg: ModelConfig, key=None) -> Params:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    dt = cfg.jnp_dtype
    if key is None:
        key = jax.random.PRNGKey(2)
    k0, k1, k2, k3 = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    return {
        "router": (jax.random.normal(k0, (d, E)) * s).astype(jnp.float32),
        "w_gate": (jax.random.normal(k1, (E, d, f)) * s).astype(dt),
        "w_up": (jax.random.normal(k2, (E, d, f)) * s).astype(dt),
        "w_down": (jax.random.normal(k3, (E, f, d)) / math.sqrt(f)).astype(dt),
    }


def moe(p: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Dropless MoE.  ``ragged``: sort tokens by expert and use
    jax.lax.ragged_dot (exact active FLOPs — 2·k·T·d·f per matmul).
    ``dense_einsum``: every expert on every token, masked combine —
    simple and GSPMD-friendly, used as sharded fallback.
    ``ep``: expert-local shard_map path for production meshes — tokens
    never leave their data shard, expert FFN width shards over the model
    axis, one psum after combine (see ``_moe_ep``)."""
    if cfg.moe_impl == "ep":
        return _moe_ep(p, x, cfg)
    B, S, d = x.shape
    T = B * S
    k = cfg.experts_per_token
    E = cfg.num_experts
    xt = x.reshape(T, d)
    logits = xt.astype(jnp.float32) @ p["router"]
    gate_vals, expert_ids = jax.lax.top_k(logits, k)          # (T, k)
    gates = jax.nn.softmax(gate_vals, axis=-1)                 # (T, k)

    if cfg.moe_impl == "dense_einsum":
        # combine weights (T, E): sum of gate over chosen slots
        combine = jnp.zeros((T, E), jnp.float32)
        onehot = jax.nn.one_hot(expert_ids, E, dtype=jnp.float32)  # (T,k,E)
        combine = (onehot * gates[..., None]).sum(axis=1)           # (T, E)
        g = jnp.einsum("td,edf->tef", xt, p["w_gate"])
        u = jnp.einsum("td,edf->tef", xt, p["w_up"])
        h = jax.nn.silu(g) * u
        y = jnp.einsum("tef,efd->ted", h, p["w_down"])
        out = jnp.einsum("ted,te->td", y, combine.astype(y.dtype))
        return out.reshape(B, S, d)

    # ragged (sort-based, dropless)
    flat_expert = expert_ids.reshape(-1)                       # (T*k,)
    sort_idx = jnp.argsort(flat_expert)                        # (T*k,)
    token_idx = sort_idx // k
    xs = xt[token_idx]                                         # (T*k, d)
    group_sizes = jnp.bincount(flat_expert, length=E).astype(jnp.int32)
    g = jax.lax.ragged_dot(xs, p["w_gate"], group_sizes)
    u = jax.lax.ragged_dot(xs, p["w_up"], group_sizes)
    h = (jax.nn.silu(g.astype(jnp.float32)) *
         u.astype(jnp.float32)).astype(xs.dtype)
    y = jax.lax.ragged_dot(h, p["w_down"], group_sizes)        # (T*k, d)
    # unsort and combine with gates
    inv = jnp.argsort(sort_idx)
    y = y[inv].reshape(T, k, d)
    out = (y.astype(jnp.float32) * gates[..., None]).sum(axis=1)
    return out.astype(x.dtype).reshape(B, S, d)


def _moe_ep(p: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Capacity-based expert-parallel MoE under shard_map.

    Why (hillclimb log, EXPERIMENTS.md §Perf): the sort-based ragged path
    does a GLOBAL argsort over all tokens, which GSPMD resolves by
    gathering every token to every chip and all-reducing the (T·k, d_ff)
    expert activations at fp32 — 180 GB/chip/layer on dbrx train_4k.
    Here tokens stay inside their (pod, data) shard:

      local top-k -> slot position by masked cumsum -> scatter into a
      fixed (E, C, d) dispatch buffer -> batched expert GEMMs with the
      FFN width sharded over ``model`` -> gather+gate combine -> one
      psum('model') of the (T_loc, d) output.

    Per-chip FLOPs are exactly the active-expert FLOPs / chips; the only
    collective is the same-sized all-reduce a dense TP MLP needs.
    Capacity C = ceil(T_loc*k/E * capacity_factor); overflow tokens are
    dropped (standard GShard semantics), with factor >= E/k the path is
    exactly dropless (tests compare it against the ragged oracle).
    """
    from repro.distributed.context import current_mesh
    mesh = current_mesh()
    assert mesh is not None, "moe_impl='ep' requires mesh_context(mesh)"
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    model_axis = "model" if "model" in mesh.shape else None

    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    cap_factor = getattr(cfg, "moe_capacity_factor", 1.25)
    # shard tokens over the largest data-axis prefix dividing the batch
    # (long-context decode has batch 1: tokens replicate across data)
    chosen = ()
    n_data = 1
    for a in data_axes:
        if B % (n_data * mesh.shape[a]) == 0:
            chosen += (a,)
            n_data *= mesh.shape[a]
    data_axes = chosen

    def local_fn(xl, router, wg, wu, wd):
        Bl, Sl, _ = xl.shape
        Tl = Bl * Sl
        xt = xl.reshape(Tl, d)
        logits = xt.astype(jnp.float32) @ router
        gate_vals, eid = jax.lax.top_k(logits, k)            # (Tl, k)
        gates = jax.nn.softmax(gate_vals, axis=-1)
        flat_e = eid.reshape(-1)                             # (Tl*k,)
        onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
        pos = (jnp.cumsum(onehot, axis=0) - onehot)
        pos = (pos * onehot).sum(-1)                         # slot in expert
        C = max(int(-(-Tl * k // E) * cap_factor), 1)
        keep = pos < C
        pos_c = jnp.minimum(pos, C - 1)
        token_idx = jnp.arange(Tl * k) // k
        xrep = xt[token_idx]                                 # (Tl*k, d)
        upd = jnp.where(keep[:, None], xrep, 0)
        disp = jnp.zeros((E, C, d), xl.dtype).at[
            flat_e, pos_c].add(upd)                          # unique slots
        g = jnp.einsum("ecd,edf->ecf", disp, wg,
                       preferred_element_type=jnp.float32)
        u = jnp.einsum("ecd,edf->ecf", disp, wu,
                       preferred_element_type=jnp.float32)
        h = (jax.nn.silu(g) * u).astype(xl.dtype)
        y = jnp.einsum("ecf,efd->ecd", h, wd,
                       preferred_element_type=jnp.float32)   # f-partial
        rows = y[flat_e, pos_c] * keep[:, None]
        out = (rows.reshape(Tl, k, d).astype(jnp.float32)
               * gates[..., None]).sum(axis=1)
        if model_axis is not None:
            out = jax.lax.psum(out, model_axis)
        return out.astype(xl.dtype).reshape(Bl, Sl, d)

    from jax.sharding import PartitionSpec as P
    dspec = data_axes if data_axes else None
    w_in = P(None, None, model_axis)     # (E, d, f/n): FFN width sharded
    w_out = P(None, model_axis, None)    # (E, f/n, d)
    return jax.shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(dspec, None, None), P(None, None), w_in, w_in,
                  w_out),
        out_specs=P(dspec, None, None),
        check_vma=False,
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])


# --------------------------------------------------------------------- #
# Embedding / unembedding
# --------------------------------------------------------------------- #
def init_embed(cfg: ModelConfig, key=None) -> Params:
    if key is None:
        key = jax.random.PRNGKey(3)
    k1, k2 = jax.random.split(key)
    V = cfg.padded_vocab
    p = {"tok": (jax.random.normal(k1, (V, cfg.d_model))
                 * 0.02).astype(cfg.jnp_dtype)}
    if not cfg.tie_embeddings:
        p["unembed"] = (jax.random.normal(
            k2, (cfg.d_model, V)) * 0.02).astype(cfg.jnp_dtype)
    return p


def embed(p: Params, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(p["tok"], tokens, axis=0)


def unembed(p: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Project to (padded) vocab logits.  Padding columns are masked to
    -inf, which keeps loss (logsumexp) and argmax EXACTLY equal to the
    unpadded computation — the padding exists purely so the vocab
    dimension shards over the model axis (no TB-scale logit gathers)."""
    if "unembed" in p:
        logits = x @ p["unembed"]
    else:
        logits = x @ p["tok"].T.astype(x.dtype)
    V = cfg.padded_vocab
    if V != cfg.vocab_size:
        col = jax.lax.broadcasted_iota(jnp.int32, (1, 1, V), 2)
        logits = jnp.where(col < cfg.vocab_size, logits,
                           jnp.asarray(-1e30, logits.dtype))
    return logits
