"""Unified model configuration covering all assigned architectures.

One dataclass describes dense / MoE / SSM / RWKV / hybrid / enc-dec / VLM
families; ``src/repro/configs/<id>.py`` instantiates the exact published
configurations and reduced smoke variants.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int               # query heads (0 for attention-free)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // num_heads

    # attention options
    rope_theta: float = 1e4
    sliding_window: Optional[int] = None    # SWA (mixtral)
    qk_norm: bool = False                   # qwen3
    qkv_bias: bool = False                  # qwen2.x
    mrope: bool = False                     # qwen2-vl M-RoPE
    mrope_sections: Tuple[int, ...] = (16, 24, 24)
    attn_logit_softcap: float = 0.0

    # mlp
    activation: str = "swiglu"              # swiglu | geglu
    # moe
    num_experts: int = 0
    experts_per_token: int = 0
    moe_impl: str = "ragged"                # ragged | dense_einsum | ep
    moe_capacity_factor: float = 1.25       # ep dispatch capacity

    # ssm (mamba2) / rwkv6
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4
    rwkv_head_dim: int = 64

    # hybrid (zamba2): one shared attention block applied every
    # ``hybrid_attn_every`` ssm layers (shared weights, paper's zamba2).
    hybrid_attn_every: int = 0

    # encoder-decoder (seamless)
    encoder_layers: int = 0

    # vlm: stubbed modality frontend; patch embeddings arrive precomputed
    num_patches: int = 0

    # numerics / embedding
    dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # -------------------------------------------------------------- #
    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim",
                               self.d_model // self.num_heads)

    @property
    def jnp_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def padded_heads(self) -> int:
        """Query heads padded to a 16 multiple (the production mesh's
        model-axis size) so attention shards instead of replicating —
        without this, qwen2.5's 40 heads replicate 16-way (16x compute
        and activation memory).  Extra heads are zero-initialized
        (Megatron-style head padding); the forward output is EXACT
        because the padded wo rows are zero."""
        H = self.num_heads
        if H == 0 or H % 16 == 0:
            return H
        return H + (-H % 16)

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a 256 multiple so the unembedding shards over
        the model axis (Megatron-style).  Padded logit columns are masked
        to -inf in ``layers.unembed`` — loss and argmax are EXACT.
        Unpadded vocabs (all multiples of 256) are unchanged."""
        return self.vocab_size + (-self.vocab_size % 256)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm" and self.num_heads == 0

    @property
    def d_inner(self) -> int:
        """Mamba2 inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    # -------------------------------------------------------------- #
    def param_count(self) -> float:
        """Approximate parameter count N (for MODEL_FLOPS = 6·N·D)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd = self.head_dim
        n = v * d * (1 if self.tie_embeddings else 2)
        per_layer = 0.0
        if self.family in ("dense", "moe", "vlm", "encdec", "audio"):
            attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) \
                + self.num_heads * hd * d
            if self.family == "moe":
                mlp = 3 * d * f * self.num_experts
            else:
                mlp = 3 * d * f
            per_layer = attn + mlp + 2 * d
            n += per_layer * self.num_layers
            if self.family == "encdec":
                # decoder layers add cross-attention
                n += (attn + 3 * d * f + 3 * d) * self.num_layers \
                    + attn * self.num_layers
        elif self.family == "ssm":      # rwkv6
            per_layer = 4 * d * d + 2 * d * self.d_ff + 8 * d
            n += per_layer * self.num_layers
        elif self.family == "hybrid":   # zamba2
            di = self.d_inner
            mamba = d * 2 * di + di * d + di * (2 * self.ssm_state) \
                + 3 * di
            n += mamba * self.num_layers
            attn = d * hd * (self.num_heads + 2 * self.num_kv_heads) \
                + self.num_heads * hd * d + 3 * d * f
            n += attn            # shared block counted once
        return float(n)

    def active_param_count(self) -> float:
        """Active parameters per token (MoE uses top-k experts)."""
        if self.family != "moe":
            return self.param_count()
        d, f = self.d_model, self.d_ff
        total = self.param_count()
        inactive = 3 * d * f * (self.num_experts - self.experts_per_token)
        return total - inactive * self.num_layers


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned (input-shape) cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

ALL_SHAPES = [TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K]
SHAPES = {s.name: s for s in ALL_SHAPES}
