"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — the dry-run must set XLA_FLAGS
before the first jax call.

``AxisType`` landed in jax 0.5; on older jax (0.4.x) ``jax.make_mesh``
has no ``axis_types`` parameter and every axis is implicitly Auto, so
we gate the import and only pass the kwarg when it exists.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

try:
    from jax.sharding import AxisType
except ImportError:                      # jax < 0.5: all axes are Auto
    AxisType = None


def _make(shape, axes) -> Mesh:
    if AxisType is None:
        return jax.make_mesh(tuple(shape), tuple(axes))
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2x16x16 = 512 chips (pod, data, model); the ``pod`` axis
    crosses DCN."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make(shape, axes)


def make_mesh(shape, axes) -> Mesh:
    return _make(shape, axes)


def host_device_mesh(n: int = 1, axis: str = "data") -> Mesh:
    """Small CPU mesh for tests (requires host-platform device count)."""
    return _make((n,), (axis,))
