"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — the dry-run must set XLA_FLAGS
before the first jax call.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType, Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2x16x16 = 512 chips (pod, data, model); the ``pod`` axis
    crosses DCN."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_mesh(shape, axes) -> Mesh:
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(AxisType.Auto,) * len(axes))


def host_device_mesh(n: int = 1, axis: str = "data") -> Mesh:
    """Small CPU mesh for tests (requires host-platform device count)."""
    return jax.make_mesh((n,), (axis,), axis_types=(AxisType.Auto,))
