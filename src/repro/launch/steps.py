"""Step-function constructors + input specs for every (arch x shape) cell.

``input_specs(arch, shape)`` returns weak-type-correct ShapeDtypeStruct
stand-ins for every input of the corresponding step function (params,
optimizer state, caches, token batches, stubbed modality embeddings) —
shardable, with zero device allocation.  ``make_step`` returns the pure
step function the dry-run lowers.

long_500k is only defined for sub-quadratic architectures (SWA ring /
SSM / hybrid); pure full-attention archs skip it (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.models import model as M
from repro.models.config import ModelConfig, SHAPES, ShapeConfig
from repro.train import optim

# Sub-quadratic serve paths for the 524k-token cell.
LONG_CONTEXT_ARCHS = {"mixtral_8x7b", "zamba2_7b", "rwkv6_3b",
                      "gpt_oss_20b"}
ENC_LEN_DEFAULT = 1024        # encoder frames for encdec serve cells


def cell_is_defined(arch: str, shape_name: str) -> Tuple[bool, str]:
    shape = SHAPES[shape_name]
    if shape_name == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
        return False, ("pure full-attention arch: 524k decode requires a "
                       "sub-quadratic mechanism (DESIGN.md skip list)")
    return True, ""


@dataclasses.dataclass
class Cell:
    arch: str
    cfg: ModelConfig
    shape: ShapeConfig
    step_kind: str              # train | prefill | decode
    extras: Tuple[str, ...]     # extra batch inputs


def get_cell(arch: str, shape_name: str,
             smoke: bool = False) -> Cell:
    cfg = configs.get_smoke(arch) if smoke else configs.get(arch)
    shape = SHAPES[shape_name]
    extras = ()
    if cfg.family == "vlm":
        extras = ("patch_embeds", "positions3")
    elif cfg.family == "encdec":
        extras = ("enc_embeds",)
    return Cell(arch=arch, cfg=cfg, shape=shape, step_kind=shape.kind,
                extras=extras)


# --------------------------------------------------------------------- #
# Step functions
# --------------------------------------------------------------------- #
def make_train_step(cfg: ModelConfig, ocfg: optim.AdamWConfig,
                    remat: bool = True) -> Callable:
    def train_step(params, opt_state, batch):
        def lf(p):
            kw = {}
            if cfg.family == "vlm":
                kw["patch_embeds"] = batch["patch_embeds"]
                kw["positions3"] = batch["positions3"]
            if cfg.family == "encdec":
                kw["enc_embeds"] = batch["enc_embeds"]
            return M.loss_fn(p, cfg, batch["tokens"], batch["targets"],
                             remat=remat, **kw)
        loss, grads = jax.value_and_grad(lf)(params)
        new_params, new_opt = optim.apply(ocfg, grads, opt_state, params)
        return new_params, new_opt, loss
    return train_step


def make_prefill_step(cfg: ModelConfig) -> Callable:
    def prefill_step(params, cache, batch):
        kw = {}
        if cfg.family == "vlm":
            kw["patch_embeds"] = batch["patch_embeds"]
            kw["positions3"] = batch["positions3"]
        if cfg.family == "encdec":
            kw["enc_embeds"] = batch["enc_embeds"]
        logits, cache = M.prefill(params, cfg, batch["tokens"], cache,
                                  **kw)
        return logits, cache
    return prefill_step


def make_decode_step(cfg: ModelConfig) -> Callable:
    def decode_step(params, cache, batch):
        kw = {}
        if cfg.family == "vlm":
            kw["positions3"] = batch["positions3"]
        logits, cache = M.decode_step(params, cfg, batch["tokens"],
                                      cache, batch["pos"], **kw)
        return logits, cache
    return decode_step


def make_step(cell: Cell, ocfg: Optional[optim.AdamWConfig] = None,
              remat: bool = True) -> Callable:
    if cell.step_kind == "train":
        return make_train_step(cell.cfg, ocfg or optim.AdamWConfig(),
                               remat=remat)
    if cell.step_kind == "prefill":
        return make_prefill_step(cell.cfg)
    return make_decode_step(cell.cfg)


# --------------------------------------------------------------------- #
# Input specs (ShapeDtypeStructs, no allocation)
# --------------------------------------------------------------------- #
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cell: Cell, batch: Optional[int] = None,
                seq: Optional[int] = None) -> Dict[str, Any]:
    cfg = cell.cfg
    B = batch if batch is not None else cell.shape.global_batch
    S = seq if seq is not None else cell.shape.seq_len
    out: Dict[str, Any] = {}
    if cell.step_kind == "train":
        out["tokens"] = _sds((B, S), jnp.int32)
        out["targets"] = _sds((B, S), jnp.int32)
    elif cell.step_kind == "prefill":
        out["tokens"] = _sds((B, S), jnp.int32)
    else:
        out["tokens"] = _sds((B, 1), jnp.int32)
        out["pos"] = _sds((B,), jnp.int32)
    if cfg.family == "vlm":
        Sref = S if cell.step_kind != "decode" else S
        out["patch_embeds"] = _sds((B, cfg.num_patches, cfg.d_model),
                                   cfg.jnp_dtype)
        s3 = S if cell.step_kind != "decode" else 1
        out["positions3"] = _sds((3, B, s3), jnp.int32)
        if cell.step_kind == "decode":
            del out["patch_embeds"]     # frontend ran at prefill
    if cfg.family == "encdec" and cell.step_kind != "decode":
        enc_len = min(S, ENC_LEN_DEFAULT) if cell.step_kind != "train" \
            else S
        out["enc_embeds"] = _sds((B, enc_len, cfg.d_model), cfg.jnp_dtype)
    return out


def input_specs(cell: Cell, ocfg: Optional[optim.AdamWConfig] = None,
                batch: Optional[int] = None,
                seq: Optional[int] = None) -> Tuple:
    """Full argument spec tuple for the cell's step function."""
    cfg = cell.cfg
    B = batch if batch is not None else cell.shape.global_batch
    S = seq if seq is not None else cell.shape.seq_len
    params = jax.eval_shape(lambda: M.init_params(cfg))
    b = batch_specs(cell, batch=B, seq=S)
    if cell.step_kind == "train":
        opt = jax.eval_shape(
            lambda p: optim.init(ocfg or optim.AdamWConfig(), p), params)
        return (params, opt, b)
    enc_len = min(S, ENC_LEN_DEFAULT) if cfg.family == "encdec" else None
    cache = jax.eval_shape(
        lambda: M.init_cache(cfg, B, max_len=S, enc_len=enc_len))
    return (params, cache, b)
