import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Hillclimb helper: compile one (arch x shape) cell (optionally unrolled
1/2-layer variant) and print the largest collectives + cost summary."""
import argparse
import dataclasses
import re
from collections import defaultdict

import jax

from repro.launch import mesh as mesh_lib
from repro.launch import steps as steps_lib
from repro.distributed.context import mesh_context
from repro.launch.dryrun import (_make_unrolled_step, _unrolled_cfg,
                                 analyze_compiled, build_shardings,
                                 compile_cell, production_cfg)
from repro.roofline.hlo import _GROUPS_IOTA_RE, _SHAPE_RE, _shape_bytes


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--units", type=int, default=0,
                    help="0 = full scan model; N = unrolled N units")
    ap.add_argument("--top", type=int, default=25)
    ap.add_argument("--mesh", default="pod1")
    args = ap.parse_args()

    mesh = mesh_lib.make_production_mesh(multi_pod=args.mesh == "pod2")
    cell = steps_lib.get_cell(args.arch, args.shape)
    if args.units:
        cfg_u = production_cfg(_unrolled_cfg(cell.cfg, args.units))
        cell = dataclasses.replace(cell, cfg=cfg_u)
        step = _make_unrolled_step(cell, remat=True)
        specs = steps_lib.input_specs(cell)
        in_sh, out_sh = build_shardings(cell, specs, mesh)
        with mesh_context(mesh), mesh:
            compiled = jax.jit(step, in_shardings=in_sh,
                               out_shardings=out_sh).lower(
                                   *specs).compile()
    else:
        compiled, _, _ = compile_cell(cell, mesh)

    a = analyze_compiled(compiled)
    print("cost:", {k: f"{v:.3e}" for k, v in a["cost"].items()})
    print("coll:", {k: f"{v:.3e}" for k, v in
                    a["collectives"]["by_op"].items()})
    print("mem:", {k: f"{v / 1e9:.2f}GB" for k, v in a["memory"].items()})

    txt = compiled.as_text()
    rows = []
    for line in txt.splitlines():
        s = line.strip()
        if " = " not in s:
            continue
        for op in ("all-gather", "all-reduce", "reduce-scatter",
                   "all-to-all", "collective-permute"):
            if f"{op}(" in s or f"{op}-start(" in s:
                head = s.split("(")[0]
                b = sum(_shape_bytes(d, dims)
                        for d, dims in _SHAPE_RE.findall(head))
                rows.append((b, op, s[:220]))
                break
    rows.sort(reverse=True)
    print(f"\ntop {args.top} collectives by result bytes:")
    for b, op, s in rows[:args.top]:
        print(f"  {b / 1e9:8.3f}GB {op:18} {s[:160]}")


if __name__ == "__main__":
    main()
