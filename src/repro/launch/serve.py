"""Serving launcher: continuous-batching engine, optionally with Tessera
kernel disaggregation for the decode step.

  PYTHONPATH=src python -m repro.launch.serve --arch gpt_oss_20b --smoke \
      --requests 8 --disaggregate

Or launch from a serialized deployment spec (the declarative API —
single engine, or the prefill/decode handoff pair when the spec says
``pd``; engine knobs come from ``spec.engine``):

  PYTHONPATH=src python -m repro.launch.serve --deployment spec.json \
      --smoke --requests 8
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

import repro.configs as configs
from repro.models import model as M
from repro.serving.engine import Request, ServingEngine


def _build_requests(args, cfg, max_len: int, rng_seed: int = 0):
    if args.trace:
        from repro.serving.engine import requests_from_trace
        from repro.serving.workload import make_trace
        trace = make_trace(args.trace, args.rate, args.requests, seed=0)
        return requests_from_trace(
            trace, cfg.vocab_size, max_prompt=max_len // 2,
            max_new=args.max_new)
    rng = np.random.default_rng(rng_seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, size=8)
                    .astype(np.int32),
                    max_new_tokens=args.max_new,
                    arrival=0.01 * i)
            for i in range(args.requests)]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt_oss_20b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--disaggregate", action="store_true")
    ap.add_argument("--policy", default="throughput",
                    choices=["throughput", "latency"])
    ap.add_argument("--sync-every", type=int, default=8,
                    help="decode steps between host syncs (1 = legacy "
                         "per-token accounting)")
    ap.add_argument("--trace", default=None,
                    choices=["poisson", "bursty", "diurnal"],
                    help="drive the engine from an open-loop workload "
                         "trace instead of fixed arrivals")
    ap.add_argument("--rate", type=float, default=20.0,
                    help="trace arrival rate (req/s)")
    ap.add_argument("--deployment", default=None, metavar="SPEC_JSON",
                    help="load a serialized DeploymentSpec and launch "
                         "its engine topology instead of the ad-hoc "
                         "flags (--arch/--slots/... are ignored except "
                         "--smoke/--requests/--max-new/--trace/--rate)")
    args = ap.parse_args()

    if args.deployment:
        from repro.serving.spec import DeploymentSpec
        spec = DeploymentSpec.load(args.deployment)
        arch = spec.arch or args.arch
        cfg = (configs.get_smoke(arch) if args.smoke
               else configs.get(arch))
        launched = spec.compile().launch(cfg)
        max_len = int(spec.engine.get("max_len", 64))
        reqs = _build_requests(args, cfg, max_len)
        out = launched.run(reqs)
        print(f"deployment: pd={spec.pd} kv_chunks={spec.kv_chunks} "
              f"engines={len(launched.engines)} "
              f"wire_bytes={out['wire_bytes']} shards={out['shards']}")
        print(out["engine"])
        return

    cfg = (configs.get_smoke(args.arch) if args.smoke
           else configs.get(args.arch))
    params = M.init_params(cfg)

    decode_fn = None
    if args.disaggregate:
        from repro.core import analyzer, planner
        from repro.core.costmodel import TPU_V5E, TPU_V5P
        from repro.core.executor import build_executable
        import jax.numpy as jnp
        cache = M.init_cache(cfg, args.slots, args.max_len)
        toks = jnp.zeros((args.slots, 1), jnp.int32)
        pos = jnp.zeros((args.slots,), jnp.int32)
        step = lambda p, c, t, q: M.decode_step(p, cfg, t, c, q,
                                                scan_layers=False)
        traced = analyzer.analyze(step, params, cache, toks, pos,
                                  state_argnums=(1,))
        g = analyzer.pin_nodes(traced.graph,
                               traced.state_readers |
                               traced.state_writers, 0)
        traced = traced.with_graph(g)
        plan = planner.plan(g, [TPU_V5P, TPU_V5E], policy=args.policy)
        print(plan.summary())
        exe = build_executable(traced, plan)
        decode_fn = lambda p, c, t, q: exe(p, c, t, q)

    reqs = _build_requests(args, cfg, args.max_len)
    engine = ServingEngine(cfg, params, slots=args.slots,
                           max_len=args.max_len, decode_fn=decode_fn,
                           sync_every=args.sync_every)
    stats = engine.run(reqs)
    print(stats.summary())


if __name__ == "__main__":
    main()
