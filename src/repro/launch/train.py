"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch llama3_8b --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Full configs train on the production mesh via the same step function the
dry-run lowers; on this CPU container use --smoke reduced configs.
"""
from __future__ import annotations

import argparse

import repro.configs as configs
from repro.data.pipeline import TokenBatches
from repro.train.compress import CompressionConfig
from repro.train.loop import TrainConfig, Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--compress", choices=["none", "int8", "topk"],
                    default="none")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = (configs.get_smoke(args.arch) if args.smoke
           else configs.get(args.arch))
    tcfg = TrainConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                       ckpt_dir=args.ckpt_dir, accum=args.accum,
                       compression=CompressionConfig(args.compress))
    trainer = Trainer(cfg, tcfg)
    batches = TokenBatches(cfg.vocab_size, args.batch, args.seq)
    if args.resume:
        trainer.resume(batches)
    else:
        trainer.run(batches)
    for m in trainer.metrics:
        print(f"step {m['step']:5d}  loss {m['loss']:.4f}  "
              f"t {m['t']:.1f}s")


if __name__ == "__main__":
    main()
