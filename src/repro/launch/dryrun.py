import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first: jax locks the device count at first
initialization, and the production meshes need 512 host-platform
placeholder devices.  (Smoke tests and benchmarks never import this
module, so they see the single real device.)

Per cell this script:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. jits the cell's step function with full input/output shardings,
  3. ``.lower().compile()`` — success proves the distribution config is
     coherent; ``memory_analysis()`` proves it fits,
  4. records cost_analysis + per-chip collective traffic (HLO parse),
  5. (single-pod only) compiles unrolled 1-layer and 2-layer variants to
     linearly extrapolate scan-hidden FLOPs/bytes/collectives — XLA's
     cost analysis counts while-loop bodies ONCE regardless of trip
     count, so the scanned full model under-reports by ~num_layers x.
     Layers are structurally identical, making c1 + (L-1)(c2-c1) exact.

Usage:
  python -m repro.launch.dryrun --arch granite_8b --shape train_4k \
      --mesh pod1 --out experiments/dryrun
  python -m repro.launch.dryrun --all          # every cell, both meshes
"""
import argparse
import dataclasses
import json
import subprocess
import sys
import time
import traceback
from pathlib import Path

import jax

from repro.launch import mesh as mesh_lib
from repro.launch import steps as steps_lib
from repro.models.config import SHAPES
from repro.roofline.hlo import collective_bytes
import repro.configs as configs

REPO = Path(__file__).resolve().parents[3]
DEFAULT_OUT = REPO / "experiments" / "dryrun"


# --------------------------------------------------------------------- #
def build_shardings(cell, specs, mesh):
    """(in_shardings, out_shardings) trees for the cell's step."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.distributed import sharding as SH
    from repro.train import optim

    rules = SH.TRAIN_RULES if cell.step_kind == "train" else SH.SERVE_RULES
    rep = NamedSharding(mesh, P())

    def batch_shardings(bspecs):
        out = {}
        for k, v in bspecs.items():
            if k == "positions3":               # (3, B, S)
                logical = (None, "batch", None)
            elif v.ndim == 1:
                logical = ("batch",)
            elif k in ("tokens", "targets"):
                logical = ("batch", "seq" if cell.step_kind == "train"
                           else None)
            else:                                # (B, S|P, d)
                logical = ("batch",) + (None,) * (v.ndim - 1)
            out[k] = NamedSharding(
                mesh, SH.spec_for(v.shape, logical, mesh, rules))
        return out

    p_sh = SH.param_shardings(specs[0], mesh, rules)
    if cell.step_kind == "train":
        o_sh = optim.AdamWState(step=rep, mu=p_sh, nu=p_sh, master=p_sh)
        b_sh = batch_shardings(specs[2])
        in_sh = (p_sh, o_sh, b_sh)
        out_sh = (p_sh, o_sh, rep)
    else:
        c_sh = SH.tree_shardings(specs[1], SH.cache_logical_axes(specs[1]),
                                 mesh, rules)
        b_sh = batch_shardings(specs[2])
        in_sh = (p_sh, c_sh, b_sh)
        B = specs[2]["tokens"].shape[0]
        V = cell.cfg.vocab_size
        logits_sh = NamedSharding(
            mesh, SH.spec_for((B, V), ("batch", "vocab"), mesh, rules))
        out_sh = (logits_sh, c_sh)
    return in_sh, out_sh


def production_cfg(cfg):
    """Per-mesh model-impl switches (hillclimb, EXPERIMENTS.md §Perf):
    MoE uses the expert-local shard_map path on production meshes — the
    sort-based ragged path forces GSPMD to globalize every token."""
    if cfg.family == "moe":
        return dataclasses.replace(cfg, moe_impl="ep")
    return cfg


def compile_cell(cell, mesh, remat=True):
    """Returns (compiled, lowered, stats dict)."""
    from repro.distributed.context import mesh_context
    cell = dataclasses.replace(cell, cfg=production_cfg(cell.cfg))
    step = steps_lib.make_step(cell, remat=remat)
    specs = steps_lib.input_specs(cell)
    in_sh, out_sh = build_shardings(cell, specs, mesh)
    # Serve steps donate the KV cache so the updated cache aliases the
    # input buffers (no copy of multi-GB caches per decode step).
    donate = (1,) if cell.step_kind != "train" else ()
    t0 = time.perf_counter()
    with mesh_context(mesh), mesh:
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*specs)
        t1 = time.perf_counter()
        compiled = lowered.compile()
        t2 = time.perf_counter()
    stats = {"lower_s": round(t1 - t0, 2),
             "compile_s": round(t2 - t1, 2)}
    return compiled, lowered, stats


def analyze_compiled(compiled):
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    txt = compiled.as_text()
    coll_total, coll_by_op, coll_counts = collective_bytes(txt)
    return {
        "memory": {
            "argument_bytes": getattr(ma, "argument_size_in_bytes", 0),
            "output_bytes": getattr(ma, "output_size_in_bytes", 0),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", 0),
            "alias_bytes": getattr(ma, "alias_size_in_bytes", 0),
        },
        "cost": {
            "flops": ca.get("flops", 0.0),
            "bytes": ca.get("bytes accessed", 0.0),
        },
        "collectives": {
            "per_chip_bytes": coll_total,
            "by_op": coll_by_op,
            "counts": coll_counts,
        },
    }


def _unrolled_cfg(cfg, units: int):
    """Config with ``units`` structural layer units, scan disabled."""
    if cfg.family == "hybrid":
        return dataclasses.replace(
            cfg, num_layers=units * cfg.hybrid_attn_every)
    if cfg.family == "encdec":
        return dataclasses.replace(cfg, num_layers=units,
                                   encoder_layers=units)
    return dataclasses.replace(cfg, num_layers=units)


def _layer_units(cfg) -> int:
    if cfg.family == "hybrid":
        return cfg.num_layers // cfg.hybrid_attn_every
    return cfg.num_layers


def extrapolate_roofline(cell, mesh, remat=True):
    """Compile unrolled 1-unit and 2-unit variants; extrapolate."""
    import repro.launch.steps as S

    def one(units):
        from repro.distributed.context import mesh_context
        cfg_u = production_cfg(_unrolled_cfg(cell.cfg, units))
        cell_u = dataclasses.replace(cell, cfg=cfg_u)
        step = _make_unrolled_step(cell_u, remat)
        specs = S.input_specs(cell_u)
        in_sh, out_sh = build_shardings(cell_u, specs, mesh)
        with mesh_context(mesh), mesh:
            compiled = jax.jit(step, in_shardings=in_sh,
                               out_shardings=out_sh).lower(*specs).compile()
        a = analyze_compiled(compiled)
        return (a["cost"]["flops"], a["cost"]["bytes"],
                a["collectives"]["per_chip_bytes"],
                a["collectives"]["by_op"])

    f1, b1, c1, ops1 = one(1)
    f2, b2, c2, ops2 = one(2)
    L = _layer_units(cell.cfg)
    ops = {k: ops1.get(k, 0.0) + (L - 1) * (ops2.get(k, 0.0) -
                                            ops1.get(k, 0.0))
           for k in set(ops1) | set(ops2)}
    return {
        "flops": f1 + (L - 1) * (f2 - f1),
        "bytes": b1 + (L - 1) * (b2 - b1),
        "collective_per_chip_bytes": c1 + (L - 1) * (c2 - c1),
        "collective_by_op": ops,
        "layer_units": L,
        "unit1": {"flops": f1, "bytes": b1, "coll": c1},
        "unit2": {"flops": f2, "bytes": b2, "coll": c2},
    }


def _make_unrolled_step(cell, remat):
    from repro.models import model as M
    from repro.train import optim
    cfg = cell.cfg
    if cell.step_kind == "train":
        ocfg = optim.AdamWConfig()

        def train_step(params, opt_state, batch):
            def lf(p):
                kw = {}
                if cfg.family == "vlm":
                    kw["patch_embeds"] = batch["patch_embeds"]
                    kw["positions3"] = batch["positions3"]
                if cfg.family == "encdec":
                    kw["enc_embeds"] = batch["enc_embeds"]
                return M.loss_fn(p, cfg, batch["tokens"],
                                 batch["targets"], remat=remat,
                                 scan_layers=False, **kw)
            loss, grads = jax.value_and_grad(lf)(params)
            p2, o2 = optim.apply(ocfg, grads, opt_state, params)
            return p2, o2, loss
        return train_step

    if cell.step_kind == "prefill":
        def prefill_step(params, cache, batch):
            kw = {}
            if cfg.family == "vlm":
                kw["patch_embeds"] = batch["patch_embeds"]
                kw["positions3"] = batch["positions3"]
            if cfg.family == "encdec":
                kw["enc_embeds"] = batch["enc_embeds"]
            return M.prefill(params, cfg, batch["tokens"], cache,
                             scan_layers=False, **kw)
        return prefill_step

    def decode_step(params, cache, batch):
        kw = {}
        if cfg.family == "vlm":
            kw["positions3"] = batch["positions3"]
        return M.decode_step(params, cfg, batch["tokens"], cache,
                             batch["pos"], scan_layers=False, **kw)
    return decode_step


# --------------------------------------------------------------------- #
def run_cell(arch: str, shape: str, mesh_name: str,
             with_extrapolation: bool = True, remat: bool = True) -> dict:
    rec = {"arch": arch, "shape": shape, "mesh": mesh_name, "ok": False}
    defined, reason = steps_lib.cell_is_defined(arch, shape)
    if not defined:
        rec.update(skipped=True, skip_reason=reason)
        return rec
    multi = mesh_name == "pod2"
    mesh = mesh_lib.make_production_mesh(multi_pod=multi)
    cell = steps_lib.get_cell(arch, shape)
    compiled, lowered, stats = compile_cell(cell, mesh, remat=remat)
    rec.update(stats)
    rec.update(analyze_compiled(compiled))
    rec["devices"] = int(len(mesh.devices.flatten()))
    rec["ok"] = True
    if with_extrapolation and not multi:
        rec["extrapolated"] = extrapolate_roofline(cell, mesh,
                                                   remat=remat)
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["pod1", "pod2"], default="pod1")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--archs", default=",".join(configs.ASSIGNED),
                    help="comma list for --all")
    ap.add_argument("--out", default=str(DEFAULT_OUT))
    ap.add_argument("--no-extrapolate", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    args = ap.parse_args()
    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    if args.all:
        # one subprocess per cell: isolates jit caches and failures
        cells = [(a, s, m)
                 for a in args.archs.split(",")
                 for s in SHAPES
                 for m in ("pod1", "pod2")]
        failures = 0
        for a, s, m in cells:
            outfile = out_dir / f"{a}.{s}.{m}.json"
            if outfile.exists():
                print(f"[skip existing] {outfile.name}")
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", a, "--shape", s, "--mesh", m,
                   "--out", str(out_dir)]
            if args.no_extrapolate:
                cmd.append("--no-extrapolate")
            t0 = time.time()
            r = subprocess.run(cmd, capture_output=True, text=True)
            dt = time.time() - t0
            ok = r.returncode == 0
            failures += 0 if ok else 1
            print(f"[{'ok' if ok else 'FAIL'}] {a} {s} {m} ({dt:.0f}s)")
            if not ok:
                (out_dir / f"{a}.{s}.{m}.err").write_text(
                    r.stdout + "\n" + r.stderr)
        return 1 if failures else 0

    assert args.arch and args.shape
    rec = {"arch": args.arch, "shape": args.shape, "mesh": args.mesh}
    try:
        rec = run_cell(args.arch, args.shape, args.mesh,
                       with_extrapolation=not args.no_extrapolate,
                       remat=not args.no_remat)
    except Exception:
        rec["ok"] = False
        rec["error"] = traceback.format_exc()
    outfile = Path(args.out) / \
        f"{args.arch}.{args.shape}.{args.mesh}.json"
    outfile.write_text(json.dumps(rec, indent=1))
    if rec.get("ok") or rec.get("skipped"):
        status = "SKIP" if rec.get("skipped") else "OK"
        print(f"[{status}] {args.arch} {args.shape} {args.mesh} "
              f"compile={rec.get('compile_s')}s "
              f"coll={rec.get('collectives', {}).get('per_chip_bytes', 0) / 1e6:.1f}MB")
        return 0
    print(rec.get("error", "unknown failure"))
    return 1


if __name__ == "__main__":
    sys.exit(main())
