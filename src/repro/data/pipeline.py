"""Synthetic token data pipeline: deterministic, learnable, prefetched.

Sequences are drawn from a fixed sparse Markov chain over the vocabulary
so a language model can actually reduce loss on them (used by the
end-to-end training example), packed to fixed length, and prefetched on
a host thread — the standard input-pipeline shape for TPU training.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np


class MarkovTokens:
    """Order-1 Markov chain with ``branching`` successors per token."""

    def __init__(self, vocab_size: int, branching: int = 4,
                 seed: int = 0):
        rng = np.random.default_rng(seed)
        self.vocab = vocab_size
        self.next_tokens = rng.integers(
            0, vocab_size, size=(vocab_size, branching))
        probs = rng.random((vocab_size, branching)) + 0.1
        self.probs = probs / probs.sum(axis=1, keepdims=True)

    def sample(self, rng: np.random.Generator, length: int) -> np.ndarray:
        out = np.empty(length, dtype=np.int32)
        t = int(rng.integers(0, self.vocab))
        for i in range(length):
            out[i] = t
            j = rng.choice(self.probs.shape[1], p=self.probs[t])
            t = int(self.next_tokens[t, j])
        return out


class TokenBatches:
    """Deterministic batched (tokens, targets) stream with packing."""

    def __init__(self, vocab_size: int, batch: int, seq_len: int,
                 seed: int = 0, branching: int = 4):
        self.chain = MarkovTokens(vocab_size, branching, seed)
        self.batch = batch
        self.seq_len = seq_len
        self.seed = seed
        self._step = 0

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Random-access batch (restart-safe: resume at any step)."""
        rng = np.random.default_rng((self.seed, step))
        toks = np.stack([self.chain.sample(rng, self.seq_len + 1)
                         for _ in range(self.batch)])
        return {"tokens": toks[:, :-1].astype(np.int32),
                "targets": toks[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = self._step
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Host-thread prefetch queue in front of any batch iterator."""

    def __init__(self, it: Iterator, depth: int = 2):
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()

        def worker():
            for item in it:
                if self._stop.is_set():
                    return
                self.q.put(item)
            self.q.put(None)

        self.t = threading.Thread(target=worker, daemon=True)
        self.t.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self.q.get()
        if item is None:
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
