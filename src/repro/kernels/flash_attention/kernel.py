"""Flash attention Pallas TPU kernels (prefill + decode).

TPU-native adaptation of the FlashAttention tiling the paper profiles as
its compute-bound exemplar (§II-C): Q/K/V tiles stream HBM->VMEM under
explicit BlockSpecs, the online-softmax accumulators (m, l, acc) live in
VMEM scratch across the KV grid dimension, and tile shapes are MXU-
aligned (block_q x block_k x head_dim multiples of 128 where dtypes
allow).  GQA is expressed in the K/V index_map (query head h reads KV
head h // rep) — no KV replication in HBM.

Causal + sliding-window masking skips fully-masked KV blocks via
``pl.when`` so SWA runs O(S * window).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


# --------------------------------------------------------------------- #
# Prefill kernel: grid (BH, nQ, nK), KV innermost (sequential on TPU).
# --------------------------------------------------------------------- #
def _prefill_kernel(q_ref, k_ref, v_ref, o_ref,
                    m_ref, l_ref, acc_ref, *,
                    sm_scale, causal, window, block_q, block_k,
                    kv_len, n_k):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    # Skip KV blocks with no unmasked element.
    run = jnp.bool_(True)
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + block_q - 1)
    if window is not None:
        run = jnp.logical_and(
            run, k_start + block_k - 1 > q_start - window)

    @pl.when(run)
    def _body():
        q = q_ref[...].astype(jnp.float32) * sm_scale    # (bq, d)
        k = k_ref[...].astype(jnp.float32)               # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)          # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = kpos < kv_len
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window is not None:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                              # (bq,)
        m_cur = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        p = jnp.where(mask, p, 0.0)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
        v = v_ref[...].astype(jnp.float32)               # (bk, d)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
        m_ref[...] = m_cur

    @pl.when(ki == n_k - 1)
    def _finish():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[...] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_prefill(q, k, v, *, causal=True, window=None,
                            sm_scale=None, block_q=128, block_k=128,
                            interpret=False):
    """q: (B, H, Sq, D); k/v: (B, Hkv, Skv, D) -> (B, H, Sq, D)."""
    B, H, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    rep = H // Hkv
    scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(D)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    assert Sq % block_q == 0 and Skv % block_k == 0
    n_q, n_k = Sq // block_q, Skv // block_k

    qf = q.reshape(B * H, Sq, D)
    kf = k.reshape(B * Hkv, Skv, D)
    vf = v.reshape(B * Hkv, Skv, D)

    def kv_index(bh, qi, ki):
        b = bh // H
        h = bh % H
        return (b * Hkv + h // rep, ki, 0)

    kernel = functools.partial(
        _prefill_kernel, sm_scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, kv_len=Skv, n_k=n_k)

    out = pl.pallas_call(
        kernel,
        grid=(B * H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((None, block_q, D),
                         lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((None, block_k, D), kv_index),
            pl.BlockSpec((None, block_k, D), kv_index),
        ],
        out_specs=pl.BlockSpec((None, block_q, D),
                               lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Sq, D)


# --------------------------------------------------------------------- #
# Decode kernel: one query token, grid (B, H, nK); per-request lengths.
# --------------------------------------------------------------------- #
def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref, *,
                   sm_scale, block_k, n_k):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[0]
    k_start = ki * block_k

    @pl.when(k_start < length)
    def _body():
        q = q_ref[...].astype(jnp.float32) * sm_scale    # (1, d)
        k = k_ref[...].astype(jnp.float32)               # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)          # (1, bk)
        kpos = k_start + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_k), 1)
        mask = kpos < length
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_cur = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.where(mask, jnp.exp(s - m_cur[:, None]), 0.0)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
        v = v_ref[...].astype(jnp.float32)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
        m_ref[...] = m_cur

    @pl.when(ki == n_k - 1)
    def _finish():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[...] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_decode(q, k, v, lengths, *, sm_scale=None,
                           block_k=128, interpret=False):
    """q: (B, H, D); k/v: (B, Hkv, T, D); lengths: (B,) -> (B, H, D)."""
    B, H, D = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    rep = H // Hkv
    scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(D)
    block_k = min(block_k, T)
    assert T % block_k == 0
    n_k = T // block_k

    qf = q.reshape(B, H, 1, D)

    kernel = functools.partial(_decode_kernel, sm_scale=scale,
                               block_k=block_k, n_k=n_k)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, n_k),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, ki: (b,)),
            pl.BlockSpec((None, None, 1, D),
                         lambda b, h, ki: (b, h, 0, 0)),
            pl.BlockSpec((None, None, block_k, D),
                         lambda b, h, ki: (b, h // rep, ki, 0)),
            pl.BlockSpec((None, None, block_k, D),
                         lambda b, h, ki: (b, h // rep, ki, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, 1, D),
                               lambda b, h, ki: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, 1, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, D), jnp.float32),
        ],
        interpret=interpret,
    )(lengths.astype(jnp.int32), qf, k, v)
    return out.reshape(B, H, D)
