"""Pure-jnp oracle for flash attention (prefill and decode)."""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True,
                  window: Optional[int] = None,
                  sm_scale: Optional[float] = None) -> jnp.ndarray:
    """q: (B, H, Sq, D); k/v: (B, Hkv, Skv, D).  GQA via head grouping.
    Returns (B, H, Sq, D) in q.dtype, accumulating in fp32."""
    B, H, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    rep = H // Hkv
    scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(D)
    qg = q.reshape(B, Hkv, rep, Sq, D).astype(jnp.float32) * scale
    logits = jnp.einsum("bhrqd,bhkd->bhrqk", qg,
                        k.astype(jnp.float32))
    qpos = jnp.arange(Sq) + (Skv - Sq if causal else 0)
    kpos = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), dtype=bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhrqk,bhkd->bhrqd", w, v.astype(jnp.float32))
    return o.reshape(B, H, Sq, D).astype(q.dtype)


def decode_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
               lengths: jnp.ndarray, *,
               sm_scale: Optional[float] = None) -> jnp.ndarray:
    """Single-token decode.  q: (B, H, D); k/v: (B, Hkv, T, D);
    lengths: (B,) valid KV length per request."""
    B, H, D = q.shape
    Hkv, T = k.shape[1], k.shape[2]
    rep = H // Hkv
    scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(D)
    qg = q.reshape(B, Hkv, rep, D).astype(jnp.float32) * scale
    logits = jnp.einsum("bhrd,bhkd->bhrk", qg, k.astype(jnp.float32))
    valid = jnp.arange(T)[None, :] < lengths[:, None]        # (B, T)
    logits = jnp.where(valid[:, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhrk,bhkd->bhrd", w, v.astype(jnp.float32))
    return o.reshape(B, H, D).astype(q.dtype)
