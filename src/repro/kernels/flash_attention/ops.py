"""Jit'd public wrappers for the flash attention kernels.

``interpret`` defaults to True off-TPU so the TPU-target kernels are
exercised (and validated) on CPU; on real TPU backends the compiled
Mosaic kernels run.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels.flash_attention import kernel as K


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "sm_scale", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    sm_scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: Optional[bool] = None):
    """Prefill/training attention.  q: (B, H, S, D); k/v: (B, Hkv, S, D)."""
    itp = _default_interpret() if interpret is None else interpret
    return K.flash_attention_prefill(
        q, k, v, causal=causal, window=window, sm_scale=sm_scale,
        block_q=block_q, block_k=block_k, interpret=itp)


@functools.partial(jax.jit, static_argnames=(
    "sm_scale", "block_k", "interpret"))
def flash_decode(q, k, v, lengths, *, sm_scale: Optional[float] = None,
                 block_k: int = 128, interpret: Optional[bool] = None):
    """Decode attention.  q: (B, H, D); k/v: (B, Hkv, T, D); lengths (B,)."""
    itp = _default_interpret() if interpret is None else interpret
    return K.flash_attention_decode(
        q, k, v, lengths, sm_scale=sm_scale, block_k=block_k,
        interpret=itp)
