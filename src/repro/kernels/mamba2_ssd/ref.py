"""Pure-jnp oracle for the Mamba2 SSD chunked scan: sequential token
recurrence  h_t = a_t * h_{t-1} + dt_t x_t (x) B_t;  y_t = C_t . h_t."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(xh: jnp.ndarray, B_: jnp.ndarray, C_: jnp.ndarray,
            a_log: jnp.ndarray):
    """xh: (B, S, H, P) dt-scaled inputs; B_/C_: (B, S, N) fp32;
    a_log: (B, S, H) log decay.  Returns (y (B,S,H,P) fp32,
    final_state (B,H,N,P) fp32)."""
    Bb, S, H, P = xh.shape
    N = B_.shape[-1]

    def step(h, t):
        a = jnp.exp(a_log[:, t])                       # (B, H)
        h = h * a[:, :, None, None] + jnp.einsum(
            "bn,bhp->bhnp", B_[:, t].astype(jnp.float32),
            xh[:, t].astype(jnp.float32))
        y = jnp.einsum("bn,bhnp->bhp", C_[:, t].astype(jnp.float32), h)
        return h, y

    h0 = jnp.zeros((Bb, H, N, P), jnp.float32)
    hT, ys = jax.lax.scan(step, h0, jnp.arange(S))
    return jnp.moveaxis(ys, 0, 1), hT
