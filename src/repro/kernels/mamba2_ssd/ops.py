"""Jit'd wrapper for the Mamba2 SSD chunk-scan kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.mamba2_ssd import kernel as K


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(xh, B_, C_, a_log, *, chunk: int = 128, interpret=None):
    itp = _default_interpret() if interpret is None else interpret
    return K.ssd_chunk_scan(xh, B_, C_, a_log, chunk=chunk,
                            interpret=itp)
