"""Mamba2 SSD chunked-scan Pallas TPU kernel.

TPU adaptation of the SSD algorithm: the sequence is processed in chunks
of Q tokens; within a chunk the recurrence closes into three MXU matmuls
(CB^T Gram matrix, masked-decay weighting, PV product) — turning a
latency-bound scan into systolic-friendly GEMMs — while the O(H*N*P)
running state is carried across the chunk grid dimension in VMEM
scratch.  Grid: (batch, n_chunks), chunks innermost (sequential on TPU,
which legalizes the scratch carry).

Shapes per block: xh (Q, H, P) -> processed per head via a fori loop to
keep VMEM small: the per-head working set is Q*P + Q*N + N*P floats.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(xh_ref, b_ref, c_ref, al_ref, y_ref, hout_ref,
                state_ref, *, chunk, heads, n_chunks):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    bmat = b_ref[...].astype(jnp.float32)            # (Q, N)
    cmat = c_ref[...].astype(jnp.float32)            # (Q, N)
    cb = jax.lax.dot_general(                        # (Q, Q) Gram
        cmat, bmat, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    Q = chunk
    row = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    tril = row >= col

    def per_head(h, _):
        al = al_ref[:, h].astype(jnp.float32)        # (Q,)
        cum = jnp.cumsum(al)                         # (Q,)
        # L[t, s] = exp(cum_t - cum_s) for s <= t
        diff = cum[:, None] - cum[None, :]
        L = jnp.where(tril, jnp.exp(diff), 0.0)
        W = cb * L                                   # (Q, Q)
        xh = xh_ref[:, h, :].astype(jnp.float32)     # (Q, P)
        y_intra = jax.lax.dot_general(
            W, xh, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)      # (Q, P)
        # incoming-state contribution: y_state[t] = (C_t * exp(cum_t)) h
        h_in = state_ref[h]                          # (N, P)
        c_dec = cmat * jnp.exp(cum)[:, None]         # (Q, N)
        y_state = jax.lax.dot_general(
            c_dec, h_in, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)      # (Q, P)
        y_ref[:, h, :] = (y_intra + y_state).astype(y_ref.dtype)
        # state update: h' = exp(cum_Q) h + sum_s exp(cum_Q - cum_s) B_s xh_s
        dec = jnp.exp(cum[Q - 1] - cum)              # (Q,)
        b_dec = bmat * dec[:, None]                  # (Q, N)
        st = jax.lax.dot_general(
            b_dec, xh, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)      # (N, P)
        state_ref[h] = jnp.exp(cum[Q - 1]) * h_in + st
        return 0

    jax.lax.fori_loop(0, heads, per_head, 0)

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        hout_ref[...] = state_ref[...]


def ssd_chunk_scan(xh, B_, C_, a_log, *, chunk=128, interpret=False):
    """xh: (B, S, H, P); B_/C_: (B, S, N); a_log: (B, S, H).
    Returns (y (B, S, H, P) fp32, final_state (B, H, N, P) fp32)."""
    Bb, S, H, P = xh.shape
    N = B_.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0
    nC = S // chunk

    kernel = functools.partial(_ssd_kernel, chunk=chunk, heads=H,
                               n_chunks=nC)
    y, hT = pl.pallas_call(
        kernel,
        grid=(Bb, nC),
        in_specs=[
            pl.BlockSpec((None, chunk, H, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((None, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((None, chunk, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((None, chunk, H), lambda b, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, chunk, H, P), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((None, H, N, P), lambda b, c: (b, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bb, S, H, P), jnp.float32),
            jax.ShapeDtypeStruct((Bb, H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((H, N, P), jnp.float32)],
        interpret=interpret,
    )(xh, B_, C_, a_log)
    return y, hT
