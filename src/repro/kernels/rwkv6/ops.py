"""Jit'd wrapper for the RWKV6 WKV kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.rwkv6 import kernel as K


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv(r, k, v, w, u, state, *, chunk: int = 64, interpret=None):
    itp = _default_interpret() if interpret is None else interpret
    return K.wkv(r, k, v, w, u, state, chunk=chunk, interpret=itp)
