"""Pure-jnp oracle for the RWKV6 WKV recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv_ref(r, k, v, w, u, state):
    """r,k,v,w: (B, S, H, P) (w = per-channel decay in (0,1));
    u: (H, P) bonus; state: (B, H, P, P) [key x value].
    y_t = r_t . (S_{t-1} + diag(u) k_t v_t^T);  S_t = diag(w_t) S_{t-1}
    + k_t v_t^T.  Returns (y (B,S,H,P) fp32, final state)."""
    B, S, H, P = r.shape

    def step(s, t):
        rt = r[:, t].astype(jnp.float32)
        kt = k[:, t].astype(jnp.float32)
        vt = v[:, t].astype(jnp.float32)
        wt = w[:, t].astype(jnp.float32)
        kv = kt[..., :, None] * vt[..., None, :]
        y = jnp.einsum("bhp,bhpq->bhq", rt, s + u[None, :, :, None] * kv)
        s = wt[..., :, None] * s + kv
        return s, y

    sT, ys = jax.lax.scan(step, state.astype(jnp.float32),
                          jnp.arange(S))
    return jnp.moveaxis(ys, 0, 1), sT
