"""RWKV6 WKV Pallas TPU kernel.

The WKV recurrence has *per-channel* data-dependent decay (diag(w_t)), so
unlike Mamba2's scalar-decay SSD it does not close into plain GEMMs
without per-channel decay matrices.  The TPU-native choice: keep the
(P x P) state resident in VMEM scratch across a (batch, head, chunk)
grid and run the token recurrence on the VPU inside the chunk — the
state never round-trips HBM (the whole point of the kernel), and chunk
blocks stream r/k/v/w tiles HBM->VMEM.

This mirrors how the official CUDA kernel works (sequential inner loop,
state in shared memory), adapted to Pallas refs + grid carry.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
                y_ref, sT_ref, state_ref, *, chunk, n_chunks):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = s0_ref[...].astype(jnp.float32)

    u = u_ref[...].astype(jnp.float32)               # (P,)

    def step(t, _):
        rt = r_ref[t, :].astype(jnp.float32)         # (P,)
        kt = k_ref[t, :].astype(jnp.float32)
        vt = v_ref[t, :].astype(jnp.float32)
        wt = w_ref[t, :].astype(jnp.float32)
        s = state_ref[...]                           # (P, P)
        kv = kt[:, None] * vt[None, :]               # (P, P)
        y = ((s + u[:, None] * kv) * rt[:, None]).sum(axis=0)
        y_ref[t, :] = y.astype(y_ref.dtype)
        state_ref[...] = wt[:, None] * s + kv
        return 0

    jax.lax.fori_loop(0, chunk, step, 0)

    @pl.when(ci == n_chunks - 1)
    def _emit():
        sT_ref[...] = state_ref[...]


def wkv(r, k, v, w, u, state, *, chunk=64, interpret=False):
    """r,k,v,w: (B, S, H, P); u: (H, P); state: (B, H, P, P).
    Returns (y (B, S, H, P) fp32, final state (B, H, P, P) fp32)."""
    B, S, H, P = r.shape
    chunk = min(chunk, S)
    assert S % chunk == 0
    nC = S // chunk

    def tok_spec():
        # (B, S, H, P) -> block (chunk, P) at (b, c, h)
        return pl.BlockSpec((None, chunk, None, P),
                            lambda b, h, c: (b, c, h, 0))

    kernel = functools.partial(_wkv_kernel, chunk=chunk, n_chunks=nC)
    y, sT = pl.pallas_call(
        kernel,
        grid=(B, H, nC),
        in_specs=[
            tok_spec(), tok_spec(), tok_spec(), tok_spec(),
            pl.BlockSpec((None, P), lambda b, h, c: (h, 0)),
            pl.BlockSpec((None, None, P, P), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_specs=[
            tok_spec(),
            pl.BlockSpec((None, None, P, P), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, P), jnp.float32),
            jax.ShapeDtypeStruct((B, H, P, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, P), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, state)
    return y, sT
