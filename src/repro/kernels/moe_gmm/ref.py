"""Pure-jnp oracle for the grouped (per-expert) matmul."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gmm_ref(x: jnp.ndarray, w: jnp.ndarray,
            group_sizes: jnp.ndarray) -> jnp.ndarray:
    """x: (T, d) rows sorted by expert; w: (E, d, f);
    group_sizes: (E,) with sum == T.  Returns (T, f)."""
    T, d = x.shape
    E, _, f = w.shape
    ends = jnp.cumsum(group_sizes)
    starts = ends - group_sizes
    row = jnp.arange(T)
    # expert id per row
    gid = jnp.sum(row[:, None] >= ends[None, :], axis=1)
    wx = w[gid]                                    # (T, d, f) gather
    return jnp.einsum("td,tdf->tf", x.astype(jnp.float32),
                      wx.astype(jnp.float32)).astype(x.dtype)
