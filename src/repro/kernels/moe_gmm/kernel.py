"""Grouped matmul (MoE expert GEMM) Pallas TPU kernel.

Megablocks-style dropless expert compute adapted to TPU: tokens arrive
sorted by expert and padded so each expert's group is a whole number of
``block_m`` row tiles.  A scalar-prefetch array maps each row tile to its
expert id; the expert weight BlockSpec *index_map consumes that scalar*
so the right (d x block_n) weight tile is streamed into VMEM per grid
step — expert indirection costs zero gather traffic.

Grid: (m_tiles, n_tiles); each step computes a full-depth
(block_m x d) @ (d x block_n) MXU product.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(gid_ref, x_ref, w_ref, o_ref):
    x = x_ref[...]
    w = w_ref[...]
    o_ref[...] = jax.lax.dot_general(
        x.astype(jnp.float32), w.astype(jnp.float32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(o_ref.dtype)


def gmm(x: jnp.ndarray, w: jnp.ndarray, tile_gid: jnp.ndarray, *,
        block_m: int = 128, block_n: int = 128,
        interpret: bool = False) -> jnp.ndarray:
    """x: (T_pad, d) expert-sorted, group-padded rows; w: (E, d, f);
    tile_gid: (T_pad // block_m,) expert id per row tile."""
    T, d = x.shape
    E, _, f = w.shape
    assert T % block_m == 0 and f % block_n == 0
    m_tiles, n_tiles = T // block_m, f // block_n

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(m_tiles, n_tiles),
        in_specs=[
            pl.BlockSpec((block_m, d), lambda mi, ni, gid: (mi, 0)),
            pl.BlockSpec((None, d, block_n),
                         lambda mi, ni, gid: (gid[mi], 0, ni)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n),
                               lambda mi, ni, gid: (mi, ni)),
    )
    return pl.pallas_call(
        _gmm_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, f), x.dtype),
        interpret=interpret,
    )(tile_gid.astype(jnp.int32), x, w)


def pad_groups(x_sorted: jnp.ndarray, group_sizes: jnp.ndarray,
               block_m: int):
    """Host-side helper: pad each expert group to a block_m multiple.

    Returns (x_padded (T_pad, d), tile_gid (T_pad/block_m,),
    scatter_idx (T,) mapping original rows into the padded layout).
    Uses concrete (non-traced) group sizes — serving engines call this on
    host metadata, matching megablocks' host-side binning.
    """
    import numpy as np
    gs = np.asarray(group_sizes)
    E = len(gs)
    padded = ((gs + block_m - 1) // block_m) * block_m
    if padded.sum() == 0:
        padded = padded.copy()
        padded[0] = block_m
    starts_pad = np.concatenate([[0], np.cumsum(padded)[:-1]])
    starts = np.concatenate([[0], np.cumsum(gs)[:-1]])
    T, d = x_sorted.shape
    scatter = np.zeros(T, dtype=np.int32)
    for e in range(E):
        scatter[starts[e]:starts[e] + gs[e]] = \
            starts_pad[e] + np.arange(gs[e])
    T_pad = int(padded.sum())
    xp = jnp.zeros((T_pad, d), x_sorted.dtype).at[scatter].set(x_sorted)
    tile_gid = np.repeat(np.arange(E), padded // block_m).astype(np.int32)
    return xp, jnp.asarray(tile_gid), jnp.asarray(scatter)
