"""Jit'd wrapper for the grouped-matmul kernel (static tile map)."""
from __future__ import annotations

import functools

import jax

from repro.kernels.moe_gmm import kernel as K

pad_groups = K.pad_groups


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("block_m", "block_n",
                                             "interpret"))
def gmm(x, w, tile_gid, *, block_m: int = 128, block_n: int = 128,
        interpret=None):
    itp = _default_interpret() if interpret is None else interpret
    return K.gmm(x, w, tile_gid, block_m=block_m, block_n=block_n,
                 interpret=itp)
