"""HLO-text parsing for roofline collective accounting.

``cost_analysis()`` does not expose collective traffic, so we parse the
compiled (SPMD, per-chip) HLO module and sum the bytes moved by every
collective op.  Per-op traffic accounting (ring-algorithm estimates):

  all-gather          result_bytes            (each chip receives ~full)
  all-reduce          2 x result_bytes x (n-1)/n
  reduce-scatter      result_bytes x n        (operand is consumed)
  all-to-all          result_bytes
  collective-permute  result_bytes

The parsed numbers are PER-CHIP traffic; the roofline collective term is
per_chip_bytes / link_bw (equivalently sum-over-chips / (chips x BW)).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter",
                "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> float:
    bs = _DTYPE_BYTES.get(dtype)
    if bs is None:
        return 0.0
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return float(n * bs)


def _result_bytes(line: str, op: str) -> float:
    """Sum shape literals appearing before the op call (the result)."""
    head = line.split(f"{op}(")[0]
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(head):
        total += _shape_bytes(dtype, dims)
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        # iota form [groups, group_size]
        return max(int(m.group(2)), 1)
    m = _GROUPS_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return 1


def collective_bytes(hlo_text: str) -> Tuple[float, Dict[str, float],
                                             Dict[str, int]]:
    """(total per-chip traffic bytes, bytes-by-op, count-by-op)."""
    by_op: Dict[str, float] = defaultdict(float)
    counts: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        s = line.strip()
        if " = " not in s:
            continue
        for op in _COLLECTIVES:
            # match the op as an instruction call, not a substring of
            # another op name (all-reduce vs all-reduce-start)
            if f"{op}(" not in s and f"{op}-start(" not in s:
                continue
            opname = op if f"{op}(" in s else f"{op}-start"
            rb = _result_bytes(s, opname.split("-start")[0]
                               if "-start" in opname else op)
            if rb == 0.0:
                continue
            n = _group_size(s)
            if op == "all-reduce":
                traffic = 2.0 * rb * (n - 1) / max(n, 1)
            elif op == "reduce-scatter":
                traffic = rb * n
            else:
                traffic = rb
            by_op[op] += traffic
            counts[op] += 1
            break
    return sum(by_op.values()), dict(by_op), dict(counts)


def count_op(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"\b{re.escape(opname)}\(", hlo_text))
