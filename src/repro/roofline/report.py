"""Roofline report: three terms per (arch x shape) from dry-run artifacts.

  compute    = HLO_FLOPs_global  / (chips * 197 TFLOP/s)
  memory     = HLO_bytes_global  / (chips * 819 GB/s)
  collective = per-chip collective traffic / 50 GB/s  (ICI)

FLOPs/bytes come from the scan-corrected L1/L2 extrapolation (see
launch/dryrun.py) — cost_analysis counts while bodies once, so raw
numbers under-report by ~num_layers x.  cost_analysis is per-chip
(post-SPMD); global = per_chip * chips.

MODEL_FLOPS = 6*N*D (train) or 2*N*D (inference), N_active for MoE —
the ratio MODEL_FLOPS / HLO_FLOPs measures how much compiled compute is
"useful" (catching remat/dispatch/redundancy waste).

Usage: PYTHONPATH=src python -m repro.roofline.report [--dir DIR]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path
from typing import Dict, List, Optional

import repro.configs as configs
from repro.models.config import SHAPES

CHIPS = 256
PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

REPO = Path(__file__).resolve().parents[3]
DEFAULT_DIR = REPO / "experiments" / "dryrun"


def model_flops(arch: str, shape_name: str) -> float:
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch            # one new token per request
    return 2.0 * n * tokens


def analyze_record(rec: dict) -> Optional[dict]:
    if rec.get("skipped") or not rec.get("ok"):
        return None
    ext = rec.get("extrapolated")
    if ext:
        flops_pc = ext["flops"]
        bytes_pc = ext["bytes"]
        coll_pc = ext["collective_per_chip_bytes"]
    else:
        flops_pc = rec["cost"]["flops"]
        bytes_pc = rec["cost"]["bytes"]
        coll_pc = rec["collectives"]["per_chip_bytes"]
    chips = rec.get("devices", CHIPS)
    t_comp = flops_pc / PEAK_FLOPS          # per-chip seconds
    t_mem = bytes_pc / HBM_BW
    t_coll = coll_pc / LINK_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dom = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    hlo_global = flops_pc * chips
    ratio = mf / hlo_global if hlo_global else 0.0
    bound = max(terms.values())
    frac = (mf / chips / PEAK_FLOPS) / bound if bound > 0 else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"],
        "t_compute": t_comp, "t_memory": t_mem, "t_collective": t_coll,
        "dominant": dom, "model_flops": mf,
        "useful_ratio": ratio,
        "roofline_fraction": frac,        # useful-compute / bound time
        "hbm_per_chip": rec["memory"]["argument_bytes"] +
        rec["memory"]["temp_bytes"],
        "compile_s": rec.get("compile_s", 0.0),
    }


def fix_note(row: dict) -> str:
    d = row["dominant"]
    if d == "collective":
        return ("reshard/collective-bound: cut all-gather volume "
                "(better weight layout, overlap, or compression)")
    if d == "memory":
        if row["shape"].startswith("decode") or \
                row["shape"].startswith("long"):
            return ("HBM-bound (weight+KV streaming): quantize KV/"
                    "weights or raise batch to amortize reads")
        return "HBM-bound: improve fusion / remat policy to cut traffic"
    return ("compute-bound: good — push MXU utilization via tiling "
            "(Pallas kernels) and reduce non-GEMM flops")


def load_rows(d: Path, mesh: str = "pod1") -> List[dict]:
    rows = []
    for f in sorted(d.glob(f"*.{mesh}.json")):
        rec = json.loads(f.read_text())
        row = analyze_record(rec)
        if row:
            rows.append(row)
    return rows


def fmt_table(rows: List[dict]) -> str:
    hdr = ("| arch | shape | compute(ms) | memory(ms) | coll(ms) | "
           "dominant | MODEL/HLO | roofline frac | note |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {r['t_compute'] * 1e3:9.2f} | {r['t_memory'] * 1e3:9.2f} "
            f"| {r['t_collective'] * 1e3:9.2f} | {r['dominant']:10} "
            f"| {r['useful_ratio']:9.3f} | {r['roofline_fraction']:8.3f} "
            f"| {fix_note(r)} |")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=str(DEFAULT_DIR))
    ap.add_argument("--csv", default=str(REPO / "experiments" /
                                         "roofline.csv"))
    args = ap.parse_args()
    rows = load_rows(Path(args.dir))
    print(fmt_table(rows))
    import csv
    with open(args.csv, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
        w.writeheader()
        w.writerows(rows)
    print(f"\nwrote {args.csv} ({len(rows)} cells)")


if __name__ == "__main__":
    main()
