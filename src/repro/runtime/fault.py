"""Fault-tolerant runtime glue: failure detection, elastic re-planning,
straggler deadlines.

Tessera-native elasticity: because the unit of placement is a
*kernel*, losing a device never requires re-architecting the
parallelism — the planner simply re-solves placement over the
surviving device set (``replan_on_failure``), pinned state is
re-homed, and the executor is rebuilt.  This is strictly more flexible
than phase/block disaggregation, whose recovery unit is an entire
phase pool.

Health primitives live in :mod:`repro.serving.faults` (the
fault-tolerance layer serving both the DES and the live engines);
``DeviceHealth`` is re-exported here for compatibility — it keeps its
historical ``alive``/``fail``/``lost`` surface but now latches a
per-device circuit breaker (``serving.faults.GroupHealth``) on
failure, so runtime device loss and serving-layer health speak the
same language.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, List, Optional, Sequence, Set

import jax

from repro.core import planner as planner_lib
from repro.core.analyzer import TracedGraph
from repro.core.executor import StagedExecutable, build_executable
from repro.core.planner import Plan
from repro.serving.faults import DeviceHealth

__all__ = ["DeviceHealth", "ElasticExecutor"]


class ElasticExecutor:
    """Disaggregated executor that survives device loss.

    On ``mark_failed(i)`` the placement is re-solved over survivors and
    stages recompiled; in-flight pure stages are simply re-executed (the
    same idempotence that powers straggler re-execution).
    """

    def __init__(self, traced: TracedGraph, device_specs,
                 jax_devices: Sequence[Any], policy: str = "throughput"):
        self.traced = traced
        self.specs = list(device_specs)
        self.jax_devices = list(jax_devices)
        self.policy = policy
        self.health = DeviceHealth([True] * len(device_specs))
        self.plan = planner_lib.plan(traced.graph, self.specs,
                                     policy=policy)
        self.replans = 0
        self._rebuild()

    def _rebuild(self) -> None:
        alive_idx = [i for i, a in enumerate(self.health.alive) if a]
        spec_map = [self.specs[i] for i in alive_idx]
        dev_map = [self.jax_devices[i % len(self.jax_devices)]
                   for i in alive_idx]
        if len(alive_idx) < len(self.specs):
            self.plan = planner_lib.replan_on_failure(
                self.traced.graph, self.specs, self.health.lost(),
                self.plan, cache=False)
        self.exe = build_executable(self.traced, self.plan, dev_map)

    def mark_failed(self, idx: int) -> None:
        self.health.fail(idx)
        self.replans += 1
        self._rebuild()

    def __call__(self, *args, **kwargs):
        return self.exe(*args, **kwargs)
