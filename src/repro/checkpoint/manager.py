"""Checkpointing: atomic, async, restart-safe.

Layout:  <dir>/step_<N>/   arrays.npz  manifest.json
Writes go to ``step_<N>.tmp`` and are renamed only after fsync — a
half-written checkpoint can never be mistaken for a complete one (the
crash-restart test exercises exactly this).  ``save_async`` offloads
serialization to a background thread so the train loop never blocks on
disk; ``latest_step``/``restore`` implement auto-resume.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        out[key] = np.asarray(leaf)
    return out


def _unflatten(template: Any, arrays: Dict[str, np.ndarray]) -> Any:
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        arr = arrays[key]
        target_dtype = getattr(leaf, "dtype", arr.dtype)
        leaves.append(np.asarray(arr).astype(target_dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._errors: List[BaseException] = []

    # ------------------------------------------------------------------ #
    def save(self, step: int, state: Any,
             extra: Optional[Dict] = None) -> Path:
        final = self.dir / f"step_{step:08d}"
        tmp = self.dir / f"step_{step:08d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        arrays = _flatten(state)
        # bf16 has no numpy dtype: store raw bits + dtype tag
        np.savez(tmp / "arrays.npz", **{
            k: (v.view(np.uint16) if v.dtype.name == "bfloat16" else v)
            for k, v in arrays.items()})
        dtypes = {k: v.dtype.name for k, v in arrays.items()}
        manifest = {"step": step, "time": time.time(),
                    "dtypes": dtypes, "extra": extra or {}}
        with open(tmp / "manifest.json", "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()
        return final

    def save_async(self, step: int, state: Any,
                   extra: Optional[Dict] = None) -> None:
        # snapshot to host memory synchronously (cheap), write async
        arrays_host = jax.tree_util.tree_map(np.asarray, state)
        self.wait()

        def work():
            try:
                self.save(step, arrays_host, extra)
            except BaseException as e:       # surfaced by wait()
                self._errors.append(e)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._errors:
            raise self._errors.pop()

    # ------------------------------------------------------------------ #
    def steps(self) -> List[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "manifest.json").exists():
                continue
            out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, template: Any,
                step: Optional[int] = None) -> Tuple[int, Any]:
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        raw = dict(np.load(d / "arrays.npz"))
        import jax.numpy as jnp
        arrays = {}
        for k, v in raw.items():
            if manifest["dtypes"].get(k) == "bfloat16":
                arrays[k] = jnp.asarray(v.view(np.uint16)).view(
                    jnp.bfloat16)
            else:
                arrays[k] = v
        return step, _unflatten(template, arrays)

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)
