"""Exact latency-policy placement via min s-t cut (and alpha-expansion).

The paper solves its latency objective

    min  sum_k t_{k,g(k)}  +  sum_{(i,j) in E, g(i) != g(j)} c_ij

with a generic MILP (Gurobi).  For |G| = 2 this objective is exactly the
energy of a binary labeling with additive unary terms and submodular
pairwise terms, so the *global optimum* is a minimum s-t cut — solved here
with Dinic's algorithm in O(E sqrt(V)).  For |G| > 2 we use alpha-expansion
(repeated binary cuts), which carries strong approximation guarantees for
metric pairwise costs and matches the exact optimum on every small random
instance in our tests.

This is both faster and stronger than the paper's formulation for the
2-device case that dominates its evaluation (heterogeneous GPU pairs).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.graph import KernelGraph

INF = float("inf")


class Dinic:
    """Max-flow/min-cut with float capacities."""

    def __init__(self, n: int):
        self.n = n
        self.to: List[int] = []
        self.cap: List[float] = []
        self.head: List[List[int]] = [[] for _ in range(n)]

    def add_edge(self, u: int, v: int, c: float, rc: float = 0.0) -> None:
        self.head[u].append(len(self.to))
        self.to.append(v)
        self.cap.append(c)
        self.head[v].append(len(self.to))
        self.to.append(u)
        self.cap.append(rc)

    def _bfs(self, s: int, t: int) -> bool:
        self.level = [-1] * self.n
        self.level[s] = 0
        q = deque([s])
        while q:
            u = q.popleft()
            for eid in self.head[u]:
                v = self.to[eid]
                if self.cap[eid] > 1e-12 and self.level[v] < 0:
                    self.level[v] = self.level[u] + 1
                    q.append(v)
        return self.level[t] >= 0

    def _dfs(self, u: int, t: int, f: float) -> float:
        if u == t:
            return f
        while self.it[u] < len(self.head[u]):
            eid = self.head[u][self.it[u]]
            v = self.to[eid]
            if self.cap[eid] > 1e-12 and self.level[v] == self.level[u] + 1:
                d = self._dfs(v, t, min(f, self.cap[eid]))
                if d > 1e-12:
                    self.cap[eid] -= d
                    self.cap[eid ^ 1] += d
                    return d
            self.it[u] += 1
        return 0.0

    def max_flow(self, s: int, t: int) -> float:
        flow = 0.0
        while self._bfs(s, t):
            self.it = [0] * self.n
            while True:
                f = self._dfs(s, t, INF)
                if f <= 1e-12:
                    break
                flow += f
        return flow

    def min_cut_side(self, s: int) -> List[bool]:
        """After max_flow: True = reachable from s in residual (label 0)."""
        seen = [False] * self.n
        seen[s] = True
        q = deque([s])
        while q:
            u = q.popleft()
            for eid in self.head[u]:
                v = self.to[eid]
                if self.cap[eid] > 1e-12 and not seen[v]:
                    seen[v] = True
                    q.append(v)
        return seen


# --------------------------------------------------------------------- #
def solve_latency_2dev(unary: Sequence[Sequence[float]],
                       pair: Dict[Tuple[int, int], Tuple[float, float]],
                       pins: Optional[Dict[int, int]] = None
                       ) -> Tuple[List[int], float]:
    """Globally optimal binary placement.

    unary[k] = (t_k on dev0, t_k on dev1)
    pair[(i, j)] = (cost if i on 0 and j on 1, cost if i on 1 and j on 0)
    pins: node -> forced device.
    Returns (labels, objective).
    """
    n = len(unary)
    s, t = n, n + 1
    g = Dinic(n + 2)
    for k, (t0, t1) in enumerate(unary):
        # label 0 (source side) pays t0 via cut of k->t; label 1 pays t1.
        g.add_edge(s, k, float(t1))
        g.add_edge(k, t, float(t0))
    if pins:
        for k, d in pins.items():
            if d == 0:
                g.add_edge(s, k, INF)
            else:
                g.add_edge(k, t, INF)
    for (i, j), (c01, c10) in pair.items():
        if c01 > 0:
            g.add_edge(i, j, float(c01))
        if c10 > 0:
            g.add_edge(j, i, float(c10))
    g.max_flow(s, t)
    side = g.min_cut_side(s)
    labels = [0 if side[k] else 1 for k in range(n)]
    obj = _energy(labels, unary, pair)
    return labels, obj


def _energy(labels, unary, pair) -> float:
    e = sum(unary[k][labels[k]] for k in range(len(labels)))
    for (i, j), (c01, c10) in pair.items():
        if labels[i] == labels[j]:
            continue
        e += c01 if labels[i] == 0 else c10
    return e


# --------------------------------------------------------------------- #
def solve_latency_multi(unary: Sequence[Sequence[float]],
                        pair_cost,  # (i, j, gi, gj) -> float
                        num_devices: int,
                        pins: Optional[Dict[int, int]] = None,
                        max_rounds: int = 8) -> Tuple[List[int], float]:
    """Alpha-expansion for |G| > 2 latency placement.

    ``pair_cost(i, j, gi, gj)`` must be 0 when gi == gj and satisfy the
    (approximate) metric property; transfer costs l + d/bw do.
    """
    n = len(unary)
    pins = pins or {}
    labels = [pins.get(k, min(range(num_devices), key=lambda g: unary[k][g]))
              for k in range(n)]

    def total(ls):
        e = sum(unary[k][ls[k]] for k in range(n))
        for (i, j) in pair_keys:
            e += pair_cost(i, j, ls[i], ls[j])
        return e

    pair_keys = list(_pair_keys_from(pair_cost))

    best = total(labels)
    for _ in range(max_rounds):
        improved = False
        for alpha in range(num_devices):
            new_labels, new_e = _expand(labels, alpha, unary, pair_cost,
                                        pair_keys, pins)
            if new_e < best - 1e-12:
                labels, best = new_labels, new_e
                improved = True
        if not improved:
            break
    return labels, best


def _pair_keys_from(pair_cost):
    keys = getattr(pair_cost, "edges", None)
    if keys is None:
        raise ValueError("pair_cost must expose .edges (list of (i, j))")
    return keys


def _expand(labels, alpha, unary, pair_cost, pair_keys, pins):
    """One alpha-expansion move: each node keeps its label (0) or
    switches to alpha (1).  Kolmogorov-Zabih construction."""
    n = len(labels)
    s, t = n, n + 1
    g = Dinic(n + 2)
    const = 0.0
    # unary: label 0 = keep -> cost unary[k][labels[k]]
    #        label 1 = alpha -> cost unary[k][alpha]
    u0 = [unary[k][labels[k]] for k in range(n)]
    u1 = [unary[k][alpha] for k in range(n)]
    for k, d in (pins or {}).items():
        if d == alpha:
            u0[k] = INF       # must switch (already alpha => keep==switch)
            if labels[k] == alpha:
                u0[k] = u1[k]
        else:
            u1[k] = INF       # may not switch to alpha
    add0 = [0.0] * n
    add1 = [0.0] * n
    for (i, j) in pair_keys:
        li, lj = labels[i], labels[j]
        t00 = pair_cost(i, j, li, lj)
        t01 = pair_cost(i, j, li, alpha)
        t10 = pair_cost(i, j, alpha, lj)
        t11 = 0.0
        # E(xi,xj) = t00 + xi(t10-t00) + xj(t11-t10) + (1-xi)xj*(t01+t10-t00-t11)
        const += t00
        add1[i] += t10 - t00
        add1[j] += t11 - t10
        w = t01 + t10 - t00 - t11
        if w < 0:             # non-submodular residue: truncate (rare,
            w = 0.0           # only when costs are not a metric)
        if w > 0:
            # pays w when xi = 0 (source side) and xj = 1 (sink side),
            # i.e. when the directed edge i -> j crosses the cut.
            g.add_edge(i, j, w)
    for k in range(n):
        c0, c1 = u0[k] + add0[k], u1[k] + add1[k]
        m = min(c0, c1)
        if m < 0:
            const += m
            c0, c1 = c0 - m, c1 - m
        g.add_edge(s, k, c1 if c1 != INF else INF)
        g.add_edge(k, t, c0 if c0 != INF else INF)
    flow = g.max_flow(s, t)
    side = g.min_cut_side(s)
    new_labels = [labels[k] if side[k] else alpha for k in range(n)]
    # recompute exact energy (truncation makes flow an upper bound)
    e = sum(unary[k][new_labels[k]] for k in range(n))
    for (i, j) in pair_keys:
        e += pair_cost(i, j, new_labels[i], new_labels[j])
    return new_labels, e


# --------------------------------------------------------------------- #
def latency_inputs_from_graph(graph: KernelGraph, devices,
                              bw_override: Optional[float] = None):
    """Build (unary, pair, pins) for the latency solvers from a DDG."""
    unary = [[dev.kernel_time(n) for dev in devices] for n in graph.nodes]
    pins = {n.idx: n.pinned for n in graph.nodes if n.pinned is not None}

    if len(devices) == 2:
        pair = {}
        for (i, j), nbytes in graph.edges.items():
            rep = max(graph.nodes[i].repeat, graph.nodes[j].repeat)
            c01 = devices[0].transfer_time(nbytes, devices[1],
                                           bw_override, repeat=rep)
            c10 = devices[1].transfer_time(nbytes, devices[0],
                                           bw_override, repeat=rep)
            pair[(i, j)] = (c01, c10)
        return unary, pair, pins

    edges = list(graph.edges)
    byte_of = dict(graph.edges)
    rep_of = {(i, j): max(graph.nodes[i].repeat, graph.nodes[j].repeat)
              for (i, j) in graph.edges}

    def pair_cost(i, j, gi, gj):
        if gi == gj:
            return 0.0
        return devices[gi].transfer_time(byte_of[(i, j)], devices[gj],
                                         bw_override,
                                         repeat=rep_of[(i, j)])
    pair_cost.edges = edges
    return unary, pair_cost, pins
