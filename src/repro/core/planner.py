"""Policy planner: kernel-to-device placement (paper §III-B).

Front-end over the solvers:
  * ``policy="latency"``  -> exact min-cut (2 devices) / alpha-expansion.
  * ``policy="throughput"`` -> min-max makespan heuristics (+ layer folding).

Output is a :class:`Plan`: per-kernel device labels plus the derived
*stage* decomposition — maximal runs of consecutive (topological) kernels
on the same device — which is what the executor compiles and the pipeline
scheduler dispatches.  Plans are cached per (graph-key, device-set,
policy, bandwidth) to support elastic re-planning (DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.core import mincut
from repro.core.costmodel import DeviceSpec
from repro.core.graph import KernelGraph
from repro.core.makespan import MakespanProblem, fold_and_solve, \
    solve_throughput


@dataclasses.dataclass
class Stage:
    """A maximal topological run of kernels placed on one device."""

    idx: int
    device: int
    node_ids: Tuple[int, ...]
    eqn_ids: Tuple[int, ...]        # raw jaxpr equation indices
    compute_time: float             # modeled
    recv_bytes: float               # bytes entering from other devices
    send_bytes: float


@dataclasses.dataclass
class Plan:
    """Placement + stage decomposition + modeled objective values."""

    labels: List[int]
    policy: str
    devices: Tuple[str, ...]
    stages: List[Stage]
    objective: float                 # solver objective (s)
    T: List[float]                   # per-device compute time
    M: List[float]                   # per-device incoming comm time
    cut_bytes: float
    cut_edges: int
    solve_seconds: float

    @property
    def bottleneck(self) -> float:
        return max(max(t, m) for t, m in zip(self.T, self.M))

    @property
    def steady_state_throughput(self) -> float:
        """Requests/s under ideal pipelining (paper's 1 / max W_g)."""
        return 1.0 / max(self.bottleneck, 1e-12)

    @property
    def unpipelined_latency(self) -> float:
        return sum(self.T) + sum(self.M)

    def cut_seconds(self, bw: float) -> float:
        """Per-request seconds this plan's cut bytes occupy a channel
        of ``bw`` bytes/s — how much shared-fabric capacity the kernel
        placement itself demands.  The quantity a contended topology
        scores placements against (see ``serving.fabric.Topology
        .planner_bw``)."""
        return self.cut_bytes / max(bw, 1e-12)

    def device_of(self, node: int) -> int:
        return self.labels[node]

    def summary(self) -> str:
        per_dev = {}
        for lbl, name in zip(range(len(self.T)), self.devices):
            cnt = sum(1 for l in self.labels if l == lbl)
            per_dev[name] = cnt
        return (f"Plan[{self.policy}] obj={self.objective * 1e3:.3f}ms "
                f"stages={len(self.stages)} cut={self.cut_bytes / 1e6:.2f}MB"
                f"/{self.cut_edges}e placement={per_dev}")


def contended_bw(bw: float, sharers: int) -> float:
    """Effective per-tenant bandwidth of a shared fabric channel: the
    channel's rate split evenly across its co-resident tenants (the
    fair-share steady state of the priority scheduler when every
    tenant keeps the channel busy).  The derating a topology applies
    before handing the planner a ``bw_override`` — kernel placement
    then balances cut bytes against the bandwidth a group will
    actually see, not the island's nameplate rate."""
    return bw / max(int(sharers), 1)


# --------------------------------------------------------------------- #
_PLAN_CACHE: Dict[Tuple, Plan] = {}


def graph_key(graph: KernelGraph) -> str:
    h = hashlib.sha1()
    for n in graph.nodes:
        h.update(repr(n.signature()).encode())
        h.update(repr(n.pinned).encode())
    for (i, j), b in sorted(graph.edges.items()):
        h.update(f"{i},{j},{b}".encode())
    return h.hexdigest()


def plan(graph: KernelGraph, devices: Sequence[DeviceSpec],
         policy: str = "throughput",
         bw_override: Optional[float] = None,
         use_folding: bool = True,
         anneal_iters: int = 4000,
         cache: bool = True) -> Plan:
    """Solve placement and derive stages. Deterministic."""
    key = (graph_key(graph), tuple(d.name for d in devices), policy,
           bw_override, use_folding, anneal_iters)
    if cache and key in _PLAN_CACHE:
        return _PLAN_CACHE[key]

    t0 = time.perf_counter()
    if policy == "latency":
        unary, pair, pins = mincut.latency_inputs_from_graph(
            graph, devices, bw_override)
        if len(devices) == 2:
            labels, obj = mincut.solve_latency_2dev(unary, pair, pins)
        else:
            labels, obj = mincut.solve_latency_multi(
                unary, pair, len(devices), pins)
    elif policy == "throughput":
        if use_folding:
            labels, obj = fold_and_solve(
                graph, devices, solve_throughput,
                bw_override=bw_override, anneal_iters=anneal_iters)
        else:
            labels, obj = solve_throughput(
                graph, devices, bw_override=bw_override,
                anneal_iters=anneal_iters)
    else:
        raise ValueError(f"unknown policy {policy!r}")
    solve_s = time.perf_counter() - t0

    p = _finalize(graph, devices, labels, obj, policy, bw_override, solve_s)
    if cache:
        _PLAN_CACHE[key] = p
    return p


def replan_on_failure(graph: KernelGraph, devices: Sequence[DeviceSpec],
                      lost: Set[int], old: Plan, **kw) -> Plan:
    """Elastic re-planning after device loss (kernel-granularity
    elasticity; DESIGN.md §6).  Pins that referenced lost devices are
    remapped to the surviving device with the most HBM."""
    surviving = [d for i, d in enumerate(devices) if i not in lost]
    if not surviving:
        raise RuntimeError("no surviving devices")
    import dataclasses as _dc
    remap = {}
    j = 0
    for i in range(len(devices)):
        if i not in lost:
            remap[i] = j
            j += 1
    fallback = max(range(len(surviving)),
                   key=lambda i: surviving[i].hbm_bytes)
    nodes = []
    for n in graph.nodes:
        pin = n.pinned
        if pin is not None:
            pin = remap.get(pin, fallback)
        nodes.append(_dc.replace(n, pinned=pin))
    g2 = KernelGraph(nodes, dict(graph.edges), name=graph.name + "+elastic")
    return plan(g2, surviving, policy=old.policy, **kw)


# --------------------------------------------------------------------- #
def _finalize(graph, devices, labels, obj, policy, bw_override,
              solve_s) -> Plan:
    prob = MakespanProblem(graph, devices, bw_override)
    T, M = prob.loads(labels)
    cut_b = 0.0
    cut_e = 0
    for (i, j), b in graph.edges.items():
        if labels[i] != labels[j]:
            cut_b += b
            cut_e += 1
    stages = build_stages(graph, labels, devices, bw_override)
    return Plan(labels=list(labels), policy=policy,
                devices=tuple(d.name for d in devices), stages=stages,
                objective=obj, T=T, M=M, cut_bytes=cut_b, cut_edges=cut_e,
                solve_seconds=solve_s)


def build_stages(graph: KernelGraph, labels: Sequence[int], devices,
                 bw_override: Optional[float] = None) -> List[Stage]:
    """Maximal consecutive same-device runs in topological order."""
    stages: List[Stage] = []
    cur_dev, cur_nodes = None, []

    def flush():
        if not cur_nodes:
            return
        nids = tuple(cur_nodes)
        nset = set(nids)
        eqns: List[int] = []
        comp = 0.0
        for k in nids:
            eqns.extend(graph.nodes[k].eqn_ids)
            comp += devices[cur_dev].kernel_time(graph.nodes[k])
        recv = sum(b for (i, j), b in graph.edges.items()
                   if j in nset and labels[i] != cur_dev)
        send = sum(b for (i, j), b in graph.edges.items()
                   if i in nset and labels[j] != cur_dev)
        stages.append(Stage(idx=len(stages), device=cur_dev,
                            node_ids=nids, eqn_ids=tuple(sorted(eqns)),
                            compute_time=comp, recv_bytes=recv,
                            send_bytes=send))

    for n in graph.nodes:
        d = labels[n.idx]
        if d != cur_dev:
            flush()
            cur_dev, cur_nodes = d, [n.idx]
        else:
            cur_nodes.append(n.idx)
    flush()
    return stages
