"""Region markers: identity primitives that tag jaxpr regions.

The paper's analyzer knows each kernel's phase/block from the CUDA launch
site; in JAX we thread a zero-cost identity primitive through the traced
value so the analyzer can recover ``phase`` / ``block`` / ``layer`` tags
from the equation stream.  Markers lower to a no-op and are removed from
the kernel graph (edges re-routed through them).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Optional, Tuple

import jax
from jax.interpreters import ad, batching, mlir

import jax.extend.core as jex_core

region_p = jex_core.Primitive("tessera_region")
region_p.def_impl(lambda x, *, kind, phase, block, layer: x)
region_p.def_abstract_eval(lambda x, *, kind, phase, block, layer: x)
mlir.register_lowering(region_p,
                       lambda ctx, x, *, kind, phase, block, layer: [x])

# Differentiation / vmap pass-through so markers can live inside train_step.
ad.deflinear2(region_p,
              lambda ct, x, *, kind, phase, block, layer: [ct])
batching.primitive_batchers[region_p] = (
    lambda args, dims, *, kind, phase, block, layer:
    (region_p.bind(args[0], kind=kind, phase=phase, block=block,
                   layer=layer), dims[0]))

MARKER_NAME = region_p.name


def _bind(x, kind: str, phase: str, block: str, layer: int):
    return region_p.bind(x, kind=kind, phase=phase, block=block, layer=layer)


@contextlib.contextmanager
def region(x_ref: list, *, phase: str = "", block: str = "",
           layer: int = -1):
    """Context-manager form: ``with region([x], block="attention") as ref:``

    The traced value must be threaded through the markers to anchor them in
    the equation stream; the single-element list is mutated in place.
    """
    x_ref[0] = _bind(x_ref[0], "begin", phase, block, layer)
    yield x_ref
    x_ref[0] = _bind(x_ref[0], "end", phase, block, layer)


def tag(x, *, phase: str = "", block: str = "", layer: int = -1):
    """Functional form: returns (begin-marked value, closer function)."""
    y = _bind(x, "begin", phase, block, layer)

    def close(z):
        return _bind(z, "end", phase, block, layer)

    return y, close


def wrap(fn, *, phase: str = "", block: str = "", layer: int = -1):
    """Wrap ``fn(x, *rest) -> y`` so its kernels carry the given tags."""
    def wrapped(x, *rest, **kw):
        x = _bind(x, "begin", phase, block, layer)
        y = fn(x, *rest, **kw)
        return _bind(y, "end", phase, block, layer)
    return wrapped
