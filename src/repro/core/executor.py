"""Disaggregated executor: run a jaxpr split across devices per a Plan.

This is the TPU/JAX analogue of the paper's GPU workers (§III-C).  The
plan's *stages* (maximal topological same-device kernel runs) are compiled
as independent jitted callables; values crossing a stage boundary onto a
different device are transferred explicitly (``jax.device_put``), which is
the runtime's ICI/DCN send-recv.  JAX's async dispatch overlaps those
transfers with compute on other stages/requests (pipeline.py).

Hot path (the *dispatch program*, built once per executable):

  * The per-request value environment is a flat Python **list** indexed by
    integer slots — no Var hashing on the critical path.  Slot indices for
    every stage's inputs/outputs are resolved at build time.
  * Plan stages that land on the same **physical** device are *fused* into
    one jitted callable (logical plan devices often alias one physical
    device — e.g. validation runs, or a 2-way plan on a 1-GPU host), which
    cuts per-request dispatch count to the number of physical-device
    alternations.
  * Cross-device transfers are issued **eagerly by the producer**: the
    moment a fused stage is dispatched, its exported values are
    ``device_put`` onto every consuming device.  With JAX async dispatch
    the send overlaps downstream compute — the consumer finds its inputs
    already resident (transfer prefetch).
  * Constants are placed onto every consuming device once at **build**
    time; weights (graph inputs consumed by a stage) are placed on first
    call and cached under a stable ``(arg slot, device index)`` key — the
    paper's selective weight replication: each device holds only the
    parameters its kernels touch, placed exactly once.

The legacy dict-environment walk (``init_env`` / ``run_stage`` /
``collect_outputs``) is retained as the *reference path*: parity tests and
straggler re-execution use it, and ``call_reference`` runs a whole request
through it.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.extend.core as jex_core

from repro.core.analyzer import TracedGraph
from repro.core.marker import MARKER_NAME
from repro.core.planner import Plan, Stage

Var = Any


def _resolve_through_markers(jaxpr):
    """Alias map routing values through (dropped) marker equations."""
    alias: Dict[Var, Var] = {}
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == MARKER_NAME:
            src = eqn.invars[0]
            while isinstance(src, jex_core.Var) and src in alias:
                src = alias[src]
            alias[eqn.outvars[0]] = src

    def resolve(v):
        while isinstance(v, jex_core.Var) and v in alias:
            v = alias[v]
        return v
    return resolve


def _jit_eqns(eqns: Sequence[Any], invars: Sequence[Var],
              outvars: Sequence[Var]):
    """Jit a callable executing ``eqns`` with the given signature.

    debug_info must be dropped: it describes the ORIGINAL jaxpr's arity,
    and jax asserts len(arg_names) == invars / len(result_paths) ==
    outvars on construction.
    """
    effects = frozenset().union(
        *[eqn.effects for eqn in eqns]) if eqns else frozenset()
    sub = jex_core.Jaxpr(
        constvars=[], invars=list(invars), outvars=list(outvars),
        eqns=list(eqns), effects=effects)
    return jax.jit(jex_core.jaxpr_as_fun(jex_core.ClosedJaxpr(sub, [])))


@dataclasses.dataclass
class CompiledStage:
    stage: Stage
    fn: Any                        # jitted callable
    invars: Tuple[Var, ...]        # external inputs, in call order
    outvars: Tuple[Var, ...]       # values this stage exports
    device: Any                    # jax.Device


@dataclasses.dataclass
class FusedStage:
    """One dispatch unit: a run of plan stages on one physical device."""
    idx: int
    stage_idxs: Tuple[int, ...]     # plan-stage indices folded in
    fn: Any                         # jitted callable over all member eqns
    device: Any                     # physical jax.Device (or None)
    in_slots: Tuple[int, ...]
    in_weight: Tuple[bool, ...]     # True -> graph input: cached placement
    out_slots: Tuple[int, ...]
    # (output position, destination device, destination slot): issued
    # eagerly right after dispatch — the transfer prefetch.
    transfers: Tuple[Tuple[int, Any, int], ...]


@dataclasses.dataclass
class DispatchProgram:
    """Indexed execution recipe: everything the hot loop needs, resolved
    to integer slots at build time."""
    num_slots: int
    arg_slots: Tuple[int, ...]              # slot per flattened invar
    const_template: Tuple[Tuple[int, Any], ...]   # (slot, placed value)
    fused: List[FusedStage]
    out_slots: Tuple[Optional[int], ...]    # per graph output; None=literal
    out_literals: Tuple[Any, ...]           # literal values (None-padded)


class StagedExecutable:
    """Callable that reproduces ``fn(*args)`` with disaggregated stages.

    ``device_map``: logical plan device id -> jax.Device.  On a real
    heterogeneous cluster these are devices of different types; in tests
    they are distinct host-platform devices, which exercises the same
    transfer paths.
    """

    def __init__(self, traced: TracedGraph, plan: Plan,
                 device_map: Sequence[Any]):
        self.traced = traced
        self.plan = plan
        self.device_map = list(device_map)
        # weight-placement cache keyed on STABLE (arg slot, device index)
        # pairs from the dispatch program — an id()-based key can alias
        # after GC reuses an address; slot indices never do.
        self._weight_cache: Dict[Tuple[int, int], Tuple[Any, Any]] = {}
        self.weight_places = 0          # device_puts of graph inputs
        self._build()
        self._build_program()

    # ------------------------------------------------------------------ #
    def _build(self) -> None:
        closed = self.traced.closed_jaxpr
        jaxpr = closed.jaxpr
        resolve = _resolve_through_markers(jaxpr)
        self._resolve = resolve

        # const vars behave like extra graph inputs
        self._const_env = dict(zip(jaxpr.constvars, closed.consts))

        graph_outs = [resolve(v) for v in jaxpr.outvars]
        graph_out_vars: Set[Var] = {
            v for v in graph_outs if isinstance(v, jex_core.Var)}

        # var -> producing stage (graph inputs/consts -> -1)
        producing_stage: Dict[Var, int] = {}
        stage_eqns: List[List[Any]] = []
        for st in self.plan.stages:
            eqns = []
            for e in st.eqn_ids:
                eqn = jaxpr.eqns[e]
                if eqn.primitive.name == MARKER_NAME:
                    continue
                new_invars = [resolve(v) for v in eqn.invars]
                eqns.append(eqn.replace(invars=new_invars))
            stage_eqns.append(eqns)
            for eqn in eqns:
                for v in eqn.outvars:
                    producing_stage[v] = st.idx

        # which stages consume each var
        consumers: Dict[Var, Set[int]] = {}
        for st, eqns in zip(self.plan.stages, stage_eqns):
            for eqn in eqns:
                for v in eqn.invars:
                    if isinstance(v, jex_core.Var):
                        consumers.setdefault(v, set()).add(st.idx)

        self.stages: List[CompiledStage] = []
        for st, eqns in zip(self.plan.stages, stage_eqns):
            defined: Set[Var] = set()
            ext: List[Var] = []
            seen_ext: Set[Var] = set()
            for eqn in eqns:
                for v in eqn.invars:
                    if (isinstance(v, jex_core.Var) and v not in defined
                            and v not in seen_ext):
                        ext.append(v)
                        seen_ext.add(v)
                for v in eqn.outvars:
                    defined.add(v)
            outs = [v for eqn in eqns for v in eqn.outvars
                    if (consumers.get(v, set()) - {st.idx})
                    or v in graph_out_vars]
            fn = _jit_eqns(eqns, ext, outs)
            dev = self.device_map[st.device] if self.device_map else None
            self.stages.append(CompiledStage(
                stage=st, fn=fn, invars=tuple(ext), outvars=tuple(outs),
                device=dev))

        self._graph_outs = graph_outs
        self._graph_out_vars = graph_out_vars
        self._invars = list(jaxpr.invars)
        self._stage_eqns = stage_eqns

    # ------------------------------------------------------------------ #
    def _build_program(self) -> None:
        """Compile the indexed dispatch program (see module docstring)."""
        # --- physical-device fusion groups ---------------------------- #
        groups: List[List[int]] = []
        for i, cs in enumerate(self.stages):
            if groups and self.stages[groups[-1][-1]].device is cs.device:
                groups[-1].append(i)
            else:
                groups.append([i])

        # interned physical devices -> stable small integer ids
        # (_dev_index is the single intern point: _place_arg cache keys
        # must match the program's const/transfer slot keys)
        self._devices: List[Any] = []
        dev_id = self._dev_index

        # --- slot allocation ------------------------------------------ #
        slot_of: Dict[Var, int] = {}
        n_slots = 0

        def alloc(v: Var) -> int:
            nonlocal n_slots
            if v not in slot_of:
                slot_of[v] = n_slots
                n_slots += 1
            return slot_of[v]

        arg_slots = tuple(alloc(v) for v in self._invars)
        const_slot = {v: alloc(v) for v in self._const_env}
        self._const_slot = const_slot

        # group-level signatures
        group_dev = [self.stages[g[0]].device for g in groups]
        group_of_stage = {s: gi for gi, g in enumerate(groups) for s in g}
        g_eqns = [[e for s in g for e in self._stage_eqns[s]]
                  for g in groups]
        g_defined = [set(v for eqn in eqns for v in eqn.outvars)
                     for eqns in g_eqns]
        producer_group: Dict[Var, int] = {}
        for gi, dset in enumerate(g_defined):
            for v in dset:
                producer_group[v] = gi
        # consumers at group granularity
        g_consumers: Dict[Var, Set[int]] = {}
        for gi, eqns in enumerate(g_eqns):
            for eqn in eqns:
                for v in eqn.invars:
                    if isinstance(v, jex_core.Var):
                        g_consumers.setdefault(v, set()).add(gi)

        # exported values + their slots (allocated in group order)
        g_ext: List[List[Var]] = []
        g_outs: List[List[Var]] = []
        for gi, eqns in enumerate(g_eqns):
            defined: Set[Var] = set()
            ext: List[Var] = []
            seen: Set[Var] = set()
            for eqn in eqns:
                for v in eqn.invars:
                    if (isinstance(v, jex_core.Var) and v not in defined
                            and v not in seen):
                        ext.append(v)
                        seen.add(v)
                for v in eqn.outvars:
                    defined.add(v)
            outs = [v for eqn in eqns for v in eqn.outvars
                    if (g_consumers.get(v, set()) - {gi})
                    or v in self._graph_out_vars]
            g_ext.append(ext)
            g_outs.append(outs)
            for v in outs:
                alloc(v)

        # transfer slots: one per (exported var, consuming device) pair
        # when the consumer group sits on a different physical device.
        xfer_slot: Dict[Tuple[Var, int], int] = {}
        for v, cons in g_consumers.items():
            pg = producer_group.get(v)
            if pg is None:
                continue            # graph input / const: placed, not sent
            for gi in cons:
                if gi == pg or group_dev[gi] is group_dev[pg]:
                    continue
                key = (v, dev_id(group_dev[gi]))
                if key not in xfer_slot:
                    slot_of_key = n_slots
                    n_slots += 1
                    xfer_slot[key] = slot_of_key

        # consts: place each onto every consuming group's device at build
        const_template: List[Tuple[int, Any]] = []
        const_dev_slot: Dict[Tuple[Var, int], int] = {}
        for v, val in self._const_env.items():
            const_template.append((const_slot[v], val))
            for gi in g_consumers.get(v, ()):
                dev = group_dev[gi]
                if dev is None:
                    continue
                key = (v, dev_id(dev))
                if key not in const_dev_slot:
                    const_dev_slot[key] = n_slots
                    const_template.append(
                        (n_slots, jax.device_put(val, dev)))
                    n_slots += 1

        # --- fused stage records -------------------------------------- #
        fused: List[FusedStage] = []
        for gi, g in enumerate(groups):
            dev = group_dev[gi]
            in_slots: List[int] = []
            in_weight: List[bool] = []
            for v in g_ext[gi]:
                if v in self._const_env:
                    if dev is not None:
                        in_slots.append(const_dev_slot[(v, dev_id(dev))])
                        in_weight.append(False)     # pre-placed at build
                    else:
                        in_slots.append(const_slot[v])
                        in_weight.append(False)
                elif v not in producer_group:
                    # graph input (weight / activation argument)
                    in_slots.append(slot_of[v])
                    in_weight.append(True)
                else:
                    pg = producer_group[v]
                    if group_dev[pg] is dev:
                        in_slots.append(slot_of[v])
                    else:
                        in_slots.append(xfer_slot[(v, dev_id(dev))])
                    in_weight.append(False)
            out_slots = tuple(slot_of[v] for v in g_outs[gi])
            transfers: List[Tuple[int, Any, int]] = []
            for pos, v in enumerate(g_outs[gi]):
                dests: Set[int] = set()
                for ci in g_consumers.get(v, ()):
                    if ci != gi and group_dev[ci] is not dev:
                        dests.add(dev_id(group_dev[ci]))
                for di in sorted(dests):
                    transfers.append(
                        (pos, self._devices[di], xfer_slot[(v, di)]))
            fused.append(FusedStage(
                idx=gi, stage_idxs=tuple(g), device=dev,
                fn=_jit_eqns(g_eqns[gi], g_ext[gi], g_outs[gi]),
                in_slots=tuple(in_slots), in_weight=tuple(in_weight),
                out_slots=tuple(out_slots), transfers=tuple(transfers)))

        out_slots: List[Optional[int]] = []
        out_literals: List[Any] = []
        for v in self._graph_outs:
            if isinstance(v, jex_core.Var):
                out_slots.append(alloc(v))
                out_literals.append(None)
            else:
                out_slots.append(None)
                out_literals.append(v.val)

        self.program = DispatchProgram(
            num_slots=n_slots, arg_slots=arg_slots,
            const_template=tuple(const_template), fused=fused,
            out_slots=tuple(out_slots), out_literals=tuple(out_literals))

    # ------------------------------------------------------------------ #
    # Indexed fast path
    # ------------------------------------------------------------------ #
    def init_slots(self, *args, **kwargs) -> List[Any]:
        """Seed the flat slot environment for one request."""
        flat, in_tree = jax.tree_util.tree_flatten((args, kwargs))
        if in_tree != self.traced.in_tree:
            raise TypeError(
                f"argument structure {in_tree} != traced "
                f"{self.traced.in_tree}")
        slots: List[Any] = [None] * self.program.num_slots
        for sl, val in self.program.const_template:
            slots[sl] = val
        for sl, val in zip(self.program.arg_slots, flat):
            slots[sl] = val
        return slots

    def _place_arg(self, slot: int, val: Any, dev) -> Any:
        """Place a graph input on ``dev``, cached per (slot, device)."""
        key = (slot, self._dev_index(dev))
        cached = self._weight_cache.get(key)
        if cached is not None and cached[0] is val:
            return cached[1]
        placed = jax.device_put(val, dev)
        self.weight_places += 1
        self._weight_cache[key] = (val, placed)
        return placed

    def _dev_index(self, dev) -> int:
        for i, d in enumerate(self._devices):
            if d is dev:
                return i
        self._devices.append(dev)
        return len(self._devices) - 1

    def run_unit(self, slots: List[Any], unit_idx: int,
                 device_override: Any = None) -> int:
        """Dispatch one fused unit (async); returns #transfers issued.

        ``device_override`` reruns the unit on a different device — used
        by straggler mitigation (stage functions are pure, so duplicate
        execution is always safe).
        """
        fs = self.program.fused[unit_idx]
        dev = device_override if device_override is not None else fs.device
        if device_override is None:
            ins = []
            for sl, w in zip(fs.in_slots, fs.in_weight):
                v = slots[sl]
                ins.append(self._place_arg(sl, v, dev)
                           if (w and dev is not None) else v)
        else:
            # override device: everything must move; weights go through
            # the cache (the fallback device keeps its own copies).
            ins = []
            for sl, w in zip(fs.in_slots, fs.in_weight):
                v = slots[sl]
                ins.append(self._place_arg(sl, v, dev) if w
                           else jax.device_put(v, dev))
        outs = fs.fn(*ins)
        if device_override is not None and fs.device is not None \
                and device_override is not fs.device:
            # restore the slot invariant "exports live on the producing
            # unit's device": later same-device consumers read these
            # slots directly (no transfer slot), and a fused fn with
            # inputs committed to two devices is a jit error.
            outs = [jax.device_put(o, fs.device) for o in outs]
        for sl, val in zip(fs.out_slots, outs):
            slots[sl] = val
        # transfer prefetch: push exports toward their consumers NOW so
        # the send overlaps downstream dispatch/compute.
        for pos, ddev, dsl in fs.transfers:
            slots[dsl] = jax.device_put(outs[pos], ddev)
        return len(fs.transfers)

    def collect_slots(self, slots: List[Any]):
        results = []
        for sl, lit in zip(self.program.out_slots,
                           self.program.out_literals):
            results.append(slots[sl] if sl is not None else lit)
        return jax.tree_util.tree_unflatten(self.traced.out_tree, results)

    def unit_outputs(self, slots: List[Any], unit_idx: int) -> List[Any]:
        fs = self.program.fused[unit_idx]
        return [slots[sl] for sl in fs.out_slots]

    @property
    def num_units(self) -> int:
        return len(self.program.fused)

    def __call__(self, *args, **kwargs):
        slots = self.init_slots(*args, **kwargs)
        for i in range(len(self.program.fused)):
            self.run_unit(slots, i)
        return self.collect_slots(slots)

    # ------------------------------------------------------------------ #
    # Reference path (legacy dict environment; per-plan-stage dispatch)
    # ------------------------------------------------------------------ #
    def _place(self, var: Var, val: Any, dev, weight: bool) -> Any:
        if dev is None:
            return val
        if weight:
            return self._place_arg(self._ref_slot(var), val, dev)
        return jax.device_put(val, dev)

    def _ref_slot(self, var: Var) -> int:
        s = getattr(self, "_ref_slot_map", None)
        if s is None:
            s = {v: sl for v, sl in zip(self._invars,
                                        self.program.arg_slots)}
            s.update(self._const_slot)
            self._ref_slot_map = s
        return s[var]

    def init_env(self, *args, **kwargs) -> Dict[Var, Any]:
        """Seed the (reference-path) value environment for one request."""
        flat, in_tree = jax.tree_util.tree_flatten((args, kwargs))
        if in_tree != self.traced.in_tree:
            raise TypeError(
                f"argument structure {in_tree} != traced "
                f"{self.traced.in_tree}")
        env: Dict[Var, Any] = dict(self._const_env)
        for var, val in zip(self._invars, flat):
            env[var] = val
        return env

    def run_stage(self, env: Dict[Var, Any], stage_idx: int,
                  device_override: Any = None) -> None:
        """Execute one plan stage (async dispatch); mutates env in place."""
        cs = self.stages[stage_idx]
        dev = device_override if device_override is not None else cs.device
        graph_inputs = self._graph_input_set
        ins = []
        for v in cs.invars:
            ins.append(self._place(v, env[v], dev,
                                   weight=v in graph_inputs))
        outs = cs.fn(*ins)
        for v, val in zip(cs.outvars, outs):
            env[v] = val

    def collect_outputs(self, env: Dict[Var, Any]):
        results = []
        for v in self._graph_outs:
            if isinstance(v, jex_core.Var):
                results.append(env[v])
            else:                                   # Literal
                results.append(v.val)
        return jax.tree_util.tree_unflatten(self.traced.out_tree, results)

    @property
    def _graph_input_set(self) -> Set[Var]:
        s = getattr(self, "_gi_cache", None)
        if s is None:
            s = set(self._invars) | set(self._const_env)
            self._gi_cache = s
        return s

    def call_reference(self, *args, **kwargs):
        """Run a request through the legacy per-stage dict walk."""
        env = self.init_env(*args, **kwargs)
        for i in range(len(self.stages)):
            self.run_stage(env, i)
        return self.collect_outputs(env)

    # ------------------------------------------------------------------ #
    def run_async(self, *args, **kwargs):
        """Same as __call__ — JAX dispatch is already asynchronous; the
        returned arrays are futures until blocked on."""
        return self(*args, **kwargs)

    def stage_summary(self) -> str:
        lines = []
        for cs in self.stages:
            st = cs.stage
            lines.append(
                f"  stage {st.idx:3d} dev={self.plan.devices[st.device]:<10}"
                f" kernels={len(st.node_ids):4d}"
                f" t={st.compute_time * 1e6:9.1f}us"
                f" recv={st.recv_bytes / 1e6:8.3f}MB"
                f" send={st.send_bytes / 1e6:8.3f}MB")
        lines.append(
            f"  fused: {len(self.stages)} stages -> "
            f"{len(self.program.fused)} dispatch units")
        return "\n".join(lines)


def build_executable(traced: TracedGraph, plan: Plan,
                     device_map: Optional[Sequence[Any]] = None
                     ) -> StagedExecutable:
    """Compile a traced graph + plan into a disaggregated executable.

    When ``device_map`` is None all stages run on the default device —
    useful for validating the stage decomposition itself.
    """
    if device_map is None:
        d = jax.devices()[0]
        device_map = [d] * (max(plan.labels) + 1 if plan.labels else 1)
    return StagedExecutable(traced, plan, device_map)
