"""Disaggregated executor: run a jaxpr split across devices per a Plan.

This is the TPU/JAX analogue of the paper's GPU workers (§III-C).  The
plan's *stages* (maximal topological same-device kernel runs) are compiled
as independent jitted callables; values crossing a stage boundary onto a
different device are transferred explicitly (``jax.device_put``), which is
the runtime's ICI/DCN send-recv.  JAX's async dispatch overlaps those
transfers with compute on other stages/requests (pipeline.py).

Weights (graph inputs consumed by a stage) are placed on the consuming
stage's device once and cached — the paper's selective weight replication:
each device holds only the parameters its kernels touch.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.extend.core as jex_core

from repro.core.analyzer import TracedGraph
from repro.core.marker import MARKER_NAME
from repro.core.planner import Plan, Stage

Var = Any


def _resolve_through_markers(jaxpr):
    """Alias map routing values through (dropped) marker equations."""
    alias: Dict[Var, Var] = {}
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == MARKER_NAME:
            src = eqn.invars[0]
            while isinstance(src, jex_core.Var) and src in alias:
                src = alias[src]
            alias[eqn.outvars[0]] = src

    def resolve(v):
        while isinstance(v, jex_core.Var) and v in alias:
            v = alias[v]
        return v
    return resolve


@dataclasses.dataclass
class CompiledStage:
    stage: Stage
    fn: Any                        # jitted callable
    invars: Tuple[Var, ...]        # external inputs, in call order
    outvars: Tuple[Var, ...]       # values this stage exports
    device: Any                    # jax.Device


class StagedExecutable:
    """Callable that reproduces ``fn(*args)`` with disaggregated stages.

    ``device_map``: logical plan device id -> jax.Device.  On a real
    heterogeneous cluster these are devices of different types; in tests
    they are distinct host-platform devices, which exercises the same
    transfer paths.
    """

    def __init__(self, traced: TracedGraph, plan: Plan,
                 device_map: Sequence[Any]):
        self.traced = traced
        self.plan = plan
        self.device_map = list(device_map)
        self._weight_cache: Dict[Tuple[int, int], Any] = {}
        self._build()

    # ------------------------------------------------------------------ #
    def _build(self) -> None:
        closed = self.traced.closed_jaxpr
        jaxpr = closed.jaxpr
        resolve = _resolve_through_markers(jaxpr)
        self._resolve = resolve

        # const vars behave like extra graph inputs
        self._const_env = dict(zip(jaxpr.constvars, closed.consts))

        graph_outs = [resolve(v) for v in jaxpr.outvars]
        graph_out_vars: Set[Var] = {
            v for v in graph_outs if isinstance(v, jex_core.Var)}

        # var -> producing stage (graph inputs/consts -> -1)
        producing_stage: Dict[Var, int] = {}
        stage_eqns: List[List[Any]] = []
        for st in self.plan.stages:
            eqns = []
            for e in st.eqn_ids:
                eqn = jaxpr.eqns[e]
                if eqn.primitive.name == MARKER_NAME:
                    continue
                new_invars = [resolve(v) for v in eqn.invars]
                eqns.append(eqn.replace(invars=new_invars))
            stage_eqns.append(eqns)
            for eqn in eqns:
                for v in eqn.outvars:
                    producing_stage[v] = st.idx

        # which stages consume each var
        consumers: Dict[Var, Set[int]] = {}
        for st, eqns in zip(self.plan.stages, stage_eqns):
            for eqn in eqns:
                for v in eqn.invars:
                    if isinstance(v, jex_core.Var):
                        consumers.setdefault(v, set()).add(st.idx)

        self.stages: List[CompiledStage] = []
        for st, eqns in zip(self.plan.stages, stage_eqns):
            defined: Set[Var] = set()
            ext: List[Var] = []
            seen_ext: Set[Var] = set()
            for eqn in eqns:
                for v in eqn.invars:
                    if (isinstance(v, jex_core.Var) and v not in defined
                            and v not in seen_ext):
                        ext.append(v)
                        seen_ext.add(v)
                for v in eqn.outvars:
                    defined.add(v)
            outs = [v for eqn in eqns for v in eqn.outvars
                    if (consumers.get(v, set()) - {st.idx})
                    or v in graph_out_vars]
            effects = frozenset().union(
                *[eqn.effects for eqn in eqns]) if eqns else frozenset()
            # debug_info must be dropped: it describes the ORIGINAL
            # jaxpr's arity, and jax asserts len(arg_names) == invars /
            # len(result_paths) == outvars on construction.
            sub = jex_core.Jaxpr(
                constvars=[], invars=list(ext), outvars=list(outs),
                eqns=eqns, effects=effects)
            fn = jax.jit(jex_core.jaxpr_as_fun(jex_core.ClosedJaxpr(sub, [])))
            dev = self.device_map[st.device] if self.device_map else None
            self.stages.append(CompiledStage(
                stage=st, fn=fn, invars=tuple(ext), outvars=tuple(outs),
                device=dev))

        self._graph_outs = graph_outs
        self._invars = list(jaxpr.invars)

    # ------------------------------------------------------------------ #
    def _place(self, var: Var, val: Any, dev, weight: bool) -> Any:
        if dev is None:
            return val
        if weight:
            key = (id(var), id(dev))
            cached = self._weight_cache.get(key)
            if cached is not None and cached[0] is val:
                return cached[1]
            placed = jax.device_put(val, dev)
            self._weight_cache[key] = (val, placed)
            return placed
        return jax.device_put(val, dev)

    def init_env(self, *args, **kwargs) -> Dict[Var, Any]:
        """Seed the value environment for one request."""
        flat, in_tree = jax.tree_util.tree_flatten((args, kwargs))
        if in_tree != self.traced.in_tree:
            raise TypeError(
                f"argument structure {in_tree} != traced "
                f"{self.traced.in_tree}")
        env: Dict[Var, Any] = dict(self._const_env)
        for var, val in zip(self._invars, flat):
            env[var] = val
        return env

    def run_stage(self, env: Dict[Var, Any], stage_idx: int,
                  device_override: Any = None) -> None:
        """Execute one stage (async dispatch); mutates env in place.

        ``device_override`` reruns the stage on a different device — used
        by straggler mitigation (the stage function is pure, so
        re-execution is always safe).
        """
        cs = self.stages[stage_idx]
        dev = device_override if device_override is not None else cs.device
        graph_inputs = self._graph_input_set
        ins = []
        for v in cs.invars:
            ins.append(self._place(v, env[v], dev,
                                   weight=v in graph_inputs))
        outs = cs.fn(*ins)
        for v, val in zip(cs.outvars, outs):
            env[v] = val

    def collect_outputs(self, env: Dict[Var, Any]):
        results = []
        for v in self._graph_outs:
            if isinstance(v, jex_core.Var):
                results.append(env[v])
            else:                                   # Literal
                results.append(v.val)
        return jax.tree_util.tree_unflatten(self.traced.out_tree, results)

    @property
    def _graph_input_set(self) -> Set[Var]:
        s = getattr(self, "_gi_cache", None)
        if s is None:
            s = set(self._invars) | set(self._const_env)
            self._gi_cache = s
        return s

    def __call__(self, *args, **kwargs):
        env = self.init_env(*args, **kwargs)
        for i in range(len(self.stages)):
            self.run_stage(env, i)
        return self.collect_outputs(env)

    # ------------------------------------------------------------------ #
    def run_async(self, *args, **kwargs):
        """Same as __call__ — JAX dispatch is already asynchronous; the
        returned arrays are futures until blocked on."""
        return self(*args, **kwargs)

    def stage_summary(self) -> str:
        lines = []
        for cs in self.stages:
            st = cs.stage
            lines.append(
                f"  stage {st.idx:3d} dev={self.plan.devices[st.device]:<10}"
                f" kernels={len(st.node_ids):4d}"
                f" t={st.compute_time * 1e6:9.1f}us"
                f" recv={st.recv_bytes / 1e6:8.3f}MB"
                f" send={st.send_bytes / 1e6:8.3f}MB")
        return "\n".join(lines)


def build_executable(traced: TracedGraph, plan: Plan,
                     device_map: Optional[Sequence[Any]] = None
                     ) -> StagedExecutable:
    """Compile a traced graph + plan into a disaggregated executable.

    When ``device_map`` is None all stages run on the default device —
    useful for validating the stage decomposition itself.
    """
    if device_map is None:
        d = jax.devices()[0]
        device_map = [d] * (max(plan.labels) + 1 if plan.labels else 1)
    return StagedExecutable(traced, plan, device_map)
