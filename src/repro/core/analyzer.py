"""Kernel analyzer: jaxpr -> KernelGraph with exact RAW dependencies.

This is the TPU-native replacement for the paper's PTX-instrumentation
analyzer (§III-A).  A jaxpr is pure SSA, so buffer read/write sets are
exact by construction: every equation's operands and results carry
``ShapedArray`` avals, giving precise per-edge transfer sizes without any
instrumentation, speculation, or min/max interval aggregation.

The analyzer also:
  * estimates per-kernel FLOPs and HBM bytes (recursing into call-like
    primitives: scan / while / cond / pjit / custom_* / remat / pallas_call),
  * recovers phase/block/layer tags from region markers (marker.py),
  * detects cross-iteration state (the paper's KV-cache RAW pattern) from a
    ``(state, inputs) -> (state', outputs)`` step signature and reports the
    node sets that read/write it so the planner can pin them.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, List, Optional, Sequence, Set, Tuple

import jax
import numpy as np
import jax.extend.core as jex_core

from repro.core.graph import KernelGraph, KernelNode
from repro.core.marker import MARKER_NAME

Var = Any       # jex_core.Var
Atom = Any      # Var | Literal


# --------------------------------------------------------------------- #
# Per-primitive FLOP / byte estimation
# --------------------------------------------------------------------- #
def _aval_bytes(aval) -> float:
    try:
        return float(aval.size) * np.dtype(aval.dtype).itemsize
    except Exception:                                    # tokens, etc.
        return 0.0


def _out_size(eqn) -> float:
    return float(sum(getattr(v.aval, "size", 0) for v in eqn.outvars))


# Elementwise transcendental cost multipliers (flops per element).
_EW_COST = {
    "exp": 4.0, "log": 4.0, "tanh": 6.0, "logistic": 5.0, "erf": 6.0,
    "pow": 8.0, "rsqrt": 2.0, "sqrt": 2.0, "sin": 4.0, "cos": 4.0,
    "integer_pow": 2.0, "div": 2.0, "rem": 2.0,
}
_ZERO_FLOP = frozenset({
    "reshape", "broadcast_in_dim", "transpose", "squeeze", "slice",
    "concatenate", "convert_element_type", "stop_gradient", "copy",
    "expand_dims", "rev", "iota", "pad", "select_n", "gather",
    "dynamic_slice", "device_put", "split", "bitcast_convert_type",
    "real", "imag", "sharding_constraint", "optimization_barrier",
})
_CALL_PRIMS = frozenset({
    "pjit", "closed_call", "core_call", "xla_call", "remat", "checkpoint",
    "custom_jvp_call", "custom_vjp_call", "custom_vjp_call_jaxpr",
    "custom_jvp_call_jaxpr",
})


def _dot_general_flops(eqn) -> float:
    (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    batch = math.prod(lhs.shape[d] for d in lb) if lb else 1
    contract = math.prod(lhs.shape[d] for d in lc) if lc else 1
    m = math.prod(s for d, s in enumerate(lhs.shape)
                  if d not in lc and d not in lb)
    n = math.prod(s for d, s in enumerate(rhs.shape)
                  if d not in rc and d not in rb)
    return 2.0 * batch * m * n * contract


def _conv_flops(eqn) -> float:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval            # kernel: spatial... x in_ch x out_ch
    k_elems = math.prod(rhs.shape[:-1])
    return 2.0 * out.size * k_elems


def _inner_jaxprs(eqn) -> List[Tuple[Any, float]]:
    """(closed_jaxpr, multiplier) pairs for call-like primitives."""
    name, p = eqn.primitive.name, eqn.params
    if name == "scan":
        return [(p["jaxpr"], float(p["length"]))]
    if name == "while":
        # Trip count is dynamic; 1 is the conservative static estimate and
        # callers that know better can multiply (decode loops use scan).
        return [(p["body_jaxpr"], 1.0), (p["cond_jaxpr"], 1.0)]
    if name == "cond":
        return [(b, 1.0 / max(len(p["branches"]), 1))
                for b in p["branches"]]
    if name in _CALL_PRIMS:
        for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
            if key in p:
                return [(p[key], 1.0)]
        return []
    if name == "pallas_call":
        grid = ()
        gm = p.get("grid_mapping")
        if gm is not None:
            grid = tuple(d for d in getattr(gm, "grid", ())
                         if isinstance(d, int))
        mult = float(math.prod(grid)) if grid else 1.0
        j = p.get("jaxpr")
        return [(j, mult)] if j is not None else []
    return []


def _jaxpr_cost(closed_jaxpr) -> Tuple[float, float]:
    """(flops, bytes) aggregate of a (Closed)Jaxpr."""
    jaxpr = getattr(closed_jaxpr, "jaxpr", closed_jaxpr)
    fl = by = 0.0
    for eqn in jaxpr.eqns:
        f, b = _eqn_cost(eqn)
        fl += f
        by += b
    return fl, by


def _eqn_cost(eqn) -> Tuple[float, float]:
    """(flops, hbm_bytes) for one equation."""
    name = eqn.primitive.name
    in_bytes = sum(_aval_bytes(v.aval) for v in eqn.invars
                   if hasattr(v, "aval"))
    out_bytes = sum(_aval_bytes(v.aval) for v in eqn.outvars)

    inner = _inner_jaxprs(eqn)
    if inner:
        fl = by = 0.0
        for cj, mult in inner:
            f, b = _jaxpr_cost(cj)
            fl += f * mult
            by += b * mult
        return fl, by

    if name == "dot_general":
        return _dot_general_flops(eqn), in_bytes + out_bytes
    if name == "ragged_dot":
        lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
        return 2.0 * lhs.size * rhs.shape[-1], in_bytes + out_bytes
    if name == "conv_general_dilated":
        return _conv_flops(eqn), in_bytes + out_bytes
    if name in ("dynamic_update_slice", "scatter", "scatter-add",
                "scatter_add"):
        # In-place update: traffic ~ update size, not full operand
        # (critical for KV-cache decode writes).
        upd = _aval_bytes(eqn.invars[1].aval) if len(eqn.invars) > 1 else 0.0
        return 0.0, 2.0 * upd + 64.0
    if name in _ZERO_FLOP:
        return 0.0, in_bytes + out_bytes
    if name.startswith("reduce_") or name in ("argmax", "argmin"):
        return float(sum(getattr(v.aval, "size", 0) for v in eqn.invars
                         if hasattr(v, "aval"))), in_bytes + out_bytes
    if name in ("cumsum", "cumprod", "cumlogsumexp", "cummax", "cummin",
                "sort", "top_k"):
        n = _out_size(eqn)
        mult = math.log2(max(n, 2.0)) if name in ("sort", "top_k") else 1.0
        return n * mult, in_bytes + out_bytes
    if name == MARKER_NAME:
        return 0.0, 0.0
    mult = _EW_COST.get(name, 1.0)
    return _out_size(eqn) * mult, in_bytes + out_bytes


# --------------------------------------------------------------------- #
# Analysis result
# --------------------------------------------------------------------- #
@dataclasses.dataclass
class TracedGraph:
    """KernelGraph plus everything the executor needs to rebuild stages."""

    graph: KernelGraph
    closed_jaxpr: Any                       # the traced ClosedJaxpr
    eqn_of_node: Dict[int, Tuple[int, ...]]  # node idx -> raw eqn indices
    in_tree: Any                            # pytree def of fn args
    out_tree: Any
    state_readers: Set[int] = dataclasses.field(default_factory=set)
    state_writers: Set[int] = dataclasses.field(default_factory=set)

    @property
    def jaxpr(self):
        return self.closed_jaxpr.jaxpr

    def with_graph(self, graph: KernelGraph) -> "TracedGraph":
        return dataclasses.replace(
            self, graph=graph,
            eqn_of_node={n.idx: n.eqn_ids for n in graph.nodes})


# --------------------------------------------------------------------- #
# Main entry point
# --------------------------------------------------------------------- #
def analyze(fn: Callable, *example_args, name: str = "ddg",
            state_argnums: Sequence[int] = (),
            fuse: bool = True, **example_kwargs) -> TracedGraph:
    """Trace ``fn`` and build its kernel graph.

    ``example_args`` may be concrete arrays or ``jax.ShapeDtypeStruct``s.
    ``state_argnums``: positional args holding cross-iteration state (e.g.
    KV caches); kernels reading them and kernels producing the matching
    outputs are reported in ``state_readers`` / ``state_writers`` so the
    planner can pin them (DESIGN.md §2, KV pinning).
    """
    closed, out_shape = jax.make_jaxpr(fn, return_shape=True)(
        *example_args, **example_kwargs)
    flat_args, in_tree = jax.tree_util.tree_flatten(
        (example_args, example_kwargs))
    out_tree = jax.tree_util.tree_structure(out_shape)
    jaxpr = closed.jaxpr

    # -- var plumbing -------------------------------------------------- #
    # alias map routes dataflow through zero-cost markers
    alias: Dict[Var, Var] = {}

    def resolve(v: Atom) -> Atom:
        while isinstance(v, jex_core.Var) and v in alias:
            v = alias[v]
        return v

    producer: Dict[Var, int] = {}        # var -> producing eqn idx
    nodes: List[KernelNode] = []
    edges: Dict[Tuple[int, int], float] = {}
    eqn_node: Dict[int, int] = {}        # raw eqn idx -> node idx

    tag_stack: List[Tuple[str, str, int]] = []   # (phase, block, layer)
    cur = ("", "", -1)

    state_in_vars: Set[Var] = set()
    if state_argnums:
        # map flattened invars back to positional args
        arg_leaf_counts = [len(jax.tree_util.tree_leaves(a))
                           for a in example_args]
        offset = 0
        spans = []
        for c in arg_leaf_counts:
            spans.append((offset, offset + c))
            offset += c
        for an in state_argnums:
            lo, hi = spans[an]
            state_in_vars.update(jaxpr.invars[lo:hi])

    state_readers: Set[int] = set()
    for raw_idx, eqn in enumerate(jaxpr.eqns):
        pname = eqn.primitive.name
        if pname == MARKER_NAME:
            # identity: alias out -> in, push/pop tag scope
            alias[eqn.outvars[0]] = resolve(eqn.invars[0])
            p = eqn.params
            if p["kind"] == "begin":
                tag_stack.append(cur)
                cur = (p["phase"] or cur[0], p["block"] or cur[1],
                       p["layer"] if p["layer"] >= 0 else cur[2])
            else:
                cur = tag_stack.pop() if tag_stack else ("", "", -1)
            continue

        node_idx = len(nodes)
        eqn_node[raw_idx] = node_idx
        flops, nbytes = _eqn_cost(eqn)
        out_b = sum(_aval_bytes(v.aval) for v in eqn.outvars)
        nodes.append(KernelNode(
            idx=node_idx, name=pname, flops=flops, bytes_accessed=nbytes,
            out_bytes=out_b, phase=cur[0], block=cur[1], layer=cur[2],
            eqn_ids=(raw_idx,)))

        for v in eqn.invars:
            v = resolve(v)
            if not isinstance(v, jex_core.Var):
                continue
            if v in state_in_vars:
                state_readers.add(node_idx)
            src = producer.get(v)
            if src is not None and src != node_idx:
                key = (src, node_idx)
                edges[key] = edges.get(key, 0.0) + _aval_bytes(v.aval)
        for v in eqn.outvars:
            producer[v] = node_idx

    # state writers: producers of outputs that correspond to carried state.
    state_writers: Set[int] = set()
    if state_argnums:
        # Convention: fn returns (state', ...) with state' matching the
        # state args' structure; the first len(state leaves) outvars.
        n_state_leaves = sum(
            len(jax.tree_util.tree_leaves(example_args[an]))
            for an in state_argnums)
        for v in jaxpr.outvars[:n_state_leaves]:
            v = resolve(v)
            if isinstance(v, jex_core.Var) and v in producer:
                state_writers.add(producer[v])

    graph = KernelGraph(nodes, edges, name=name)
    graph.validate()
    traced = TracedGraph(
        graph=graph, closed_jaxpr=closed,
        eqn_of_node={n.idx: n.eqn_ids for n in nodes},
        in_tree=in_tree, out_tree=out_tree,
        state_readers=state_readers, state_writers=state_writers)
    if fuse:
        fused = graph.fuse_elementwise()
        # remap state reader/writer sets through fusion
        old_to_new: Dict[int, int] = {}
        for n in fused.nodes:
            for e in n.eqn_ids:
                old_to_new[eqn_node[e]] = n.idx
        traced = dataclasses.replace(
            traced.with_graph(fused),
            state_readers={old_to_new[i] for i in state_readers},
            state_writers={old_to_new[i] for i in state_writers})
        # eqn_of_node must map to raw eqn ids (it already does via eqn_ids)
    return traced


def pin_nodes(graph: KernelGraph, node_ids: Set[int],
              device: int) -> KernelGraph:
    """Return a copy of the graph with the given nodes pinned to a device."""
    nodes = [dataclasses.replace(n, pinned=device) if n.idx in node_ids
             else n for n in graph.nodes]
    return KernelGraph(nodes, dict(graph.edges), name=graph.name)
